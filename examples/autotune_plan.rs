//! NSR-guided mixed-precision autotuning in five minutes.
//!
//! ```bash
//! cargo run --release --example autotune_plan [n_calib_images]
//! ```
//!
//! Plans per-layer `(L_W, L_I)` mantissa widths for LeNet against the
//! quality of the paper's uniform 8/8 configuration, prints the plan and
//! its Pareto frontier, then executes the plan per-layer through the
//! coordinator engine to show the serving stack honours it.

use bfp_cnn::autotune::{
    autotune_with_stats, calibrate, measure_schedule, uniform_predicted_snr_db, PlannerOptions,
};
use bfp_cnn::coordinator::engine::{forward_batch_ref, ExecMode};
use bfp_cnn::harness::autotune_report;
use bfp_cnn::models::ModelId;
use bfp_cnn::quant::{BfpConfig, LayerSchedule};
use std::path::Path;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(4);
    let model = ModelId::Lenet.build(32, 1, Path::new("artifacts"));
    let calib = bfp_cnn::data::DigitDataset::generate(n, 2024).images;

    // --- 1. calibrate once, derive the budget from uniform 8/8 ---
    let opts = PlannerOptions::default();
    let convs = calibrate(&model, &calib, &opts).expect("calibration");
    let budget = uniform_predicted_snr_db(&convs, 8);
    println!("budget: match uniform 8/8 predicted output SNR = {budget:.2} dB\n");

    // --- 2. plan + measure + refine ---
    let plan = autotune_with_stats(&model, &calib, &convs, budget, &opts);
    autotune_report::plan_table(&plan).print();
    println!();
    autotune_report::frontier_table(&plan).print();

    // --- 3. compare against the uniform baseline ---
    let uni = measure_schedule(&model, &calib, &LayerSchedule::uniform(BfpConfig::paper_default()));
    println!(
        "\nuniform 8/8 measured {:.2} dB @ {:.1} kbit | plan measured {:.2} dB @ {:.1} kbit ({:.1}% saved)",
        uni.conv_out_snr_db,
        plan.uniform_traffic_bits(8, 8) / 1000.0,
        plan.measured_snr_db,
        plan.total_traffic_bits() / 1000.0,
        100.0 * plan.savings_vs_uniform8(),
    );

    // --- 4. the serving engine executes the plan per-layer ---
    let eval = bfp_cnn::data::DigitDataset::generate(4, 7).images;
    let fp = forward_batch_ref(&model, &eval, ExecMode::Fp32);
    let mixed = forward_batch_ref(&model, &eval, ExecMode::Mixed(plan.to_schedule()));
    let agree = fp
        .iter()
        .zip(&mixed)
        .filter(|(a, b)| argmax(&a.data) == argmax(&b.data))
        .count();
    println!("engine ExecMode::Mixed: {agree}/{} top-1 agreement with fp32", eval.len());
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}
