//! Table 4 + Figure 3: the §4 error-analysis model validated against the
//! instrumented dual forward on VGG-16.
//!
//! ```bash
//! cargo run --release --example error_analysis [n_images [input_size]]
//! ```

use bfp_cnn::harness::{fig3, table4};
use std::path::Path;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(3);
    let size: usize = std::env::args().nth(2).and_then(|a| a.parse().ok()).unwrap_or(32);
    let artifacts = Path::new("artifacts");

    let (t, dev) = table4::run(size, n, 1, artifacts);
    t.print();
    println!("\nmax |multi-model − experimental| conv-output deviation: {dev:.2} dB (paper: ≤ 8.9 dB)");
    println!();
    fig3::run(size, n, 1, artifacts).print();
    println!("\n(the layer with the heaviest ≥0.8 energy tail should show the largest model deviation — §4.4's correlation argument)");
}
