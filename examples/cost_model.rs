//! Table 1 cost model explorer.
//!
//! ```bash
//! cargo run --release --example cost_model [M K N [L_W L_I]]
//! ```
//!
//! Prints the storage / block-exponent cost of the four partition
//! schemes (§3.3) for a custom GEMM geometry, plus the full VGG-16
//! reproduction of Table 1.

use bfp_cnn::harness::table1;

fn main() {
    let args: Vec<usize> = std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    if args.len() >= 3 {
        let (m, k, n) = (args[0], args[1], args[2]);
        let lw = *args.get(3).unwrap_or(&8) as u32;
        let li = *args.get(4).unwrap_or(&8) as u32;
        table1::run_for_layer("custom", m, k, n, lw, li).print();
        return;
    }
    for t in table1::run(8, 8) {
        t.print();
        println!();
    }
    println!("hint: pass `M K N [L_W L_I]` for a custom geometry");
}
