//! End-to-end serving demo on the pure-Rust backend: the coordinator's
//! dynamic batcher over the trained LeNet in both numeric modes, with
//! accuracy + latency/throughput metrics. (The PJRT-artifact variant is
//! `repro e2e`; this example exercises the same coordinator without
//! requiring the AOT artifacts.)
//!
//! ```bash
//! cargo run --release --example e2e_serving [requests]
//! ```

use bfp_cnn::coordinator::batcher::BatchPolicy;
use bfp_cnn::coordinator::engine::ExecMode;
use bfp_cnn::coordinator::server::{InferenceServer, RustBackend, ServerConfig};
use bfp_cnn::data::DigitDataset;
use bfp_cnn::models::ModelId;
use bfp_cnn::quant::BfpConfig;
use std::path::Path;

fn main() {
    let requests: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(128);
    let ds = DigitDataset::generate(requests, 2024);

    for (label, mode) in [
        ("fp32", ExecMode::Fp32),
        ("bfp 8/8", ExecMode::Bfp(BfpConfig::paper_default())),
        ("bfp 4/4", ExecMode::Bfp(BfpConfig::new(4, 4))),
    ] {
        let model = ModelId::Lenet.build(32, 1, Path::new("artifacts"));
        let mut server = InferenceServer::start(
            Box::new(RustBackend { model, mode }),
            ServerConfig {
                policy: BatchPolicy { max_batch: 8, linger: std::time::Duration::from_millis(2) },
            },
        );
        let pending: Vec<_> = ds.images.iter().map(|img| server.submit(img.clone())).collect();
        let mut correct = 0usize;
        for (rx, &label) in pending.into_iter().zip(&ds.labels) {
            let resp = rx.recv().expect("response");
            let pred = resp
                .logits
                .data
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if pred == label {
                correct += 1;
            }
        }
        let metrics = server.shutdown();
        println!("[{label:>8}] accuracy {}/{} = {:.4}", correct, requests, correct as f64 / requests as f64);
        println!("[{label:>8}] {}", metrics.summary());
    }
}
