//! Quickstart: the BFP numeric format in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the paper's §3 story: block-format a vector, inspect mantissas
//! and the shared exponent, reproduce the §3.4 worked example, run a BFP
//! GEMM against its f32 reference, and check the eq. (18) SNR prediction.

use bfp_cnn::analysis::single_layer::output_snr_db;
use bfp_cnn::analysis::snr::{measured_snr, theoretical_block_snr};
use bfp_cnn::bfp::gemm::f32_gemm;
use bfp_cnn::bfp::{bfp_gemm, block_format, BfpFormat, BfpMatrix};
use bfp_cnn::bfp::partition::BlockAxis;
use bfp_cnn::data::Rng;

fn main() {
    // --- 1. block formatting (§3.1) ---
    let xs = [1.25f32, 0.33, -0.07, 2.6, 0.001];
    let fmt = BfpFormat::new(8); // 8 bits incl. sign, the paper's pick
    let block = block_format(&xs, fmt);
    println!("values     : {xs:?}");
    println!("block exp  : {} (max element exponent)", block.exponent);
    println!("mantissas  : {:?} (integers, shared scale 2^{})", block.mantissas, block.exponent - block.frac_bits);
    println!("dequantized: {:?}", block.to_f32());

    // --- 2. the paper's §3.4 worked example ---
    let fmt4 = BfpFormat::new(4); // L=3 excluding sign in the paper's text
    let w = BfpMatrix::quantize(&[0.5, 1.25], 1, 2, fmt4, BlockAxis::PerRow);
    let i = BfpMatrix::quantize(&[1.25, 1.25, 2.5, 5.0], 2, 2, fmt4, BlockAxis::Whole);
    let o = bfp_gemm(&w, &i);
    println!("\n§3.4 worked example: W'I' = {:?} (exact paper value: [4.25, 6.75])", o.data);

    // --- 3. a conv-sized BFP GEMM vs f32 (Figure 2 data flow) ---
    let (m, k, n) = (64usize, 288usize, 196usize);
    let mut rng = Rng::new(42);
    let wdata = rng.laplacian_vec(m * k, 0.06);
    let idata = rng.normal_vec(k * n, 1.2);
    let wq = BfpMatrix::quantize(&wdata, m, k, fmt, BlockAxis::PerRow);
    let iq = BfpMatrix::quantize(&idata, k, n, fmt, BlockAxis::Whole);
    let bfp_out = bfp_gemm(&wq, &iq);
    let mut f32_out = vec![0f32; m * n];
    f32_gemm(&wdata, &idata, m, k, n, &mut f32_out);
    let snr_measured = measured_snr(&f32_out, &bfp_out.data);

    // --- 4. the §4 theory predicts that SNR ---
    let snr_w = measured_snr(&wdata, &wq.to_f32());
    let snr_i = theoretical_block_snr(&idata, fmt);
    let snr_predicted = output_snr_db(snr_i, snr_w);
    println!("\nBFP GEMM {m}x{k}x{n} @ 8-bit:");
    println!("  input  SNR (eq. 9 theory) : {snr_i:.2} dB");
    println!("  weight SNR (measured)     : {snr_w:.2} dB");
    println!("  output SNR predicted (18) : {snr_predicted:.2} dB");
    println!("  output SNR measured       : {snr_measured:.2} dB");
    assert!((snr_predicted - snr_measured).abs() < 2.0, "theory should track measurement");
    println!("\nquickstart OK");
}
