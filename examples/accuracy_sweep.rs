//! Table 3 accuracy sweep on the two genuinely *trained* networks
//! (LeNet on procedural digits, cifar-net on procedural textures).
//!
//! ```bash
//! cargo run --release --example accuracy_sweep [n_images]
//! ```
//!
//! The ImageNet-class rows are heavier; regenerate them with
//! `repro table3 --images 50`. This example also demonstrates the
//! truncation-vs-rounding ablation the paper argues for in §3.1.

use bfp_cnn::harness::table3::{drop_for, eval_set_for, run_model};
use bfp_cnn::models::ModelId;
use bfp_cnn::quant::BfpConfig;
use std::path::Path;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(50);
    let artifacts = Path::new("artifacts");

    for id in [ModelId::Lenet, ModelId::Cifar10] {
        run_model(id, 32, n, 1, artifacts).print();
        println!();
    }

    // §3.1 ablation: rounding vs truncation at narrow widths.
    println!("== §3.1 ablation — round-off vs truncation (lenet, {n} images) ==");
    let model = ModelId::Lenet.build(32, 1, artifacts);
    let set = eval_set_for(ModelId::Lenet, &model, n, 7);
    println!("{:<10} {:>12} {:>12}", "width", "round drop", "trunc drop");
    for bits in [3u32, 4, 5, 6] {
        let round = drop_for(&model, &set, BfpConfig::new(bits, bits));
        let trunc = drop_for(&model, &set, BfpConfig::new(bits, bits).with_truncation());
        println!("{bits:<10} {round:>12.4} {trunc:>12.4}");
    }
    println!("\n(truncation's DC bias should show a same-or-larger drop at every width)");
}
