"""AOT export: train the small nets, dump weight bundles, and lower the
JAX/Pallas computations to HLO **text** artifacts for the Rust runtime.

HLO text (never ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids. See
/opt/xla-example/README.md.

Artifacts written to ``--out-dir`` (default ../artifacts):
  lenet_weights.bfpw / cifar_weights.bfpw   trained parameters
  lenet_fwd_b8.hlo.txt                      BFP LeNet forward, batch 8
  lenet_fwd_fp32_b8.hlo.txt                 FP32 LeNet forward, batch 8
  bfp_gemm_demo.hlo.txt                     standalone BFP GEMM (runtime test)
  train_log.txt                             loss curves + eval accuracies
"""

import argparse
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, train_small


def to_hlo_text(lowered):
    """Lowered jitted fn → HLO text via stablehlo → XlaComputation."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_and_write(fn, example_args, path, log):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path.write_text(text)
    log(f"  wrote {path} ({len(text)} chars)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat: ignored single-file path")
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--force", action="store_true", help="retrain even if weights exist")
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    log_lines = []

    def log(msg):
        print(msg)
        log_lines.append(str(msg))

    # ---- train (or reuse) the small nets ----
    lenet_w = out / "lenet_weights.bfpw"
    if args.force or not lenet_w.exists():
        params, acc, curve = train_small.train_lenet(steps=args.steps, log=log)
        model.dump_bfpw(params, lenet_w)
        log(f"  wrote {lenet_w} (eval acc {acc:.4f})")
    else:
        log(f"  reusing {lenet_w}")
        params = load_bfpw(lenet_w)

    cifar_w = out / "cifar_weights.bfpw"
    if args.force or not cifar_w.exists():
        cparams, cacc, _ = train_small.train_cifar(steps=args.steps + 100, log=log)
        model.dump_bfpw(cparams, cifar_w)
        log(f"  wrote {cifar_w} (eval acc {cacc:.4f})")
    else:
        log(f"  reusing {cifar_w}")

    # ---- lower the serving artifacts ----
    # Weights are lowered as *arguments*, not closed-over constants: the
    # MLIR-text round trip elides large constants silently, and feeding
    # weights at execute time is what a real serving runtime does anyway.
    # The `.args.txt` manifest records the argument order for Rust.
    params = jax.tree.map(jnp.asarray, params)
    spec8 = jax.ShapeDtypeStruct((8, 1, 28, 28), jnp.float32)
    flat, treedef = jax.tree_util.tree_flatten(params)  # dict → sorted keys
    names = sorted(params.keys())
    param_specs = tuple(jax.ShapeDtypeStruct(l.shape, l.dtype) for l in flat)

    def write_manifest(path):
        lines = [f"{n} {' '.join(str(d) for d in params[n].shape)}" for n in names]
        lines.append("__input__ 8 1 28 28")
        path.write_text("\n".join(lines) + "\n")
        log(f"  wrote {path}")

    log("lowering lenet_fwd_b8 (BFP, pallas)")
    lower_and_write(
        lambda *a: (
            model.lenet_fwd_bfp(jax.tree_util.tree_unflatten(treedef, a[:-1]), a[-1], 8, 8, use_pallas=True),
        ),
        (*param_specs, spec8),
        out / "lenet_fwd_b8.hlo.txt",
        log,
    )
    write_manifest(out / "lenet_fwd_b8.args.txt")

    log("lowering lenet_fwd_fp32_b8")
    lower_and_write(
        lambda *a: (model.lenet_fwd_fp32(jax.tree_util.tree_unflatten(treedef, a[:-1]), a[-1]),),
        (*param_specs, spec8),
        out / "lenet_fwd_fp32_b8.hlo.txt",
        log,
    )
    write_manifest(out / "lenet_fwd_fp32_b8.args.txt")

    log("lowering bfp_gemm_demo (pallas kernel, 4x8 @ 8x16, L=8)")
    from .kernels import bfp_matmul_pallas

    lower_and_write(
        lambda w, i: (bfp_matmul_pallas(w, i, 8, 8),),
        (jax.ShapeDtypeStruct((4, 8), jnp.float32), jax.ShapeDtypeStruct((8, 16), jnp.float32)),
        out / "bfp_gemm_demo.hlo.txt",
        log,
    )

    (out / "train_log.txt").write_text("\n".join(log_lines) + "\n")
    log("aot done")


def load_bfpw(path):
    """Parse a .bfpw file back into a params dict (for --reuse runs)."""
    import numpy as np

    lines = [l for l in path.read_text().splitlines() if l.strip() and not l.startswith("#")]
    assert lines[0] == "bfpw-v1"
    params = {}
    i = 1
    while i < len(lines):
        parts = lines[i].split()
        assert parts[0] == "param"
        name = parts[1]
        ndim = int(parts[2])
        shape = tuple(int(d) for d in parts[3 : 3 + ndim])
        data = np.array([float(v) for v in lines[i + 1].split()], dtype=np.float32)
        params[name] = jnp.array(data.reshape(shape))
        i += 2
    return params


if __name__ == "__main__":
    main()
