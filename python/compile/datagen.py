"""Procedural digit dataset — the Python twin of `rust/src/data/digits.rs`.

Shares the same 5×7 glyph table and rendering recipe (scale/offset jitter,
soft edges, additive noise) so the JAX-trained LeNet sees the same
distribution the Rust evaluation set draws from. Exact bit-identity with
the Rust RNG is not required (and not attempted); distribution identity is
what matters for the trained weights.
"""

import numpy as np

# 5×7 glyphs for digits 0-9; each row is 5 bits, MSB = leftmost column.
# MUST stay in sync with rust/src/data/digits.rs::GLYPHS.
GLYPHS = [
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110],  # 0
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110],  # 1
    [0b01110, 0b10001, 0b00001, 0b00010, 0b00100, 0b01000, 0b11111],  # 2
    [0b11111, 0b00010, 0b00100, 0b00010, 0b00001, 0b10001, 0b01110],  # 3
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010],  # 4
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110],  # 5
    [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110],  # 6
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000],  # 7
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110],  # 8
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100],  # 9
]


def render_digit(digit, rng):
    """One 28×28 grayscale digit with jitter; values in [0, 1]."""
    glyph = GLYPHS[digit % 10]
    img = np.zeros((28, 28), dtype=np.float32)
    scale = rng.uniform(2.6, 3.8)
    ox = rng.uniform(2.0, 8.0)
    oy = rng.uniform(1.0, 5.0)
    intensity = rng.uniform(0.75, 1.0)
    ys, xs = np.mgrid[0:28, 0:28]
    gx = (xs - ox) / scale
    gy = (ys - oy) / scale
    valid = (gx >= 0) & (gx < 5) & (gy >= 0) & (gy < 7)
    cx = np.clip(gx.astype(int), 0, 4)
    cy = np.clip(gy.astype(int), 0, 6)
    glyph_arr = np.array(
        [[(row >> (4 - c)) & 1 for c in range(5)] for row in glyph], dtype=np.float32
    )
    lit = glyph_arr[cy, cx] * valid
    fx = np.abs(gx - cx - 0.5)
    fy = np.abs(gy - cy - 0.5)
    soft = np.clip(1.0 - np.maximum(fx, fy) * 0.6, 0.3, 1.0)
    img = (lit * intensity * soft).astype(np.float32)
    img += rng.normal(0, 0.03, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def digit_dataset(n, seed):
    """`n` balanced labelled digits: images [n,1,28,28], labels [n]."""
    rng = np.random.default_rng(seed)
    images = np.zeros((n, 1, 28, 28), dtype=np.float32)
    labels = np.zeros(n, dtype=np.int32)
    for idx in range(n):
        d = idx % 10
        images[idx, 0] = render_digit(d, rng)
        labels[idx] = d
    return images, labels


# ---- cifar-like procedural textures (python twin of textures.rs) ----

def render_texture(cls, rng):
    """One 3×32×32 texture of class `cls` in [0, 1]."""
    phase = rng.uniform(0, 2 * np.pi)
    freq = rng.uniform(0.5, 1.5)
    base = rng.uniform(0.2, 0.8, 3)
    ys, xs = np.mgrid[0:32, 0:32]
    xf = xs / 32.0
    yf = ys / 32.0
    c = cls % 10
    if c == 0:
        v = xf
    elif c == 1:
        v = yf
    elif c == 2:
        v = (((xf * 8 * freq).astype(int) + (yf * 8 * freq).astype(int)) % 2).astype(float)
    elif c == 3:
        v = (np.sin(xf * 12 * freq + phase) + 1) / 2
    elif c == 4:
        v = (np.sin(yf * 12 * freq + phase) + 1) / 2
    elif c == 5:
        v = (np.sin((xf + yf) * 9 * freq + phase) + 1) / 2
    elif c == 6:
        r = np.sqrt((xf - 0.5) ** 2 + (yf - 0.5) ** 2)
        v = (np.sin(r * 20 * freq + phase) + 1) / 2
    elif c == 7:
        r2 = (xf - 0.5) ** 2 + (yf - 0.5) ** 2
        v = np.exp(-r2 * 12 * freq)
    elif c == 8:
        v = (np.sin(xf * 25 * freq) * np.sin(yf * 25 * freq) + 1) / 2
    else:
        v = rng.uniform(0, 1, (32, 32))
    img = np.zeros((3, 32, 32), dtype=np.float32)
    for ch in range(3):
        chan_mod = 0.6 + 0.4 * np.abs(np.sin((ch + 1.0) * v))
        img[ch] = np.clip(
            v * chan_mod * 0.8 + base[ch] * 0.2 + rng.normal(0, 0.02, (32, 32)), 0, 1
        )
    return img


def texture_dataset(n, seed):
    """`n` balanced labelled textures: images [n,3,32,32], labels [n]."""
    rng = np.random.default_rng(seed ^ 0xC1FA)
    images = np.zeros((n, 3, 32, 32), dtype=np.float32)
    labels = np.zeros(n, dtype=np.int32)
    for idx in range(n):
        c = idx % 10
        images[idx] = render_texture(c, rng)
        labels[idx] = c
    return images, labels
