"""Pallas kernel: the Figure 2 fixed-point GEMM over aligned mantissas.

The mantissa MAC runs on integer-valued f32 (products ≤ 2^(L_W+L_I-2) and
K-term sums < 2^24 stay exact in f32 — the §3.4 width plan, asserted
below), so the kernel is bit-exact against an integer reference while
targeting the MXU on real hardware (DESIGN.md §6).

Tiling: grid over (M/bm, N/bn) output tiles with the full K panel of both
operands resident in VMEM — the eq. (4) partition maps W rows to MXU rows
and broadcasts the shared-exponent I panel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .bfp_quantize import block_mantissas_pallas


def _matmul_kernel(qw_ref, qi_ref, o_ref):
    """One (bm, bn) output tile: mantissa GEMM in f32 (integer-valued)."""
    o_ref[...] = jnp.dot(
        qw_ref[...], qi_ref[...], preferred_element_type=jnp.float32
    )


def mantissa_matmul_pallas(qw, qi, bm=128, bn=1024):
    """Tiled mantissa GEMM ``qw [M,K] @ qi [K,N]`` via Pallas.

    Default tiles are sized for the lowered-artifact shapes: large enough
    to collapse the interpret-mode grid (each grid step costs an XLA
    while-loop iteration on CPU — §Perf-L1), small enough that one
    (bm,K)+(K,bn)+(bm,bn) working set stays far below VMEM on a real TPU
    (see python/compile/vmem_report.py).
    """
    m, k = qw.shape
    k2, n = qi.shape
    assert k == k2
    bm = min(bm, m)
    bn = min(bn, n)
    # shrink tiles to divide evenly (interpret mode has no masked stores)
    while m % bm:
        bm -= 1
    while n % bn:
        bn -= 1
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(qw.astype(jnp.float32), qi.astype(jnp.float32))


@functools.partial(jax.jit, static_argnums=(2, 3))
def bfp_matmul_pallas(w, i, l_w, l_i):
    """Eq. (4) BFP GEMM through Pallas kernels — the Pallas twin of
    :func:`ref.bfp_matmul`: per-row quantize W, whole-block quantize I,
    mantissa MAC, per-row rescale."""
    m, k = w.shape
    k2, n = i.shape
    assert k == k2
    # exact bound: K·(2^(L_W-1)-1)·(2^(L_I-1)-1) must stay in f32's
    # exact-integer range [0, 2^24] (the §3.4 width plan)
    assert k * (2 ** (l_w - 1) - 1) * (2 ** (l_i - 1) - 1) <= 2**24, (
        f"mantissa MAC would lose exactness: K={k}, L_W={l_w}, L_I={l_i}"
    )
    f_w, f_i = l_w - 2, l_i - 2
    qw, ew = block_mantissas_pallas(w, l_w, axis=1)
    qi, ei = block_mantissas_pallas(i, l_i, axis=None)
    om = mantissa_matmul_pallas(qw, qi)
    row_scale = jnp.where(
        (ew <= ref.ZERO_EXP // 2) | (ei <= ref.ZERO_EXP // 2),
        jnp.float32(0.0),
        jnp.exp2((ew + ei - f_w - f_i).astype(jnp.float32)),
    )
    return om * row_scale[:, None]
