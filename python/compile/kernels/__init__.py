"""Layer-1 Pallas kernels: BFP block formatting and the fixed-point GEMM
of the paper's Figure 2 data flow, plus the pure-jnp oracle (`ref`).

All kernels run with ``interpret=True`` — the CPU PJRT client cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO so the
Rust runtime can run the artifacts (see /opt/xla-example/README.md).
"""

from .bfp_quantize import block_mantissas_pallas, bfp_quantize_pallas
from .bfp_matmul import bfp_matmul_pallas

__all__ = [
    "block_mantissas_pallas",
    "bfp_quantize_pallas",
    "bfp_matmul_pallas",
]
