"""Pure-jnp oracle for the BFP kernels.

Semantics are bit-matched to the Rust substrate (`rust/src/bfp/`):

* mantissa width ``L`` *includes* the sign bit (Table 3 convention);
  fractional bits ``f = L - 2`` (one sign, one integer bit);
* block exponent ``eps = max_i floor(log2 |x_i|)`` over the block,
  extracted from the f32 bit pattern (exact, unlike ``log2``);
* step ``delta = 2^(eps - f)``; mantissas ``q = round_half_away(x/delta)``
  saturated to ``±(2^(L-1) - 1)``;
* the eq. (4) GEMM quantizes ``W`` per row and ``I`` as a whole, then
  multiply-accumulates mantissas exactly and rescales by
  ``2^(eps_W(row) + eps_I - f_W - f_I)``.
"""

import jax
import jax.numpy as jnp

ZERO_EXP = jnp.int32(-(2**30))  # plays the role of Rust's i32::MIN/2 sentinel


def round_half_away(x):
    """Round to nearest, ties away from zero (Rust ``f32::round``)."""
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def exponent_of(x):
    """floor(log2 |x|) per element via the f32 exponent field (exact).

    Zeros map to ZERO_EXP so they never win the block max. Subnormals
    (absent from CNN activations in practice) are normalised first.
    """
    x = x.astype(jnp.float32)
    absx = jnp.abs(x)
    is_sub = (absx > 0) & (absx < jnp.float32(2.0**-126))
    scaled = jnp.where(is_sub, absx * jnp.float32(2.0**64), absx)
    bits = jax.lax.bitcast_convert_type(scaled, jnp.uint32)
    e = ((bits >> 23) & 0xFF).astype(jnp.int32) - 127
    e = jnp.where(is_sub, e - 64, e)
    return jnp.where(absx > 0, e, ZERO_EXP)


def block_exponent(x, axis=None):
    """Block exponent: max exponent over ``axis`` (None = whole array)."""
    return jnp.max(exponent_of(x), axis=axis)


def _inv_step(eps_b, frac):
    return jnp.where(
        eps_b <= ZERO_EXP // 2,
        jnp.float32(0.0),
        jnp.exp2((frac - eps_b).astype(jnp.float32)),
    )


def _step(eps_b, frac):
    return jnp.where(
        eps_b <= ZERO_EXP // 2,
        jnp.float32(0.0),
        jnp.exp2((eps_b - frac).astype(jnp.float32)),
    )


def block_mantissas(x, total_bits, axis=None):
    """Block-format ``x`` into integer mantissas (as f32) + exponent(s).

    ``axis=None`` treats the whole array as one block; ``axis=1`` with a
    2-D array gives per-row blocks (the eq. 4 weight layout).

    Returns ``(q, eps)``: ``q`` integer-valued f32 shaped like ``x``,
    ``eps`` the int32 block exponent(s).
    """
    frac = total_bits - 2
    maxm = float(2 ** (total_bits - 1) - 1)
    eps = block_exponent(x, axis=axis)
    eps_b = eps if axis is None else jnp.expand_dims(eps, axis)
    q = jnp.clip(round_half_away(x * _inv_step(eps_b, frac)), -maxm, maxm)
    return q.astype(jnp.float32), eps


def bfp_quantize(x, total_bits, axis=None):
    """Quantize-dequantize round trip: the BFP approximation of ``x``."""
    frac = total_bits - 2
    q, eps = block_mantissas(x, total_bits, axis=axis)
    eps_b = eps if axis is None else jnp.expand_dims(eps, axis)
    return q * _step(eps_b, frac)


def bfp_matmul(w, i, l_w, l_i):
    """Eq. (4) BFP GEMM oracle: ``O ≈ W @ I`` through the Figure 2 flow.

    ``w`` is ``[M, K]`` (per-row blocks), ``i`` is ``[K, N]`` (one block).
    The mantissa MAC stays exact in f32 provided
    ``K · 2^(l_w + l_i - 2) < 2^24`` (asserted; §3.4 width plan).
    """
    m, k = w.shape
    k2, n = i.shape
    assert k == k2, f"inner dim mismatch {k} vs {k2}"
    # exact bound: K·(2^(L_W-1)-1)·(2^(L_I-1)-1) must stay in f32's
    # exact-integer range [0, 2^24] (the §3.4 width plan)
    assert k * (2 ** (l_w - 1) - 1) * (2 ** (l_i - 1) - 1) <= 2**24, (
        f"mantissa MAC would lose exactness: K={k}, L_W={l_w}, L_I={l_i}"
    )
    f_w, f_i = l_w - 2, l_i - 2
    qw, ew = block_mantissas(w, l_w, axis=1)     # [M,K], [M]
    qi, ei = block_mantissas(i, l_i, axis=None)  # [K,N], scalar
    om = qw @ qi  # integer-valued f32, exact under the width plan
    row_scale = jnp.where(
        (ew <= ZERO_EXP // 2) | (ei <= ZERO_EXP // 2),
        jnp.float32(0.0),
        jnp.exp2((ew + ei - f_w - f_i).astype(jnp.float32)),
    )
    return om * row_scale[:, None]
