"""Pallas kernel: BFP block formatting (§3.1 / eq. 1).

One grid program per block (a weight row, or the whole matrix flattened to
a single row). The kernel keeps the entire block resident in VMEM, reduces
to the block max, extracts the shared exponent from the f32 bit pattern,
then shifts/rounds every mantissa — the two-pass scan-then-align data flow
a hardware BFP unit implements, expressed as a BlockSpec.

TPU adaptation note (DESIGN.md §6): the block IS the VMEM tile. The
max-reduction and the shift/round are VPU work; the downstream mantissa
GEMM (bfp_matmul.py) is the MXU work.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _quantize_row_kernel(x_ref, q_ref, e_ref, *, frac, maxm):
    """Quantize one block (row) held in VMEM."""
    x = x_ref[...]
    absmax = jnp.max(jnp.abs(x))
    bits = jax.lax.bitcast_convert_type(absmax, jnp.uint32)
    eps = ((bits >> 23) & 0xFF).astype(jnp.int32) - 127
    has_signal = absmax > 0
    # plain-int sentinel: jnp constants would be captured as consts,
    # which pallas kernels disallow
    eps = jnp.where(has_signal, eps, jnp.int32(-(2**30)))
    inv_step = jnp.where(has_signal, jnp.exp2((frac - eps).astype(jnp.float32)), 0.0)
    q = jnp.clip(ref.round_half_away(x * inv_step), -maxm, maxm)
    q_ref[...] = q.astype(jnp.float32)
    e_ref[...] = jnp.full(e_ref.shape, eps, dtype=jnp.int32)


def block_mantissas_pallas(x, total_bits, axis=None):
    """Pallas version of :func:`ref.block_mantissas`.

    ``x`` must be 2-D. ``axis=1`` → per-row blocks; ``axis=None`` → one
    block over the whole matrix (internally a single grid step over the
    flattened view).
    """
    assert x.ndim == 2, "block_mantissas_pallas expects a 2-D matrix"
    frac = total_bits - 2
    maxm = float(2 ** (total_bits - 1) - 1)
    if axis is None:
        flat = x.reshape(1, -1)
        q, e = block_mantissas_pallas(flat, total_bits, axis=1)
        return q.reshape(x.shape), e[0]
    assert axis == 1, "only per-row (axis=1) or whole (axis=None) blocks"
    rows, cols = x.shape
    kernel = functools.partial(_quantize_row_kernel, frac=frac, maxm=maxm)
    q, e = pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, cols), lambda r: (r, 0))],
        out_specs=[
            pl.BlockSpec((1, cols), lambda r: (r, 0)),
            pl.BlockSpec((1,), lambda r: (r,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), jnp.float32),
            jax.ShapeDtypeStruct((rows,), jnp.int32),
        ],
        interpret=True,
    )(x.astype(jnp.float32))
    return q, e


def bfp_quantize_pallas(x, total_bits, axis=None):
    """Quantize-dequantize through the Pallas kernel (block-formatted
    values back in f32) — the Pallas twin of :func:`ref.bfp_quantize`."""
    frac = total_bits - 2
    q, eps = block_mantissas_pallas(x, total_bits, axis=axis)
    eps_b = eps if axis is None else jnp.expand_dims(eps, axis)
    step = jnp.where(
        eps_b <= ref.ZERO_EXP // 2,
        jnp.float32(0.0),
        jnp.exp2((eps_b - frac).astype(jnp.float32)),
    )
    return q * step
