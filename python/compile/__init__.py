"""Build-time compile path: JAX models + Pallas kernels, AOT-lowered to
HLO-text artifacts for the Rust runtime. Never imported at request time."""
