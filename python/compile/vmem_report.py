"""L1 kernel structure report: VMEM footprint + MXU utilization estimates
per BlockSpec (DESIGN.md §6, EXPERIMENTS.md §Perf-L1).

interpret=True timings are CPU-numpy, not a TPU proxy, so the Pallas
kernels are optimized *structurally*: keep every block resident in VMEM
(≤ ~16 MiB), feed the MXU (128×128 systolic) tiles that are as close to
128-multiples as the problem allows, and amortise the HBM↔VMEM transfer
of the shared-exponent I panel across all W row-blocks (the eq. 4
partition). This script evaluates those properties for the shapes we
lower, and for the VGG-scale shapes a TPU deployment would use.

Usage: python -m compile.vmem_report
"""

VMEM_BYTES = 16 * 1024 * 1024  # v4-lite class scratchpad
MXU = 128


def quantize_kernel_report(rows, cols, name):
    """Per-row block-format kernel: one (1, cols) block per grid step."""
    block_bytes = cols * 4 * 2 + 4  # in block + out block + exponent
    util = min(cols / MXU, 1.0)  # VPU lane utilization (8x128 vregs)
    print(f"  quantize[{name}] grid=({rows},) block=(1,{cols})  "
          f"VMEM {block_bytes/1024:8.1f} KiB  ({block_bytes/VMEM_BYTES*100:5.2f}% of VMEM)  "
          f"VPU lane util ~{util*100:5.1f}%")
    return block_bytes <= VMEM_BYTES


def matmul_kernel_report(m, k, n, bm, bn, name):
    """Mantissa GEMM tile: (bm,k) x (k,bn) -> (bm,bn) per grid step."""
    bm = min(bm, m)
    bn = min(bn, n)
    while m % bm:
        bm -= 1
    while n % bn:
        bn -= 1
    block_bytes = (bm * k + k * bn + bm * bn) * 4
    grid = (m // bm) * (n // bn)
    # MXU utilization: fraction of the 128x128 systolic array the tile
    # keeps busy (both dims), amortised over K
    util = min(bm / MXU, 1.0) * min(bn / MXU, 1.0)
    # HBM traffic amortisation: the I panel is loaded once per column
    # tile and shared by all m/bm row tiles under eq. (4)
    reuse = m // bm
    ok = block_bytes <= VMEM_BYTES
    print(f"  matmul[{name}] grid={grid} tile=({bm},{k})x({k},{bn})  "
          f"VMEM {block_bytes/1024:8.1f} KiB ({block_bytes/VMEM_BYTES*100:5.2f}%)  "
          f"MXU util ~{util*100:5.1f}%  I-panel reuse x{reuse}  {'OK' if ok else 'OVERFLOWS VMEM'}")
    return ok


def main():
    print("== lowered artifacts (CPU interpret; structure-checked) ==")
    # lenet conv1: W [8,25], I [25,784]; conv2: W [16,200], I [200,784]
    quantize_kernel_report(8, 25, "lenet.conv1.W")
    quantize_kernel_report(1, 25 * 784, "lenet.conv1.I(whole)")
    matmul_kernel_report(8, 25, 784, 8, 128, "lenet.conv1")
    quantize_kernel_report(16, 200, "lenet.conv2.W")
    quantize_kernel_report(1, 200 * 784, "lenet.conv2.I(whole)")
    matmul_kernel_report(16, 200, 784, 8, 128, "lenet.conv2")

    print("\n== TPU-scale shapes (VGG-16 @224, the deployment target) ==")
    ok = True
    for (name, m, k, n) in [
        ("conv1_1", 64, 27, 224 * 224),
        ("conv2_2", 128, 1152, 112 * 112),
        ("conv3_3", 256, 2304, 56 * 56),
        ("conv5_3", 512, 4608, 14 * 14),
    ]:
        ok &= matmul_kernel_report(m, k, n, 128, 128, name)
    print("\nall blocks fit VMEM:", ok)
    print("""
notes:
 * the eq.(4) partition maps naturally: one W row-block + the shared
   I panel per tile; the block exponent rides along as SMEM scalars.
 * 8-bit mantissas as bf16/int8 on real MXUs halve the VMEM numbers
   above (we estimate with f32 carriers, the interpret-mode dtype).
 * deeper layers (k=4608) keep >=89%% MXU utilization at 128x128 tiles;
   conv1_1's k=27 underfills the systolic depth - the classic first-layer
   problem, usually batched across images on real deployments.""")


if __name__ == "__main__":
    main()
