"""Layer-2 JAX models: LeNet (mnist) and the cifar net, with both the
FP32 training/reference path and the BFP inference path built on the
Layer-1 Pallas kernels.

Architectures mirror `rust/src/models/{lenet,cifar}.rs` exactly (shapes in
the module docs there). Weight layout is OIHW for convs, [out, in] for
dense — the `.bfpw` interchange layout.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import bfp_matmul_pallas
from .kernels import ref as kref


# ---------- shared ops ----------

def conv2d_fp32(x, w, b, stride=1, padding=0):
    """NCHW conv, OIHW weights, symmetric padding."""
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def im2col(x, kh, kw, stride=1, padding=0):
    """Patches of NCHW `x`: returns [B, K, N] with K=C·kh·kw, N=oh·ow —
    the Figure 1 layout (feature order C, kh, kw matches OIHW reshape)."""
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # [B, C*kh*kw, oh, ow]
    b, k, oh, ow = patches.shape
    return patches.reshape(b, k, oh * ow), (oh, ow)


def conv2d_bfp(x, w, b, l_w, l_i, stride=1, padding=0, use_pallas=True):
    """BFP conv (Figure 2): per-image eq. (4) block formatting, mantissa
    GEMM via the Pallas kernel, f32 bias. Loops the (static) batch so each
    image gets its own whole-matrix input block, matching the Rust engine.
    """
    m = w.shape[0]
    kh, kw = w.shape[2], w.shape[3]
    wmat = w.reshape(m, -1)
    cols, (oh, ow) = im2col(x, kh, kw, stride, padding)
    mm = bfp_matmul_pallas if use_pallas else kref.bfp_matmul
    outs = [mm(wmat, cols[i], l_w, l_i) for i in range(x.shape[0])]
    out = jnp.stack(outs).reshape(x.shape[0], m, oh, ow)
    return out + b[None, :, None, None]


def max_pool(x, k=2, s=2):
    """NCHW max pooling, no padding."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, k, k), (1, 1, s, s), "VALID"
    )


# ---------- LeNet ----------

def init_lenet(key):
    """He-initialised LeNet parameters (layout mirrors lenet.rs)."""
    ks = jax.random.split(key, 4)
    he = lambda k, shape, fan: jax.random.normal(k, shape, jnp.float32) * jnp.sqrt(2.0 / fan)
    return {
        "conv1_w": he(ks[0], (8, 1, 5, 5), 25),
        "conv1_b": jnp.zeros(8),
        "conv2_w": he(ks[1], (16, 8, 5, 5), 200),
        "conv2_b": jnp.zeros(16),
        "fc1_w": he(ks[2], (64, 784), 784),
        "fc1_b": jnp.zeros(64),
        "fc2_w": he(ks[3], (10, 64), 64),
        "fc2_b": jnp.zeros(10),
    }


def lenet_fwd_fp32(params, x):
    """FP32 LeNet forward: [B,1,28,28] -> [B,10] logits."""
    x = jax.nn.relu(conv2d_fp32(x, params["conv1_w"], params["conv1_b"], 1, 2))
    x = max_pool(x)
    x = jax.nn.relu(conv2d_fp32(x, params["conv2_w"], params["conv2_b"], 1, 2))
    x = max_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1_w"].T + params["fc1_b"])
    return x @ params["fc2_w"].T + params["fc2_b"]


def lenet_fwd_bfp(params, x, l_w=8, l_i=8, use_pallas=True):
    """BFP LeNet forward: conv layers through the Figure 2 data flow,
    FC layers in FP32 (the paper's Caffe port, §5.1)."""
    x = jax.nn.relu(conv2d_bfp(x, params["conv1_w"], params["conv1_b"], l_w, l_i, 1, 2, use_pallas))
    x = max_pool(x)
    x = jax.nn.relu(conv2d_bfp(x, params["conv2_w"], params["conv2_b"], l_w, l_i, 1, 2, use_pallas))
    x = max_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1_w"].T + params["fc1_b"])
    return x @ params["fc2_w"].T + params["fc2_b"]


# ---------- cifar net ----------

def init_cifar(key):
    """He-initialised cifar-net parameters (layout mirrors cifar.rs)."""
    ks = jax.random.split(key, 5)
    he = lambda k, shape, fan: jax.random.normal(k, shape, jnp.float32) * jnp.sqrt(2.0 / fan)
    return {
        "conv1_w": he(ks[0], (16, 3, 3, 3), 27),
        "conv1_b": jnp.zeros(16),
        "conv2_w": he(ks[1], (32, 16, 3, 3), 144),
        "conv2_b": jnp.zeros(32),
        "conv3_w": he(ks[2], (64, 32, 3, 3), 288),
        "conv3_b": jnp.zeros(64),
        "fc1_w": he(ks[3], (64, 1024), 1024),
        "fc1_b": jnp.zeros(64),
        "fc2_w": he(ks[4], (10, 64), 64),
        "fc2_b": jnp.zeros(10),
    }


def cifar_fwd_fp32(params, x):
    """FP32 cifar-net forward: [B,3,32,32] -> [B,10] logits."""
    x = jax.nn.relu(conv2d_fp32(x, params["conv1_w"], params["conv1_b"], 1, 1))
    x = max_pool(x)
    x = jax.nn.relu(conv2d_fp32(x, params["conv2_w"], params["conv2_b"], 1, 1))
    x = max_pool(x)
    x = jax.nn.relu(conv2d_fp32(x, params["conv3_w"], params["conv3_b"], 1, 1))
    x = max_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1_w"].T + params["fc1_b"])
    return x @ params["fc2_w"].T + params["fc2_b"]


def cifar_fwd_bfp(params, x, l_w=8, l_i=8, use_pallas=True):
    """BFP cifar-net forward."""
    x = jax.nn.relu(conv2d_bfp(x, params["conv1_w"], params["conv1_b"], l_w, l_i, 1, 1, use_pallas))
    x = max_pool(x)
    x = jax.nn.relu(conv2d_bfp(x, params["conv2_w"], params["conv2_b"], l_w, l_i, 1, 1, use_pallas))
    x = max_pool(x)
    x = jax.nn.relu(conv2d_bfp(x, params["conv3_w"], params["conv3_b"], l_w, l_i, 1, 1, use_pallas))
    x = max_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1_w"].T + params["fc1_b"])
    return x @ params["fc2_w"].T + params["fc2_b"]


# ---------- .bfpw interchange ----------

def dump_bfpw(params, path):
    """Write params in the `.bfpw` text format weights_io.rs parses."""
    import numpy as np

    lines = ["bfpw-v1"]
    for name in sorted(params):
        arr = np.asarray(params[name], dtype=np.float32)
        dims = " ".join(str(d) for d in arr.shape)
        lines.append(f"param {name} {arr.ndim} {dims}")
        lines.append(" ".join(repr(float(v)) for v in arr.reshape(-1)))
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
