"""Build-time trainer for the two small networks of Table 3.

Trains LeNet on the procedural digit dataset and the cifar net on the
procedural texture dataset with plain SGD+momentum (no optax in the
offline image), logs the loss curve, and dumps `.bfpw` weight bundles for
the Rust side. Recorded in EXPERIMENTS.md §E2E.
"""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen, model


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(labels.shape[0]), labels])


def accuracy(logits, labels):
    return float(jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32)))


def train(fwd, params, images, labels, *, steps, batch, lr=0.1, momentum=0.9, seed=0, log_every=50, log=print):
    """SGD+momentum training loop; returns (params, loss_curve)."""
    n = images.shape[0]
    velocity = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, velocity, xb, yb):
        loss, grads = jax.value_and_grad(lambda p: cross_entropy(fwd(p, xb), yb))(params)
        velocity = jax.tree.map(lambda v, g: momentum * v - lr * g, velocity, grads)
        params = jax.tree.map(lambda p, v: p + v, params, velocity)
        return params, velocity, loss

    rng = np.random.default_rng(seed)
    curve = []
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, n, batch)
        params, velocity, loss = step(params, velocity, images[idx], labels[idx])
        if s % log_every == 0 or s == steps - 1:
            curve.append((s, float(loss)))
            log(f"  step {s:4d}  loss {float(loss):.4f}  ({time.time()-t0:.1f}s)")
    return params, curve


def train_lenet(steps=500, n_train=4000, n_eval=500, seed=0, log=print):
    """Train LeNet on procedural digits; returns (params, eval_acc, curve)."""
    log(f"[lenet] generating {n_train}+{n_eval} digits")
    xtr, ytr = datagen.digit_dataset(n_train, seed)
    xev, yev = datagen.digit_dataset(n_eval, seed + 1)
    params = model.init_lenet(jax.random.PRNGKey(seed))
    log(f"[lenet] training {steps} steps")
    params, curve = train(model.lenet_fwd_fp32, params, jnp.array(xtr), jnp.array(ytr),
                          steps=steps, batch=64, seed=seed, log=log)
    acc = accuracy(model.lenet_fwd_fp32(params, jnp.array(xev)), jnp.array(yev))
    log(f"[lenet] eval accuracy {acc:.4f}")
    return params, acc, curve


def train_cifar(steps=600, n_train=4000, n_eval=500, seed=0, log=print):
    """Train the cifar net on procedural textures."""
    log(f"[cifar] generating {n_train}+{n_eval} textures")
    xtr, ytr = datagen.texture_dataset(n_train, seed)
    xev, yev = datagen.texture_dataset(n_eval, seed + 1)
    params = model.init_cifar(jax.random.PRNGKey(seed + 7))
    log(f"[cifar] training {steps} steps")
    params, curve = train(model.cifar_fwd_fp32, params, jnp.array(xtr), jnp.array(ytr),
                          steps=steps, batch=64, lr=0.05, seed=seed, log=log)
    acc = accuracy(model.cifar_fwd_fp32(params, jnp.array(xev)), jnp.array(yev))
    log(f"[cifar] eval accuracy {acc:.4f}")
    return params, acc, curve


if __name__ == "__main__":
    train_lenet()
    train_cifar()
