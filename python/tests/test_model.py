"""L2 model tests: shapes, FP32-vs-BFP consistency, training smoke."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import datagen, model, train_small


def test_lenet_shapes():
    params = model.init_lenet(jax.random.PRNGKey(0))
    x = jnp.zeros((4, 1, 28, 28))
    assert model.lenet_fwd_fp32(params, x).shape == (4, 10)
    assert model.lenet_fwd_bfp(params, x, 8, 8, use_pallas=False).shape == (4, 10)


def test_cifar_shapes():
    params = model.init_cifar(jax.random.PRNGKey(0))
    x = jnp.zeros((2, 3, 32, 32))
    assert model.cifar_fwd_fp32(params, x).shape == (2, 10)
    assert model.cifar_fwd_bfp(params, x, 8, 8, use_pallas=False).shape == (2, 10)


def test_bfp_forward_pallas_matches_ref_path():
    """The Pallas-kernel BFP forward must bit-match the jnp-oracle BFP
    forward (same math, two implementations)."""
    params = model.init_lenet(jax.random.PRNGKey(1))
    x = jnp.array(datagen.digit_dataset(4, 3)[0])
    a = model.lenet_fwd_bfp(params, x, 8, 8, use_pallas=True)
    b = model.lenet_fwd_bfp(params, x, 8, 8, use_pallas=False)
    np.testing.assert_array_equal(np.array(a), np.array(b))


def test_bfp_forward_tracks_fp32():
    params = model.init_lenet(jax.random.PRNGKey(2))
    x = jnp.array(datagen.digit_dataset(6, 5)[0])
    fp = np.array(model.lenet_fwd_fp32(params, x))
    bfp = np.array(model.lenet_fwd_bfp(params, x, 8, 8, use_pallas=False))
    nsr = np.sum((fp - bfp) ** 2) / np.sum(fp**2)
    assert nsr < 1e-3, nsr


def test_im2col_matches_conv():
    """im2col + matmul == lax conv (Figure 1 equivalence)."""
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(0, 1, (2, 3, 9, 9)).astype(np.float32))
    w = jnp.array(rng.normal(0, 0.3, (5, 3, 3, 3)).astype(np.float32))
    b = jnp.zeros(5)
    want = np.array(model.conv2d_fp32(x, w, b, stride=1, padding=1))
    cols, (oh, ow) = model.im2col(x, 3, 3, stride=1, padding=1)
    wmat = w.reshape(5, -1)
    got = np.stack([np.array(wmat @ cols[i]).reshape(5, oh, ow) for i in range(2)])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_training_reduces_loss():
    params = model.init_lenet(jax.random.PRNGKey(3))
    x, y = datagen.digit_dataset(300, 11)
    params, curve = train_small.train(
        model.lenet_fwd_fp32, params, jnp.array(x), jnp.array(y),
        steps=40, batch=32, log=lambda *_: None,
    )
    assert curve[0][1] > 2.0  # ~ln(10) at init
    assert curve[-1][1] < 0.8  # clearly learning


def test_dump_and_reload_bfpw(tmp_path):
    params = model.init_lenet(jax.random.PRNGKey(4))
    p = tmp_path / "w.bfpw"
    model.dump_bfpw(params, p)
    from compile.aot import load_bfpw

    back = load_bfpw(p)
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(np.array(params[k]), np.array(back[k]))


def test_datagen_determinism_and_balance():
    x1, y1 = datagen.digit_dataset(50, 7)
    x2, y2 = datagen.digit_dataset(50, 7)
    np.testing.assert_array_equal(x1, x2)
    assert all((y1 == d).sum() == 5 for d in range(10))
    tx, ty = datagen.texture_dataset(20, 1)
    assert tx.shape == (20, 3, 32, 32)
    assert tx.min() >= 0 and tx.max() <= 1
