"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, widths and value distributions; every sweep
asserts *bit-exact* agreement between the Pallas kernel and `ref`, plus
the §3.1/§3.4 semantic invariants (error bound, block exponent, paper
worked example).
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bfp_matmul import bfp_matmul_pallas, mantissa_matmul_pallas
from compile.kernels.bfp_quantize import bfp_quantize_pallas, block_mantissas_pallas

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, shape, scale, dist):
    if dist == "normal":
        return rng.normal(0, scale, shape).astype(np.float32)
    if dist == "laplace":
        return rng.laplace(0, scale, shape).astype(np.float32)
    return rng.uniform(-scale, scale, shape).astype(np.float32)


# ---------- quantize kernel ----------

@settings(**SETTINGS)
@given(
    rows=st.integers(1, 12),
    cols=st.integers(1, 64),
    bits=st.integers(3, 12),
    scale=st.floats(1e-3, 1e3),
    dist=st.sampled_from(["normal", "laplace", "uniform"]),
    seed=st.integers(0, 2**31),
)
def test_quantize_pallas_matches_ref_per_row(rows, cols, bits, scale, dist, seed):
    x = rand(np.random.default_rng(seed), (rows, cols), scale, dist)
    qr, er = ref.block_mantissas(jnp.array(x), bits, axis=1)
    qp, ep = block_mantissas_pallas(jnp.array(x), bits, axis=1)
    np.testing.assert_array_equal(np.array(qr), np.array(qp))
    np.testing.assert_array_equal(np.array(er), np.array(ep))


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 10),
    cols=st.integers(1, 48),
    bits=st.integers(3, 10),
    seed=st.integers(0, 2**31),
)
def test_quantize_pallas_matches_ref_whole(rows, cols, bits, seed):
    x = rand(np.random.default_rng(seed), (rows, cols), 2.0, "normal")
    a = ref.bfp_quantize(jnp.array(x), bits, axis=None)
    b = bfp_quantize_pallas(jnp.array(x), bits, axis=None)
    np.testing.assert_array_equal(np.array(a), np.array(b))


@settings(**SETTINGS)
@given(bits=st.integers(3, 12), seed=st.integers(0, 2**31))
def test_quantize_error_bounded_by_step(bits, seed):
    x = rand(np.random.default_rng(seed), (4, 64), 3.0, "laplace")
    xq = np.array(bfp_quantize_pallas(jnp.array(x), bits, axis=None))
    eps = int(ref.block_exponent(jnp.array(x)))
    step = 2.0 ** (eps - (bits - 2))
    # round-off: |err| <= step/2, saturation of the rounded-up max: <= step
    assert np.max(np.abs(xq - x)) <= step + 1e-12


def test_block_exponent_is_max_exponent():
    x = jnp.array([[0.49, -3.5, 0.0, 1.0]])
    # exponents: -2, 1, (none), 0 -> block exponent 1
    assert int(ref.block_exponent(x)) == 1


def test_zero_block_quantizes_to_zero():
    x = jnp.zeros((3, 8))
    out = np.array(bfp_quantize_pallas(x, 8, axis=1))
    assert np.all(out == 0.0)


def test_exponent_of_matches_frexp_semantics():
    vals = np.array([1.0, 1.5, 2.0, 0.75, -5.25, 2.0**-10, 2.0**20], dtype=np.float32)
    got = np.array(ref.exponent_of(jnp.array(vals)))
    want = np.floor(np.log2(np.abs(vals))).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_round_half_away_ties():
    x = jnp.array([0.5, -0.5, 1.5, -1.5, 2.5])
    got = np.array(ref.round_half_away(x))
    np.testing.assert_array_equal(got, [1.0, -1.0, 2.0, -2.0, 3.0])


# ---------- matmul kernel ----------

@settings(**SETTINGS)
@given(
    m=st.integers(1, 12),
    k=st.integers(1, 48),
    n=st.integers(1, 32),
    lw=st.integers(3, 9),
    li=st.integers(3, 9),
    seed=st.integers(0, 2**31),
)
def test_matmul_pallas_matches_ref(m, k, n, lw, li, seed):
    rng = np.random.default_rng(seed)
    w = rand(rng, (m, k), 0.2, "laplace")
    i = rand(rng, (k, n), 1.5, "normal")
    a = ref.bfp_matmul(jnp.array(w), jnp.array(i), lw, li)
    b = bfp_matmul_pallas(jnp.array(w), jnp.array(i), lw, li)
    np.testing.assert_array_equal(np.array(a), np.array(b))


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31))
def test_matmul_is_exact_fixed_point(seed):
    """Dequantized GEMM of quantized operands == f32 GEMM of dequantized
    operands — the §3.4 exactness guarantee."""
    rng = np.random.default_rng(seed)
    w = rand(rng, (6, 20), 0.3, "normal")
    i = rand(rng, (20, 10), 2.0, "normal")
    got = np.array(bfp_matmul_pallas(jnp.array(w), jnp.array(i), 8, 8))
    wq = np.array(ref.bfp_quantize(jnp.array(w), 8, axis=1))
    iq = np.array(ref.bfp_quantize(jnp.array(i), 8, axis=None))
    want = wq @ iq
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-6)


def test_paper_worked_example():
    """§3.4: W=(1.00₂×2⁻¹, 1.01₂×2⁰), I=((1.01₂×2⁰,1.01₂×2⁰),(1.01₂×2¹,
    1.01₂×2²)), L=3 excl. sign → O' = (17/4, 27/4)."""
    w = jnp.array([[0.5, 1.25]])
    i = jnp.array([[1.25, 1.25], [2.5, 5.0]])
    out = np.array(bfp_matmul_pallas(w, i, 4, 4))
    np.testing.assert_array_equal(out, [[4.25, 6.75]])


def test_matmul_nsr_improves_with_width():
    rng = np.random.default_rng(5)
    w = rand(rng, (16, 64), 0.1, "laplace")
    i = rand(rng, (64, 32), 1.0, "normal")
    exact = w @ i

    def nsr(bits):
        o = np.array(bfp_matmul_pallas(jnp.array(w), jnp.array(i), bits, bits))
        return np.sum((o - exact) ** 2) / np.sum(exact**2)

    n6, n8, n10 = nsr(6), nsr(8), nsr(10)
    assert n6 > n8 > n10
    # ~12 dB per 2 bits (6.02 dB/bit)
    assert 8.0 < 10 * np.log10(n6 / n8) < 16.0


def test_mantissa_matmul_tiles_align():
    """Tiled Pallas mantissa GEMM == jnp.dot across awkward shapes."""
    rng = np.random.default_rng(9)
    for (m, k, n) in [(1, 1, 1), (3, 7, 5), (8, 16, 128), (13, 9, 130)]:
        a = rng.integers(-100, 100, (m, k)).astype(np.float32)
        b = rng.integers(-100, 100, (k, n)).astype(np.float32)
        got = np.array(mantissa_matmul_pallas(jnp.array(a), jnp.array(b)))
        np.testing.assert_array_equal(got, a @ b)


def test_width_plan_assertion_fires():
    w = jnp.ones((2, 5000))
    i = jnp.ones((5000, 2))
    with pytest.raises(AssertionError):
        ref.bfp_matmul(w, i, 12, 12)
