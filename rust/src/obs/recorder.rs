//! The span flight recorder: fixed-capacity, lock-free, always-on-able.
//!
//! Each recording thread owns one ring of [`RING_SLOTS`] slots taken
//! from a process-wide registry. A slot is four `AtomicU64` words —
//! `[seq, t0_us, dur_us, meta]` — written under a seqlock protocol
//! (odd `seq` while the words are in flux, even once stable), so the
//! owning thread appends without locks while `snapshot()` reads every
//! ring concurrently and simply discards slots it catches mid-write.
//! Wraparound keeps the newest records; memory is bounded by the peak
//! number of concurrently recording threads (rings are recycled through
//! a free list when threads exit, and their contents are retained for
//! the dump).
//!
//! Everything is gated on one process-wide `ARMED` atomic: unarmed,
//! `span()` returns an inert guard and the hot path performs one
//! relaxed load — no clock read, no allocation, no ring write.

use super::clock::Clock;
use std::cell::{Cell, RefCell};
use std::sync::atomic::{fence, AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

// ---- arming ---------------------------------------------------------------

static ARMED: AtomicBool = AtomicBool::new(false);

/// Arm the recorder process-wide (idempotent). Pins the clock origin so
/// span timestamps share one time base from here on.
pub fn arm() {
    Clock::init();
    // SeqCst: arming is a once-per-process cold toggle; a downgrade to
    // Release would be sound (armed() tolerates staleness) but saves
    // nothing off the hot path, so keep the strongest order for clarity.
    ARMED.store(true, Ordering::SeqCst);
}

/// Disarm the recorder (tests; serving arms once and never disarms).
pub fn disarm() {
    // SeqCst: test-only cold toggle, same rationale as `arm`.
    ARMED.store(false, Ordering::SeqCst);
}

/// Whether tracing is armed — the one relaxed load unarmed hot paths pay.
#[inline]
pub fn armed() -> bool {
    // Relaxed: a stale read only delays span capture by one check; no
    // data is published through this flag.
    ARMED.load(Ordering::Relaxed)
}

// ---- stage and event vocabulary -------------------------------------------

/// The span stage classes of the serving pipeline, in pipeline order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Enqueue → batch dispatch (queue wait, recorded per request).
    Queue,
    /// Batch formation: EDF class pick + linger + pop.
    Assemble,
    /// One batched forward pass on a serving lane.
    Forward,
    /// Patch gather into a column tile (per GEMM tile).
    Im2col,
    /// Activation quantize + BFP panel pack (per conv layer).
    Pack,
    /// The tiled BFP GEMM microkernel sweep (per conv layer).
    Gemm,
    /// Response encode + channel/socket write.
    Reply,
}

impl Stage {
    /// Every stage, in pipeline order (also the wire/code order).
    pub const ALL: [Stage; 7] = [
        Stage::Queue,
        Stage::Assemble,
        Stage::Forward,
        Stage::Im2col,
        Stage::Pack,
        Stage::Gemm,
        Stage::Reply,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Queue => "queue",
            Stage::Assemble => "assemble",
            Stage::Forward => "forward",
            Stage::Im2col => "im2col",
            Stage::Pack => "pack",
            Stage::Gemm => "gemm",
            Stage::Reply => "reply",
        }
    }

    fn code(self) -> u8 {
        self as u8
    }

    fn from_code(v: u8) -> Option<Stage> {
        Stage::ALL.get(v as usize).copied()
    }
}

/// Instant (zero-duration) fabric events.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EventKind {
    /// NSR monitor demanded a safer rung (hot-swap).
    Swap,
    /// NSR headroom allowed a cheaper rung (promotion).
    Promote,
    /// Lane supervisor respawned a panicked executor.
    Restart,
    /// Lane supervisor exhausted its budget and retired the lane.
    Retire,
    /// A per-lane worker stole a batch from a hotter lane.
    Steal,
    /// A batch was shed/downgraded out of its home class.
    Shed,
    /// The fault injector fired on a batch.
    Fault,
    /// The deadline reaper expired a queued request.
    Timeout,
    /// Drain began refusing new work.
    Drain,
    /// The weight-cache scrubber verified the cache (one pass).
    Scrub,
    /// Data corruption detected: a weight-cache checksum mismatch (the
    /// entry is evicted and requantized), a frame CRC failure, or a
    /// non-finite lane output.
    Corrupt,
}

impl EventKind {
    pub const ALL: [EventKind; 11] = [
        EventKind::Swap,
        EventKind::Promote,
        EventKind::Restart,
        EventKind::Retire,
        EventKind::Steal,
        EventKind::Shed,
        EventKind::Fault,
        EventKind::Timeout,
        EventKind::Drain,
        EventKind::Scrub,
        EventKind::Corrupt,
    ];

    pub fn name(self) -> &'static str {
        match self {
            EventKind::Swap => "swap",
            EventKind::Promote => "promote",
            EventKind::Restart => "restart",
            EventKind::Retire => "retire",
            EventKind::Steal => "steal",
            EventKind::Shed => "shed",
            EventKind::Fault => "fault",
            EventKind::Timeout => "timeout",
            EventKind::Drain => "drain",
            EventKind::Scrub => "scrub",
            EventKind::Corrupt => "corrupt",
        }
    }

    fn code(self) -> u8 {
        self as u8
    }

    fn from_code(v: u8) -> Option<EventKind> {
        EventKind::ALL.get(v as usize).copied()
    }
}

// ---- thread-local tagging context -----------------------------------------

pub(crate) const LANE_NONE: u8 = u8::MAX;
pub(crate) const LAYER_NONE: u16 = u16::MAX;

fn lane_code(label: &str) -> u8 {
    match label {
        "gold" => 0,
        "standard" => 1,
        "economy" => 2,
        "shed" => 3,
        _ => LANE_NONE,
    }
}

fn lane_name(code: u8) -> &'static str {
    match code {
        0 => "gold",
        1 => "standard",
        2 => "economy",
        3 => "shed",
        _ => "-",
    }
}

/// The per-thread tagging context every recorded span inherits: lane,
/// conv layer index, and the BFP weight/activation fraction widths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ctx {
    pub lane: u8,
    pub layer: u16,
    pub wbits: u8,
    pub ibits: u8,
}

impl Default for Ctx {
    fn default() -> Self {
        Self { lane: LANE_NONE, layer: LAYER_NONE, wbits: 0, ibits: 0 }
    }
}

thread_local! {
    static CTX: Cell<Ctx> =
        const { Cell::new(Ctx { lane: u8::MAX, layer: u16::MAX, wbits: 0, ibits: 0 }) };
}

/// This thread's current tagging context.
pub fn current_ctx() -> Ctx {
    CTX.try_with(Cell::get).unwrap_or_default()
}

/// Overwrite this thread's tagging context (pool workers install the
/// spawner's context with this; scoped code uses the guards below).
pub fn set_ctx(ctx: Ctx) {
    let _ = CTX.try_with(|c| c.set(ctx));
}

/// Restores the previous context on drop.
pub struct CtxGuard {
    prev: Ctx,
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        set_ctx(self.prev);
    }
}

/// Tag this thread's spans with a lane until the guard drops.
#[must_use = "the context reverts when the guard drops"]
pub fn lane_scope(label: &str) -> CtxGuard {
    let prev = current_ctx();
    set_ctx(Ctx { lane: lane_code(label), ..prev });
    CtxGuard { prev }
}

/// Tag this thread's spans with a conv layer index and its BFP widths
/// until the guard drops.
#[must_use = "the context reverts when the guard drops"]
pub fn layer_scope(layer: u16, wbits: u8, ibits: u8) -> CtxGuard {
    let prev = current_ctx();
    set_ctx(Ctx { layer, wbits, ibits, ..prev });
    CtxGuard { prev }
}

// ---- record encoding ------------------------------------------------------

const KIND_SPAN: u8 = 0;
const KIND_INSTANT: u8 = 1;

/// Pack kind + stage/event code + context into one word:
/// `byte0 kind · byte1 code · byte2 lane · byte3 wbits · byte4 ibits ·
/// bytes5-6 layer`.
fn pack(kind: u8, code: u8, ctx: Ctx) -> u64 {
    (kind as u64)
        | (code as u64) << 8
        | (ctx.lane as u64) << 16
        | (ctx.wbits as u64) << 24
        | (ctx.ibits as u64) << 32
        | (ctx.layer as u64) << 40
}

// ---- the seqlock ring -----------------------------------------------------

/// Slots per ring; at 32 B/slot one ring is 128 KiB of bounded memory.
pub(crate) const RING_SLOTS: usize = 4096;
const WORDS: usize = 4;

struct RawRecord {
    seq: u64,
    t0_us: u64,
    dur_us: u64,
    meta: u64,
}

struct Ring {
    id: u32,
    /// Claimed by exactly one live thread at a time (free-list CAS).
    in_use: AtomicBool,
    /// Monotone write counter; slot = head % RING_SLOTS.
    head: AtomicU64,
    /// `RING_SLOTS × [seq, t0_us, dur_us, meta]`.
    slots: Vec<AtomicU64>,
}

impl Ring {
    fn new(id: u32) -> Self {
        Self {
            id,
            in_use: AtomicBool::new(false),
            head: AtomicU64::new(0),
            slots: (0..RING_SLOTS * WORDS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Single-writer append (only the owning thread calls this), safe
    /// against concurrent `read_all`. Seqlock: `seq` goes odd (2n+1)
    /// before the data words change and even (2n+2) after, with a
    /// release fence between, so a reader that sees matching even
    /// generations on both sides of its data loads saw a whole record.
    fn write(&self, t0_us: u64, dur_us: u64, meta: u64) {
        // Relaxed: single-writer counter; only this thread increments it.
        let n = self.head.fetch_add(1, Ordering::Relaxed);
        let base = (n as usize % RING_SLOTS) * WORDS;
        // Relaxed store + the Release fence below: the odd seq must be
        // visible before any data word changes (fence orders them).
        self.slots[base].store(2 * n + 1, Ordering::Relaxed);
        fence(Ordering::Release);
        // Relaxed: the surrounding seq protocol, not these stores,
        // carries the ordering (fence above, Release seq store below).
        self.slots[base + 1].store(t0_us, Ordering::Relaxed);
        self.slots[base + 2].store(dur_us, Ordering::Relaxed);
        self.slots[base + 3].store(meta, Ordering::Relaxed);
        // Release: publishes the data words to readers that Acquire-load
        // an even seq.
        self.slots[base].store(2 * n + 2, Ordering::Release);
    }

    /// Read every stable slot; slots the writer is inside (odd seq or a
    /// generation change across the data loads) are retried briefly and
    /// then skipped — a snapshot never blocks the hot path.
    fn read_all(&self) -> Vec<RawRecord> {
        let mut out = Vec::new();
        for chunk in self.slots.chunks_exact(WORDS) {
            for _ in 0..16 {
                // Acquire: pairs with the writer's Release seq store, so
                // an even seq means the data words below are visible.
                let s1 = chunk[0].load(Ordering::Acquire);
                if s1 == 0 {
                    break; // never written
                }
                if s1 & 1 == 1 {
                    std::hint::spin_loop();
                    continue; // writer is inside this record
                }
                // Relaxed: validated by the seq recheck after the
                // Acquire fence below; torn reads are detected, not
                // prevented.
                let t0_us = chunk[1].load(Ordering::Relaxed);
                let dur_us = chunk[2].load(Ordering::Relaxed);
                let meta = chunk[3].load(Ordering::Relaxed);
                fence(Ordering::Acquire);
                // Relaxed: the fence above orders this recheck after the
                // data loads; equality with s1 proves stability.
                if chunk[0].load(Ordering::Relaxed) == s1 {
                    out.push(RawRecord { seq: s1 / 2 - 1, t0_us, dur_us, meta });
                    break;
                }
                std::hint::spin_loop();
            }
        }
        out
    }
}

// ---- registry and per-thread ownership ------------------------------------

fn registry() -> &'static Mutex<Vec<Arc<Ring>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

/// Releases the ring back to the free list when the thread exits; the
/// ring (and its records) stays in the registry for the dump.
struct LocalRing(Arc<Ring>);

impl Drop for LocalRing {
    fn drop(&mut self) {
        // Release: the exiting thread's ring writes happen-before any
        // thread that re-acquires the ring (Acquire CAS in acquire_ring).
        self.0.in_use.store(false, Ordering::Release);
    }
}

thread_local! {
    static LOCAL: RefCell<Option<LocalRing>> = const { RefCell::new(None) };
}

fn acquire_ring() -> Arc<Ring> {
    let mut reg = registry().lock().unwrap();
    for ring in reg.iter() {
        // Acquire on success: pairs with the Release in LocalRing::drop
        // so the previous owner's writes are visible; Relaxed on failure
        // (the loop just moves on).
        if ring
            .in_use
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed) // see above
            .is_ok()
        {
            return Arc::clone(ring);
        }
    }
    let ring = Arc::new(Ring::new(reg.len() as u32));
    // Relaxed: the ring is brand new and unshared until pushed under the
    // registry lock, which publishes it.
    ring.in_use.store(true, Ordering::Relaxed);
    reg.push(Arc::clone(&ring));
    ring
}

fn write_record(t0_us: u64, dur_us: u64, meta: u64) {
    // try_with: a span dropped during thread teardown records nothing
    // rather than panicking in a destructor
    let _ = LOCAL.try_with(|cell| {
        let mut slot = cell.borrow_mut();
        let local = slot.get_or_insert_with(|| LocalRing(acquire_ring()));
        local.0.write(t0_us, dur_us, meta);
    });
}

// ---- the recording API ----------------------------------------------------

/// RAII span guard: records `[creation, drop]` into the flight recorder
/// when tracing is armed; an inert shell otherwise.
pub struct SpanGuard {
    start_us: u64,
    meta: u64,
    live: bool,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.live {
            let dur = Clock::micros().saturating_sub(self.start_us);
            write_record(self.start_us, dur, self.meta);
        }
    }
}

/// Open a span for `stage`, tagged with this thread's current context.
#[must_use = "the span is recorded when the guard drops"]
#[inline]
pub fn span(stage: Stage) -> SpanGuard {
    if !armed() {
        return SpanGuard { start_us: 0, meta: 0, live: false };
    }
    SpanGuard {
        start_us: Clock::micros(),
        meta: pack(KIND_SPAN, stage.code(), current_ctx()),
        live: true,
    }
}

/// Open a span tagged with an explicit lane (overrides the context lane).
#[must_use = "the span is recorded when the guard drops"]
#[inline]
pub fn span_for_lane(stage: Stage, lane: &str) -> SpanGuard {
    if !armed() {
        return SpanGuard { start_us: 0, meta: 0, live: false };
    }
    let ctx = Ctx { lane: lane_code(lane), ..current_ctx() };
    SpanGuard { start_us: Clock::micros(), meta: pack(KIND_SPAN, stage.code(), ctx), live: true }
}

/// Record a span with explicit timing — for cross-thread stages like
/// queue wait, where no single guard can straddle both ends.
#[inline]
pub fn record_span_at(stage: Stage, start_us: u64, dur_us: u64) {
    if armed() {
        write_record(start_us, dur_us, pack(KIND_SPAN, stage.code(), current_ctx()));
    }
}

/// Record an instant event tagged with this thread's current context.
#[inline]
pub fn event(kind: EventKind) {
    if armed() {
        write_record(Clock::micros(), 0, pack(KIND_INSTANT, kind.code(), current_ctx()));
    }
}

/// Record an instant event tagged with an explicit lane.
#[inline]
pub fn event_lane(kind: EventKind, lane: &str) {
    if armed() {
        let ctx = Ctx { lane: lane_code(lane), ..current_ctx() };
        write_record(Clock::micros(), 0, pack(KIND_INSTANT, kind.code(), ctx));
    }
}

// ---- snapshots ------------------------------------------------------------

/// One decoded flight-recorder record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRecord {
    /// Flight-recorder ring id — a stable per-thread "virtual tid".
    pub ring: u32,
    /// Per-ring write sequence (newest-wins wraparound order).
    pub seq: u64,
    pub start_us: u64,
    pub dur_us: u64,
    /// `true` for instant events (`dur_us` is 0).
    pub instant: bool,
    /// Stage or event name.
    pub name: &'static str,
    /// Lane label, `-` when untagged.
    pub lane: &'static str,
    /// Conv layer index, when tagged.
    pub layer: Option<u16>,
    pub wbits: u8,
    pub ibits: u8,
}

/// Decode every stable record in every ring, sorted by start time.
/// Safe to call while recording continues.
pub fn snapshot() -> Vec<SpanRecord> {
    let rings: Vec<Arc<Ring>> = registry().lock().unwrap().iter().map(Arc::clone).collect();
    let mut out = Vec::new();
    for ring in rings {
        for raw in ring.read_all() {
            if let Some(rec) = decode(ring.id, raw) {
                out.push(rec);
            }
        }
    }
    out.sort_by_key(|r| (r.start_us, r.ring, r.seq));
    out
}

fn decode(ring: u32, raw: RawRecord) -> Option<SpanRecord> {
    let kind = (raw.meta & 0xff) as u8;
    let code = ((raw.meta >> 8) & 0xff) as u8;
    let lane = ((raw.meta >> 16) & 0xff) as u8;
    let wbits = ((raw.meta >> 24) & 0xff) as u8;
    let ibits = ((raw.meta >> 32) & 0xff) as u8;
    let layer = ((raw.meta >> 40) & 0xffff) as u16;
    let (instant, name) = match kind {
        KIND_SPAN => (false, Stage::from_code(code)?.name()),
        KIND_INSTANT => (true, EventKind::from_code(code)?.name()),
        _ => return None,
    };
    Some(SpanRecord {
        ring,
        seq: raw.seq,
        start_us: raw.t0_us,
        dur_us: raw.dur_us,
        instant,
        name,
        lane: lane_name(lane),
        layer: (layer != LAYER_NONE).then_some(layer),
        wbits,
        ibits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::MutexGuard;

    /// Serializes tests that flip the process-global `ARMED` flag so
    /// concurrent armed/unarmed assertions cannot cross-contaminate.
    fn arm_lock() -> MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn ring_wraparound_keeps_the_newest_records() {
        let ring = Ring::new(0);
        let total = (RING_SLOTS + 123) as u64;
        for i in 0..total {
            ring.write(i, i * 2, i * 3);
        }
        let mut recs = ring.read_all();
        assert_eq!(recs.len(), RING_SLOTS);
        recs.sort_by_key(|r| r.seq);
        assert_eq!(recs.first().unwrap().seq, total - RING_SLOTS as u64);
        assert_eq!(recs.last().unwrap().seq, total - 1);
        for r in &recs {
            assert_eq!(r.t0_us, r.seq);
            assert_eq!(r.dur_us, r.seq * 2);
            assert_eq!(r.meta, r.seq * 3);
        }
    }

    #[test]
    fn concurrent_reads_never_observe_torn_records() {
        const MAGIC: u64 = 0xdead_beef;
        let ring = Arc::new(Ring::new(1));
        let writer = {
            let ring = Arc::clone(&ring);
            std::thread::spawn(move || {
                for i in 0..50_000u64 {
                    // self-validating pattern: any torn mix of two
                    // records breaks at least one equation below
                    ring.write(i, i ^ MAGIC, i.wrapping_mul(31));
                }
            })
        };
        let mut seen = 0usize;
        let mut validate = |recs: Vec<RawRecord>| {
            for r in recs {
                assert_eq!(r.t0_us, r.seq, "torn record: seq/t0 mismatch");
                assert_eq!(r.dur_us, r.t0_us ^ MAGIC, "torn record: dur mismatch");
                assert_eq!(r.meta, r.t0_us.wrapping_mul(31), "torn record: meta mismatch");
                seen += 1;
            }
        };
        while !writer.is_finished() {
            validate(ring.read_all());
        }
        writer.join().unwrap();
        validate(ring.read_all());
        assert!(seen >= RING_SLOTS, "reader never saw a full ring");
    }

    #[test]
    fn ctx_scopes_nest_and_restore() {
        let base = current_ctx();
        {
            let _lane = lane_scope("gold");
            assert_eq!(current_ctx().lane, 0);
            {
                let _layer = layer_scope(3, 8, 7);
                let c = current_ctx();
                assert_eq!((c.lane, c.layer, c.wbits, c.ibits), (0, 3, 8, 7));
            }
            assert_eq!(current_ctx().layer, LAYER_NONE);
            assert_eq!(current_ctx().lane, 0);
        }
        assert_eq!(current_ctx(), base);
    }

    #[test]
    fn unarmed_spans_record_nothing() {
        let _lock = arm_lock();
        disarm();
        let before = snapshot().len();
        {
            let _g = span(Stage::Reply);
            let _h = span_for_lane(Stage::Gemm, "gold");
            event(EventKind::Drain);
            event_lane(EventKind::Steal, "economy");
            record_span_at(Stage::Queue, 1, 2);
        }
        assert_eq!(snapshot().len(), before, "unarmed recording leaked records");
    }

    #[test]
    fn released_rings_are_reused_and_snapshots_retain_thread_spans() {
        let _lock = arm_lock();
        arm();
        // a marker layer index no real model reaches, to pick our spans
        // out of whatever else the process recorded
        let marker = 912u16;
        let t = std::thread::spawn(move || {
            let _ctx = layer_scope(marker, 6, 5);
            let _lane = lane_scope("economy");
            drop(span(Stage::Gemm));
            event(EventKind::Fault);
        });
        t.join().unwrap();
        disarm();
        let mine: Vec<SpanRecord> =
            snapshot().into_iter().filter(|r| r.layer == Some(marker)).collect();
        assert_eq!(mine.len(), 2, "thread-exit dropped retained records: {mine:?}");
        let gemm = mine.iter().find(|r| r.name == "gemm").expect("gemm span");
        assert!(!gemm.instant);
        assert_eq!((gemm.lane, gemm.wbits, gemm.ibits), ("economy", 6, 5));
        let fault = mine.iter().find(|r| r.name == "fault").expect("fault event");
        assert!(fault.instant);
        // the exited thread's ring is back on the free list
        let reused = registry().lock().unwrap().iter().any(|r| !r.in_use.load(Ordering::Relaxed));
        assert!(reused, "no ring returned to the free list after thread exit");
    }

    #[test]
    fn armed_tracing_never_changes_logits() {
        use crate::models::Model;
        use crate::nn::prepared::PreparedModel;
        use crate::nn::Block;
        use crate::quant::{BfpConfig, LayerSchedule};
        use crate::tensor::Tensor;

        let _lock = arm_lock();
        let mut rng = crate::data::Rng::new(5);
        let model = Model {
            name: "obs-tiny".into(),
            graph: Block::seq(vec![
                Block::Conv(crate::models::init::conv2d("c1", 4, 2, 3, 3, 1, 1, &mut rng)),
                Block::ReLU,
                Block::Conv(crate::models::init::conv2d("c2", 3, 4, 3, 3, 1, 1, &mut rng)),
                Block::Flatten,
            ]),
            input_shape: vec![2, 8, 8],
            num_classes: 0,
        };
        let img =
            Tensor::from_vec(crate::data::Rng::new(7).normal_vec(2 * 8 * 8, 1.0), &[2, 8, 8]);
        let prepared = PreparedModel::new(model, LayerSchedule::uniform(BfpConfig::new(7, 7)));
        disarm();
        let cold = prepared.forward(&img);
        arm();
        let hot = prepared.forward(&img);
        disarm();
        assert_eq!(cold.data.len(), hot.data.len());
        for (a, b) in cold.data.iter().zip(&hot.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "armed tracing changed the math");
        }
        // and the armed run actually recorded the conv-stage spans
        let names: std::collections::HashSet<&str> = snapshot().iter().map(|r| r.name).collect();
        for want in ["im2col", "pack", "gemm"] {
            assert!(names.contains(want), "armed forward recorded no `{want}` span");
        }
    }
}
