//! One monotonic time base for the whole serving stack.
//!
//! Span timestamps, latency measurements, reaper deadlines and pacing
//! decisions used to call `Instant::now()` independently; they now share
//! this clock, so a trace span and the latency histogram it explains are
//! guaranteed to agree on when things happened. The clock is mockable in
//! tests only through [`Clock::advance`], which skews every subsequent
//! reading forward — serving code never calls it, so in production the
//! clock is exactly the OS monotonic clock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Microseconds of artificial forward skew (test mocking; 0 in serving).
static SKEW_US: AtomicU64 = AtomicU64::new(0);

/// Wakes [`Clock::sleep`]ers when the clock skews forward: sleepers
/// wait on the condvar against a [`Clock::now`]-based deadline, and
/// [`Clock::advance`] notifies so mocked time passes without real time.
fn sleepers() -> &'static (Mutex<()>, Condvar) {
    static SLEEPERS: OnceLock<(Mutex<()>, Condvar)> = OnceLock::new();
    SLEEPERS.get_or_init(|| (Mutex::new(()), Condvar::new()))
}

/// The process-wide origin every microsecond timestamp is relative to.
/// Pinned lazily on first use; [`Clock::init`] (called by `obs::arm`)
/// pins it eagerly so trace timestamps start near process start.
fn origin() -> Instant {
    static ORIGIN: OnceLock<Instant> = OnceLock::new();
    *ORIGIN.get_or_init(Instant::now)
}

/// The single monotonic clock (see the module docs).
pub struct Clock;

impl Clock {
    /// The current instant, including any test skew. Drop-in for
    /// `Instant::now()` — the returned `Instant` composes with
    /// `Duration` arithmetic and deadlines exactly as before.
    #[inline]
    pub fn now() -> Instant {
        // Relaxed: the skew is a monotone test knob; readers only need
        // *some* recent value, not cross-thread ordering with it.
        let skew = SKEW_US.load(Ordering::Relaxed);
        let now = Instant::now();
        if skew == 0 {
            now
        } else {
            now + Duration::from_micros(skew)
        }
    }

    /// Microseconds since the process origin (the trace time base).
    #[inline]
    pub fn micros() -> u64 {
        Self::micros_of(Self::now())
    }

    /// Microseconds since the origin for an already-captured instant
    /// (saturates to 0 for instants that predate the origin).
    #[inline]
    pub fn micros_of(t: Instant) -> u64 {
        t.saturating_duration_since(origin()).as_micros() as u64
    }

    /// Pin the origin (idempotent). Arming the recorder calls this so
    /// span timestamps are anchored before the first span is cut.
    pub fn init() {
        let _ = origin();
    }

    /// Skew the clock forward — the test mock. Affects every consumer
    /// process-wide; serving code must never call it.
    pub fn advance(d: Duration) {
        // Relaxed: monotone counter, no other memory is published with it.
        SKEW_US.fetch_add(d.as_micros() as u64, Ordering::Relaxed);
        // wake sleepers so mocked time passes without real waiting
        sleepers().1.notify_all();
    }

    /// Clock-aware sleep: blocks until `Clock::now() >= start + d`.
    /// In serving this is an ordinary bounded wait; under test mocking,
    /// [`Clock::advance`] wakes sleepers immediately, so periodic
    /// threads (scrubber cadence, restart backoff) fast-forward instead
    /// of stalling the test for real wall time.
    pub fn sleep(d: Duration) {
        let deadline = Self::now() + d;
        let (mutex, condvar) = sleepers();
        let mut guard = match mutex.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        loop {
            let now = Self::now();
            if now >= deadline {
                return;
            }
            guard = match condvar.wait_timeout(guard, deadline - now) {
                Ok((g, _)) => g,
                Err(poisoned) => poisoned.into_inner().0,
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic_and_origin_relative() {
        Clock::init();
        let a = Clock::micros();
        let b = Clock::micros();
        assert!(b >= a, "clock went backwards");
        let t = Clock::now();
        let us = Clock::micros_of(t);
        assert!(us >= a, "instant conversion disagrees with direct reads");
    }

    #[test]
    fn advance_skews_every_subsequent_reading() {
        // keep the skew tiny: it is process-global and other tests run
        // concurrently against the same clock
        let before = Clock::micros();
        Clock::advance(Duration::from_micros(700));
        let after = Clock::micros();
        assert!(after >= before + 700, "skew not applied: {before} -> {after}");
    }
}
