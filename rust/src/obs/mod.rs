//! Zero-dependency observability for the serving stack.
//!
//! Three pieces, threaded through every layer of the serving path:
//!
//! * [`clock`] — the single monotonic time base (mockable in tests)
//!   that spans, latency histograms, reaper deadlines, and pacing all
//!   share.
//! * [`recorder`] — the span flight recorder: per-thread lock-free
//!   seqlock rings behind a process-wide registry, recording
//!   queue→assemble→forward→im2col/pack/gemm→reply stage spans (tagged
//!   with lane, conv layer, and BFP widths) plus instant events for
//!   swaps, promotions, restarts, retirements, steals, sheds, faults,
//!   timeouts, and drains. One relaxed atomic load when unarmed;
//!   bounded memory when armed.
//! * [`trace`] — Chrome/Perfetto `trace_event` JSON export with atomic
//!   (tmp + rename) file writes.
//!
//! Arm with [`arm`] (the CLI does this for `--trace`), cut spans with
//! [`span`]/[`event`], dump with [`write_chrome_trace`] or aggregate
//! with `coordinator::metrics::stage_rows` for the report tables.

pub mod clock;
pub mod recorder;
pub mod trace;

pub use clock::Clock;
pub use recorder::{
    arm, armed, current_ctx, disarm, event, event_lane, lane_scope, layer_scope, record_span_at,
    set_ctx, snapshot, span, span_for_lane, Ctx, CtxGuard, EventKind, SpanGuard, SpanRecord, Stage,
};
pub use trace::{chrome_trace_json, write_chrome_trace};
