//! Chrome/Perfetto `trace_event` export of the flight recorder.
//!
//! The emitted JSON is the "JSON Array Format" both `chrome://tracing`
//! and [ui.perfetto.dev](https://ui.perfetto.dev) load directly: spans
//! as complete events (`ph:"X"`, microsecond `ts`/`dur`) and fabric
//! events as global instants (`ph:"i"`, `s:"g"`), one virtual `tid` per
//! recorder ring, with lane / layer / BFP widths in `args`. Written by
//! hand — the crate stays zero-dependency.

use super::recorder::{self, SpanRecord};
use std::fmt::Write as _;
use std::path::Path;

/// Render records as a Chrome `trace_event` JSON document.
pub fn chrome_trace_json(recs: &[SpanRecord]) -> String {
    let mut out = String::with_capacity(recs.len() * 128 + 32);
    out.push_str("{\"traceEvents\":[");
    for (i, r) in recs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        // stage/event/lane names are fixed identifiers — nothing to escape
        if r.instant {
            write!(
                out,
                "{{\"name\":\"{}\",\"cat\":\"event\",\"ph\":\"i\",\"s\":\"g\",\"ts\":{},\
                 \"pid\":1,\"tid\":{},\"args\":{{\"lane\":\"{}\"}}}}",
                r.name, r.start_us, r.ring, r.lane
            )
            .expect("write to String cannot fail");
        } else {
            write!(
                out,
                "{{\"name\":\"{}\",\"cat\":\"stage\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":1,\"tid\":{},\"args\":{{\"lane\":\"{}\"",
                r.name, r.start_us, r.dur_us, r.ring, r.lane
            )
            .expect("write to String cannot fail");
            if let Some(layer) = r.layer {
                write!(out, ",\"layer\":{layer},\"wbits\":{},\"ibits\":{}", r.wbits, r.ibits)
                    .expect("write to String cannot fail");
            }
            out.push_str("}}");
        }
    }
    out.push_str("]}");
    out
}

/// Snapshot the recorder and write the Chrome trace file atomically:
/// the JSON is staged to `<path>.tmp` and renamed over `path`, so a
/// concurrent reader — or a `kill` between periodic dumps — never sees
/// a half-written file.
pub fn write_chrome_trace(path: &Path) -> std::io::Result<()> {
    let json = chrome_trace_json(&recorder::snapshot());
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, json)?;
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- a minimal JSON parser: just enough to round-trip the trace ----

    #[derive(Debug, PartialEq)]
    enum Json {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Json>),
        Obj(Vec<(String, Json)>),
    }

    impl Json {
        fn get(&self, key: &str) -> Option<&Json> {
            match self {
                Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
                _ => None,
            }
        }

        fn num(&self) -> f64 {
            match self {
                Json::Num(v) => *v,
                other => panic!("expected a number, got {other:?}"),
            }
        }

        fn str(&self) -> &str {
            match self {
                Json::Str(s) => s,
                other => panic!("expected a string, got {other:?}"),
            }
        }
    }

    struct Parser<'a> {
        b: &'a [u8],
        i: usize,
    }

    impl<'a> Parser<'a> {
        fn parse(text: &'a str) -> Json {
            let mut p = Parser { b: text.as_bytes(), i: 0 };
            let v = p.value();
            p.ws();
            assert_eq!(p.i, p.b.len(), "trailing garbage after the document");
            v
        }

        fn ws(&mut self) {
            while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
                self.i += 1;
            }
        }

        fn peek(&mut self) -> u8 {
            self.ws();
            self.b[self.i]
        }

        fn eat(&mut self, c: u8) {
            assert_eq!(self.peek(), c, "expected `{}` at byte {}", c as char, self.i);
            self.i += 1;
        }

        fn lit(&mut self, s: &str) {
            self.ws();
            assert_eq!(&self.b[self.i..self.i + s.len()], s.as_bytes());
            self.i += s.len();
        }

        fn value(&mut self) -> Json {
            match self.peek() {
                b'{' => self.obj(),
                b'[' => self.arr(),
                b'"' => Json::Str(self.string()),
                b't' => {
                    self.lit("true");
                    Json::Bool(true)
                }
                b'f' => {
                    self.lit("false");
                    Json::Bool(false)
                }
                b'n' => {
                    self.lit("null");
                    Json::Null
                }
                _ => self.number(),
            }
        }

        fn obj(&mut self) -> Json {
            self.eat(b'{');
            let mut fields = Vec::new();
            if self.peek() != b'}' {
                loop {
                    self.ws();
                    let k = self.string();
                    self.eat(b':');
                    fields.push((k, self.value()));
                    if self.peek() == b',' {
                        self.eat(b',');
                    } else {
                        break;
                    }
                }
            }
            self.eat(b'}');
            Json::Obj(fields)
        }

        fn arr(&mut self) -> Json {
            self.eat(b'[');
            let mut items = Vec::new();
            if self.peek() != b']' {
                loop {
                    items.push(self.value());
                    if self.peek() == b',' {
                        self.eat(b',');
                    } else {
                        break;
                    }
                }
            }
            self.eat(b']');
            Json::Arr(items)
        }

        fn string(&mut self) -> String {
            self.eat(b'"');
            let mut s = String::new();
            while self.b[self.i] != b'"' {
                let c = self.b[self.i];
                if c == b'\\' {
                    self.i += 1;
                    s.push(self.b[self.i] as char);
                } else {
                    s.push(c as char);
                }
                self.i += 1;
            }
            self.i += 1;
            s
        }

        fn number(&mut self) -> Json {
            self.ws();
            let start = self.i;
            while self.i < self.b.len()
                && (self.b[self.i].is_ascii_digit()
                    || matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E'))
            {
                self.i += 1;
            }
            let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
            Json::Num(text.parse().expect("malformed number"))
        }
    }

    fn sample_records() -> Vec<SpanRecord> {
        vec![
            SpanRecord {
                ring: 0,
                seq: 0,
                start_us: 10,
                dur_us: 40,
                instant: false,
                name: "gemm",
                lane: "gold",
                layer: Some(2),
                wbits: 8,
                ibits: 7,
            },
            SpanRecord {
                ring: 1,
                seq: 3,
                start_us: 55,
                dur_us: 0,
                instant: true,
                name: "swap",
                lane: "economy",
                layer: None,
                wbits: 0,
                ibits: 0,
            },
            SpanRecord {
                ring: 0,
                seq: 1,
                start_us: 60,
                dur_us: 5,
                instant: false,
                name: "reply",
                lane: "-",
                layer: None,
                wbits: 0,
                ibits: 0,
            },
        ]
    }

    #[test]
    fn perfetto_json_round_trips_through_the_parser() {
        let doc = Parser::parse(&chrome_trace_json(&sample_records()));
        let events = match doc.get("traceEvents").unwrap() {
            Json::Arr(v) => v,
            other => panic!("traceEvents is not an array: {other:?}"),
        };
        assert_eq!(events.len(), 3);

        let gemm = &events[0];
        assert_eq!(gemm.get("name").unwrap().str(), "gemm");
        assert_eq!(gemm.get("ph").unwrap().str(), "X");
        assert_eq!(gemm.get("ts").unwrap().num(), 10.0);
        assert_eq!(gemm.get("dur").unwrap().num(), 40.0);
        assert_eq!(gemm.get("tid").unwrap().num(), 0.0);
        let args = gemm.get("args").unwrap();
        assert_eq!(args.get("lane").unwrap().str(), "gold");
        assert_eq!(args.get("layer").unwrap().num(), 2.0);
        assert_eq!(args.get("wbits").unwrap().num(), 8.0);
        assert_eq!(args.get("ibits").unwrap().num(), 7.0);

        let swap = &events[1];
        assert_eq!(swap.get("ph").unwrap().str(), "i");
        assert_eq!(swap.get("s").unwrap().str(), "g");
        assert!(swap.get("dur").is_none(), "instants carry no duration");
        assert_eq!(swap.get("args").unwrap().get("lane").unwrap().str(), "economy");

        let reply = &events[2];
        assert_eq!(reply.get("args").unwrap().get("lane").unwrap().str(), "-");
        assert!(reply.get("args").unwrap().get("layer").is_none());
    }

    #[test]
    fn empty_snapshot_is_still_a_valid_document() {
        let doc = Parser::parse(&chrome_trace_json(&[]));
        assert!(matches!(doc.get("traceEvents").unwrap(), Json::Arr(v) if v.is_empty()));
    }

    #[test]
    fn trace_file_write_is_atomic() {
        let dir = std::env::temp_dir().join("bfp_obs_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        write_chrome_trace(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Parser::parse(&text);
        assert!(doc.get("traceEvents").is_some());
        assert!(!path.with_extension("tmp").exists(), "staging file left behind");
        std::fs::remove_dir_all(&dir).ok();
    }
}
