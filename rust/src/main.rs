//! `repro` — the CLI over the bfp-cnn library.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts:
//!
//! ```text
//! bfp-cnn table1 [--lw 8] [--li 8]
//! bfp-cnn table2 [--images 20] [--size 32] [--seed 1]
//! bfp-cnn table3 [--model vgg16|resnet18|...|all] [--images 20] [--size 32]
//! bfp-cnn table4 [--images 5] [--size 32]
//! bfp-cnn fig3   [--images 5] [--size 32]
//! bfp-cnn autotune <model> [--budget-db <snr>] [--images 4] [--size 32]
//!                 [--max-width 10] [--min-width 3] [--out plan.txt]
//! bfp-cnn serve  [--model lenet] [--requests 64] [--mode bfp|fp32|plan]
//!                [--plan plan.txt] [--batch 8] [--prepared]
//! bfp-cnn serve  --qos [gold=<plan.txt|9/9>] [standard=<spec>] [economy=<spec>]
//!                [shed=<spec>] [--pressure 32] [--mix 1:1:1]
//!                [--workers single|per-lane|per-lane-nosteal]
//! bfp-cnn serve  --qos --listen 127.0.0.1:0 [--serve-secs 0] [--max-conns 256]
//!                [--quota-rps 0] [--quota-burst 32] [--quota-debt 64]
//!                [--reap-grace-ms 0] [--drain-ms 0]
//!                [--faults panic:economy:3:2,reset:conn:1] [--faults-seed 0]
//! bfp-cnn chaos  [--model lenet]
//!                [--scenario kill-lane|slow-lane|flaky-net|bit-flip|poison-input|all]
//!                [--workers <mode>] [--seed 1] [--json CHAOS_all.json]
//! bfp-cnn loadgen [--model lenet] [--requests 96] [--mix 1:3:8] [--lanes 4]
//!                 [--pressure 16] [--calib 3] [--batch 8] [--workers <mode>]
//! bfp-cnn loadgen --connect <addr> [--arrivals poisson:200|burst:150:4|diurnal:120]
//!                 [--scenario spike|tenant-mix|slow-client|all] [--requests 96]
//!                 [--rps 200] [--tenant default] [--class standard] [--json out.json]
//! bfp-cnn top    --connect <addr> [--interval-ms 500] [--iters 0]
//! bfp-cnn e2e    [--requests 64] [--artifacts artifacts]
//! bfp-cnn all    [--images 10]
//! ```
//!
//! Every subcommand also takes `--trace <path>`: it arms the span
//! flight recorder (`obs`) and dumps a Chrome/Perfetto `trace_event`
//! JSON there about once a second (atomic rename, loadable mid-run in
//! [ui.perfetto.dev](https://ui.perfetto.dev)), with a final dump on
//! clean exit. Unarmed, tracing costs one relaxed atomic load per
//! span site. `top --connect` polls the serving front's `Stats` frame
//! (lane rungs, queue depths, tenant quota balances, per-stage latency
//! attribution) into a refreshing terminal dashboard; the stage table
//! needs the *server* started with `--trace`.
//!
//! `autotune` runs the NSR-guided mixed-precision planner: it calibrates
//! on generated images, searches per-layer mantissa widths against the
//! SNR budget (default: match the uniform 8/8 prediction), prints the
//! plan + Pareto frontier, demonstrates per-layer execution through the
//! coordinator engine, and optionally serializes the plan for
//! `serve --mode plan`.
//!
//! `serve --qos --listen <addr>` puts the zero-dependency TCP front
//! (`net::server`) over the router: length-prefixed binary frames,
//! per-connection reader/writer threads, connection-cap admission and
//! per-tenant token-bucket quotas (`--quota-rps`; over-quota traffic
//! degrades to the economy lane, then sheds). `loadgen --connect`
//! drives it from a second process with the open-loop,
//! coordinated-omission-free arrival engine (`net::loadgen`): latency
//! is measured from each request's *intended* send instant, so server
//! stalls are charged to the requests they actually delayed.
//!
//! `serve --qos` starts the QoS precision router: one serving lane per
//! class (`gold=`/`standard=`/`economy=` each take a plan file or a
//! `lw/li` uniform width pair; missing classes default to 9/9, 7/7 and
//! 5/5), class-pure EDF batching, pressure-driven downgrades and online
//! NSR telemetry. `--workers per-lane` swaps the single-thread
//! reference scheduler for the dispatcher + per-lane-executor fabric
//! (one thread per lane, idle-steal between adjacent classes — see
//! `coordinator::qos`); unset, it honours `BFP_QOS_WORKERS` and
//! defaults to `single`. `loadgen` is the self-contained demo: it
//! autotunes a lane set off the Pareto frontier, then drives a
//! mixed-class workload through the router and prints the per-class /
//! per-lane QoS report.
//!
//! Resilience: `--reap-grace-ms` arms the deadline reaper (requests
//! still queued that long past their deadline fail with a typed
//! `Timeout`), `--drain-ms` turns the timed shutdown into a graceful
//! drain, and `--faults` arms the deterministic fault injector
//! (`runtime::faults` grammar, including the integrity faults
//! `flip:weights:<lane>:<layer>:<n>`, `corrupt:frame:<n>` and
//! `nan:input:<n>`; also via `BFP_FAULTS`/`BFP_FAULTS_SEED`).
//! `chaos` runs the seeded fault scenarios from `harness::chaos` —
//! kill-lane / slow-lane / flaky-net / bit-flip / poison-input —
//! asserts their recovery SLOs, and exits non-zero on any violation
//! (CI's chaos smoke job). Integrity is end-to-end: every wire frame
//! carries a payload CRC, request tensors are validated at admission,
//! cached weight panels are checksummed and scrubbed/repaired by a
//! background thread, and non-finite lane output fails typed — the
//! counters all surface in the `Stats` frame and the `top` dashboard.

use bfp_cnn::coordinator::engine::{forward_batch_ref, ExecMode};
use bfp_cnn::coordinator::server::{Backend, InferenceServer, PreparedBackend, RustBackend, ServerConfig};
use bfp_cnn::harness::{autotune_report, fig3, table1, table2, table3, table4};
use bfp_cnn::models::ModelId;
use bfp_cnn::quant::{BfpConfig, LayerSchedule};
use std::path::{Path, PathBuf};

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Self { flags }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

fn model_by_name(name: &str) -> Option<ModelId> {
    ModelId::all().into_iter().find(|m| m.name() == name)
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Arm the span flight recorder when `--trace <path>` is present and
/// spawn the periodic dump thread (~1 s cadence, atomic tmp+rename
/// writes, so a `kill` mid-run still leaves a loadable trace). Returns
/// the path so the caller can cut a final dump before exiting; `None`
/// leaves tracing disarmed and zero-cost.
fn arm_tracing(args: &Args) -> Option<PathBuf> {
    let path = args.flags.get("trace").map(PathBuf::from)?;
    bfp_cnn::obs::arm();
    {
        let path = path.clone();
        std::thread::Builder::new()
            .name("trace-dump".into())
            .spawn(move || loop {
                // LINT-ALLOW: bare-sleep — trace-dump cadence is a real
                // wall-clock interval for an operator tailing the file.
                std::thread::sleep(std::time::Duration::from_secs(1));
                if bfp_cnn::obs::write_chrome_trace(&path).is_err() {
                    return;
                }
            })
            .ok();
    }
    eprintln!("tracing armed; writing Perfetto trace to {}", path.display());
    Some(path)
}

/// Cut a final trace dump on the way out (the periodic thread may be
/// mid-sleep with newer spans still only in the rings).
fn finish_tracing(path: &Option<PathBuf>) {
    if let Some(path) = path {
        match bfp_cnn::obs::write_chrome_trace(path) {
            Ok(()) => eprintln!("wrote trace {}", path.display()),
            Err(e) => eprintln!("final trace dump failed: {e}"),
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    let artifacts = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let size: usize = args.get("size", 32);
    let seed: u64 = args.get("seed", 1);
    let trace = arm_tracing(&args);

    match cmd {
        "table1" => {
            for t in table1::run(args.get("lw", 8), args.get("li", 8)) {
                t.print();
                println!();
            }
        }
        "table2" => {
            let images: usize = args.get("images", 20);
            table2::run(size, images, seed, &artifacts).print();
        }
        "table3" => {
            let images: usize = args.get("images", 20);
            let which = args.get_str("model", "all");
            let ids: Vec<ModelId> = if which == "all" {
                ModelId::all().to_vec()
            } else {
                vec![model_by_name(&which).unwrap_or_else(|| {
                    eprintln!("unknown model {which}; choose from:");
                    for m in ModelId::all() {
                        eprintln!("  {}", m.name());
                    }
                    std::process::exit(2);
                })]
            };
            for id in ids {
                // LINT-ALLOW: clock-source — CLI progress timing shown
                // to a human; mocked time would lie to the operator.
                let t0 = std::time::Instant::now();
                table3::run_model(id, size, images, seed, &artifacts).print();
                println!("({:.1}s)\n", t0.elapsed().as_secs_f64());
            }
        }
        "table4" => {
            let images: usize = args.get("images", 5);
            let (t, dev) = table4::run(size, images, seed, &artifacts);
            t.print();
            println!("\nmax |multi-model − experimental| output deviation: {dev:.2} dB (paper: ≤ 8.9 dB)");
        }
        "fig3" => {
            let images: usize = args.get("images", 5);
            fig3::run(size, images, seed, &artifacts).print();
        }
        "autotune" => {
            let name = argv
                .get(1)
                .filter(|a| !a.starts_with("--"))
                .cloned()
                .unwrap_or_else(|| args.get_str("model", "lenet"));
            let id = model_by_name(&name).unwrap_or_else(|| {
                eprintln!("unknown model {name}; choose from:");
                for m in ModelId::all() {
                    eprintln!("  {}", m.name());
                }
                std::process::exit(2);
            });
            let images: usize = args.get("images", 4);
            let out = args.flags.get("out").map(PathBuf::from);
            let opts = bfp_cnn::autotune::PlannerOptions {
                max_width: args.get("max-width", 10),
                min_width: args.get("min-width", 3),
                refine_rounds: args.get("refine", 3),
            };
            let budget: Option<f64> = match args.flags.get("budget-db") {
                None => None,
                Some(v) => match v.parse() {
                    Ok(x) => Some(x),
                    Err(_) => {
                        eprintln!("invalid --budget-db value `{v}` (expected a dB number, e.g. 30.0)");
                        std::process::exit(2);
                    }
                },
            };
            if let Err(e) = autotune_cmd(id, size, seed, &artifacts, images, budget, &opts, out.as_deref()) {
                eprintln!("autotune failed: {e:#}");
                std::process::exit(1);
            }
        }
        "serve" => {
            let requests: usize = args.get("requests", 64);
            let batch: usize = args.get("batch", 8);
            let id = model_by_name(&args.get_str("model", "lenet")).expect("unknown model");
            let class_specs = collect_class_specs(&argv);
            if args.flags.contains_key("qos") || !class_specs.is_empty() {
                let set = match lane_set_from_specs(&class_specs, id.name()) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("cannot build QoS lane set: {e:#}");
                        std::process::exit(1);
                    }
                };
                if let Some(listen) = args.flags.get("listen") {
                    if let Err(e) = serve_net(
                        id,
                        size,
                        seed,
                        &artifacts,
                        batch,
                        args.get("pressure", 32),
                        set,
                        parse_workers(&args),
                        listen,
                        &args,
                    ) {
                        eprintln!("serve --listen failed: {e:#}");
                        std::process::exit(1);
                    }
                    finish_tracing(&trace);
                    return;
                }
                let mix = parse_mix(&args.get_str("mix", "1:1:1"));
                qos_serve_demo(
                    id,
                    size,
                    seed,
                    &artifacts,
                    requests,
                    batch,
                    args.get("pressure", 32),
                    set,
                    &mix,
                    parse_workers(&args),
                );
                finish_tracing(&trace);
                return;
            }
            if args.flags.contains_key("listen") {
                eprintln!("--listen needs the QoS router: add --qos (or class= lane specs)");
                std::process::exit(2);
            }
            let mode = match args.get_str("mode", "bfp").as_str() {
                "fp32" => ExecMode::Fp32,
                "plan" => {
                    let path = PathBuf::from(args.get_str("plan", "plan.txt"));
                    match bfp_cnn::autotune::PrecisionPlan::load(&path) {
                        Ok(plan) => {
                            let served = args.get_str("model", "lenet");
                            if plan.model != served {
                                eprintln!(
                                    "precision plan {} was tuned for model `{}`, refusing to serve `{}` with it",
                                    path.display(),
                                    plan.model,
                                    served
                                );
                                std::process::exit(2);
                            }
                            ExecMode::Mixed(plan.to_schedule())
                        }
                        Err(e) => {
                            eprintln!("cannot load precision plan: {e:#}");
                            std::process::exit(1);
                        }
                    }
                }
                _ => ExecMode::Bfp(BfpConfig::new(args.get("lw", 8), args.get("li", 8))),
            };
            let prepared = args.get_str("prepared", "false") == "true";
            serve_demo(id, size, seed, &artifacts, requests, batch, mode, prepared);
        }
        "loadgen" => {
            let id = model_by_name(&args.get_str("model", "lenet")).expect("unknown model");
            if let Some(addr) = args.flags.get("connect") {
                if let Err(e) = net_loadgen(id, size, seed, &artifacts, addr, &args) {
                    eprintln!("loadgen --connect failed: {e:#}");
                    std::process::exit(1);
                }
                finish_tracing(&trace);
                return;
            }
            let opts = bfp_cnn::autotune::PlannerOptions {
                max_width: args.get("max-width", 10),
                min_width: args.get("min-width", 3),
                refine_rounds: 0,
            };
            if let Err(e) = loadgen(
                id,
                size,
                seed,
                &artifacts,
                args.get("requests", 96),
                args.get("batch", 8),
                args.get("calib", 3),
                args.get("lanes", 4),
                args.get("pressure", 16),
                &parse_mix(&args.get_str("mix", "1:3:8")),
                &opts,
                parse_workers(&args),
            ) {
                eprintln!("loadgen failed: {e:#}");
                std::process::exit(1);
            }
        }
        "chaos" => {
            let id = model_by_name(&args.get_str("model", "lenet")).expect("unknown model");
            let which = args.get_str("scenario", "all");
            let workers = parse_workers(&args);
            if let Err(e) = chaos_cmd(id, size, seed, &artifacts, &which, workers, &args) {
                eprintln!("chaos failed: {e:#}");
                std::process::exit(1);
            }
        }
        "top" => {
            let Some(addr) = args.flags.get("connect") else {
                eprintln!("top needs --connect <addr> (a running `serve --qos --listen` front)");
                std::process::exit(2);
            };
            let interval = std::time::Duration::from_millis(args.get("interval-ms", 500));
            let iters: usize = args.get("iters", 0);
            if let Err(e) = top_cmd(addr, interval, iters) {
                eprintln!("top failed: {e:#}");
                std::process::exit(1);
            }
        }
        "lint" => {
            let fix = args.flags.contains_key("fix-baseline");
            let json = args.flags.get("json").map(PathBuf::from);
            match bfp_cnn::analysis::lint::cli(fix, json.as_deref()) {
                Ok(code) => {
                    if code != 0 {
                        eprintln!(
                            "lint failed: fix the findings (or, for a deliberate exception, \
                             add a `// LINT-ALLOW: <rule> — reason` comment)"
                        );
                        std::process::exit(code);
                    }
                }
                Err(e) => {
                    eprintln!("lint failed to run: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        "e2e" => {
            let requests: usize = args.get("requests", 64);
            if let Err(e) = e2e(&artifacts, requests, args.get("batch", 8)) {
                eprintln!("e2e failed: {e:#}");
                std::process::exit(1);
            }
        }
        "all" => {
            let images: usize = args.get("images", 10);
            for t in table1::run(8, 8) {
                t.print();
                println!();
            }
            table2::run(size, images, seed, &artifacts).print();
            println!();
            for id in ModelId::all() {
                table3::run_model(id, size, images, seed, &artifacts).print();
                println!();
            }
            let (t, dev) = table4::run(size, images.min(5), seed, &artifacts);
            t.print();
            println!("max deviation: {dev:.2} dB\n");
            fig3::run(size, images.min(5), seed, &artifacts).print();
        }
        _ => {
            eprintln!(
                "usage: bfp-cnn <table1|table2|table3|table4|fig3|autotune|serve|loadgen|top|chaos|lint|e2e|all> [--flags]"
            );
            eprintln!("see rust/src/main.rs docs for flags");
            std::process::exit(2);
        }
    }
    finish_tracing(&trace);
}

/// Generate a model-appropriate synthetic image batch.
fn gen_images(id: ModelId, input_shape: &[usize], n: usize, seed: u64) -> Vec<bfp_cnn::tensor::Tensor> {
    match id {
        ModelId::Lenet => bfp_cnn::data::DigitDataset::generate(n, seed).images,
        ModelId::Cifar10 => bfp_cnn::data::TextureDataset::generate(n, seed).images,
        _ => bfp_cnn::data::imagenet_like_batch(n, input_shape[1], seed),
    }
}

/// Coordinator demo: serve a stream of requests through the dynamic
/// batcher and print the metrics line. With `prepared`, serve through the
/// [`PreparedBackend`] (cached weight quantization + scratch arenas —
/// the steady-state configuration; see EXPERIMENTS.md §Perf).
#[allow(clippy::too_many_arguments)]
fn serve_demo(
    id: ModelId,
    size: usize,
    seed: u64,
    artifacts: &Path,
    requests: usize,
    batch: usize,
    mode: ExecMode,
    prepared: bool,
) {
    let model = id.build(size, seed, artifacts);
    let input_shape = model.input_shape.clone();
    let use_prepared = prepared && !matches!(mode, ExecMode::Fp32);
    if prepared && !use_prepared {
        eprintln!("--prepared has no cached weights in fp32 mode; serving unprepared");
    }
    let backend: Box<dyn Backend + Send> = if use_prepared {
        Box::new(PreparedBackend::new(model, &mode).expect("non-fp32 mode"))
    } else {
        Box::new(RustBackend { model, mode })
    };
    println!("serving {} requests on {} ...", requests, backend.describe());
    let mut server = InferenceServer::start(
        backend,
        ServerConfig {
            policy: bfp_cnn::coordinator::batcher::BatchPolicy {
                max_batch: batch,
                linger: std::time::Duration::from_millis(2),
            },
        },
    );
    let images = gen_images(id, &input_shape, requests, seed);
    let pending: Vec<_> = images.into_iter().map(|img| server.submit(img)).collect();
    for rx in pending {
        rx.recv().expect("response");
    }
    let metrics = server.shutdown();
    println!("{}", metrics.summary());
}

/// Gather `class=spec` tokens (any position) for the QoS lane set:
/// `gold=plan.txt standard=7/7 economy=5/5 [shed=4/4]`.
fn collect_class_specs(argv: &[String]) -> Vec<(String, String)> {
    use bfp_cnn::coordinator::QosClass;
    argv.iter()
        .filter_map(|tok| {
            let (class, spec) = tok.split_once('=')?;
            (QosClass::parse(class).is_some() || class == "shed")
                .then(|| (class.to_string(), spec.to_string()))
        })
        .collect()
}

/// Parse one lane spec: a `lw/li` uniform width pair, or a precision-plan
/// file produced by `bfp-cnn autotune --out`.
fn parse_lane_step(spec: &str, model: &str) -> anyhow::Result<bfp_cnn::coordinator::LaneStep> {
    if let Some((lw, li)) = spec.split_once('/') {
        if let (Ok(lw), Ok(li)) = (lw.parse::<u32>(), li.parse::<u32>()) {
            return Ok(bfp_cnn::coordinator::LaneStep::uniform(lw, li));
        }
    }
    let plan = bfp_cnn::autotune::PrecisionPlan::load(Path::new(spec))?;
    anyhow::ensure!(
        plan.model == model,
        "precision plan {spec} was tuned for model `{}`, refusing to serve `{model}` with it",
        plan.model
    );
    Ok(bfp_cnn::coordinator::LaneStep::from_plan(&plan))
}

/// Build the lane set from CLI specs; unspecified classes fall back to
/// demo uniform widths (gold 9/9, standard 7/7, economy 5/5, no shed).
fn lane_set_from_specs(
    specs: &[(String, String)],
    model: &str,
) -> anyhow::Result<bfp_cnn::coordinator::LaneSet> {
    use bfp_cnn::coordinator::{LaneSet, LaneStep};
    let find = |class: &str| specs.iter().find(|(c, _)| c == class).map(|(_, s)| s.as_str());
    let step = |class: &str, default: (u32, u32)| -> anyhow::Result<LaneStep> {
        match find(class) {
            Some(spec) => parse_lane_step(spec, model),
            None => Ok(LaneStep::uniform(default.0, default.1)),
        }
    };
    let shed = match find("shed") {
        Some(spec) => Some(parse_lane_step(spec, model)?),
        None => None,
    };
    Ok(LaneSet::from_steps(
        step("gold", (9, 9))?,
        step("standard", (7, 7))?,
        step("economy", (5, 5))?,
        shed,
    ))
}

/// Resolve the QoS worker mode: `--workers` flag first, then the
/// `BFP_QOS_WORKERS` env var, defaulting to the single-worker reference
/// scheduler. A typo'd mode would silently serve a different
/// concurrency experiment, so reject it loudly.
fn parse_workers(args: &Args) -> bfp_cnn::coordinator::WorkerMode {
    match args.flags.get("workers") {
        None => bfp_cnn::coordinator::WorkerMode::from_env(),
        Some(v) => bfp_cnn::coordinator::WorkerMode::parse(v).unwrap_or_else(|| {
            eprintln!("invalid --workers `{v}` (expected single|per-lane|per-lane-nosteal)");
            std::process::exit(2);
        }),
    }
}

/// Parse a `g:s:e` class-mix ratio into a submission pattern. Rejects
/// malformed components — a silently-coerced typo would serve a
/// different mix than the one the experiment asked for.
fn parse_mix(s: &str) -> Vec<bfp_cnn::coordinator::QosClass> {
    use bfp_cnn::coordinator::QosClass;
    let counts: Vec<usize> = s
        .split(':')
        .map(|t| {
            t.parse().unwrap_or_else(|_| {
                eprintln!(
                    "invalid --mix component `{t}` in `{s}` (expected g:s:e counts, e.g. 1:3:8)"
                );
                std::process::exit(2);
            })
        })
        .collect();
    let mut pattern = Vec::new();
    for (i, class) in QosClass::ALL.into_iter().enumerate() {
        for _ in 0..counts.get(i).copied().unwrap_or(1) {
            pattern.push(class);
        }
    }
    if pattern.is_empty() {
        pattern.push(QosClass::Standard);
    }
    pattern
}

/// QoS router demo: serve a mixed-class stream and print the QoS report.
#[allow(clippy::too_many_arguments)]
fn qos_serve_demo(
    id: ModelId,
    size: usize,
    seed: u64,
    artifacts: &Path,
    requests: usize,
    batch: usize,
    pressure: usize,
    set: bfp_cnn::coordinator::LaneSet,
    mix: &[bfp_cnn::coordinator::QosClass],
    workers: bfp_cnn::coordinator::WorkerMode,
) {
    use bfp_cnn::coordinator::{QosConfig, QosServer, ShedPolicy};
    let model = id.build(size, seed, artifacts);
    let input_shape = model.input_shape.clone();
    let config = QosConfig {
        policy: bfp_cnn::coordinator::batcher::BatchPolicy {
            max_batch: batch,
            linger: std::time::Duration::from_millis(2),
        },
        shed: ShedPolicy { enabled: true, queue_pressure: pressure },
        workers,
        ..QosConfig::default()
    };
    println!(
        "serving {} mixed-class requests on qos/{} (lanes gold/standard/economy{}, workers {}) ...",
        requests,
        id.name(),
        if set.shed.is_some() { "/shed" } else { "" },
        workers.name(),
    );
    let mut server = QosServer::start(model, &set, config);
    let images = gen_images(id, &input_shape, requests, seed);
    let pending: Vec<_> = images
        .into_iter()
        .enumerate()
        .map(|(i, img)| server.submit(mix[i % mix.len()], img))
        .collect();
    let mut failures = 0usize;
    for rx in pending {
        // every accepted submit resolves: a served response, or a typed
        // failure (timeout / executor panic / retired lane / drain)
        match rx {
            Ok(rx) => match rx.recv() {
                Ok(Ok(_)) => {}
                _ => failures += 1,
            },
            Err(_) => failures += 1,
        }
    }
    if failures > 0 {
        eprintln!("{failures} request(s) failed with typed errors; the report accounts for them");
    }
    let report = server.shutdown();
    bfp_cnn::harness::qos_report::print(&report);
}

/// The `chaos` subcommand: run the deterministic fault scenarios
/// (`harness::chaos`), print the loadgen-shaped stats, optionally
/// mirror them to a `CHAOS_*.json` artifact, and exit non-zero if any
/// recovery SLO was violated.
fn chaos_cmd(
    id: ModelId,
    size: usize,
    seed: u64,
    artifacts: &Path,
    which: &str,
    workers: bfp_cnn::coordinator::WorkerMode,
    args: &Args,
) -> anyhow::Result<()> {
    use bfp_cnn::harness::{chaos, net_report};

    let model = id.build(size, seed, artifacts);
    let pool = gen_images(id, &model.input_shape, 8, seed);
    println!("chaos `{which}` on {} (workers {}, seed {seed}) ...", id.name(), workers.name());
    let out = chaos::run_scenarios(&model, &pool, which, workers, seed)?;
    net_report::print(&out.stats);
    if let Some(path) = args.flags.get("json").map(PathBuf::from) {
        let tag = format!("chaos_{}_{}", which, workers.name());
        net_report::write_json(&path, &tag, &out.stats)?;
        println!("wrote {}", path.display());
    }
    if out.violations.is_empty() {
        println!("chaos `{which}`: every recovery SLO held");
        return Ok(());
    }
    for v in &out.violations {
        eprintln!("SLO VIOLATION: {v}");
    }
    anyhow::bail!("{} recovery SLO violation(s)", out.violations.len());
}

/// Parse `--faults`/`--faults-seed` into an armed injector; `None`
/// falls through to the `BFP_FAULTS` environment arming in the config
/// defaults.
fn parse_faults(args: &Args) -> Option<std::sync::Arc<bfp_cnn::runtime::FaultInjector>> {
    let spec = args.flags.get("faults")?;
    match bfp_cnn::runtime::FaultInjector::parse(spec, args.get("faults-seed", 0u64)) {
        Ok(inj) => Some(std::sync::Arc::new(inj)),
        Err(e) => {
            eprintln!("invalid --faults `{spec}`: {e:#}");
            std::process::exit(2);
        }
    }
}

/// `serve --qos --listen`: put the TCP front over the router and block.
/// With `--serve-secs 0` (the default) the process serves until killed;
/// otherwise it shuts down after the window and prints the QoS report
/// (tenant quota accounting included). `--drain-ms` makes that timed
/// stop graceful: submits are refused, queued work gets the bound to
/// finish, and every accepted request still resolves as a frame.
#[allow(clippy::too_many_arguments)]
fn serve_net(
    id: ModelId,
    size: usize,
    seed: u64,
    artifacts: &Path,
    batch: usize,
    pressure: usize,
    set: bfp_cnn::coordinator::LaneSet,
    workers: bfp_cnn::coordinator::WorkerMode,
    listen: &str,
    args: &Args,
) -> anyhow::Result<()> {
    use anyhow::Context as _;
    use bfp_cnn::coordinator::{QosConfig, QosServer, ShedPolicy};
    use bfp_cnn::net::{NetServer, NetServerConfig, QuotaConfig};
    use std::io::Write as _;

    let model = id.build(size, seed, artifacts);
    let faults = parse_faults(args);
    let mut config = QosConfig {
        policy: bfp_cnn::coordinator::batcher::BatchPolicy {
            max_batch: batch,
            linger: std::time::Duration::from_millis(2),
        },
        shed: ShedPolicy { enabled: true, queue_pressure: pressure },
        workers,
        ..QosConfig::default()
    };
    let reap_grace_ms: u64 = args.get("reap-grace-ms", 0);
    if reap_grace_ms > 0 {
        config.reap_grace = Some(std::time::Duration::from_millis(reap_grace_ms));
    }
    if faults.is_some() {
        config.faults = faults.clone();
    }
    let qos = QosServer::start(model, &set, config);
    let mut net_config = NetServerConfig {
        max_conns: args.get("max-conns", 256),
        quota: QuotaConfig {
            rate_per_s: args.get("quota-rps", 0.0),
            burst: args.get("quota-burst", 32.0),
            reject_debt: args.get("quota-debt", 64.0),
        },
        ..NetServerConfig::default()
    };
    if faults.is_some() {
        net_config.faults = faults;
    }
    let listener =
        std::net::TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
    let server = NetServer::start(listener, qos, net_config)?;
    // scripts (CI's loopback smoke) parse the port out of this line, so
    // flush past the pipe buffering before blocking
    println!("listening on {} (model {}, workers {})", server.addr(), id.name(), workers.name());
    std::io::stdout().flush().ok();
    let serve_secs: u64 = args.get("serve-secs", 0);
    if serve_secs == 0 {
        loop {
            // LINT-ALLOW: bare-sleep — parking the main thread while a
            // real server serves; wall time is the whole point.
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    // LINT-ALLOW: bare-sleep — `--serve-secs` is an operator-facing
    // wall-clock duration for the CI loopback smoke.
    std::thread::sleep(std::time::Duration::from_secs(serve_secs));
    let drain_ms: u64 = args.get("drain-ms", 0);
    let report = if drain_ms > 0 {
        server.shutdown_with_drain(std::time::Duration::from_millis(drain_ms))
    } else {
        server.shutdown()
    };
    bfp_cnn::harness::qos_report::print(&report);
    Ok(())
}

/// `loadgen --connect`: drive a remote serving front with the open-loop
/// arrival engine — either one ad-hoc `--arrivals` run or the canned
/// `--scenario` suite — and print/emit the per-run report.
fn net_loadgen(
    id: ModelId,
    size: usize,
    seed: u64,
    artifacts: &Path,
    addr: &str,
    args: &Args,
) -> anyhow::Result<()> {
    use anyhow::Context as _;
    use bfp_cnn::harness::net_report;
    use bfp_cnn::net::loadgen::{self, RunOpts};
    use std::net::ToSocketAddrs;

    let target = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving `{addr}`"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("`{addr}` resolves to no address"))?;
    let model = id.build(size, seed, artifacts);
    let pool = gen_images(id, &model.input_shape, 16, seed);
    let n: usize = args.get("requests", 96);
    let rps: f64 = args.get("rps", 200.0);

    let rows = if let Some(which) = args.flags.get("scenario") {
        println!("running scenario suite `{which}` against {target} ...");
        loadgen::run_scenarios(target, which, &pool, n, rps, seed)?
    } else {
        let spec = args.get_str("arrivals", "poisson:200");
        let kind = loadgen::parse_arrivals(&spec)?;
        let class_name = args.get_str("class", "standard");
        let class = bfp_cnn::coordinator::QosClass::parse(&class_name)
            .ok_or_else(|| anyhow::anyhow!("unknown class `{class_name}`"))?;
        let offsets = loadgen::schedule(kind, n, seed);
        let opts =
            RunOpts { tenant: args.get_str("tenant", "default"), class, ..RunOpts::default() };
        println!("open-loop `{spec}` ({n} requests) against {target} ...");
        vec![loadgen::run_open_loop(target, &pool, &offsets, &opts, "adhoc")?]
    };
    net_report::print(&rows);
    if let Some(path) = args.flags.get("json").map(PathBuf::from) {
        net_report::write_json(&path, "loadgen", &rows)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// `top --connect`: poll the server's `Stats` frame into a refreshing
/// terminal dashboard (ANSI clear-and-home between frames). `--iters 0`
/// polls until killed; a positive count exits after that many frames
/// (useful for CI and scripts). The stage table is empty unless the
/// *server* was started with `--trace` (the recorder is per-process).
fn top_cmd(addr: &str, interval: std::time::Duration, iters: usize) -> anyhow::Result<()> {
    use bfp_cnn::harness::report::{ms, Table};
    use bfp_cnn::net::NetClient;
    use std::io::Write as _;

    let mut client = NetClient::connect(addr)?;
    client.set_read_timeout(Some(std::time::Duration::from_secs(5)))?;
    let mut frame = 0usize;
    loop {
        let stats = client.stats()?;
        frame += 1;
        print!("\x1b[2J\x1b[H");
        println!(
            "bfp-cnn top — {addr} | up {:.1}s | {} requests served | frame {frame}",
            stats.uptime_ms as f64 / 1000.0,
            stats.total_requests,
        );
        let integ = &stats.integrity;
        println!(
            "integrity — scrubs {} (repairs {}) | frame CRC errors {} | bad inputs {} | \
             corrupt outputs {}",
            integ.scrub_passes,
            integ.scrub_repairs,
            integ.frame_crc_errors,
            integ.bad_inputs,
            integ.corrupt_outputs,
        );
        println!();
        let mut lanes = Table::new(
            "lanes",
            &["lane", "state", "rung", "queued", "restarts", "swaps", "promotes"],
        );
        for l in &stats.lanes {
            lanes.row(vec![
                l.label.clone(),
                if l.retired { "retired" } else { "live" }.to_string(),
                if l.rung == 0 { "-".to_string() } else { format!("{}/{}", l.rung, l.ladder) },
                l.queued.to_string(),
                l.restarts.to_string(),
                l.swaps.to_string(),
                l.promotions.to_string(),
            ]);
        }
        lanes.print();
        if !stats.tenants.is_empty() {
            println!();
            let mut t = Table::new("tenant quota balances", &["tenant", "tokens"]);
            for ten in &stats.tenants {
                let balance = format!("{:.3}", ten.tokens_milli as f64 / 1000.0);
                t.row(vec![ten.tenant.clone(), balance]);
            }
            t.print();
        }
        println!();
        if stats.stages.is_empty() {
            println!("(no stage spans — start the server with --trace to arm the recorder)");
        } else {
            let mut t = Table::new(
                "stage latency attribution (ms)",
                &["lane", "stage", "spans", "p50", "p99", "max"],
            );
            for s in &stats.stages {
                t.row(vec![
                    s.lane.clone(),
                    s.stage.clone(),
                    s.count.to_string(),
                    ms(s.p50_us as f64 / 1000.0),
                    ms(s.p99_us as f64 / 1000.0),
                    ms(s.max_us as f64 / 1000.0),
                ]);
            }
            t.print();
        }
        std::io::stdout().flush().ok();
        if iters > 0 && frame >= iters {
            return Ok(());
        }
        // LINT-ALLOW: bare-sleep — stats-watch refresh interval for a
        // human terminal; pacing a remote poll needs real wall time.
        std::thread::sleep(interval);
    }
}

/// The `loadgen` subcommand: autotune a lane set off the Pareto
/// frontier, then drive a mixed-class workload through the QoS router.
#[allow(clippy::too_many_arguments)]
fn loadgen(
    id: ModelId,
    size: usize,
    seed: u64,
    artifacts: &Path,
    requests: usize,
    batch: usize,
    calib: usize,
    lanes: usize,
    pressure: usize,
    mix: &[bfp_cnn::coordinator::QosClass],
    opts: &bfp_cnn::autotune::PlannerOptions,
    workers: bfp_cnn::coordinator::WorkerMode,
) -> anyhow::Result<()> {
    use bfp_cnn::autotune;
    use bfp_cnn::coordinator::LaneSet;

    let model = id.build(size, seed, artifacts);
    let calib_images = gen_images(id, &model.input_shape, calib.max(1), seed);
    // LINT-ALLOW: clock-source — CLI progress timing shown to a human.
    let t0 = std::time::Instant::now();
    let convs = autotune::calibrate(&model, &calib_images, opts)?;
    let plans = autotune::plan_lane_set(&model.name, &convs, lanes.max(1), opts);
    println!(
        "lane set from the Pareto frontier ({} plans, {:.2}s calibration+planning):",
        plans.len(),
        t0.elapsed().as_secs_f64()
    );
    for p in &plans {
        println!(
            "  predicted {:>7.2} dB, traffic {:>9.1} kbit ({:.1}% saved vs uniform 8/8)",
            p.predicted_snr_db,
            p.total_traffic_bits() / 1000.0,
            100.0 * p.savings_vs_uniform8()
        );
    }
    let set = LaneSet::from_plans(&plans)?;
    qos_serve_demo(id, size, seed, artifacts, requests, batch, pressure, set, mix, workers);
    Ok(())
}

/// The `autotune` subcommand: calibrate → plan → measure → report, then
/// prove the plan executes per-layer through the coordinator engine.
#[allow(clippy::too_many_arguments)]
fn autotune_cmd(
    id: ModelId,
    size: usize,
    seed: u64,
    artifacts: &Path,
    images: usize,
    budget_db: Option<f64>,
    opts: &bfp_cnn::autotune::PlannerOptions,
    out: Option<&Path>,
) -> anyhow::Result<()> {
    use bfp_cnn::autotune;

    let model = id.build(size, seed, artifacts);
    let calib = gen_images(id, &model.input_shape, images, seed);
    // LINT-ALLOW: clock-source — CLI progress timing shown to a human.
    let t0 = std::time::Instant::now();
    let convs = autotune::calibrate(&model, &calib, opts)?;
    // default budget: match the uniform-8/8 prediction — clamped into the
    // calibrated grid so e.g. --max-width 7 still derives a real budget
    let ref_w = 8u32.clamp(opts.min_width, opts.max_width);
    let uniform_pred = autotune::uniform_predicted_snr_db(&convs, ref_w);
    let budget = budget_db.unwrap_or(uniform_pred);
    println!(
        "calibrated {} conv layers on {} images ({:.2}s); uniform {ref_w}/{ref_w} predicts {:.2} dB; budget ≥ {:.2} dB",
        convs.len(),
        calib.len(),
        t0.elapsed().as_secs_f64(),
        uniform_pred,
        budget
    );

    let plan = autotune::autotune_with_stats(&model, &calib, &convs, budget, opts);
    autotune_report::plan_table(&plan).print();
    println!();
    autotune_report::frontier_table(&plan).print();
    println!();

    let uni = autotune::measure_schedule(&model, &calib, &LayerSchedule::uniform(BfpConfig::paper_default()));
    println!(
        "uniform 8/8: measured conv-out SNR {:>8.2} dB, traffic {:>10.1} kbit",
        uni.conv_out_snr_db,
        plan.uniform_traffic_bits(8, 8) / 1000.0
    );
    println!(
        "mixed plan : measured conv-out SNR {:>8.2} dB, traffic {:>10.1} kbit ({:.1}% saved)",
        plan.measured_snr_db,
        plan.total_traffic_bits() / 1000.0,
        100.0 * plan.savings_vs_uniform8()
    );
    if plan.measured_snr_db + 0.05 < budget {
        eprintln!(
            "warning: measured SNR {:.2} dB misses the {:.2} dB budget — the budget may be \
             infeasible within widths {}..={}",
            plan.measured_snr_db, budget, opts.min_width, opts.max_width
        );
    }

    // per-layer execution through the engine on fresh images
    let eval = gen_images(id, &model.input_shape, images.min(4), seed + 1);
    let fp = forward_batch_ref(&model, &eval, ExecMode::Fp32);
    let mixed = forward_batch_ref(&model, &eval, ExecMode::Mixed(plan.to_schedule()));
    let (mut sig, mut err) = (0f64, 0f64);
    for (a, b) in fp.iter().zip(&mixed) {
        for (&x, &y) in a.data.iter().zip(&b.data) {
            sig += (x as f64) * (x as f64);
            err += ((y - x) as f64) * ((y - x) as f64);
        }
    }
    println!(
        "engine ExecMode::Mixed over {} fresh images: output SNR {:.2} dB vs fp32",
        eval.len(),
        bfp_cnn::analysis::snr_db(sig, err)
    );

    if let Some(path) = out {
        plan.save(path)?;
        println!("plan written to {} (serve it: bfp-cnn serve --model {} --mode plan --plan {})",
            path.display(), id.name(), path.display());
    }
    Ok(())
}

/// End-to-end driver: PJRT-compiled LeNet (JAX/Pallas artifact) served
/// through the coordinator on the procedural digit workload, reporting
/// accuracy and latency. See EXPERIMENTS.md §E2E.
fn e2e(artifacts: &Path, requests: usize, batch: usize) -> anyhow::Result<()> {
    use bfp_cnn::runtime::PjrtRuntime;

    if cfg!(not(feature = "pjrt")) {
        anyhow::bail!(
            "e2e needs the PJRT runtime: rebuild with `--features pjrt` (and the `xla` dependency)"
        );
    }

    let hlo = artifacts.join("lenet_fwd_b8.hlo.txt");
    anyhow::ensure!(hlo.exists(), "{} missing — run `make artifacts` first", hlo.display());
    let manifest = artifacts.join("lenet_fwd_b8.args.txt");
    let weights = bfp_cnn::models::weights_io::WeightBundle::load(&artifacts.join("lenet_weights.bfpw"))?;

    // Weight arguments in manifest order (the artifact takes weights as
    // parameters — see aot.py), followed by the image batch.
    let mut weight_args: Vec<(Vec<f32>, Vec<i64>)> = Vec::new();
    for line in std::fs::read_to_string(&manifest)?.lines() {
        let mut parts = line.split_whitespace();
        let name = parts.next().unwrap_or("");
        if name == "__input__" {
            continue;
        }
        let shape: Vec<i64> = parts.map(|d| d.parse().unwrap()).collect();
        weight_args.push((weights.vec(name)?, shape));
    }

    // PJRT backend: pad each batch to the lowered batch size (8).
    struct PjrtBackend {
        art: bfp_cnn::runtime::CompiledArtifact,
        weight_args: Vec<(Vec<f32>, Vec<i64>)>,
        lowered_batch: usize,
    }
    impl Backend for PjrtBackend {
        fn infer_batch(&mut self, images: Vec<bfp_cnn::tensor::Tensor>) -> Vec<bfp_cnn::tensor::Tensor> {
            let b = self.lowered_batch;
            let per: usize = images[0].len();
            let mut flat = vec![0f32; b * per];
            for (i, img) in images.iter().take(b).enumerate() {
                flat[i * per..(i + 1) * per].copy_from_slice(&img.data);
            }
            let shape = [b as i64, 1, 28, 28];
            let mut args: Vec<(&[f32], &[i64])> = self
                .weight_args
                .iter()
                .map(|(d, s)| (d.as_slice(), s.as_slice()))
                .collect();
            args.push((&flat, &shape));
            let outs = self.art.run_f32(&args).expect("pjrt execute");
            let logits = &outs[0];
            let classes = logits.len() / b;
            images
                .iter()
                .take(b)
                .enumerate()
                .map(|(i, _)| {
                    bfp_cnn::tensor::Tensor::from_vec(logits[i * classes..(i + 1) * classes].to_vec(), &[classes])
                })
                .collect()
        }
        fn describe(&self) -> String {
            format!("pjrt/{}", self.art.name)
        }
    }

    let ds = bfp_cnn::data::DigitDataset::generate(requests, 777);
    // PJRT handles are thread-pinned: build client + executable on the
    // worker thread via the factory entry point.
    let mut server = InferenceServer::start_with(
        move || {
            let rt = PjrtRuntime::cpu().expect("PJRT cpu client");
            println!("PJRT: {}", rt.describe());
            let art = rt.load_hlo_text(&hlo).expect("compile artifact");
            Box::new(PjrtBackend { art, weight_args, lowered_batch: 8 })
        },
        ServerConfig {
            policy: bfp_cnn::coordinator::batcher::BatchPolicy {
                max_batch: batch.min(8),
                linger: std::time::Duration::from_millis(2),
            },
        },
    );
    let pending: Vec<_> = ds.images.iter().map(|img| server.submit(img.clone())).collect();
    let mut correct = 0usize;
    for (rx, &label) in pending.into_iter().zip(&ds.labels) {
        let resp = rx.recv()?;
        if argmax(&resp.logits.data) == label {
            correct += 1;
        }
    }
    let metrics = server.shutdown();
    println!("accuracy: {}/{} = {:.4}", correct, requests, correct as f64 / requests as f64);
    println!("{}", metrics.summary());
    Ok(())
}
