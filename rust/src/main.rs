//! `repro` — the CLI over the bfp-cnn library.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts:
//!
//! ```text
//! bfp-cnn table1 [--lw 8] [--li 8]
//! bfp-cnn table2 [--images 20] [--size 32] [--seed 1]
//! bfp-cnn table3 [--model vgg16|resnet18|...|all] [--images 20] [--size 32]
//! bfp-cnn table4 [--images 5] [--size 32]
//! bfp-cnn fig3   [--images 5] [--size 32]
//! bfp-cnn autotune <model> [--budget-db <snr>] [--images 4] [--size 32]
//!                 [--max-width 10] [--min-width 3] [--out plan.txt]
//! bfp-cnn serve  [--model lenet] [--requests 64] [--mode bfp|fp32|plan]
//!                [--plan plan.txt] [--batch 8] [--prepared]
//! bfp-cnn e2e    [--requests 64] [--artifacts artifacts]
//! bfp-cnn all    [--images 10]
//! ```
//!
//! `autotune` runs the NSR-guided mixed-precision planner: it calibrates
//! on generated images, searches per-layer mantissa widths against the
//! SNR budget (default: match the uniform 8/8 prediction), prints the
//! plan + Pareto frontier, demonstrates per-layer execution through the
//! coordinator engine, and optionally serializes the plan for
//! `serve --mode plan`.

use bfp_cnn::coordinator::engine::{forward_batch_ref, ExecMode};
use bfp_cnn::coordinator::server::{Backend, InferenceServer, PreparedBackend, RustBackend, ServerConfig};
use bfp_cnn::harness::{autotune_report, fig3, table1, table2, table3, table4};
use bfp_cnn::models::ModelId;
use bfp_cnn::quant::{BfpConfig, LayerSchedule};
use std::path::{Path, PathBuf};

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Self { flags }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

fn model_by_name(name: &str) -> Option<ModelId> {
    ModelId::all().into_iter().find(|m| m.name() == name)
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    let artifacts = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let size: usize = args.get("size", 32);
    let seed: u64 = args.get("seed", 1);

    match cmd {
        "table1" => {
            for t in table1::run(args.get("lw", 8), args.get("li", 8)) {
                t.print();
                println!();
            }
        }
        "table2" => {
            let images: usize = args.get("images", 20);
            table2::run(size, images, seed, &artifacts).print();
        }
        "table3" => {
            let images: usize = args.get("images", 20);
            let which = args.get_str("model", "all");
            let ids: Vec<ModelId> = if which == "all" {
                ModelId::all().to_vec()
            } else {
                vec![model_by_name(&which).unwrap_or_else(|| {
                    eprintln!("unknown model {which}; choose from:");
                    for m in ModelId::all() {
                        eprintln!("  {}", m.name());
                    }
                    std::process::exit(2);
                })]
            };
            for id in ids {
                let t0 = std::time::Instant::now();
                table3::run_model(id, size, images, seed, &artifacts).print();
                println!("({:.1}s)\n", t0.elapsed().as_secs_f64());
            }
        }
        "table4" => {
            let images: usize = args.get("images", 5);
            let (t, dev) = table4::run(size, images, seed, &artifacts);
            t.print();
            println!("\nmax |multi-model − experimental| output deviation: {dev:.2} dB (paper: ≤ 8.9 dB)");
        }
        "fig3" => {
            let images: usize = args.get("images", 5);
            fig3::run(size, images, seed, &artifacts).print();
        }
        "autotune" => {
            let name = argv
                .get(1)
                .filter(|a| !a.starts_with("--"))
                .cloned()
                .unwrap_or_else(|| args.get_str("model", "lenet"));
            let id = model_by_name(&name).unwrap_or_else(|| {
                eprintln!("unknown model {name}; choose from:");
                for m in ModelId::all() {
                    eprintln!("  {}", m.name());
                }
                std::process::exit(2);
            });
            let images: usize = args.get("images", 4);
            let out = args.flags.get("out").map(PathBuf::from);
            let opts = bfp_cnn::autotune::PlannerOptions {
                max_width: args.get("max-width", 10),
                min_width: args.get("min-width", 3),
                refine_rounds: args.get("refine", 3),
            };
            let budget: Option<f64> = match args.flags.get("budget-db") {
                None => None,
                Some(v) => match v.parse() {
                    Ok(x) => Some(x),
                    Err(_) => {
                        eprintln!("invalid --budget-db value `{v}` (expected a dB number, e.g. 30.0)");
                        std::process::exit(2);
                    }
                },
            };
            if let Err(e) = autotune_cmd(id, size, seed, &artifacts, images, budget, &opts, out.as_deref()) {
                eprintln!("autotune failed: {e:#}");
                std::process::exit(1);
            }
        }
        "serve" => {
            let requests: usize = args.get("requests", 64);
            let batch: usize = args.get("batch", 8);
            let mode = match args.get_str("mode", "bfp").as_str() {
                "fp32" => ExecMode::Fp32,
                "plan" => {
                    let path = PathBuf::from(args.get_str("plan", "plan.txt"));
                    match bfp_cnn::autotune::PrecisionPlan::load(&path) {
                        Ok(plan) => {
                            let served = args.get_str("model", "lenet");
                            if plan.model != served {
                                eprintln!(
                                    "precision plan {} was tuned for model `{}`, refusing to serve `{}` with it",
                                    path.display(),
                                    plan.model,
                                    served
                                );
                                std::process::exit(2);
                            }
                            ExecMode::Mixed(plan.to_schedule())
                        }
                        Err(e) => {
                            eprintln!("cannot load precision plan: {e:#}");
                            std::process::exit(1);
                        }
                    }
                }
                _ => ExecMode::Bfp(BfpConfig::new(args.get("lw", 8), args.get("li", 8))),
            };
            let id = model_by_name(&args.get_str("model", "lenet")).expect("unknown model");
            let prepared = args.get_str("prepared", "false") == "true";
            serve_demo(id, size, seed, &artifacts, requests, batch, mode, prepared);
        }
        "e2e" => {
            let requests: usize = args.get("requests", 64);
            if let Err(e) = e2e(&artifacts, requests, args.get("batch", 8)) {
                eprintln!("e2e failed: {e:#}");
                std::process::exit(1);
            }
        }
        "all" => {
            let images: usize = args.get("images", 10);
            for t in table1::run(8, 8) {
                t.print();
                println!();
            }
            table2::run(size, images, seed, &artifacts).print();
            println!();
            for id in ModelId::all() {
                table3::run_model(id, size, images, seed, &artifacts).print();
                println!();
            }
            let (t, dev) = table4::run(size, images.min(5), seed, &artifacts);
            t.print();
            println!("max deviation: {dev:.2} dB\n");
            fig3::run(size, images.min(5), seed, &artifacts).print();
        }
        _ => {
            eprintln!("usage: bfp-cnn <table1|table2|table3|table4|fig3|autotune|serve|e2e|all> [--flags]");
            eprintln!("see rust/src/main.rs docs for flags");
            std::process::exit(2);
        }
    }
}

/// Generate a model-appropriate synthetic image batch.
fn gen_images(id: ModelId, input_shape: &[usize], n: usize, seed: u64) -> Vec<bfp_cnn::tensor::Tensor> {
    match id {
        ModelId::Lenet => bfp_cnn::data::DigitDataset::generate(n, seed).images,
        ModelId::Cifar10 => bfp_cnn::data::TextureDataset::generate(n, seed).images,
        _ => bfp_cnn::data::imagenet_like_batch(n, input_shape[1], seed),
    }
}

/// Coordinator demo: serve a stream of requests through the dynamic
/// batcher and print the metrics line. With `prepared`, serve through the
/// [`PreparedBackend`] (cached weight quantization + scratch arenas —
/// the steady-state configuration; see EXPERIMENTS.md §Perf).
#[allow(clippy::too_many_arguments)]
fn serve_demo(
    id: ModelId,
    size: usize,
    seed: u64,
    artifacts: &Path,
    requests: usize,
    batch: usize,
    mode: ExecMode,
    prepared: bool,
) {
    let model = id.build(size, seed, artifacts);
    let input_shape = model.input_shape.clone();
    let use_prepared = prepared && !matches!(mode, ExecMode::Fp32);
    if prepared && !use_prepared {
        eprintln!("--prepared has no cached weights in fp32 mode; serving unprepared");
    }
    let backend: Box<dyn Backend + Send> = if use_prepared {
        Box::new(PreparedBackend::new(model, &mode).expect("non-fp32 mode"))
    } else {
        Box::new(RustBackend { model, mode })
    };
    println!("serving {} requests on {} ...", requests, backend.describe());
    let mut server = InferenceServer::start(
        backend,
        ServerConfig {
            policy: bfp_cnn::coordinator::batcher::BatchPolicy {
                max_batch: batch,
                linger: std::time::Duration::from_millis(2),
            },
        },
    );
    let images = gen_images(id, &input_shape, requests, seed);
    let pending: Vec<_> = images.into_iter().map(|img| server.submit(img)).collect();
    for rx in pending {
        rx.recv().expect("response");
    }
    let metrics = server.shutdown();
    println!("{}", metrics.summary());
}

/// The `autotune` subcommand: calibrate → plan → measure → report, then
/// prove the plan executes per-layer through the coordinator engine.
#[allow(clippy::too_many_arguments)]
fn autotune_cmd(
    id: ModelId,
    size: usize,
    seed: u64,
    artifacts: &Path,
    images: usize,
    budget_db: Option<f64>,
    opts: &bfp_cnn::autotune::PlannerOptions,
    out: Option<&Path>,
) -> anyhow::Result<()> {
    use bfp_cnn::autotune;

    let model = id.build(size, seed, artifacts);
    let calib = gen_images(id, &model.input_shape, images, seed);
    let t0 = std::time::Instant::now();
    let convs = autotune::calibrate(&model, &calib, opts)?;
    // default budget: match the uniform-8/8 prediction — clamped into the
    // calibrated grid so e.g. --max-width 7 still derives a real budget
    let ref_w = 8u32.clamp(opts.min_width, opts.max_width);
    let uniform_pred = autotune::uniform_predicted_snr_db(&convs, ref_w);
    let budget = budget_db.unwrap_or(uniform_pred);
    println!(
        "calibrated {} conv layers on {} images ({:.2}s); uniform {ref_w}/{ref_w} predicts {:.2} dB; budget ≥ {:.2} dB",
        convs.len(),
        calib.len(),
        t0.elapsed().as_secs_f64(),
        uniform_pred,
        budget
    );

    let plan = autotune::autotune_with_stats(&model, &calib, &convs, budget, opts);
    autotune_report::plan_table(&plan).print();
    println!();
    autotune_report::frontier_table(&plan).print();
    println!();

    let uni = autotune::measure_schedule(&model, &calib, &LayerSchedule::uniform(BfpConfig::paper_default()));
    println!(
        "uniform 8/8: measured conv-out SNR {:>8.2} dB, traffic {:>10.1} kbit",
        uni.conv_out_snr_db,
        plan.uniform_traffic_bits(8, 8) / 1000.0
    );
    println!(
        "mixed plan : measured conv-out SNR {:>8.2} dB, traffic {:>10.1} kbit ({:.1}% saved)",
        plan.measured_snr_db,
        plan.total_traffic_bits() / 1000.0,
        100.0 * plan.savings_vs_uniform8()
    );
    if plan.measured_snr_db + 0.05 < budget {
        eprintln!(
            "warning: measured SNR {:.2} dB misses the {:.2} dB budget — the budget may be \
             infeasible within widths {}..={}",
            plan.measured_snr_db, budget, opts.min_width, opts.max_width
        );
    }

    // per-layer execution through the engine on fresh images
    let eval = gen_images(id, &model.input_shape, images.min(4), seed + 1);
    let fp = forward_batch_ref(&model, &eval, ExecMode::Fp32);
    let mixed = forward_batch_ref(&model, &eval, ExecMode::Mixed(plan.to_schedule()));
    let (mut sig, mut err) = (0f64, 0f64);
    for (a, b) in fp.iter().zip(&mixed) {
        for (&x, &y) in a.data.iter().zip(&b.data) {
            sig += (x as f64) * (x as f64);
            err += ((y - x) as f64) * ((y - x) as f64);
        }
    }
    println!(
        "engine ExecMode::Mixed over {} fresh images: output SNR {:.2} dB vs fp32",
        eval.len(),
        bfp_cnn::analysis::snr_db(sig, err)
    );

    if let Some(path) = out {
        plan.save(path)?;
        println!("plan written to {} (serve it: bfp-cnn serve --model {} --mode plan --plan {})",
            path.display(), id.name(), path.display());
    }
    Ok(())
}

/// End-to-end driver: PJRT-compiled LeNet (JAX/Pallas artifact) served
/// through the coordinator on the procedural digit workload, reporting
/// accuracy and latency. See EXPERIMENTS.md §E2E.
fn e2e(artifacts: &Path, requests: usize, batch: usize) -> anyhow::Result<()> {
    use bfp_cnn::runtime::PjrtRuntime;

    if cfg!(not(feature = "pjrt")) {
        anyhow::bail!(
            "e2e needs the PJRT runtime: rebuild with `--features pjrt` (and the `xla` dependency)"
        );
    }

    let hlo = artifacts.join("lenet_fwd_b8.hlo.txt");
    anyhow::ensure!(hlo.exists(), "{} missing — run `make artifacts` first", hlo.display());
    let manifest = artifacts.join("lenet_fwd_b8.args.txt");
    let weights = bfp_cnn::models::weights_io::WeightBundle::load(&artifacts.join("lenet_weights.bfpw"))?;

    // Weight arguments in manifest order (the artifact takes weights as
    // parameters — see aot.py), followed by the image batch.
    let mut weight_args: Vec<(Vec<f32>, Vec<i64>)> = Vec::new();
    for line in std::fs::read_to_string(&manifest)?.lines() {
        let mut parts = line.split_whitespace();
        let name = parts.next().unwrap_or("");
        if name == "__input__" {
            continue;
        }
        let shape: Vec<i64> = parts.map(|d| d.parse().unwrap()).collect();
        weight_args.push((weights.vec(name)?, shape));
    }

    // PJRT backend: pad each batch to the lowered batch size (8).
    struct PjrtBackend {
        art: bfp_cnn::runtime::CompiledArtifact,
        weight_args: Vec<(Vec<f32>, Vec<i64>)>,
        lowered_batch: usize,
    }
    impl Backend for PjrtBackend {
        fn infer_batch(&mut self, images: Vec<bfp_cnn::tensor::Tensor>) -> Vec<bfp_cnn::tensor::Tensor> {
            let b = self.lowered_batch;
            let per: usize = images[0].len();
            let mut flat = vec![0f32; b * per];
            for (i, img) in images.iter().take(b).enumerate() {
                flat[i * per..(i + 1) * per].copy_from_slice(&img.data);
            }
            let shape = [b as i64, 1, 28, 28];
            let mut args: Vec<(&[f32], &[i64])> = self
                .weight_args
                .iter()
                .map(|(d, s)| (d.as_slice(), s.as_slice()))
                .collect();
            args.push((&flat, &shape));
            let outs = self.art.run_f32(&args).expect("pjrt execute");
            let logits = &outs[0];
            let classes = logits.len() / b;
            images
                .iter()
                .take(b)
                .enumerate()
                .map(|(i, _)| {
                    bfp_cnn::tensor::Tensor::from_vec(logits[i * classes..(i + 1) * classes].to_vec(), &[classes])
                })
                .collect()
        }
        fn describe(&self) -> String {
            format!("pjrt/{}", self.art.name)
        }
    }

    let ds = bfp_cnn::data::DigitDataset::generate(requests, 777);
    // PJRT handles are thread-pinned: build client + executable on the
    // worker thread via the factory entry point.
    let mut server = InferenceServer::start_with(
        move || {
            let rt = PjrtRuntime::cpu().expect("PJRT cpu client");
            println!("PJRT: {}", rt.describe());
            let art = rt.load_hlo_text(&hlo).expect("compile artifact");
            Box::new(PjrtBackend { art, weight_args, lowered_batch: 8 })
        },
        ServerConfig {
            policy: bfp_cnn::coordinator::batcher::BatchPolicy {
                max_batch: batch.min(8),
                linger: std::time::Duration::from_millis(2),
            },
        },
    );
    let pending: Vec<_> = ds.images.iter().map(|img| server.submit(img.clone())).collect();
    let mut correct = 0usize;
    for (rx, &label) in pending.into_iter().zip(&ds.labels) {
        let resp = rx.recv()?;
        if argmax(&resp.logits.data) == label {
            correct += 1;
        }
    }
    let metrics = server.shutdown();
    println!("accuracy: {}/{} = {:.4}", correct, requests, correct as f64 / requests as f64);
    println!("{}", metrics.summary());
    Ok(())
}
