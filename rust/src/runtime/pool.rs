//! Zero-dependency scoped thread pool for the inference hot path.
//!
//! Two primitives, both built on `std::thread::scope` (no persistent
//! worker threads, no channels, nothing to shut down):
//!
//! * [`parallel_row_panels`] — split a row-major output buffer into
//!   contiguous row panels and compute each panel on its own worker. The
//!   GEMM kernels parallelize over output rows, and every row is computed
//!   with exactly the instruction sequence of the serial path (including
//!   the chunked-K accumulation order), so results are **bit-identical**
//!   for every thread count.
//! * [`parallel_map_with`] — order-preserving parallel map with
//!   per-thread state (an executor, a scratch [`crate::nn::prepared::Workspace`]),
//!   used to spread `forward_batch` over images. Like the other
//!   primitives it takes a caller work estimate and stays on the calling
//!   thread under [`MIN_PARALLEL_WORK`].
//! * [`parallel_tasks`] — run `n` independent, identically-typed tasks on
//!   the pool with atomic work-stealing. The tiled GEMM
//!   ([`crate::bfp::kernel`]) uses it to parallelize in 2D (M panels ×
//!   N blocks): each task owns a disjoint output tile, so results are
//!   deterministic regardless of which worker runs which task.
//!
//! Thread count resolves as: [`with_threads`] override (tests) →
//! `BFP_NUM_THREADS` env var → `std::thread::available_parallelism()`.
//! Workers mark themselves with a thread-local flag and any nested
//! parallel region degrades to serial, so image-level and panel-level
//! parallelism compose without oversubscription: a batch of one image
//! parallelizes its GEMM panels, a full batch parallelizes over images
//! and runs each GEMM serially.
//!
//! Workers inherit the spawner's [`crate::obs`] tagging context (lane /
//! layer / BFP widths), so spans cut inside a parallel region land in
//! the flight recorder with the same tags as the calling thread's.

use std::cell::Cell;
use std::sync::OnceLock;

/// Safety valve against absurd `BFP_NUM_THREADS` values.
const MAX_THREADS: usize = 64;

thread_local! {
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
    /// 0 = no override; set by [`with_threads`].
    static OVERRIDE: Cell<usize> = const { Cell::new(0) };
}

fn env_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        match std::env::var("BFP_NUM_THREADS").ok().and_then(|v| v.trim().parse::<usize>().ok()) {
            Some(n) if n >= 1 => n.min(MAX_THREADS),
            _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(MAX_THREADS),
        }
    })
}

/// Worker threads a parallel primitive may use from the current thread
/// (1 inside a pool worker — nested regions run serial).
pub fn num_threads() -> usize {
    if IN_POOL.with(|c| c.get()) {
        return 1;
    }
    let o = OVERRIDE.with(|c| c.get());
    if o > 0 {
        o.min(MAX_THREADS)
    } else {
        env_threads()
    }
}

/// Run `f` with an explicit thread count, overriding `BFP_NUM_THREADS`
/// for the current thread (the bit-exactness tests sweep {1, 2, 4}).
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    assert!(n >= 1, "thread count must be >= 1");
    struct Reset(usize);
    impl Drop for Reset {
        fn drop(&mut self) {
            OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _reset = Reset(OVERRIDE.with(|c| c.replace(n)));
    f()
}

/// Below this much total work (caller-defined units; the GEMMs pass
/// MACs), a parallel region runs serial: spawning and joining scoped OS
/// threads costs tens of microseconds, which would swamp a small kernel
/// (a LeNet conv is ~10^5 MACs; a VGG conv3_1 is ~7.5·10^7).
pub const MIN_PARALLEL_WORK: usize = 1 << 17;

/// Split `out` (`rows × row_width`, row-major) into contiguous row panels
/// and run `f(first_row, panel)` on scoped workers. Rows are never split
/// across panels, so workers write disjoint slices and per-row results
/// are bit-identical to the serial path regardless of thread count.
///
/// `work_per_row` is the caller's estimate of the cost of one row (the
/// GEMMs pass `K·N` MACs); when `rows · work_per_row` falls under
/// [`MIN_PARALLEL_WORK`] the call runs serial on the calling thread —
/// tiny layers must not pay thread spawn/join latency.
pub fn parallel_row_panels<F>(out: &mut [f32], rows: usize, row_width: usize, work_per_row: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), rows * row_width, "panel buffer shape mismatch");
    if rows == 0 || row_width == 0 {
        return;
    }
    let threads = if rows.saturating_mul(work_per_row) < MIN_PARALLEL_WORK {
        1
    } else {
        num_threads().min(rows)
    };
    if threads <= 1 {
        f(0, out);
        return;
    }
    let panel_rows = rows.div_ceil(threads);
    let ctx = crate::obs::current_ctx();
    std::thread::scope(|s| {
        for (p, panel) in out.chunks_mut(panel_rows * row_width).enumerate() {
            let f = &f;
            s.spawn(move || {
                IN_POOL.with(|c| c.set(true));
                crate::obs::set_ctx(ctx);
                f(p * panel_rows, panel);
            });
        }
    });
}

/// Run `tasks` independent closures-by-index on the pool. Workers pull
/// task indices from a shared atomic counter (cheap work stealing — tile
/// costs vary with tail sizes and zero blocks), so *which* worker runs a
/// task is nondeterministic; callers must make each task's effect depend
/// only on its index (the GEMM tasks write disjoint output tiles).
///
/// `total_work` is the caller's cost estimate for the whole call (the
/// GEMMs pass `M·K·N` MACs); below [`MIN_PARALLEL_WORK`], and inside a
/// nested pool region, tasks run serially in index order on the calling
/// thread.
pub fn parallel_tasks<F>(tasks: usize, total_work: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if tasks == 0 {
        return;
    }
    let threads = if total_work < MIN_PARALLEL_WORK { 1 } else { num_threads().min(tasks) };
    if threads <= 1 {
        for t in 0..tasks {
            f(t);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let ctx = crate::obs::current_ctx();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let (f, next) = (&f, &next);
            s.spawn(move || {
                IN_POOL.with(|c| c.set(true));
                crate::obs::set_ctx(ctx);
                loop {
                    // Relaxed: work-stealing ticket counter — the claim
                    // itself is the synchronization-free contract (each
                    // task index is handed out exactly once); the scope
                    // join publishes the results.
                    let t = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if t >= tasks {
                        break;
                    }
                    f(t);
                }
            });
        }
    });
}

/// Threads each of `parts` concurrent pool users should budget so their
/// nested parallel regions don't oversubscribe the machine: the ambient
/// [`num_threads`] split `parts` ways, rounded up, never below one. The
/// per-lane QoS executors each wrap their forwards in
/// [`with_threads`]`(share_threads(lanes), ..)` — four lanes on a
/// four-core box get one GEMM/panel worker each instead of sixteen.
pub fn share_threads(parts: usize) -> usize {
    num_threads().div_ceil(parts.max(1))
}

/// Order-preserving parallel map with per-thread state: each worker
/// builds one `S` via `init` and folds its contiguous chunk of `items`
/// through `f`. Serial (single state, in order) when one thread is
/// available or when already inside a pool region.
///
/// `work_per_item` is the caller's cost estimate for one item (the
/// batched forwards pass approximate per-image MACs); when
/// `items · work_per_item` falls under [`MIN_PARALLEL_WORK`] the map
/// runs serial on the calling thread — a two-image batch of a tiny model
/// must not pay scoped-thread spawn/join latency.
pub fn parallel_map_with<T, R, S, I, F>(
    items: Vec<T>,
    work_per_item: usize,
    init: I,
    f: F,
) -> Vec<R>
where
    T: Send,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = if n.saturating_mul(work_per_item) < MIN_PARALLEL_WORK {
        1
    } else {
        num_threads().min(n)
    };
    if threads <= 1 {
        let mut state = init();
        return items.into_iter().map(|t| f(&mut state, t)).collect();
    }
    let per = n.div_ceil(threads);
    let mut chunks: Vec<Vec<T>> = Vec::with_capacity(threads);
    let mut it = items.into_iter();
    loop {
        let c: Vec<T> = it.by_ref().take(per).collect();
        if c.is_empty() {
            break;
        }
        chunks.push(c);
    }
    let ctx = crate::obs::current_ctx();
    std::thread::scope(|s| {
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|c| {
                let (init, f) = (&init, &f);
                s.spawn(move || {
                    IN_POOL.with(|cell| cell.set(true));
                    crate::obs::set_ctx(ctx);
                    let mut state = init();
                    c.into_iter().map(|t| f(&mut state, t)).collect::<Vec<R>>()
                })
            })
            .collect();
        // a panicking worker propagates its original payload to the
        // caller (scope joins the siblings first); swallowing it here
        // would deadlock callers waiting on results that never come
        handles
            .into_iter()
            .flat_map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_threads_overrides_and_restores() {
        let outer = num_threads();
        with_threads(3, || assert_eq!(num_threads(), 3));
        assert_eq!(num_threads(), outer);
    }

    #[test]
    fn row_panels_cover_every_row_once() {
        for threads in [1, 2, 4, 7] {
            with_threads(threads, || {
                let (rows, width) = (13, 5);
                let mut out = vec![0f32; rows * width];
                // work_per_row above the cutoff so the parallel path runs
                parallel_row_panels(&mut out, rows, width, MIN_PARALLEL_WORK, |r0, panel| {
                    for (pr, row) in panel.chunks_mut(width).enumerate() {
                        row.fill((r0 + pr) as f32);
                    }
                });
                for r in 0..rows {
                    assert!(out[r * width..(r + 1) * width].iter().all(|&v| v == r as f32), "row {r}");
                }
            });
        }
    }

    #[test]
    fn nested_region_degrades_to_serial() {
        with_threads(4, || {
            let mut out = vec![0f32; 8];
            parallel_row_panels(&mut out, 4, 2, MIN_PARALLEL_WORK, |_, _| {
                // inside a worker the pool must report a single thread
                assert_eq!(num_threads(), 1);
            });
        });
    }

    #[test]
    fn tiny_work_stays_on_the_calling_thread() {
        with_threads(4, || {
            let caller = std::thread::current().id();
            let mut out = vec![0f32; 8];
            // 4 rows × 10 work units ≪ MIN_PARALLEL_WORK → serial
            parallel_row_panels(&mut out, 4, 2, 10, |_, _| {
                assert_eq!(std::thread::current().id(), caller, "small kernel must not spawn");
            });
        });
    }

    #[test]
    fn map_preserves_order_with_per_thread_state() {
        for threads in [1, 2, 4] {
            let got = with_threads(threads, || {
                parallel_map_with(
                    (0..23u32).collect(),
                    MIN_PARALLEL_WORK,
                    || 0u32,
                    |count, x| {
                        *count += 1;
                        x * 2
                    },
                )
            });
            assert_eq!(got, (0..23u32).map(|x| x * 2).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    /// The map has the same small-work guard as the other primitives: a
    /// tiny batch must run on the calling thread, not spawn workers.
    #[test]
    fn tiny_map_stays_on_the_calling_thread() {
        with_threads(4, || {
            let caller = std::thread::current().id();
            // 4 items × 100 work units ≪ MIN_PARALLEL_WORK → serial
            let got = parallel_map_with((0..4u32).collect(), 100, || (), |_, x| {
                assert_eq!(std::thread::current().id(), caller, "small map must not spawn");
                x + 1
            });
            assert_eq!(got, vec![1, 2, 3, 4]);
        });
    }

    #[test]
    fn share_threads_splits_the_ambient_budget() {
        with_threads(4, || {
            assert_eq!(share_threads(1), 4);
            assert_eq!(share_threads(2), 2);
            assert_eq!(share_threads(3), 2, "rounded up, slight overlap beats idling");
            assert_eq!(share_threads(4), 1);
            assert_eq!(share_threads(100), 1, "never below one");
            assert_eq!(share_threads(0), 4, "degenerate parts treated as one user");
        });
        with_threads(1, || assert_eq!(share_threads(3), 1));
    }

    #[test]
    fn empty_inputs_are_fine() {
        parallel_row_panels(&mut [], 0, 4, MIN_PARALLEL_WORK, |_, _| unreachable!());
        let out: Vec<u32> =
            parallel_map_with(Vec::<u32>::new(), MIN_PARALLEL_WORK, || (), |_, x| x);
        assert!(out.is_empty());
        parallel_tasks(0, MIN_PARALLEL_WORK, |_| unreachable!());
    }

    #[test]
    fn tasks_each_run_exactly_once() {
        use std::sync::atomic::{AtomicU32, Ordering};
        for threads in [1usize, 2, 4, 7] {
            with_threads(threads, || {
                let hits: Vec<AtomicU32> = (0..23).map(|_| AtomicU32::new(0)).collect();
                parallel_tasks(hits.len(), MIN_PARALLEL_WORK, |t| {
                    hits[t].fetch_add(1, Ordering::Relaxed);
                });
                for (t, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1, "task {t} at {threads} threads");
                }
            });
        }
    }

    #[test]
    fn tiny_task_sets_stay_serial() {
        with_threads(4, || {
            let caller = std::thread::current().id();
            parallel_tasks(8, 100, |_| {
                assert_eq!(std::thread::current().id(), caller, "small work must not spawn");
            });
        });
    }

    /// A panicking task must propagate out of the pool (no deadlocked
    /// join, no hung work-stealing loop) while every sibling task still
    /// runs exactly once.
    #[test]
    fn panicking_task_propagates_without_deadlock() {
        use std::sync::atomic::{AtomicU32, Ordering};
        for threads in [2usize, 4] {
            with_threads(threads, || {
                let hits: Vec<AtomicU32> = (0..16).map(|_| AtomicU32::new(0)).collect();
                let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    parallel_tasks(hits.len(), MIN_PARALLEL_WORK, |t| {
                        if t == 7 {
                            panic!("injected task fault");
                        }
                        hits[t].fetch_add(1, Ordering::Relaxed);
                    });
                }));
                assert!(got.is_err(), "the panic must propagate at {threads} threads");
                for (t, h) in hits.iter().enumerate() {
                    if t == 7 {
                        continue;
                    }
                    assert_eq!(h.load(Ordering::Relaxed), 1, "task {t} at {threads} threads");
                }
            });
        }
    }

    /// Same contract for the order-preserving map, plus the original
    /// panic payload must survive the join; items chunked onto the
    /// *other* workers all complete.
    #[test]
    fn panicking_map_item_propagates_with_its_payload() {
        use std::sync::atomic::{AtomicU32, Ordering};
        for threads in [2usize, 4] {
            with_threads(threads, || {
                let done = AtomicU32::new(0);
                let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    parallel_map_with(
                        (0..16u32).collect(),
                        MIN_PARALLEL_WORK,
                        || (),
                        |_, x| {
                            if x == 3 {
                                panic!("injected map fault");
                            }
                            done.fetch_add(1, Ordering::Relaxed);
                            x
                        },
                    )
                }));
                assert!(got.is_err(), "the panic must propagate at {threads} threads");
                let payload = got.unwrap_err();
                let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
                assert_eq!(msg, "injected map fault", "payload must survive the join");
                // item 3 kills its own chunk's tail; every other chunk
                // (16/threads items each) still finishes
                let other_chunks = 16 - 16 / threads as u32;
                assert!(
                    done.load(Ordering::Relaxed) >= other_chunks,
                    "sibling chunks must finish at {threads} threads"
                );
            });
        }
    }
}
