//! Deterministic fault injection for the serving fabric.
//!
//! The resilience layer (lane supervision, deadline reaper, retrying
//! client) is only trustworthy if its failure paths run continuously —
//! so this module provides a *deterministic*, seeded injector that the
//! chaos scenario suite and CI arm through the environment or the CLI:
//!
//! * `panic:<lane>:<nth>[:<times>]` — panic the named lane's executor on
//!   its `nth` batch (1-based), for `times` consecutive batches
//!   (default 1). Batch counts survive respawns: the supervisor rebuilds
//!   the lane, not the counter, so `panic:economy:3:2` kills exactly
//!   batches 3 and 4 however often the lane restarts.
//! * `delay:<lane>:<ms>:<every>` — sleep `ms` before every `every`-th
//!   batch on the named lane (a slow-lane latency spike).
//! * `reset:conn:<nth>` — hard-reset the `nth` accepted TCP connection
//!   after its first request frame (the client sees a dead socket).
//! * `truncate:conn:<nth>` — answer the `nth` accepted connection's
//!   first request with a truncated frame (a length prefix promising
//!   more bytes than arrive), then close.
//! * `flip:weights:<lane>:<layer>:<nth>` — on the named lane's `nth`
//!   batch, flip one mantissa bit of `layer`'s entry in the shared
//!   weight cache (the scrubber must detect and repair it).
//! * `corrupt:frame:<nth>` — answer the `nth` accepted connection's
//!   first request with a well-framed but bit-flipped payload (the
//!   client's CRC check must refuse it), then close.
//! * `nan:input:<nth>` — smuggle a NaN into the `nth` decoded request's
//!   tensor *after* the wire CRC passes (admission validation must
//!   refuse it with a typed `BadInput`).
//!
//! Specs combine comma-separated (`BFP_FAULTS=panic:economy:3,reset:conn:1`,
//! seed from `BFP_FAULTS_SEED`). Everything keys off monotone per-lane
//! batch counters and a per-process connection counter, so a scenario is
//! reproducible run-to-run; the seed is carried for consumers that add
//! randomness on top (the retrying client's jitter). When no spec is
//! configured the injector is simply absent (`Option<Arc<FaultInjector>>`
//! is `None`) and the hot path pays nothing.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One configured fault.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultSpec {
    /// Panic the lane's executor on batches `nth .. nth + times` (1-based).
    PanicLane { lane: String, nth: u64, times: u64 },
    /// Sleep `ms` before every `every`-th batch on the lane.
    DelayLane { lane: String, ms: u64, every: u64 },
    /// Hard-reset the `nth` accepted connection after its first request.
    ResetConn { nth: u64 },
    /// Send the `nth` accepted connection a truncated frame, then close.
    TruncateConn { nth: u64 },
    /// Flip one cached-weight mantissa bit of `layer` on the lane's
    /// `nth` batch (1-based).
    FlipWeights { lane: String, layer: String, nth: u64 },
    /// Send the `nth` accepted connection a bit-flipped frame, then close.
    CorruptFrame { nth: u64 },
    /// Poison the `nth` decoded request's tensor with a NaN (1-based).
    NanInput { nth: u64 },
}

/// Parse one `kind:...` spec (grammar in the module docs).
pub fn parse_spec(spec: &str) -> Result<FaultSpec> {
    let fields: Vec<&str> = spec.split(':').collect();
    let num = |i: usize, what: &str| -> Result<u64> {
        fields
            .get(i)
            .with_context(|| format!("fault spec `{spec}` is missing its {what} field"))?
            .parse::<u64>()
            .with_context(|| format!("bad {what} in fault spec `{spec}`"))
    };
    let lane = |i: usize| -> Result<String> {
        let l = *fields.get(i).with_context(|| format!("fault spec `{spec}` names no lane"))?;
        if l.is_empty() {
            bail!("fault spec `{spec}` names no lane");
        }
        Ok(l.to_string())
    };
    let parsed = match fields[0] {
        "panic" => {
            let times = if fields.len() > 3 { num(3, "times")? } else { 1 };
            if fields.len() > 4 {
                bail!("trailing fields in fault spec `{spec}`");
            }
            FaultSpec::PanicLane { lane: lane(1)?, nth: num(2, "nth-batch")?.max(1), times }
        }
        "delay" => {
            if fields.len() > 4 {
                bail!("trailing fields in fault spec `{spec}`");
            }
            let every = num(3, "every")?.max(1);
            FaultSpec::DelayLane { lane: lane(1)?, ms: num(2, "ms")?, every }
        }
        "reset" | "truncate" => {
            if fields.get(1) != Some(&"conn") || fields.len() != 3 {
                bail!("connection fault spec must be `{}:conn:<nth>`, got `{spec}`", fields[0]);
            }
            let nth = num(2, "nth-connection")?.max(1);
            if fields[0] == "reset" {
                FaultSpec::ResetConn { nth }
            } else {
                FaultSpec::TruncateConn { nth }
            }
        }
        "flip" => {
            if fields.get(1) != Some(&"weights") || fields.len() != 5 {
                bail!("weight-flip fault spec must be `flip:weights:<lane>:<layer>:<nth>`, got `{spec}`");
            }
            let layer = fields[3];
            if layer.is_empty() {
                bail!("fault spec `{spec}` names no layer");
            }
            FaultSpec::FlipWeights {
                lane: lane(2)?,
                layer: layer.to_string(),
                nth: num(4, "nth-batch")?.max(1),
            }
        }
        "corrupt" => {
            if fields.get(1) != Some(&"frame") || fields.len() != 3 {
                bail!("frame fault spec must be `corrupt:frame:<nth>`, got `{spec}`");
            }
            FaultSpec::CorruptFrame { nth: num(2, "nth-connection")?.max(1) }
        }
        "nan" => {
            if fields.get(1) != Some(&"input") || fields.len() != 3 {
                bail!("input fault spec must be `nan:input:<nth>`, got `{spec}`");
            }
            FaultSpec::NanInput { nth: num(2, "nth-request")?.max(1) }
        }
        other => bail!("unknown fault kind `{other}` (panic|delay|reset|truncate|flip|corrupt|nan)"),
    };
    Ok(parsed)
}

/// Parse a comma-separated spec list (the `BFP_FAULTS` / `--faults` grammar).
pub fn parse_specs(specs: &str) -> Result<Vec<FaultSpec>> {
    specs.split(',').map(str::trim).filter(|s| !s.is_empty()).map(parse_spec).collect()
}

/// What, if anything, the fabric should do to one accepted connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnFault {
    None,
    Reset,
    Truncate,
    /// Reply with a well-framed but bit-flipped payload, then close.
    Corrupt,
}

/// The armed injector: deterministic counters over the configured specs.
/// Shared as `Option<Arc<FaultInjector>>` — absent means every hook is
/// never called and costs nothing.
#[derive(Debug)]
pub struct FaultInjector {
    specs: Vec<FaultSpec>,
    seed: u64,
    /// Batches seen per lane label — deliberately *outside* the lanes, so
    /// the count survives a supervisor respawn.
    lane_batches: Mutex<HashMap<String, u64>>,
    /// Connections accepted so far.
    conns: AtomicU64,
    /// Requests decoded so far (the `nan:input` counter).
    requests: AtomicU64,
}

impl FaultInjector {
    pub fn new(specs: Vec<FaultSpec>, seed: u64) -> Self {
        Self {
            specs,
            seed,
            lane_batches: Mutex::new(HashMap::new()),
            conns: AtomicU64::new(0),
            requests: AtomicU64::new(0),
        }
    }

    /// Parse-and-build from one comma-separated spec string.
    pub fn parse(specs: &str, seed: u64) -> Result<Self> {
        Ok(Self::new(parse_specs(specs)?, seed))
    }

    /// Arm from `BFP_FAULTS` / `BFP_FAULTS_SEED`. Unset ⇒ `None` (the
    /// common case); a malformed spec is reported and ignored rather
    /// than taking the server down.
    pub fn from_env() -> Option<Arc<FaultInjector>> {
        let spec = std::env::var("BFP_FAULTS").ok()?;
        if spec.trim().is_empty() {
            return None;
        }
        let seed = std::env::var("BFP_FAULTS_SEED")
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .unwrap_or(0);
        match FaultInjector::parse(&spec, seed) {
            Ok(inj) => Some(Arc::new(inj)),
            Err(e) => {
                eprintln!("ignoring BFP_FAULTS ({e:#})");
                None
            }
        }
    }

    /// The configured randomness seed (consumers add jitter on top; the
    /// injector itself is counter-deterministic).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Executor hook: called once per batch on the owning lane, *inside*
    /// the supervised (`catch_unwind`) region and before the forward.
    /// May sleep (delay specs) and may panic (panic specs) — an injected
    /// panic exercises exactly the respawn path a real one would. A
    /// `flip:weights` spec firing on this batch is *returned* as the
    /// layer name to corrupt rather than performed — the injector holds
    /// no weight-cache handle; the executor does.
    pub fn on_batch(&self, lane: &str) -> Option<String> {
        let n = {
            let mut counts = self.lane_batches.lock().unwrap();
            let c = counts.entry(lane.to_string()).or_insert(0);
            *c += 1;
            *c
        };
        let mut flip = None;
        for spec in &self.specs {
            match spec {
                FaultSpec::DelayLane { lane: l, ms, every } if l == lane && n % every == 0 => {
                    crate::obs::event_lane(crate::obs::EventKind::Fault, lane);
                    // LINT-ALLOW: bare-sleep — an injected latency spike
                    // must stall the executor for real wall time; routing
                    // it through the mockable clock would let tests skip
                    // the very delay the chaos scenario is asserting on.
                    std::thread::sleep(Duration::from_millis(*ms));
                }
                FaultSpec::PanicLane { lane: l, nth, times }
                    if l == lane && n >= *nth && n < nth + times =>
                {
                    crate::obs::event_lane(crate::obs::EventKind::Fault, lane);
                    panic!("injected fault: lane {lane} batch {n}");
                }
                FaultSpec::FlipWeights { lane: l, layer, nth } if l == lane && n == *nth => {
                    crate::obs::event_lane(crate::obs::EventKind::Fault, lane);
                    flip = Some(layer.clone());
                }
                _ => {}
            }
        }
        flip
    }

    /// Admission hook: called once per decoded request frame on the TCP
    /// front. `true` means smuggle a NaN into this request's tensor
    /// before validation — modelling payload memory going bad *after*
    /// the wire CRC passed (or a hostile client that computes correct
    /// CRCs over garbage).
    pub fn poison_input(&self) -> bool {
        // Relaxed: monotone request counter; no memory is published.
        let r = self.requests.fetch_add(1, Ordering::Relaxed) + 1;
        self.specs.iter().any(|s| matches!(s, FaultSpec::NanInput { nth } if *nth == r))
    }

    /// Acceptor hook: called once per accepted connection; the returned
    /// plan tells the connection handler whether (and how) to sabotage
    /// this connection.
    pub fn on_conn(&self) -> ConnFault {
        // Relaxed: monotone connection counter; no memory is published.
        let c = self.conns.fetch_add(1, Ordering::Relaxed) + 1;
        for spec in &self.specs {
            match spec {
                FaultSpec::ResetConn { nth } if *nth == c => return ConnFault::Reset,
                FaultSpec::TruncateConn { nth } if *nth == c => return ConnFault::Truncate,
                FaultSpec::CorruptFrame { nth } if *nth == c => return ConnFault::Corrupt,
                _ => {}
            }
        }
        ConnFault::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_documented_grammar() {
        assert_eq!(
            parse_spec("panic:economy:3").unwrap(),
            FaultSpec::PanicLane { lane: "economy".into(), nth: 3, times: 1 }
        );
        assert_eq!(
            parse_spec("panic:economy:3:2").unwrap(),
            FaultSpec::PanicLane { lane: "economy".into(), nth: 3, times: 2 }
        );
        assert_eq!(
            parse_spec("delay:gold:25:4").unwrap(),
            FaultSpec::DelayLane { lane: "gold".into(), ms: 25, every: 4 }
        );
        assert_eq!(parse_spec("reset:conn:1").unwrap(), FaultSpec::ResetConn { nth: 1 });
        assert_eq!(parse_spec("truncate:conn:2").unwrap(), FaultSpec::TruncateConn { nth: 2 });
        assert_eq!(
            parse_spec("flip:weights:gold:c1:2").unwrap(),
            FaultSpec::FlipWeights { lane: "gold".into(), layer: "c1".into(), nth: 2 }
        );
        assert_eq!(parse_spec("corrupt:frame:3").unwrap(), FaultSpec::CorruptFrame { nth: 3 });
        assert_eq!(parse_spec("nan:input:4").unwrap(), FaultSpec::NanInput { nth: 4 });
        let both = parse_specs(" panic:economy:3:2 , reset:conn:1 ").unwrap();
        assert_eq!(both.len(), 2);
        for bad in [
            "panic:economy",
            "panic::3",
            "delay:gold:25",
            "reset:sock:1",
            "reset:conn:x",
            "nuke:everything",
            "panic:economy:3:2:9",
            "flip:weights:gold:c1",
            "flip:mantissa:gold:c1:2",
            "flip:weights:gold::2",
            "corrupt:conn:1",
            "corrupt:frame:x",
            "nan:input",
            "nan:logits:1",
        ] {
            assert!(parse_spec(bad).is_err(), "`{bad}` should be rejected");
        }
    }

    #[test]
    fn panic_fires_on_exactly_the_configured_batches() {
        let inj = FaultInjector::parse("panic:economy:3:2", 7).unwrap();
        // batches 1, 2 pass; 3 and 4 panic; 5 passes again
        for _ in 0..2 {
            inj.on_batch("economy");
        }
        for expect_panic in [true, true, false] {
            let got =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| inj.on_batch("economy")));
            assert_eq!(got.is_err(), expect_panic);
        }
        // other lanes keep their own counters and never fire
        for _ in 0..6 {
            inj.on_batch("gold");
        }
    }

    #[test]
    fn conn_faults_hit_only_the_named_connection() {
        let inj = FaultInjector::parse("reset:conn:2,truncate:conn:3,corrupt:frame:4", 0).unwrap();
        assert_eq!(inj.on_conn(), ConnFault::None);
        assert_eq!(inj.on_conn(), ConnFault::Reset);
        assert_eq!(inj.on_conn(), ConnFault::Truncate);
        assert_eq!(inj.on_conn(), ConnFault::Corrupt);
        assert_eq!(inj.on_conn(), ConnFault::None);
    }

    #[test]
    fn weight_flip_fires_once_on_the_named_lane_and_batch() {
        let inj = FaultInjector::parse("flip:weights:economy:c1:2", 0).unwrap();
        assert_eq!(inj.on_batch("gold"), None, "other lanes never flip");
        assert_eq!(inj.on_batch("economy"), None);
        assert_eq!(inj.on_batch("economy"), Some("c1".to_string()));
        assert_eq!(inj.on_batch("economy"), None, "the flip is one-shot");
    }

    #[test]
    fn input_poison_hits_exactly_the_named_request() {
        let inj = FaultInjector::parse("nan:input:3", 0).unwrap();
        let hits: Vec<bool> = (0..5).map(|_| inj.poison_input()).collect();
        assert_eq!(hits, vec![false, false, true, false, false]);
    }

    #[test]
    fn delay_is_periodic_and_panic_free() {
        let inj = FaultInjector::parse("delay:standard:0:2", 0).unwrap();
        for _ in 0..5 {
            inj.on_batch("standard"); // ms=0: exercises the arm without sleeping
        }
        assert_eq!(inj.seed(), 0);
    }
}
