//! Execution runtimes: the scoped thread [`pool`] that parallelizes the
//! pure-Rust hot path, the deterministic [`faults`] injection plane the
//! chaos suite arms against the serving fabric, and the PJRT loader for
//! AOT-compiled HLO-text artifacts produced by `python/compile/aot.py`.
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto` — jax ≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

pub mod faults;
pub mod pjrt;
pub mod pool;

pub use faults::{ConnFault, FaultInjector, FaultSpec};
pub use pjrt::{CompiledArtifact, PjrtRuntime};
