//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! The `xla` crate (and its native `xla_extension` libraries) cannot be
//! fetched in the offline build image, so the real implementation is
//! gated behind the `pjrt` cargo feature. The default build ships an
//! API-identical stub whose constructors return a descriptive error —
//! callers such as `bfp-cnn e2e` degrade gracefully, and everything that
//! doesn't touch PJRT (the whole pure-Rust stack) is unaffected.

use anyhow::Result;
use std::path::Path;

#[cfg(feature = "pjrt")]
mod real {
    use anyhow::{Context, Result};
    use std::path::Path;

    /// A PJRT client (CPU). One per process; artifacts share it.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    /// A compiled artifact ready to execute.
    pub struct CompiledArtifact {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl PjrtRuntime {
        /// Create the CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Self { client })
        }

        /// Platform description (for logs).
        pub fn describe(&self) -> String {
            format!("{} ({} devices)", self.client.platform_name(), self.client.device_count())
        }

        /// Load an HLO-text artifact and compile it.
        pub fn load_hlo_text(&self, path: &Path) -> Result<CompiledArtifact> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
            let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("artifact").to_string();
            Ok(CompiledArtifact { exe, name })
        }
    }

    impl CompiledArtifact {
        /// Execute with f32 inputs of the given shapes. The artifact must
        /// have been lowered with `return_tuple=True`; all tuple elements
        /// are returned as flat f32 vectors.
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, shape)| {
                    let lit = xla::Literal::vec1(data);
                    lit.reshape(shape).map_err(|e| anyhow::anyhow!("reshape to {shape:?}: {e:?}"))
                })
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
            let first = result
                .first()
                .and_then(|r| r.first())
                .context("empty execution result")?
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
            let parts = first.to_tuple().map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
            parts
                .into_iter()
                .map(|p| p.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}")))
                .collect()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::{CompiledArtifact, PjrtRuntime};

/// Stub PJRT runtime for builds without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    _private: (),
}

/// Stub compiled artifact for builds without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
pub struct CompiledArtifact {
    pub name: String,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    /// Always fails: the build carries no PJRT backend.
    pub fn cpu() -> Result<Self> {
        Err(anyhow::anyhow!(
            "PJRT runtime unavailable: bfp-cnn was built without the `pjrt` feature \
             (the offline image cannot fetch the `xla` crate)"
        ))
    }

    /// Platform description (for logs).
    pub fn describe(&self) -> String {
        "pjrt-stub (feature disabled)".to_string()
    }

    /// Always fails in the stub build.
    pub fn load_hlo_text(&self, path: &Path) -> Result<CompiledArtifact> {
        Err(anyhow::anyhow!(
            "cannot compile {}: built without the `pjrt` feature",
            path.display()
        ))
    }
}

#[cfg(not(feature = "pjrt"))]
impl CompiledArtifact {
    /// Always fails in the stub build.
    pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        Err(anyhow::anyhow!("execute {}: built without the `pjrt` feature", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration smoke test against a real artifact; skipped (pass) when
    /// `make artifacts` hasn't run or the build carries no PJRT backend.
    #[test]
    fn loads_and_runs_gemm_artifact_when_present() {
        let path = Path::new("artifacts/bfp_gemm_demo.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: {} not built", path.display());
            return;
        }
        let rt = match PjrtRuntime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: {e}");
                return;
            }
        };
        let art = rt.load_hlo_text(path).unwrap();
        // artifact computes bfp_matmul(w: [4,8], i: [8,16]) as 1-tuple
        let w = vec![0.5f32; 32];
        let i = vec![0.25f32; 128];
        let outs = art.run_f32(&[(&w, &[4, 8]), (&i, &[8, 16])]).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].len(), 64);
        // 0.5·0.25·8 = 1.0 per output element (all values exactly representable)
        for v in &outs[0] {
            assert!((v - 1.0).abs() < 1e-3, "{v}");
        }
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn stub_reports_missing_feature() {
        let e = PjrtRuntime::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}
