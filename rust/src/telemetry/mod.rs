//! Online numeric-quality telemetry for the serving stack.
//!
//! The §4 error analysis predicts each precision plan's output SNR from
//! calibration statistics — but calibration traffic is not production
//! traffic. This module closes the loop online: a [`NsrMonitor`] samples
//! served batches at a configurable rate, runs a BFP-vs-f32 probe forward
//! on the sampled image, and folds the observed noise-to-signal ratio
//! into a [`Welford`] streaming accumulator. When the measured SNR falls
//! below the plan's predicted §4 bound (minus a slack margin), the
//! monitor reports a [`Verdict::Violation`] and the QoS lane hot-swaps to
//! the next-safer frontier plan through the existing schedule-swap path
//! ([`crate::nn::prepared::PreparedModel::set_schedule`]).

pub mod monitor;
pub mod welford;

pub use monitor::{MonitorConfig, NsrMonitor, Verdict};
pub use welford::Welford;
