//! Welford's streaming mean/variance — constant-memory accumulation of
//! per-probe NSR observations (no sample vector to grow or re-scan).

/// Streaming mean and variance (Welford's online algorithm). Numerically
/// stable: the incremental update never subtracts two large running sums.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    /// Number of observations folded in so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Unbiased sample variance (0 with fewer than two observations).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Forget everything (used after a lane hot-swap: the old plan's
    /// observations say nothing about the new plan).
    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_two_pass_mean_and_variance() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert_eq!(w.count(), 8);
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert!((w.stddev() - var.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_and_single_are_safe() {
        let mut w = Welford::new();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.variance(), 0.0);
        w.push(3.5);
        assert_eq!(w.mean(), 3.5);
        assert_eq!(w.variance(), 0.0);
    }

    #[test]
    fn reset_forgets() {
        let mut w = Welford::new();
        w.push(1.0);
        w.push(2.0);
        w.reset();
        assert_eq!(w.count(), 0);
        assert_eq!(w.mean(), 0.0);
    }

    /// Stability: a large constant offset must not corrupt the variance
    /// (the classic naive sum-of-squares failure).
    #[test]
    fn stable_under_large_offset() {
        let mut w = Welford::new();
        for x in [1e9 + 4.0, 1e9 + 7.0, 1e9 + 13.0, 1e9 + 16.0] {
            w.push(x);
        }
        assert!((w.variance() - 30.0).abs() < 1e-3, "variance {}", w.variance());
    }
}
