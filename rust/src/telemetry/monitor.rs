//! Streaming NSR/logit-drift monitor: measured-vs-predicted quality for
//! one serving lane.
//!
//! Probing is sampled — every [`MonitorConfig::sample_every`]-th batch
//! runs one extra f32 reference forward on a single image and compares it
//! against the lane's (already computed) BFP output. The per-probe
//! noise-to-signal ratio accumulates in a [`Welford`] stream; once enough
//! probes are in, [`NsrMonitor::verdict`] compares the running measured
//! SNR against the plan's predicted §4 bound minus a slack margin (the
//! surrogate is deliberately a bound, so a few dB of model-vs-reality gap
//! is expected and tolerated).

use super::welford::Welford;

/// Sampling and judgement knobs for a lane monitor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MonitorConfig {
    /// Probe every Nth served batch (0 disables probing entirely).
    pub sample_every: u64,
    /// Probes required before the monitor will judge the lane — a single
    /// unlucky image must not trigger a swap.
    pub min_probes: u64,
    /// Slack below the predicted bound (dB) before a violation fires.
    pub margin_db: f64,
    /// Probes of sustained health required before a demoted lane may
    /// walk back toward its frontier plan (0 disables re-promotion).
    /// Deliberately longer than `min_probes`: demotion is a safety
    /// action, re-promotion an optimization.
    pub promote_min_probes: u64,
    /// Hysteresis: the measured SNR must clear the *target* rung's
    /// predicted bound by this many dB before re-promotion. Together
    /// with `margin_db` the two margins straddle the bound, so a lane
    /// sitting near it holds position instead of flapping.
    pub promote_margin_db: f64,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        Self {
            sample_every: 8,
            min_probes: 4,
            margin_db: 3.0,
            promote_min_probes: 16,
            promote_margin_db: 6.0,
        }
    }
}

/// The monitor's judgement of a lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Measured SNR respects the predicted bound (within the margin), or
    /// the lane carries no finite bound to check against.
    Healthy,
    /// Not enough probes accumulated to judge.
    Warming,
    /// Measured SNR fell below `bound − margin`: the plan is noisier in
    /// production than the §4 analysis predicted — hot-swap to the
    /// next-safer plan.
    Violation,
}

/// Per-lane streaming NSR monitor.
///
/// Owned by exactly one serving thread (in the multi-worker QoS router,
/// the lane's executor): probing, judging and the hot-swap it triggers
/// all happen on that thread, between batches — the monitor needs no
/// internal synchronization.
#[derive(Debug, Clone, Default)]
pub struct NsrMonitor {
    cfg: MonitorConfig,
    batches: u64,
    probes: u64,
    /// Rotates the in-batch probe position across sampled batches —
    /// always probing a batch's first (most-urgent-deadline) image would
    /// bias the measured NSR toward one slice of the traffic.
    probe_cursor: u64,
    /// Linear (not dB) per-probe NSR — averaging in linear space weights
    /// noisy outliers correctly; the dB view is derived on read.
    nsr: Welford,
}

impl NsrMonitor {
    pub fn new(cfg: MonitorConfig) -> Self {
        Self { cfg, ..Self::default() }
    }

    pub fn config(&self) -> &MonitorConfig {
        &self.cfg
    }

    /// Count one served batch; returns true when this batch should be
    /// probed (the caller then runs the f32 reference forward and calls
    /// [`NsrMonitor::record_probe`]).
    pub fn tick_batch(&mut self) -> bool {
        if self.cfg.sample_every == 0 {
            return false;
        }
        self.batches += 1;
        self.batches % self.cfg.sample_every == 0
    }

    /// [`NsrMonitor::tick_batch`] plus probe placement: for a sampled
    /// batch of `batch_len` images, returns the in-batch index to probe.
    /// The position rotates across sampled batches (EDF pops batches in
    /// deadline order, so index 0 is always the most urgent request —
    /// pinning the probe there would sample only one slice of the
    /// traffic and bias the measured NSR).
    pub fn tick_batch_probe(&mut self, batch_len: usize) -> Option<usize> {
        if batch_len == 0 || !self.tick_batch() {
            return None;
        }
        let idx = (self.probe_cursor % batch_len as u64) as usize;
        self.probe_cursor += 1;
        Some(idx)
    }

    /// Fold in one probe: `reference` is the f32 forward of the sampled
    /// image, `quantized` the lane's BFP output for the same image.
    /// Returns this probe's SNR in dB.
    pub fn record_probe(&mut self, reference: &[f32], quantized: &[f32]) -> f64 {
        assert_eq!(reference.len(), quantized.len(), "probe output shapes differ");
        let (mut sig, mut err) = (0f64, 0f64);
        for (&a, &b) in reference.iter().zip(quantized) {
            sig += (a as f64) * (a as f64);
            err += ((b - a) as f64) * ((b - a) as f64);
        }
        let nsr = if sig > 0.0 {
            err / sig
        } else if err > 0.0 {
            f64::INFINITY
        } else {
            0.0
        };
        self.probes += 1;
        self.nsr.push(nsr);
        crate::analysis::snr_db(sig, err)
    }

    /// Batches seen (probed or not).
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Probes folded in since the last reset.
    pub fn probes(&self) -> u64 {
        self.probes
    }

    /// Running measured SNR in dB (−10·log₁₀ of the mean linear NSR);
    /// +∞ before any probe or when no noise has been observed.
    pub fn measured_snr_db(&self) -> f64 {
        if self.probes == 0 {
            return f64::INFINITY;
        }
        let mean = self.nsr.mean();
        if mean <= 0.0 {
            f64::INFINITY
        } else {
            -10.0 * mean.log10()
        }
    }

    /// Judge the lane against its plan's predicted SNR bound (dB). A NaN
    /// or non-finite bound means the lane is unmonitored → always healthy.
    pub fn verdict(&self, predicted_bound_db: f64) -> Verdict {
        if !predicted_bound_db.is_finite() || self.cfg.sample_every == 0 {
            return Verdict::Healthy;
        }
        if self.probes < self.cfg.min_probes {
            return Verdict::Warming;
        }
        if self.measured_snr_db() < predicted_bound_db - self.cfg.margin_db {
            Verdict::Violation
        } else {
            Verdict::Healthy
        }
    }

    /// The inverse judgement of [`NsrMonitor::verdict`]: may the lane
    /// walk one rung back toward its frontier plan? True only after a
    /// sustained healthy window — at least `promote_min_probes` probes
    /// accumulated since the last swap (a violation swaps and resets the
    /// window, so the streak is violation-free by construction) — whose
    /// measured SNR clears the *target* rung's predicted bound plus the
    /// promotion hysteresis margin. A lane demoted for cause therefore
    /// needs both time and headroom before it earns its way back.
    pub fn promotion_ready(&self, target_bound_db: f64) -> bool {
        if !target_bound_db.is_finite()
            || self.cfg.sample_every == 0
            || self.cfg.promote_min_probes == 0
        {
            return false;
        }
        self.probes >= self.cfg.promote_min_probes
            && self.measured_snr_db() >= target_bound_db + self.cfg.promote_margin_db
    }

    /// Forget accumulated probes (after a hot-swap: the observations
    /// describe the plan that was just retired). Batch count is kept so
    /// sampling cadence continues.
    pub fn reset_probes(&mut self) {
        self.probes = 0;
        self.nsr.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test shorthand: the demotion knobs, promotion left at defaults.
    fn cfg(sample_every: u64, min_probes: u64, margin_db: f64) -> MonitorConfig {
        MonitorConfig { sample_every, min_probes, margin_db, ..MonitorConfig::default() }
    }

    #[test]
    fn samples_every_nth_batch() {
        let mut m = NsrMonitor::new(cfg(3, 1, 0.0));
        let probed: Vec<bool> = (0..9).map(|_| m.tick_batch()).collect();
        assert_eq!(probed, vec![false, false, true, false, false, true, false, false, true]);
        assert_eq!(m.batches(), 9);
    }

    /// The probe position must rotate across sampled batches and cover
    /// every in-batch index, not pin itself to the most-urgent slot 0.
    #[test]
    fn probe_index_rotates_and_covers_the_batch() {
        let mut m = NsrMonitor::new(cfg(1, 1, 0.0));
        let picked: Vec<usize> = (0..6).filter_map(|_| m.tick_batch_probe(3)).collect();
        assert_eq!(picked, vec![0, 1, 2, 0, 1, 2], "cursor must cycle the batch positions");
        // shrinking batches stay in range; the cursor keeps advancing
        for len in [2usize, 1, 4, 1] {
            let idx = m.tick_batch_probe(len).expect("sample_every=1 probes every batch");
            assert!(idx < len, "probe index {idx} out of range for batch of {len}");
        }
    }

    /// Rotation respects the sampling cadence: unsampled batches advance
    /// the batch counter but not the probe cursor.
    #[test]
    fn probe_rotation_only_advances_on_sampled_batches() {
        let mut m = NsrMonitor::new(cfg(2, 1, 0.0));
        let picked: Vec<Option<usize>> = (0..6).map(|_| m.tick_batch_probe(4)).collect();
        assert_eq!(picked, vec![None, Some(0), None, Some(1), None, Some(2)]);
        assert_eq!(m.batches(), 6);
        // empty batches never probe (and must not divide by zero)
        assert_eq!(m.tick_batch_probe(0), None);
    }

    #[test]
    fn disabled_sampling_never_probes_and_stays_healthy() {
        let mut m = NsrMonitor::new(cfg(0, 0, 0.0));
        assert!(!m.tick_batch());
        assert_eq!(m.verdict(100.0), Verdict::Healthy);
    }

    #[test]
    fn probe_snr_matches_hand_computation() {
        let mut m = NsrMonitor::new(cfg(1, 1, 0.0));
        // signal energy 100, error energy 1 → SNR 20 dB
        let snr = m.record_probe(&[10.0, 0.0], &[10.0, 1.0]);
        assert!((snr - 20.0).abs() < 1e-9, "snr {snr}");
        assert!((m.measured_snr_db() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn verdict_respects_margin_and_warmup() {
        let mut m = NsrMonitor::new(cfg(1, 2, 3.0));
        m.record_probe(&[10.0, 0.0], &[10.0, 1.0]); // 20 dB
        assert_eq!(m.verdict(30.0), Verdict::Warming, "one probe is not evidence");
        m.record_probe(&[10.0, 0.0], &[10.0, 1.0]); // still 20 dB
        // bound 22 dB, margin 3 → tolerated down to 19 dB
        assert_eq!(m.verdict(22.0), Verdict::Healthy);
        // bound 30 dB → 20 dB measured is a clear violation
        assert_eq!(m.verdict(30.0), Verdict::Violation);
        // an unmonitored lane (NaN bound) never violates
        assert_eq!(m.verdict(f64::NAN), Verdict::Healthy);
    }

    #[test]
    fn reset_probes_restarts_judgement() {
        let mut m = NsrMonitor::new(cfg(1, 1, 0.0));
        m.record_probe(&[1.0], &[2.0]); // 0 dB
        assert_eq!(m.verdict(10.0), Verdict::Violation);
        m.reset_probes();
        assert_eq!(m.probes(), 0);
        assert_eq!(m.verdict(10.0), Verdict::Warming);
        assert!(m.measured_snr_db().is_infinite());
    }

    #[test]
    fn mean_is_linear_not_db() {
        let mut m = NsrMonitor::new(cfg(1, 1, 0.0));
        m.record_probe(&[10.0], &[10.0]); // zero noise → NSR 0
        m.record_probe(&[10.0], &[11.0]); // NSR 0.01 → 20 dB
        // mean linear NSR 0.005 → ≈23.01 dB, NOT the dB-average (∞+20)/2
        assert!((m.measured_snr_db() - 23.0103).abs() < 1e-3, "{}", m.measured_snr_db());
    }

    /// Re-promotion needs the full sustained window AND the hysteresis
    /// headroom above the target rung's bound — either alone is not
    /// enough, and the guards (NaN target, disabled sampling, disabled
    /// promotion) always say no.
    #[test]
    fn promotion_needs_window_and_hysteresis() {
        let mut m = NsrMonitor::new(MonitorConfig {
            sample_every: 1,
            min_probes: 1,
            margin_db: 0.0,
            promote_min_probes: 3,
            promote_margin_db: 6.0,
        });
        // each probe measures 20 dB
        m.record_probe(&[10.0, 0.0], &[10.0, 1.0]);
        m.record_probe(&[10.0, 0.0], &[10.0, 1.0]);
        assert!(!m.promotion_ready(10.0), "2 probes < promote_min_probes");
        m.record_probe(&[10.0, 0.0], &[10.0, 1.0]);
        // window met: 20 dB clears 10 + 6 but not 15 + 6
        assert!(m.promotion_ready(10.0));
        assert!(!m.promotion_ready(15.0), "hysteresis margin must gate");
        // a healthy-but-tight lane (bound just met) must hold position
        assert!(!m.promotion_ready(19.0));
        assert_eq!(m.verdict(19.0), Verdict::Healthy, "no-flap zone: healthy yet unpromotable");
        // guards
        assert!(!m.promotion_ready(f64::NAN));
        assert!(!m.promotion_ready(f64::INFINITY));
        // the swap that follows a violation restarts the window
        m.reset_probes();
        assert!(!m.promotion_ready(10.0));
        // promotion disabled entirely
        let mut off = NsrMonitor::new(MonitorConfig {
            sample_every: 1,
            min_probes: 1,
            margin_db: 0.0,
            promote_min_probes: 0,
            promote_margin_db: 0.0,
        });
        for _ in 0..8 {
            off.record_probe(&[10.0, 0.0], &[10.0, 1.0]);
        }
        assert!(!off.promotion_ready(0.0));
    }
}
