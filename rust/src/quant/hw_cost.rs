//! FPGA resource cost model for the §3.1 hardware argument.
//!
//! The paper motivates BFP with Virtex-7 690T datapoints: a 32-bit
//! fixed-point adder costs 1 DSP slice at 300 MHz, while a 16-bit
//! 4-stage-pipelined floating-point adder costs 2 DSPs + 117 LUTs at
//! 219 MHz. This module generalises those anchors into a coarse
//! per-operator cost model so the accelerator-level saving of the BFP
//! data flow (Figure 2) can be tabulated for any word width — the kind
//! of estimate §4's NSR model is meant to be paired with.
//!
//! The model is deliberately simple (linear DSP/LUT scaling between
//! anchor points); its purpose is ranking formats, not gate-accurate
//! synthesis.

/// Resource estimate for one arithmetic operator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCost {
    pub dsp: f64,
    pub lut: f64,
    pub fmax_mhz: f64,
}

/// Fixed-point adder of `bits` width (anchor: 32-bit = 1 DSP @ 300 MHz).
pub fn fixed_adder(bits: u32) -> OpCost {
    OpCost { dsp: (bits as f64 / 32.0).min(1.0).max(0.25), lut: 0.0, fmax_mhz: 300.0 }
}

/// Fixed-point multiplier of `a`×`b` bits: DSP48 handles 18×25; count the
/// DSP tiles needed by decomposition.
pub fn fixed_multiplier(a_bits: u32, b_bits: u32) -> OpCost {
    let tiles_a = (a_bits as f64 / 18.0).ceil();
    let tiles_b = (b_bits as f64 / 25.0).ceil();
    OpCost { dsp: tiles_a * tiles_b, lut: 0.0, fmax_mhz: 300.0 }
}

/// Floating-point adder (anchor: fp16 = 2 DSP + 117 LUT @ 219 MHz;
/// fp32 scales to ~2 DSP + ~230 LUT per vendor IP tables).
pub fn float_adder(bits: u32) -> OpCost {
    let scale = bits as f64 / 16.0;
    OpCost { dsp: 2.0, lut: 117.0 * scale, fmax_mhz: 219.0 }
}

/// Floating-point multiplier (vendor IP: fp16 ≈ 1 DSP + ~80 LUT; fp32 ≈
/// 3 DSP + ~150 LUT).
pub fn float_multiplier(bits: u32) -> OpCost {
    let scale = bits as f64 / 16.0;
    OpCost { dsp: (1.0 + 2.0 * (scale - 1.0)).max(1.0), lut: 80.0 * scale, fmax_mhz: 230.0 }
}

/// Cost of one MAC lane in the Figure 2 BFP engine at mantissa widths
/// `l_w`/`l_i` for inner dimension `k`: a fixed multiplier of the §3.4
/// product width plus a fixed adder of the accumulator width.
pub fn bfp_mac(l_w: u32, l_i: u32, k: usize) -> OpCost {
    let plan = crate::quant::widths::WidthPlan::plan(k, l_w, l_i);
    let mul = fixed_multiplier(l_w, l_i);
    let add = fixed_adder(plan.accumulator_bits);
    OpCost { dsp: mul.dsp + add.dsp, lut: mul.lut + add.lut, fmax_mhz: mul.fmax_mhz.min(add.fmax_mhz) }
}

/// Cost of one MAC lane in an fp32 engine (multiplier + adder).
pub fn float_mac(bits: u32) -> OpCost {
    let m = float_multiplier(bits);
    let a = float_adder(bits);
    OpCost { dsp: m.dsp + a.dsp, lut: m.lut + a.lut, fmax_mhz: m.fmax_mhz.min(a.fmax_mhz) }
}

/// DSP-count advantage of the 8-bit BFP MAC over the fp32 MAC — the
/// §3.1 headline, as a single ratio (≈ effective MACs per DSP per clock,
/// normalised by fmax).
pub fn bfp_vs_float_dsp_ratio(l_w: u32, l_i: u32, k: usize, float_bits: u32) -> f64 {
    let b = bfp_mac(l_w, l_i, k);
    let f = float_mac(float_bits);
    (f.dsp * f.fmax_mhz.recip()) / (b.dsp * b.fmax_mhz.recip())
}

/// Off-chip storage/traffic bits one conv GEMM `W_{M×K}·I_{K×N}` moves
/// under the Table 1 model (mantissas incl. sign plus amortised block
/// exponents). This is the per-layer cost the mixed-precision planner
/// ([`crate::autotune`]) minimises when it trades mantissa bits between
/// layers.
pub fn layer_traffic_bits(
    m: usize,
    k: usize,
    n: usize,
    l_w: u32,
    l_i: u32,
    scheme: crate::bfp::PartitionScheme,
    l_e: u32,
) -> f64 {
    let c = scheme.cost(m, k, n, l_w, l_i, l_e);
    (c.total_bits_w + c.total_bits_i) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchor_points() {
        let fixed32 = fixed_adder(32);
        assert_eq!(fixed32.dsp, 1.0);
        assert_eq!(fixed32.fmax_mhz, 300.0);
        let fp16 = float_adder(16);
        assert_eq!(fp16.dsp, 2.0);
        assert_eq!(fp16.lut, 117.0);
        assert_eq!(fp16.fmax_mhz, 219.0);
    }

    #[test]
    fn bfp8_mac_is_single_dsp_class() {
        // 8×8 mantissa product fits one DSP48 tile; accumulator add ≤ 1.
        let c = bfp_mac(8, 8, 4608);
        assert!(c.dsp <= 2.0, "{c:?}");
    }

    #[test]
    fn bfp_beats_float_substantially() {
        let r = bfp_vs_float_dsp_ratio(8, 8, 4608, 32);
        assert!(r > 1.5, "expected a clear DSP advantage, got {r}");
    }

    #[test]
    fn traffic_grows_with_width_and_tracks_table1() {
        use crate::bfp::PartitionScheme;
        let (m, k, n) = (64usize, 9usize, 50176usize);
        let t8 = layer_traffic_bits(m, k, n, 8, 8, PartitionScheme::Eq4, 8);
        let t6 = layer_traffic_bits(m, k, n, 6, 6, PartitionScheme::Eq4, 8);
        assert!(t6 < t8, "{t6} vs {t8}");
        // mantissa term dominates: 8-bit total ≈ 8·(MK + KN)
        let mantissa = 8.0 * ((m * k + k * n) as f64);
        assert!((t8 - mantissa).abs() / mantissa < 0.02, "{t8} vs {mantissa}");
    }

    #[test]
    fn wider_mantissas_cost_more_dsp() {
        let c8 = bfp_mac(8, 8, 1024);
        let c16 = bfp_mac(16, 16, 1024);
        // 16×16 still decomposes into one 18×25 tile; 19+ would not.
        assert!(c16.dsp >= c8.dsp);
        let c20 = bfp_mac(20, 20, 1024);
        assert!(c20.dsp > c16.dsp, "{c20:?} vs {c16:?}");
    }
}
