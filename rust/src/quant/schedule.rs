//! Per-layer BFP precision schedules.
//!
//! A [`LayerSchedule`] maps conv/dense layer names to [`BfpConfig`]s with
//! a uniform fallback, so the executor stack can run *mixed-precision*
//! networks: the sensitive early layers keep wide mantissas while the
//! error-tolerant deep layers shed bits. Schedules are produced by the
//! [`crate::autotune`] planner (as part of a `PrecisionPlan`) and consumed
//! by [`crate::nn::exec::BfpExec`] and
//! [`crate::coordinator::engine::ExecMode::Mixed`].

use super::BfpConfig;
use std::collections::HashMap;

/// A per-layer precision assignment: named overrides over a default
/// [`BfpConfig`]. Layers not named run at the default.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerSchedule {
    default: BfpConfig,
    overrides: HashMap<String, BfpConfig>,
}

impl LayerSchedule {
    /// A schedule that runs every layer at `cfg` (equivalent to the
    /// classic uniform `ExecMode::Bfp`).
    pub fn uniform(cfg: BfpConfig) -> Self {
        Self { default: cfg, overrides: HashMap::new() }
    }

    /// Build from `(layer, config)` pairs over a default.
    pub fn from_pairs<I, S>(default: BfpConfig, pairs: I) -> Self
    where
        I: IntoIterator<Item = (S, BfpConfig)>,
        S: Into<String>,
    {
        let overrides = pairs.into_iter().map(|(n, c)| (n.into(), c)).collect();
        Self { default, overrides }
    }

    /// Override one layer's config (builder form).
    pub fn with_layer(mut self, layer: impl Into<String>, cfg: BfpConfig) -> Self {
        self.set(layer, cfg);
        self
    }

    /// Override one layer's config.
    pub fn set(&mut self, layer: impl Into<String>, cfg: BfpConfig) {
        self.overrides.insert(layer.into(), cfg);
    }

    /// The config a named layer runs at.
    pub fn for_layer(&self, layer: &str) -> BfpConfig {
        self.overrides.get(layer).copied().unwrap_or(self.default)
    }

    /// The fallback config for layers without an override.
    pub fn default_config(&self) -> BfpConfig {
        self.default
    }

    /// Named overrides (unordered).
    pub fn overrides(&self) -> &HashMap<String, BfpConfig> {
        &self.overrides
    }

    /// True when no layer deviates from the default.
    pub fn is_uniform(&self) -> bool {
        self.overrides.values().all(|c| *c == self.default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_falls_through() {
        let s = LayerSchedule::uniform(BfpConfig::new(8, 8));
        assert_eq!(s.for_layer("conv1"), BfpConfig::new(8, 8));
        assert!(s.is_uniform());
    }

    #[test]
    fn overrides_take_precedence() {
        let s = LayerSchedule::uniform(BfpConfig::new(8, 8))
            .with_layer("conv1", BfpConfig::new(9, 10))
            .with_layer("conv3", BfpConfig::new(5, 6));
        assert_eq!(s.for_layer("conv1"), BfpConfig::new(9, 10));
        assert_eq!(s.for_layer("conv2"), BfpConfig::new(8, 8));
        assert_eq!(s.for_layer("conv3"), BfpConfig::new(5, 6));
        assert!(!s.is_uniform());
    }

    #[test]
    fn from_pairs_round_trips() {
        let s = LayerSchedule::from_pairs(
            BfpConfig::new(8, 8),
            vec![("a", BfpConfig::new(4, 4)), ("b", BfpConfig::new(6, 7))],
        );
        assert_eq!(s.for_layer("a"), BfpConfig::new(4, 4));
        assert_eq!(s.for_layer("b"), BfpConfig::new(6, 7));
        assert_eq!(s.overrides().len(), 2);
    }

    #[test]
    fn redundant_overrides_still_uniform() {
        let s = LayerSchedule::uniform(BfpConfig::new(8, 8)).with_layer("x", BfpConfig::new(8, 8));
        assert!(s.is_uniform());
    }
}
