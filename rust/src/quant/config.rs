//! End-to-end BFP configuration: the knobs swept in the paper's Table 3.

use crate::bfp::{BfpFormat, PartitionScheme, Rounding};

/// A full BFP configuration for running a network: weight / input mantissa
/// widths (incl. sign, Table 3 convention), rounding mode and partition
/// scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BfpConfig {
    /// `L_W`: weight mantissa bits including sign.
    pub l_w: u32,
    /// `L_I`: activation mantissa bits including sign.
    pub l_i: u32,
    /// Rounding of out-shifted bits (paper default: round-off).
    pub rounding: Rounding,
    /// Matrix partition scheme (paper default: eq. 4).
    pub scheme: PartitionScheme,
}

impl BfpConfig {
    /// The paper's recommended configuration: 8-bit mantissas, round-off,
    /// eq. (4) partitioning.
    pub fn paper_default() -> Self {
        Self::new(8, 8)
    }

    /// Config with the given widths and paper-default scheme/rounding.
    pub fn new(l_w: u32, l_i: u32) -> Self {
        Self { l_w, l_i, rounding: Rounding::Nearest, scheme: PartitionScheme::Eq4 }
    }

    /// Same widths, different scheme.
    pub fn with_scheme(mut self, scheme: PartitionScheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Same widths, truncating rounding (ablation).
    pub fn with_truncation(mut self) -> Self {
        self.rounding = Rounding::Truncate;
        self
    }

    /// Same widths, arbitrary rounding mode (ablation).
    pub fn with_rounding(mut self, rounding: Rounding) -> Self {
        self.rounding = rounding;
        self
    }

    /// Weight-matrix format.
    pub fn w_format(&self) -> BfpFormat {
        BfpFormat { total_bits: self.l_w, rounding: self.rounding }
    }

    /// Input-matrix format.
    pub fn i_format(&self) -> BfpFormat {
        BfpFormat { total_bits: self.l_i, rounding: self.rounding }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_is_8bit_eq4_rounding() {
        let c = BfpConfig::paper_default();
        assert_eq!((c.l_w, c.l_i), (8, 8));
        assert_eq!(c.scheme, PartitionScheme::Eq4);
        assert_eq!(c.rounding, Rounding::Nearest);
    }

    #[test]
    fn builders() {
        let c = BfpConfig::new(6, 9).with_scheme(PartitionScheme::Eq2).with_truncation();
        assert_eq!(c.w_format().total_bits, 6);
        assert_eq!(c.i_format().total_bits, 9);
        assert_eq!(c.scheme, PartitionScheme::Eq2);
        assert_eq!(c.rounding, Rounding::Truncate);
        assert_eq!(c.w_format().rounding, Rounding::Truncate);
    }
}
