//! Quantization configuration, the §3.4 bit-width planner, baseline
//! numeric formats (§2 related work) and the §3.1 FPGA cost model.

pub mod baselines;
pub mod config;
pub mod hw_cost;
pub mod schedule;
pub mod widths;

pub use config::BfpConfig;
pub use schedule::LayerSchedule;
pub use widths::WidthPlan;
