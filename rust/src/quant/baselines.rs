//! Baseline numeric formats the paper positions BFP against (§2 related
//! work), used by the `ablation_formats` bench:
//!
//! * **Uniform fixed point** — one global Q-format for the whole network
//!   (Page & Mohsenin 2016 style: e.g. Q3.6). Its word width must cover
//!   the union of every layer's dynamic range, which is why Hill et al.
//!   2016 measure GoogLeNet needing ~40 bits.
//! * **Dynamic fixed point** — per-matrix power-of-two scaling chosen
//!   from the data (Mellempudi et al. 2017's cluster scaling with one
//!   cluster): equivalent to BFP eq. (2) with the scale restricted to the
//!   max exponent, i.e. whole-matrix BFP. Included to show the gap that
//!   *block-level* exponent sharing (eq. 4) closes.
//!
//! Both quantizers mirror the BFP API so the same conv/GEMM pipeline can
//! run all formats.

use crate::bfp::format::{exp2i, Rounding};

/// Uniform fixed point Q(int_bits).(frac_bits) with sign, saturating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixedPointFormat {
    /// Integer bits (excluding sign).
    pub int_bits: i32,
    /// Fractional bits.
    pub frac_bits: i32,
}

impl FixedPointFormat {
    pub fn new(int_bits: i32, frac_bits: i32) -> Self {
        assert!(int_bits >= 0 && frac_bits >= 0 && int_bits + frac_bits >= 1);
        Self { int_bits, frac_bits }
    }

    /// Total width including sign.
    pub fn total_bits(&self) -> u32 {
        (1 + self.int_bits + self.frac_bits) as u32
    }

    /// Largest representable magnitude.
    pub fn max_value(&self) -> f32 {
        let max_q = (1i64 << (self.int_bits + self.frac_bits)) - 1;
        max_q as f32 * exp2i(-self.frac_bits)
    }

    /// Quantize one value (round-to-nearest, saturate).
    #[inline]
    pub fn quantize(&self, x: f32, rounding: Rounding) -> f32 {
        let scale = exp2i(self.frac_bits);
        let scaled = x * scale;
        let q = match rounding {
            Rounding::Nearest => scaled.round(),
            Rounding::Truncate => scaled.trunc(),
            Rounding::Stochastic => crate::bfp::format::round_stochastic(scaled),
        };
        let max_q = ((1i64 << (self.int_bits + self.frac_bits)) - 1) as f32;
        q.clamp(-max_q, max_q) * exp2i(-self.frac_bits)
    }

    /// Quantize a slice.
    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<f32> {
        xs.iter().map(|&x| self.quantize(x, Rounding::Nearest)).collect()
    }

    /// The smallest Q-format of `total` bits (incl. sign) that avoids
    /// saturating `max_abs`: spend integer bits on range, rest on
    /// precision — how a designer would pick a global format.
    pub fn for_range(total: u32, max_abs: f32) -> Self {
        assert!(total >= 2);
        let needed_int = if max_abs <= 0.0 {
            0
        } else {
            let e = max_abs.log2().ceil() as i32;
            e.max(0)
        };
        let int_bits = needed_int.min(total as i32 - 1);
        Self { int_bits, frac_bits: total as i32 - 1 - int_bits }
    }
}

/// Dynamic fixed point: per-matrix power-of-two scale from the data max
/// (one "cluster" of Mellempudi et al.) — exactly whole-matrix BFP, so we
/// delegate and keep the name for the ablation's readability.
pub fn dynamic_fixed_quantize(xs: &[f32], total_bits: u32) -> Vec<f32> {
    crate::bfp::dequantize(xs, crate::bfp::BfpFormat::new(total_bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn q3_6_basics() {
        let f = FixedPointFormat::new(3, 6);
        assert_eq!(f.total_bits(), 10);
        assert!((f.max_value() - (2f32.powi(3) - 2f32.powi(-6))).abs() < 1e-6);
        assert_eq!(f.quantize(1.0, Rounding::Nearest), 1.0);
        // step = 1/64
        assert!((f.quantize(0.011, Rounding::Nearest) - 0.015625).abs() < 1e-7);
    }

    #[test]
    fn saturation() {
        let f = FixedPointFormat::new(2, 5);
        assert!((f.quantize(100.0, Rounding::Nearest) - f.max_value()).abs() < 1e-6);
        assert!((f.quantize(-100.0, Rounding::Nearest) + f.max_value()).abs() < 1e-6);
    }

    #[test]
    fn for_range_covers_max() {
        for max in [0.3f32, 1.0, 7.9, 100.0] {
            let f = FixedPointFormat::for_range(8, max);
            assert_eq!(f.total_bits(), 8);
            assert!(f.max_value() >= max * 0.99 || f.int_bits == 7, "max={max} fmt={f:?}");
        }
    }

    #[test]
    fn fixed_point_loses_to_bfp_on_wide_dynamic_range() {
        // Data spanning many octaves: a single global Q-format must
        // either clip or starve precision; BFP adapts per block.
        let mut rng = Rng::new(5);
        let mut xs = rng.normal_vec(4096, 0.01);
        xs.extend(rng.normal_vec(64, 10.0)); // rare large values
        let bits = 8u32;
        let fixed = FixedPointFormat::for_range(bits, xs.iter().fold(0f32, |m, &v| m.max(v.abs())));
        let fq = fixed.quantize_slice(&xs);
        let bq = dynamic_fixed_quantize(&xs, bits);
        let err = |ys: &[f32]| -> f64 {
            xs.iter().zip(ys).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        // dynamic (data-scaled) ≥ static at the same width
        assert!(err(&bq) <= err(&fq) * 1.01, "bfp {} vs fixed {}", err(&bq), err(&fq));
    }

    #[test]
    fn dynamic_fixed_is_whole_matrix_bfp() {
        let xs = [0.5f32, -1.25, 3.0, 0.125];
        assert_eq!(
            dynamic_fixed_quantize(&xs, 8),
            crate::bfp::dequantize(&xs, crate::bfp::BfpFormat::new(8))
        );
    }
}
