//! The §3.4 bit-width planner for the fixed-point MAC datapath.
//!
//! With `L_W`/`L_I` mantissa bits (incl. sign) the product of two aligned
//! mantissas needs `L_W + L_I + 2` bits... the paper states the multiplier
//! must be "no less than `L_W + L_I + 2`" including sign, and the
//! accumulator adds `S = ⌊log2 K⌋` carry bits for a `K`-term sum. These
//! widths guarantee the integer MAC introduces **no** rounding error — the
//! only error in the whole pipeline is the block-formatting quantization.


/// Planned datapath widths for one GEMM shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WidthPlan {
    /// Multiplier output width in bits (incl. sign).
    pub multiplier_bits: u32,
    /// Accumulator width in bits (incl. sign).
    pub accumulator_bits: u32,
    /// Carry allowance `S = ⌊log2 K⌋`.
    pub carry_bits: u32,
    /// Whether a 32-bit integer lane suffices (else 64-bit).
    pub fits_i32: bool,
}

impl WidthPlan {
    /// Plan widths for an inner dimension `K` and mantissa widths
    /// `l_w`/`l_i` (incl. sign).
    pub fn plan(k: usize, l_w: u32, l_i: u32) -> Self {
        assert!(k >= 1);
        let multiplier_bits = l_w + l_i; // §3.4 counts ≥ L_W + L_I + 2 where
                                         // L excludes sign; ours includes both
                                         // signs so the product of two
                                         // (L-1)-magnitude values fits in
                                         // (l_w-1)+(l_i-1)+1 = l_w+l_i-1 bits;
                                         // we keep one headroom bit.
        let carry_bits = usize::BITS - 1 - k.leading_zeros(); // ⌊log2 K⌋
        let accumulator_bits = multiplier_bits + carry_bits + 1;
        Self { multiplier_bits, accumulator_bits, carry_bits, fits_i32: accumulator_bits <= 32 }
    }

    /// Worst-case accumulator magnitude for this plan:
    /// `K · (2^(l_w-1)-1) · (2^(l_i-1)-1)` — used by the saturation
    /// proptest.
    pub fn worst_case_acc(k: usize, l_w: u32, l_i: u32) -> i128 {
        let wm = (1i128 << (l_w - 1)) - 1;
        let im = (1i128 << (l_i - 1)) - 1;
        k as i128 * wm * im
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_widths() {
        // 8-bit × 8-bit, K=4608 (VGG conv with 512 ch): ⌊log2 4608⌋ = 12.
        let p = WidthPlan::plan(4608, 8, 8);
        assert_eq!(p.carry_bits, 12);
        assert_eq!(p.multiplier_bits, 16);
        assert_eq!(p.accumulator_bits, 29);
        assert!(p.fits_i32);
    }

    #[test]
    fn wide_mantissas_need_i64() {
        let p = WidthPlan::plan(5000, 16, 16);
        assert!(!p.fits_i32);
    }

    #[test]
    fn worst_case_fits_planned_width() {
        for &(k, lw, li) in &[(9usize, 8u32, 8u32), (4608, 8, 8), (27, 6, 9), (1, 4, 4), (100_000, 10, 10)] {
            let p = WidthPlan::plan(k, lw, li);
            let worst = WidthPlan::worst_case_acc(k, lw, li);
            let capacity = (1i128 << (p.accumulator_bits - 1)) - 1;
            assert!(worst <= capacity, "k={k} lw={lw} li={li}: {worst} > {capacity}");
        }
    }

    #[test]
    fn k_equals_one_no_carry() {
        let p = WidthPlan::plan(1, 8, 8);
        assert_eq!(p.carry_bits, 0);
    }
}
