//! VGG-16 (Simonyan & Zisserman 2014): 13 conv layers in 5 stages, each
//! followed by ReLU, max-pool after every stage, then 3 FC layers.
//!
//! Layer names match the paper's Table 4 rows (`conv1_1` … `conv5_3`,
//! `pool1` … `pool5`) so the error-analysis harness can line up directly.

use super::init;
use super::zoo::Model;
use crate::data::rng::Rng;
use crate::nn::Block;

/// VGG-16 stage plan: (stage, convs, channels).
pub const STAGES: [(usize, usize, usize); 5] =
    [(1, 2, 64), (2, 2, 128), (3, 3, 256), (4, 3, 512), (5, 3, 512)];

/// Build VGG-16 for `input` = `[3, s, s]` with synthetic weights.
///
/// `s` must be divisible by 32 (five 2× pools). The FC head adapts to the
/// final spatial size; FC widths are scaled down from 4096 to keep the
/// parameter count laptop-scale while preserving all 13 conv shapes.
pub fn vgg16(input_size: usize, num_classes: usize, seed: u64) -> Model {
    assert_eq!(input_size % 32, 0, "VGG-16 needs input divisible by 32");
    let mut rng = Rng::new(seed ^ 0x7661_6716); // "vgg16"
    let mut blocks = Vec::new();
    let mut in_ch = 3usize;
    for (stage, convs, ch) in STAGES {
        for i in 1..=convs {
            blocks.push(Block::Conv(init::conv2d(
                &format!("conv{stage}_{i}"),
                ch,
                in_ch,
                3,
                3,
                1,
                1,
                &mut rng,
            )));
            blocks.push(Block::ReLU);
            in_ch = ch;
        }
        blocks.push(Block::MaxPool { name: format!("pool{stage}"), k: 2, s: 2, p: 0 });
    }
    let spatial = input_size / 32;
    let fc_in = 512 * spatial * spatial;
    let fc_width = 512; // scaled-down stand-in for 4096 (DESIGN.md §4)
    blocks.push(Block::Flatten);
    blocks.push(Block::Dense(init::dense("fc6", fc_width, fc_in, &mut rng)));
    blocks.push(Block::ReLU);
    blocks.push(Block::Dropout);
    blocks.push(Block::Dense(init::dense("fc7", fc_width, fc_width, &mut rng)));
    blocks.push(Block::ReLU);
    blocks.push(Block::Dropout);
    blocks.push(Block::Dense(init::dense("fc8", num_classes, fc_width, &mut rng)));
    Model {
        name: "vgg16".into(),
        graph: Block::Seq(blocks),
        input_shape: vec![3, input_size, input_size],
        num_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Fp32Exec;
    use crate::tensor::Tensor;

    #[test]
    fn thirteen_convs() {
        let m = vgg16(32, 10, 1);
        assert_eq!(m.graph.conv_count(), 13);
    }

    #[test]
    fn forward_shape_32() {
        let m = vgg16(32, 10, 1);
        let x = Tensor::zeros(&[3, 32, 32]);
        let y = m.graph.execute(x, &mut Fp32Exec);
        assert_eq!(y.shape, vec![10]);
    }

    #[test]
    fn forward_shape_64() {
        let m = vgg16(64, 1000, 2);
        let x = Tensor::from_vec((0..3 * 64 * 64).map(|i| (i as f32 * 0.01).sin()).collect(), &[3, 64, 64]);
        let y = m.graph.execute(x, &mut Fp32Exec);
        assert_eq!(y.shape, vec![1000]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn activations_do_not_explode() {
        // Kaiming init keeps the activation scale stable through 13 layers.
        let m = vgg16(32, 10, 3);
        let x = Tensor::from_vec(crate::data::imagenet_like_batch(1, 32, 5)[0].data.clone(), &[3, 32, 32]);
        let y = m.graph.execute(x, &mut Fp32Exec);
        assert!(y.max_abs() < 1e6, "logits exploded: {}", y.max_abs());
        assert!(y.max_abs() > 1e-6, "logits vanished");
    }
}
