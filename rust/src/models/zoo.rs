//! The model zoo: a uniform handle over the six evaluated networks.

use super::{cifar, googlenet, lenet, resnet, vgg};
use crate::nn::Block;
use std::path::Path;

/// A network ready for inference.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub graph: Block,
    /// `[C, H, W]` expected input shape.
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
}

impl Model {
    /// Order-of-magnitude MAC count for one image through the conv
    /// layers: per conv, `|W| · (H/stride)·(W/stride)` against the
    /// *model input* spatial size (pooling between layers is ignored, so
    /// deep layers over-count — an upper-bound-flavoured estimate).
    /// This feeds the thread pool's small-work guards, which only need
    /// the right order of magnitude: a LeNet image is ~10^6 by this
    /// measure, the toy test models ~10^4.
    pub fn approx_macs_per_image(&self) -> usize {
        let (h, w) = (self.input_shape[1], self.input_shape[2]);
        let mut macs = 0usize;
        self.graph.visit_convs(&mut |c| {
            let s = c.stride.max(1);
            let out_px = ((h / s) * (w / s)).max(1);
            macs = macs.saturating_add(c.weights.data.len().saturating_mul(out_px));
        });
        macs
    }
}

/// Identifiers for every network in Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelId {
    Vgg16,
    Resnet18,
    Resnet50,
    GooglenetLoss1,
    GooglenetLoss2,
    GooglenetLoss3,
    Lenet,
    Cifar10,
}

impl ModelId {
    /// All Table 3 rows in paper order.
    pub fn all() -> [ModelId; 8] {
        [
            ModelId::Vgg16,
            ModelId::GooglenetLoss1,
            ModelId::GooglenetLoss2,
            ModelId::GooglenetLoss3,
            ModelId::Resnet18,
            ModelId::Resnet50,
            ModelId::Lenet,
            ModelId::Cifar10,
        ]
    }

    /// Short name used in reports and CLI.
    pub fn name(&self) -> &'static str {
        match self {
            ModelId::Vgg16 => "vgg16",
            ModelId::Resnet18 => "resnet18",
            ModelId::Resnet50 => "resnet50",
            ModelId::GooglenetLoss1 => "googlenet_loss1",
            ModelId::GooglenetLoss2 => "googlenet_loss2",
            ModelId::GooglenetLoss3 => "googlenet_loss3",
            ModelId::Lenet => "lenet",
            ModelId::Cifar10 => "cifar10",
        }
    }

    /// Instantiate the network. `input_size` applies to the ImageNet-class
    /// models (must be divisible by 32); LeNet / cifar have fixed inputs.
    /// `artifacts` is searched for trained weights for the small nets.
    pub fn build(&self, input_size: usize, seed: u64, artifacts: &Path) -> Model {
        const IMAGENET_CLASSES: usize = 1000;
        match self {
            ModelId::Vgg16 => vgg::vgg16(input_size, IMAGENET_CLASSES, seed),
            ModelId::Resnet18 => resnet::resnet18(input_size, IMAGENET_CLASSES, seed),
            ModelId::Resnet50 => resnet::resnet50(input_size, IMAGENET_CLASSES, seed),
            ModelId::GooglenetLoss1 => googlenet::googlenet(googlenet::Head::Loss1, input_size, IMAGENET_CLASSES, seed),
            ModelId::GooglenetLoss2 => googlenet::googlenet(googlenet::Head::Loss2, input_size, IMAGENET_CLASSES, seed),
            ModelId::GooglenetLoss3 => googlenet::googlenet(googlenet::Head::Loss3, input_size, IMAGENET_CLASSES, seed),
            ModelId::Lenet => lenet::lenet_from_artifacts(artifacts, seed),
            ModelId::Cifar10 => cifar::cifar_from_artifacts(artifacts, seed),
        }
    }

    /// Is this one of the ImageNet-class (synthetic-weight) models?
    pub fn is_imagenet_class(&self) -> bool {
        !matches!(self, ModelId::Lenet | ModelId::Cifar10)
    }

    /// The `L_W`/`L_I` grid the paper sweeps for this model (Table 3).
    pub fn table3_widths(&self) -> Vec<u32> {
        match self {
            ModelId::Lenet => vec![3, 4, 5, 6],
            ModelId::Cifar10 => vec![5, 6, 7, 8],
            _ => vec![6, 7, 8, 9],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_unique() {
        let names: Vec<&str> = ModelId::all().iter().map(|m| m.name()).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }

    #[test]
    fn table3_grids_match_paper() {
        assert_eq!(ModelId::Vgg16.table3_widths(), vec![6, 7, 8, 9]);
        assert_eq!(ModelId::Lenet.table3_widths(), vec![3, 4, 5, 6]);
        assert_eq!(ModelId::Cifar10.table3_widths(), vec![5, 6, 7, 8]);
    }

    #[test]
    fn build_small_models() {
        let m = ModelId::Lenet.build(32, 1, Path::new("artifacts"));
        assert_eq!(m.input_shape, vec![1, 28, 28]);
        let m = ModelId::Cifar10.build(32, 1, Path::new("artifacts"));
        assert_eq!(m.input_shape, vec![3, 32, 32]);
    }
}
