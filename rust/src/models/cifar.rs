//! Small CIFAR-10-class network — the "cifar10" column of Table 3.
//!
//! Architecture (shared with `python/compile/model.py`):
//!
//! ```text
//! conv1: 16×3×3×3  p1 → ReLU → maxpool 2×2
//! conv2: 32×16×3×3 p1 → ReLU → maxpool 2×2
//! conv3: 64×32×3×3 p1 → ReLU → maxpool 2×2
//! fc1:   64×1024 → ReLU
//! fc2:   10×64
//! ```

use super::init;
use super::weights_io::WeightBundle;
use super::zoo::Model;
use crate::data::rng::Rng;
use crate::nn::{Block, Conv2d, Dense};
use std::path::Path;

/// Build the cifar net, from a trained bundle when available.
pub fn cifar_net(weights: Option<&WeightBundle>, seed: u64) -> Model {
    let graph = match weights {
        Some(w) => graph_from_bundle(w).expect("malformed cifar weight bundle"),
        None => synthetic_graph(seed),
    };
    Model { name: "cifar10".into(), graph, input_shape: vec![3, 32, 32], num_classes: 10 }
}

/// Load from `artifacts/` when present, else synthetic.
pub fn cifar_from_artifacts(dir: &Path, seed: u64) -> Model {
    let path = dir.join("cifar_weights.bfpw");
    match WeightBundle::load(&path) {
        Ok(w) => cifar_net(Some(&w), seed),
        Err(_) => cifar_net(None, seed),
    }
}

fn graph_from_bundle(w: &WeightBundle) -> anyhow::Result<Block> {
    Ok(assemble(
        Conv2d::new("conv1", w.tensor("conv1_w")?, w.vec("conv1_b")?, 1, 1),
        Conv2d::new("conv2", w.tensor("conv2_w")?, w.vec("conv2_b")?, 1, 1),
        Conv2d::new("conv3", w.tensor("conv3_w")?, w.vec("conv3_b")?, 1, 1),
        Dense::new("fc1", w.tensor("fc1_w")?, w.vec("fc1_b")?),
        Dense::new("fc2", w.tensor("fc2_w")?, w.vec("fc2_b")?),
    ))
}

fn synthetic_graph(seed: u64) -> Block {
    let mut rng = Rng::new(seed ^ 0xC1FA_0001);
    assemble(
        init::conv2d("conv1", 16, 3, 3, 3, 1, 1, &mut rng),
        init::conv2d("conv2", 32, 16, 3, 3, 1, 1, &mut rng),
        init::conv2d("conv3", 64, 32, 3, 3, 1, 1, &mut rng),
        init::dense("fc1", 64, 1024, &mut rng),
        init::dense("fc2", 10, 64, &mut rng),
    )
}

fn assemble(c1: Conv2d, c2: Conv2d, c3: Conv2d, fc1: Dense, fc2: Dense) -> Block {
    Block::seq(vec![
        Block::Conv(c1),
        Block::ReLU,
        Block::MaxPool { name: "pool1".into(), k: 2, s: 2, p: 0 },
        Block::Conv(c2),
        Block::ReLU,
        Block::MaxPool { name: "pool2".into(), k: 2, s: 2, p: 0 },
        Block::Conv(c3),
        Block::ReLU,
        Block::MaxPool { name: "pool3".into(), k: 2, s: 2, p: 0 },
        Block::Flatten,
        Block::Dense(fc1),
        Block::ReLU,
        Block::Dense(fc2),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Fp32Exec;
    use crate::tensor::Tensor;

    #[test]
    fn forward_shape() {
        let m = cifar_net(None, 1);
        let x = Tensor::from_vec((0..3 * 32 * 32).map(|i| (i as f32 * 0.007).sin().abs()).collect(), &[3, 32, 32]);
        let y = m.graph.execute(x, &mut Fp32Exec);
        assert_eq!(y.shape, vec![10]);
    }

    #[test]
    fn three_convs() {
        assert_eq!(cifar_net(None, 1).graph.conv_count(), 3);
    }
}
