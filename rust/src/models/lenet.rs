//! LeNet-style mnist network — the "mnist" column of Table 3.
//!
//! Architecture (shared bit-for-bit with `python/compile/model.py`, which
//! trains it at build time on the procedural digit dataset):
//!
//! ```text
//! conv1: 8×1×5×5  s1 p2 → ReLU → maxpool 2×2
//! conv2: 16×8×5×5 s1 p2 → ReLU → maxpool 2×2
//! fc1:   64×784 → ReLU
//! fc2:   10×64
//! ```

use super::weights_io::WeightBundle;
use super::zoo::Model;
use super::init;
use crate::data::rng::Rng;
use crate::nn::{Block, Conv2d, Dense};
use std::path::Path;

/// Build LeNet. If `weights` is given (the JAX-trained bundle), use it;
/// otherwise fall back to synthetic weights so tests run without
/// artifacts.
pub fn lenet(weights: Option<&WeightBundle>, seed: u64) -> Model {
    let graph = match weights {
        Some(w) => graph_from_bundle(w).expect("malformed lenet weight bundle"),
        None => synthetic_graph(seed),
    };
    Model { name: "lenet".into(), graph, input_shape: vec![1, 28, 28], num_classes: 10 }
}

/// Convenience: load from the default artifact path when present.
pub fn lenet_from_artifacts(dir: &Path, seed: u64) -> Model {
    let path = dir.join("lenet_weights.bfpw");
    match WeightBundle::load(&path) {
        Ok(w) => lenet(Some(&w), seed),
        Err(_) => lenet(None, seed),
    }
}

fn graph_from_bundle(w: &WeightBundle) -> anyhow::Result<Block> {
    Ok(assemble(
        Conv2d::new("conv1", w.tensor("conv1_w")?, w.vec("conv1_b")?, 1, 2),
        Conv2d::new("conv2", w.tensor("conv2_w")?, w.vec("conv2_b")?, 1, 2),
        Dense::new("fc1", w.tensor("fc1_w")?, w.vec("fc1_b")?),
        Dense::new("fc2", w.tensor("fc2_w")?, w.vec("fc2_b")?),
    ))
}

fn synthetic_graph(seed: u64) -> Block {
    let mut rng = Rng::new(seed ^ 0x1e4e_7000);
    assemble(
        init::conv2d("conv1", 8, 1, 5, 5, 1, 2, &mut rng),
        init::conv2d("conv2", 16, 8, 5, 5, 1, 2, &mut rng),
        init::dense("fc1", 64, 784, &mut rng),
        init::dense("fc2", 10, 64, &mut rng),
    )
}

fn assemble(conv1: Conv2d, conv2: Conv2d, fc1: Dense, fc2: Dense) -> Block {
    Block::seq(vec![
        Block::Conv(conv1),
        Block::ReLU,
        Block::MaxPool { name: "pool1".into(), k: 2, s: 2, p: 0 },
        Block::Conv(conv2),
        Block::ReLU,
        Block::MaxPool { name: "pool2".into(), k: 2, s: 2, p: 0 },
        Block::Flatten,
        Block::Dense(fc1),
        Block::ReLU,
        Block::Dense(fc2),
    ])
}

/// Shape sanity used by both the loader and the tests.
pub fn expected_shapes() -> Vec<(&'static str, Vec<usize>)> {
    vec![
        ("conv1_w", vec![8, 1, 5, 5]),
        ("conv2_w", vec![16, 8, 5, 5]),
        ("fc1_w", vec![64, 784]),
        ("fc2_w", vec![10, 64]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Fp32Exec;
    use crate::tensor::Tensor;

    #[test]
    fn synthetic_forward_shape() {
        let m = lenet(None, 1);
        let x = Tensor::from_vec((0..784).map(|i| (i as f32 * 0.011).sin().abs()).collect(), &[1, 28, 28]);
        let y = m.graph.execute(x, &mut Fp32Exec);
        assert_eq!(y.shape, vec![10]);
    }

    #[test]
    fn conv_count_is_two() {
        assert_eq!(lenet(None, 1).graph.conv_count(), 2);
    }

    #[test]
    fn fallback_when_artifacts_missing() {
        let m = lenet_from_artifacts(Path::new("/nonexistent"), 3);
        assert_eq!(m.name, "lenet");
        assert_eq!(m.graph.conv_count(), 2);
    }
}
