//! Trained-weight interchange with the JAX build-time trainer.
//!
//! `python/compile/train_small.py` dumps `artifacts/<model>_weights.bfpw`,
//! a deliberately trivial line-oriented text format (the offline build has
//! no JSON dependency and the files are a few MB, written once):
//!
//! ```text
//! bfpw-v1
//! param <name> <ndim> <d0> <d1> ...
//! <v0> <v1> ... <vN-1>          # one whitespace-separated line of f32
//! param ...
//! ```

use crate::tensor::Tensor;
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// One serialized parameter tensor.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// A named bundle of parameter tensors.
#[derive(Debug, Clone, Default)]
pub struct WeightBundle {
    pub params: HashMap<String, ParamEntry>,
}

impl WeightBundle {
    /// Parse a bundle from `.bfpw` text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty() && !l.starts_with('#'));
        ensure!(lines.next() == Some("bfpw-v1"), "missing bfpw-v1 header");
        let mut params = HashMap::new();
        while let Some(header) = lines.next() {
            let mut parts = header.split_whitespace();
            ensure!(parts.next() == Some("param"), "expected 'param' line, got: {header}");
            let name = parts.next().context("param line missing name")?.to_string();
            let ndim: usize = parts.next().context("param line missing ndim")?.parse()?;
            let shape: Vec<usize> =
                parts.take(ndim).map(|s| s.parse::<usize>()).collect::<std::result::Result<_, _>>()?;
            ensure!(shape.len() == ndim, "param {name}: expected {ndim} dims");
            let count: usize = shape.iter().product();
            let data_line = lines.next().with_context(|| format!("param {name}: missing data line"))?;
            let data: Vec<f32> = data_line
                .split_whitespace()
                .map(|s| s.parse::<f32>())
                .collect::<std::result::Result<_, _>>()
                .with_context(|| format!("param {name}: bad f32"))?;
            ensure!(data.len() == count, "param {name}: {} values != shape {:?}", data.len(), shape);
            if params.insert(name.clone(), ParamEntry { shape, data }).is_some() {
                bail!("duplicate parameter {name}");
            }
        }
        Ok(Self { params })
    }

    /// Load a bundle from a `.bfpw` file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Fetch a tensor by name.
    pub fn tensor(&self, name: &str) -> Result<Tensor> {
        let p = self.params.get(name).with_context(|| format!("missing parameter {name}"))?;
        Ok(Tensor::from_vec(p.data.clone(), &p.shape))
    }

    /// Fetch a flat vector by name.
    pub fn vec(&self, name: &str) -> Result<Vec<f32>> {
        Ok(self.params.get(name).with_context(|| format!("missing parameter {name}"))?.data.clone())
    }

    /// The default artifact path for a model name.
    pub fn artifact_path(dir: &Path, model: &str) -> std::path::PathBuf {
        dir.join(format!("{model}_weights.bfpw"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "bfpw-v1\nparam conv1_w 4 2 1 2 2\n1 2 3 4 5 6 7 8\nparam conv1_b 1 2\n0.5 -0.5\n";

    #[test]
    fn parse_roundtrip() {
        let b = WeightBundle::parse(SAMPLE).unwrap();
        let t = b.tensor("conv1_w").unwrap();
        assert_eq!(t.shape, vec![2, 1, 2, 2]);
        assert_eq!(t.data[3], 4.0);
        assert_eq!(b.vec("conv1_b").unwrap(), vec![0.5, -0.5]);
        assert!(b.tensor("nope").is_err());
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let text = format!("# trained by jax\n\n{SAMPLE}");
        assert!(WeightBundle::parse(&text).is_ok());
    }

    #[test]
    fn rejects_bad_header() {
        assert!(WeightBundle::parse("nope\n").is_err());
    }

    #[test]
    fn rejects_count_mismatch() {
        let text = "bfpw-v1\nparam w 1 3\n1 2\n";
        assert!(WeightBundle::parse(text).is_err());
    }

    #[test]
    fn rejects_duplicates() {
        let text = "bfpw-v1\nparam w 1 1\n1\nparam w 1 1\n2\n";
        assert!(WeightBundle::parse(text).is_err());
    }

    #[test]
    fn load_from_file() {
        let dir = std::env::temp_dir().join("bfp_cnn_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bfpw");
        std::fs::write(&path, SAMPLE).unwrap();
        let b = WeightBundle::load(&path).unwrap();
        assert_eq!(b.params.len(), 2);
    }
}
