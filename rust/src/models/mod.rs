//! Structural definitions of the six networks evaluated in the paper.
//!
//! Per DESIGN.md §4, the large networks carry deterministic synthetic
//! "pretrained" weights (Kaiming-scaled Laplacian — see [`init`]); the two
//! small networks (LeNet / CIFAR-net) load genuinely trained weights from
//! `artifacts/` when present (trained at build time by
//! `python/compile/train_small.py`) and fall back to synthetic weights so
//! `cargo test` works without the artifacts.
//!
//! Spatial resolution of the ImageNet-class models is configurable
//! (default 64×64 instead of 224×224) — the architecture, depth and layer
//! shapes that drive BFP quantization error are preserved while keeping
//! the sweeps laptop-scale; see DESIGN.md §4.

pub mod cifar;
pub mod googlenet;
pub mod init;
pub mod lenet;
pub mod resnet;
pub mod vgg;
pub mod weights_io;
pub mod zoo;

pub use zoo::{Model, ModelId};
