//! GoogLeNet v1 (Szegedy et al. 2015) with its three classifier heads.
//!
//! The paper's Table 3 reports top-1 separately for `loss1` (aux head
//! after inception 4a), `loss2` (aux head after 4d) and `loss3` (the main
//! head). We expose each head as its own [`Model`] sharing the same seed,
//! so the trunk weights are identical across heads.

use super::init;
use super::zoo::Model;
use crate::data::rng::Rng;
use crate::nn::Block;

/// Inception module: 1×1 / 1×1→3×3 / 1×1→5×5 / pool→1×1 branches.
#[allow(clippy::too_many_arguments)]
fn inception(name: &str, in_ch: usize, c1: usize, c3r: usize, c3: usize, c5r: usize, c5: usize, pp: usize, rng: &mut Rng) -> Block {
    Block::Concat(vec![
        Block::Seq(vec![
            Block::Conv(init::conv2d(&format!("{name}_1x1"), c1, in_ch, 1, 1, 1, 0, rng)),
            Block::ReLU,
        ]),
        Block::Seq(vec![
            Block::Conv(init::conv2d(&format!("{name}_3x3r"), c3r, in_ch, 1, 1, 1, 0, rng)),
            Block::ReLU,
            Block::Conv(init::conv2d(&format!("{name}_3x3"), c3, c3r, 3, 3, 1, 1, rng)),
            Block::ReLU,
        ]),
        Block::Seq(vec![
            Block::Conv(init::conv2d(&format!("{name}_5x5r"), c5r, in_ch, 1, 1, 1, 0, rng)),
            Block::ReLU,
            Block::Conv(init::conv2d(&format!("{name}_5x5"), c5, c5r, 5, 5, 1, 2, rng)),
            Block::ReLU,
        ]),
        Block::Seq(vec![
            Block::MaxPool { name: format!("{name}_pool"), k: 3, s: 1, p: 1 },
            Block::Conv(init::conv2d(&format!("{name}_poolproj"), pp, in_ch, 1, 1, 1, 0, rng)),
            Block::ReLU,
        ]),
    ])
}

/// Which classifier head to attach.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Head {
    /// Aux classifier branching after inception 4a.
    Loss1,
    /// Aux classifier branching after inception 4d.
    Loss2,
    /// The main head after inception 5b.
    Loss3,
}

/// The canonical GoogLeNet inception parameter table
/// (name, c1, c3r, c3, c5r, c5, pool-proj, output channels).
const INCEPTIONS: [(&str, usize, usize, usize, usize, usize, usize); 9] = [
    ("3a", 64, 96, 128, 16, 32, 32),
    ("3b", 128, 128, 192, 32, 96, 64),
    ("4a", 192, 96, 208, 16, 48, 64),
    ("4b", 160, 112, 224, 24, 64, 64),
    ("4c", 128, 128, 256, 24, 64, 64),
    ("4d", 112, 144, 288, 32, 64, 64),
    ("4e", 256, 160, 320, 32, 128, 128),
    ("5a", 256, 160, 320, 32, 128, 128),
    ("5b", 384, 192, 384, 48, 128, 128),
];

fn aux_head(name: &str, in_ch: usize, num_classes: usize, rng: &mut Rng) -> Vec<Block> {
    vec![
        // 5×5 avg pool stride 3 in the original; adapt kernel to the small
        // spatial size by using global-avg + 1×1-equivalent dense stack.
        Block::AvgPool { name: format!("{name}_pool"), k: 3, s: 2, p: 1 },
        Block::Conv(init::conv2d(&format!("{name}_conv"), 128, in_ch, 1, 1, 1, 0, rng)),
        Block::ReLU,
        Block::GlobalAvgPool,
        Block::Dense(init::dense(&format!("{name}_fc1"), 256, 128, rng)),
        Block::ReLU,
        Block::Dense(init::dense(&format!("{name}_fc2"), num_classes, 256, rng)),
    ]
}

/// Build GoogLeNet with the requested head for `[3, s, s]` inputs
/// (s divisible by 32).
pub fn googlenet(head: Head, input_size: usize, num_classes: usize, seed: u64) -> Model {
    assert_eq!(input_size % 32, 0);
    let mut rng = Rng::new(seed ^ 0x6007_1e47);
    let mut blocks = vec![
        Block::Conv(init::conv2d("conv1", 64, 3, 7, 7, 2, 3, &mut rng)),
        Block::ReLU,
        Block::MaxPool { name: "pool1".into(), k: 3, s: 2, p: 1 },
        Block::Conv(init::conv2d("conv2_reduce", 64, 64, 1, 1, 1, 0, &mut rng)),
        Block::ReLU,
        Block::Conv(init::conv2d("conv2", 192, 64, 3, 3, 1, 1, &mut rng)),
        Block::ReLU,
        Block::MaxPool { name: "pool2".into(), k: 3, s: 2, p: 1 },
    ];
    let mut in_ch = 192usize;
    for (iname, c1, c3r, c3, c5r, c5, pp) in INCEPTIONS {
        blocks.push(inception(&format!("inception_{iname}"), in_ch, c1, c3r, c3, c5r, c5, pp, &mut rng));
        in_ch = c1 + c3 + c5 + pp;
        // The trunk pools after 3b and 4e; heads branch after 4a / 4d.
        if iname == "3b" || iname == "4e" {
            blocks.push(Block::MaxPool { name: format!("pool_{iname}"), k: 3, s: 2, p: 1 });
        }
        if iname == "4a" && head == Head::Loss1 {
            blocks.extend(aux_head("loss1", in_ch, num_classes, &mut rng));
            return finish(head, blocks, input_size, num_classes);
        }
        if iname == "4d" && head == Head::Loss2 {
            blocks.extend(aux_head("loss2", in_ch, num_classes, &mut rng));
            return finish(head, blocks, input_size, num_classes);
        }
    }
    blocks.push(Block::GlobalAvgPool);
    blocks.push(Block::Dropout);
    blocks.push(Block::Dense(init::dense("loss3_fc", num_classes, in_ch, &mut rng)));
    finish(head, blocks, input_size, num_classes)
}

fn finish(head: Head, blocks: Vec<Block>, input_size: usize, num_classes: usize) -> Model {
    let name = match head {
        Head::Loss1 => "googlenet_loss1",
        Head::Loss2 => "googlenet_loss2",
        Head::Loss3 => "googlenet_loss3",
    };
    Model {
        name: name.into(),
        graph: Block::Seq(blocks),
        input_shape: vec![3, input_size, input_size],
        num_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Fp32Exec;
    use crate::tensor::Tensor;

    fn input(s: usize) -> Tensor {
        Tensor::from_vec((0..3 * s * s).map(|i| (i as f32 * 0.013).sin() * 50.0).collect(), &[3, s, s])
    }

    #[test]
    fn loss3_forward_shape() {
        let m = googlenet(Head::Loss3, 32, 10, 1);
        let y = m.graph.execute(input(32), &mut Fp32Exec);
        assert_eq!(y.shape, vec![10]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn loss1_branches_early() {
        let m1 = googlenet(Head::Loss1, 32, 10, 1);
        let m3 = googlenet(Head::Loss3, 32, 10, 1);
        assert!(m1.graph.conv_count() < m3.graph.conv_count());
        let y = m1.graph.execute(input(32), &mut Fp32Exec);
        assert_eq!(y.shape, vec![10]);
    }

    #[test]
    fn loss2_between() {
        let m1 = googlenet(Head::Loss1, 32, 10, 1);
        let m2 = googlenet(Head::Loss2, 32, 10, 1);
        let m3 = googlenet(Head::Loss3, 32, 10, 1);
        assert!(m1.graph.conv_count() < m2.graph.conv_count());
        assert!(m2.graph.conv_count() < m3.graph.conv_count());
        let y = m2.graph.execute(input(32), &mut Fp32Exec);
        assert_eq!(y.shape, vec![10]);
    }

    #[test]
    fn trunk_weights_shared_across_heads() {
        // Same seed ⇒ the common prefix must have identical weights.
        let m1 = googlenet(Head::Loss1, 32, 10, 42);
        let m3 = googlenet(Head::Loss3, 32, 10, 42);
        let mut w1 = Vec::new();
        m1.graph.visit_convs(&mut |c| w1.push((c.name.clone(), c.weights.data.clone())));
        let mut w3 = Vec::new();
        m3.graph.visit_convs(&mut |c| w3.push((c.name.clone(), c.weights.data.clone())));
        // every trunk conv in m1 (up to 4a) must appear identically in m3
        for (name, data) in w1.iter().filter(|(n, _)| !n.starts_with("loss")) {
            let found = w3.iter().find(|(n, _)| n == name).expect(name);
            assert_eq!(&found.1, data, "trunk weight {name} differs between heads");
        }
    }

    #[test]
    fn full_conv_count() {
        // stem 3 + 9 inceptions × 6 convs = 57
        let m = googlenet(Head::Loss3, 32, 10, 1);
        assert_eq!(m.graph.conv_count(), 57);
    }
}
