//! Synthetic "pretrained" weight initialisation.
//!
//! Trained CNN weights are well modelled by zero-mean heavy-tailed
//! distributions at Kaiming scale (`std = gain·sqrt(2/fan_in)`); we use a
//! Laplacian with matching variance, which reproduces the block max /
//! RMS ratio that determines BFP quantization error (DESIGN.md §4).

use crate::data::rng::Rng;
use crate::nn::{BatchNorm, Conv2d, Dense};
use crate::tensor::Tensor;

/// Laplacian weights at Kaiming scale for a conv `[m, c, kh, kw]`.
pub fn conv2d(name: &str, m: usize, c: usize, kh: usize, kw: usize, stride: usize, padding: usize, rng: &mut Rng) -> Conv2d {
    let fan_in = (c * kh * kw) as f64;
    let std = (2.0 / fan_in).sqrt();
    let scale = std / std::f64::consts::SQRT_2; // Laplacian var = 2·scale²
    let w = rng.laplacian_vec(m * c * kh * kw, scale);
    // small biases, as in trained nets
    let b = rng.normal_vec(m, std * 0.1);
    Conv2d::new(name, Tensor::from_vec(w, &[m, c, kh, kw]), b, stride, padding)
}

/// Laplacian weights at Kaiming scale for a dense `[out, inp]`.
pub fn dense(name: &str, out: usize, inp: usize, rng: &mut Rng) -> Dense {
    let std = (2.0 / inp as f64).sqrt();
    let scale = std / std::f64::consts::SQRT_2;
    let w = rng.laplacian_vec(out * inp, scale);
    let b = rng.normal_vec(out, std * 0.1);
    Dense::new(name, Tensor::from_vec(w, &[out, inp]), b)
}

/// Batch-norm with mildly jittered scale/shift (inference-folded stats of
/// a trained net are near identity but not exactly).
pub fn batch_norm(name: &str, c: usize, rng: &mut Rng) -> BatchNorm {
    let scale = (0..c).map(|_| (1.0 + rng.normal() * 0.15) as f32).collect();
    let shift = (0..c).map(|_| (rng.normal() * 0.1) as f32).collect();
    BatchNorm::new(name, scale, shift)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_kaiming_scale() {
        let mut rng = Rng::new(1);
        let c = conv2d("c", 64, 32, 3, 3, 1, 1, &mut rng);
        let fan_in: f64 = 32.0 * 9.0;
        let expect_std = (2.0 / fan_in).sqrt();
        let n = c.weights.len() as f64;
        let var = c.weights.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / n;
        assert!((var.sqrt() - expect_std).abs() / expect_std < 0.1, "std {} vs {}", var.sqrt(), expect_std);
    }

    #[test]
    fn weights_heavy_tailed() {
        // Laplacian kurtosis ≈ 6 > Gaussian 3; check excess kurtosis > 1
        let mut rng = Rng::new(2);
        let c = conv2d("c", 128, 64, 3, 3, 1, 1, &mut rng);
        let n = c.weights.len() as f64;
        let var = c.weights.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / n;
        let m4 = c.weights.data.iter().map(|&x| (x as f64).powi(4)).sum::<f64>() / n;
        let kurt = m4 / (var * var);
        assert!(kurt > 4.0, "kurtosis {kurt} not heavy-tailed");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = conv2d("c", 8, 4, 3, 3, 1, 1, &mut Rng::new(7));
        let b = conv2d("c", 8, 4, 3, 3, 1, 1, &mut Rng::new(7));
        assert_eq!(a.weights.data, b.weights.data);
    }

    #[test]
    fn bn_near_identity() {
        let bn = batch_norm("bn", 256, &mut Rng::new(3));
        let mean_scale: f32 = bn.scale.iter().sum::<f32>() / 256.0;
        assert!((mean_scale - 1.0).abs() < 0.1);
    }
}
