//! ResNet-18 and ResNet-50 (He et al. 2016).
//!
//! ResNet-18: stem + 4 stages of 2 BasicBlocks (3×3+3×3).
//! ResNet-50: stem + stages of [3,4,6,3] Bottlenecks (1×1, 3×3, 1×1 ×4).
//! Projection (1×1 stride-2) shortcuts at stage boundaries; BN after every
//! conv (inference-folded affine).

use super::init;
use super::zoo::Model;
use crate::data::rng::Rng;
use crate::nn::Block;

fn conv_bn(name: &str, m: usize, c: usize, k: usize, stride: usize, pad: usize, rng: &mut Rng) -> Vec<Block> {
    vec![
        Block::Conv(init::conv2d(name, m, c, k, k, stride, pad, rng)),
        Block::BatchNorm(init::batch_norm(&format!("{name}_bn"), m, rng)),
    ]
}

/// BasicBlock: 3×3 → BN → ReLU → 3×3 → BN, plus shortcut, then ReLU.
fn basic_block(name: &str, in_ch: usize, out_ch: usize, stride: usize, rng: &mut Rng) -> Block {
    let mut main = conv_bn(&format!("{name}_conv1"), out_ch, in_ch, 3, stride, 1, rng);
    main.push(Block::ReLU);
    main.extend(conv_bn(&format!("{name}_conv2"), out_ch, out_ch, 3, 1, 1, rng));
    let shortcut = if stride != 1 || in_ch != out_ch {
        Block::Seq(conv_bn(&format!("{name}_proj"), out_ch, in_ch, 1, stride, 0, rng))
    } else {
        Block::Seq(vec![])
    };
    Block::Seq(vec![
        Block::Residual { main: Box::new(Block::Seq(main)), shortcut: Box::new(shortcut) },
        Block::ReLU,
    ])
}

/// Bottleneck: 1×1 reduce → 3×3 → 1×1 expand (×4), plus shortcut, ReLU.
fn bottleneck(name: &str, in_ch: usize, mid_ch: usize, stride: usize, rng: &mut Rng) -> Block {
    let out_ch = mid_ch * 4;
    let mut main = conv_bn(&format!("{name}_conv1"), mid_ch, in_ch, 1, 1, 0, rng);
    main.push(Block::ReLU);
    main.extend(conv_bn(&format!("{name}_conv2"), mid_ch, mid_ch, 3, stride, 1, rng));
    main.push(Block::ReLU);
    main.extend(conv_bn(&format!("{name}_conv3"), out_ch, mid_ch, 1, 1, 0, rng));
    let shortcut = if stride != 1 || in_ch != out_ch {
        Block::Seq(conv_bn(&format!("{name}_proj"), out_ch, in_ch, 1, stride, 0, rng))
    } else {
        Block::Seq(vec![])
    };
    Block::Seq(vec![
        Block::Residual { main: Box::new(Block::Seq(main)), shortcut: Box::new(shortcut) },
        Block::ReLU,
    ])
}

fn stem(rng: &mut Rng) -> Vec<Block> {
    let mut blocks = conv_bn("conv1", 64, 3, 7, 2, 3, rng);
    blocks.push(Block::ReLU);
    blocks.push(Block::MaxPool { name: "pool1".into(), k: 3, s: 2, p: 1 });
    blocks
}

/// ResNet-18 for `[3, s, s]` inputs (s divisible by 32).
pub fn resnet18(input_size: usize, num_classes: usize, seed: u64) -> Model {
    assert_eq!(input_size % 32, 0);
    let mut rng = Rng::new(seed ^ 0x4E54_1218);
    let mut blocks = stem(&mut rng);
    let stage_ch = [64usize, 128, 256, 512];
    let mut in_ch = 64;
    for (si, &ch) in stage_ch.iter().enumerate() {
        for b in 0..2 {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            blocks.push(basic_block(&format!("res{}_{}", si + 2, b), in_ch, ch, stride, &mut rng));
            in_ch = ch;
        }
    }
    blocks.push(Block::GlobalAvgPool);
    blocks.push(Block::Dense(init::dense("fc", num_classes, 512, &mut rng)));
    Model {
        name: "resnet18".into(),
        graph: Block::Seq(blocks),
        input_shape: vec![3, input_size, input_size],
        num_classes,
    }
}

/// ResNet-50 for `[3, s, s]` inputs (s divisible by 32).
pub fn resnet50(input_size: usize, num_classes: usize, seed: u64) -> Model {
    assert_eq!(input_size % 32, 0);
    let mut rng = Rng::new(seed ^ 0x4E54_5050);
    let mut blocks = stem(&mut rng);
    let plan: [(usize, usize); 4] = [(64, 3), (128, 4), (256, 6), (512, 3)];
    let mut in_ch = 64usize;
    for (si, &(mid, count)) in plan.iter().enumerate() {
        for b in 0..count {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            blocks.push(bottleneck(&format!("res{}_{}", si + 2, b), in_ch, mid, stride, &mut rng));
            in_ch = mid * 4;
        }
    }
    blocks.push(Block::GlobalAvgPool);
    blocks.push(Block::Dense(init::dense("fc", num_classes, 2048, &mut rng)));
    Model {
        name: "resnet50".into(),
        graph: Block::Seq(blocks),
        input_shape: vec![3, input_size, input_size],
        num_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Fp32Exec;
    use crate::tensor::Tensor;

    #[test]
    fn resnet18_conv_count() {
        // 1 stem + 8 blocks × 2 convs + 3 projection convs = 20
        let m = resnet18(32, 10, 1);
        assert_eq!(m.graph.conv_count(), 20);
    }

    #[test]
    fn resnet50_conv_count() {
        // 1 stem + 16 bottlenecks × 3 + 4 projections = 53
        let m = resnet50(32, 10, 1);
        assert_eq!(m.graph.conv_count(), 53);
    }

    #[test]
    fn resnet18_forward_shape() {
        let m = resnet18(32, 10, 1);
        let x = Tensor::from_vec((0..3 * 32 * 32).map(|i| (i as f32 * 0.02).sin()).collect(), &[3, 32, 32]);
        let y = m.graph.execute(x, &mut Fp32Exec);
        assert_eq!(y.shape, vec![10]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn resnet50_forward_shape() {
        let m = resnet50(32, 10, 2);
        let x = Tensor::from_vec((0..3 * 32 * 32).map(|i| (i as f32 * 0.03).cos()).collect(), &[3, 32, 32]);
        let y = m.graph.execute(x, &mut Fp32Exec);
        assert_eq!(y.shape, vec![10]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn resnet18_param_count_plausible() {
        // True ResNet-18 has ~11.7M params; ours differs only in the FC head.
        let m = resnet18(32, 10, 1);
        let p = m.graph.param_count();
        assert!((10_000_000..13_000_000).contains(&p), "{p}");
    }
}
