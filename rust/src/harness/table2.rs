//! Table 2 — the impact of block size: whole-matrix `W` (eq. 2) vs
//! per-row `W` (eq. 4) at 8-bit mantissas on VGG-16.
//!
//! The paper reports absolute ILSVRC-12 top-1/top-5; with synthetic
//! weights the comparable quantities are the *drops* relative to the FP32
//! reference (Table 2's floating-point row). Expect eq. (4) to sit well
//! above eq. (2) because whole-matrix blocks tie every filter to the
//! globally largest filter's exponent.

use super::report::Table;
use super::table3::{drop_for, prepare_model_and_set};
use crate::bfp::PartitionScheme;
use crate::models::ModelId;
use crate::quant::BfpConfig;
use std::path::Path;

/// Run Table 2: eq. (2) vs eq. (4) vs floating point on VGG-16.
///
/// Besides the paper's accuracy rows (at L=8 and, for sensitivity on the
/// easier 10-class readout task, L=6) we report the measured **logit
/// SNR** of each scheme — the mechanism-level quantity that separates
/// the schemes even when both clear the accuracy bar.
pub fn run(input_size: usize, n_images: usize, seed: u64, artifacts: &Path) -> Table {
    let id = ModelId::Vgg16;
    let (model, set) = prepare_model_and_set(id, input_size, n_images, seed, artifacts);
    let fp_logits = crate::coordinator::engine::forward_batch_ref(
        &model,
        &set.images,
        crate::coordinator::engine::ExecMode::Fp32,
    );
    let logit_snr = |cfg: BfpConfig| -> f64 {
        let out = crate::coordinator::engine::forward_batch_ref(
            &model,
            &set.images,
            crate::coordinator::engine::ExecMode::Bfp(cfg),
        );
        let mut sig = 0f64;
        let mut err = 0f64;
        for (f, b) in fp_logits.iter().zip(&out) {
            sig += f.energy();
            err += f.data.iter().zip(&b.data).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>();
        }
        10.0 * (sig / err.max(1e-300)).log10()
    };
    let mut t = Table::new(
        format!("Table 2 — block-size impact, {} ({} images)", model.name, n_images),
        &["method", "top-1 accuracy", "top-1 drop vs fp32", "logit SNR (dB)"],
    );
    for bits in [8u32, 6] {
        let cfg = BfpConfig::new(bits, bits);
        for (label, scheme) in [("Equation(2)", PartitionScheme::Eq2), ("Equation(4)", PartitionScheme::Eq4)] {
            let c = cfg.with_scheme(scheme);
            let d = drop_for(&model, &set, c);
            t.row(vec![
                format!("{label} L={bits}"),
                format!("{:.4}", set.fp_acc - d),
                format!("{d:.4}"),
                format!("{:.2}", logit_snr(c)),
            ]);
        }
    }
    t.row(vec!["Floating point".into(), format!("{:.4}", set.fp_acc), "0.0000".into(), "inf".into()]);
    t
}

/// The eq2/eq4 drops as raw numbers (for benches and EXPERIMENTS.md).
pub fn drops(input_size: usize, n_images: usize, seed: u64, artifacts: &Path) -> (f64, f64) {
    let id = ModelId::Vgg16;
    let (model, set) = prepare_model_and_set(id, input_size, n_images, seed, artifacts);
    let cfg = BfpConfig::new(8, 8);
    (
        drop_for(&model, &set, cfg.with_scheme(PartitionScheme::Eq2)),
        drop_for(&model, &set, cfg.with_scheme(PartitionScheme::Eq4)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quantization-noise ordering must hold even on tiny eval sets: the
    /// per-row scheme's *output NSR* is never worse than whole-matrix.
    /// (Accuracy flips on a few images can tie, so assert on NSR.)
    #[test]
    fn eq4_output_noise_no_worse_than_eq2() {
        use crate::coordinator::engine::{forward_batch_ref, ExecMode};
        let id = ModelId::Vgg16;
        let model = id.build(32, 1, Path::new("artifacts"));
        let images = crate::data::imagenet_like_batch(2, 32, 5);
        let fp = forward_batch_ref(&model, &images, ExecMode::Fp32);
        let nsr = |scheme| {
            let cfg = BfpConfig::new(8, 8).with_scheme(scheme);
            let out = forward_batch_ref(&model, &images, ExecMode::Bfp(cfg));
            let mut sig = 0f64;
            let mut err = 0f64;
            for (f, b) in fp.iter().zip(&out) {
                sig += f.energy();
                err += f.data.iter().zip(&b.data).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>();
            }
            err / sig
        };
        let n2 = nsr(PartitionScheme::Eq2);
        let n4 = nsr(PartitionScheme::Eq4);
        assert!(n4 <= n2 * 1.05, "eq4 NSR {n4} vs eq2 {n2}");
    }
}
