//! Human- and machine-readable readout for the networked loadgen
//! scenarios: one table row per (scenario, tenant) run, plus a
//! hand-rolled `NET_*.json` mirror for CI artifacts (no serde in the
//! offline image).

use super::report::{json_escape, ms, Table};
use crate::net::loadgen::RunStats;

/// One row per run: client-side counters and intended-send latency.
pub fn scenario_table(rows: &[RunStats]) -> Table {
    let mut t = Table::new(
        "loadgen scenarios (latency from intended send, ms)",
        &[
            "scenario", "tenant", "mode", "sent", "ok", "errors", "timeouts", "retries",
            "quota-dg", "dg", "ddl-miss", "p50", "p99", "max", "rps",
        ],
    );
    for r in rows {
        t.row(vec![
            r.name.clone(),
            r.tenant.clone(),
            r.mode.to_string(),
            r.sent.to_string(),
            r.ok.to_string(),
            r.errors.to_string(),
            r.timeouts.to_string(),
            r.retries.to_string(),
            r.quota_downgraded.to_string(),
            r.downgraded.to_string(),
            r.deadline_missed.to_string(),
            ms(r.latency_p(50.0)),
            ms(r.latency_p(99.0)),
            ms(r.latency_us.max() as f64 / 1000.0),
            format!("{:.1}", r.throughput()),
        ]);
    }
    t
}

/// Print the scenario table.
pub fn print(rows: &[RunStats]) {
    scenario_table(rows).print();
}

/// One machine-readable entry (a line inside `"runs": [...]`).
fn json_entry(r: &RunStats) -> String {
    format!(
        "{{\"scenario\":\"{}\",\"tenant\":\"{}\",\"mode\":\"{}\",\"sent\":{},\"ok\":{},\
         \"errors\":{},\"timeouts\":{},\"retries\":{},\"quota_downgraded\":{},\
         \"downgraded\":{},\"deadline_missed\":{},\
         \"p50_ms\":{:.3},\"p99_ms\":{:.3},\"max_ms\":{:.3},\"rps\":{:.2},\"wall_s\":{:.3}}}",
        json_escape(&r.name),
        json_escape(&r.tenant),
        r.mode,
        r.sent,
        r.ok,
        r.errors,
        r.timeouts,
        r.retries,
        r.quota_downgraded,
        r.downgraded,
        r.deadline_missed,
        r.latency_p(50.0),
        r.latency_p(99.0),
        r.latency_us.max() as f64 / 1000.0,
        r.throughput(),
        r.wall.as_secs_f64(),
    )
}

/// Write `NET_<tag>.json` for the CI artifact trail, mirroring the
/// `BENCH_*.json` shape (a `"runs"` array of one-line objects).
pub fn write_json(path: &std::path::Path, tag: &str, rows: &[RunStats]) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"suite\": \"{}\",\n", json_escape(tag)));
    s.push_str("  \"runs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str("    ");
        s.push_str(&json_entry(r));
        s.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::LogHistogram;
    use std::time::Duration;

    fn stats(name: &str, tenant: &str) -> RunStats {
        let mut latency_us = LogHistogram::default();
        for v in [900, 1100, 5000] {
            latency_us.record(v);
        }
        RunStats {
            name: name.to_string(),
            tenant: tenant.to_string(),
            mode: "open-loop",
            sent: 3,
            ok: 2,
            errors: 1,
            timeouts: 2,
            retries: 1,
            downgraded: 1,
            quota_downgraded: 1,
            deadline_missed: 0,
            latency_us,
            wall: Duration::from_secs(2),
        }
    }

    #[test]
    fn table_renders_one_row_per_run() {
        let rows = vec![stats("spike", "spike"), stats("tenant-mix", "vip")];
        let s = scenario_table(&rows).render();
        assert!(s.contains("spike"));
        assert!(s.contains("vip"));
        assert!(s.contains("open-loop"));
        assert_eq!(s.lines().count(), 3 + rows.len(), "title + header + rule + rows");
    }

    #[test]
    fn json_has_every_run_and_valid_scaffolding() {
        let rows = vec![stats("slow-client", "sloth \"lazy\"")];
        let path = std::env::temp_dir().join("bfp_cnn_net_report_test.json");
        write_json(&path, "scenarios_t1_single", &rows).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(body.contains("\"suite\": \"scenarios_t1_single\""));
        assert!(body.contains("\\\"lazy\\\""), "tenant names must be escaped: {body}");
        assert!(body.contains("\"sent\":3"));
        assert!(body.contains("\"timeouts\":2"));
        assert!(body.contains("\"retries\":1"));
        assert!(body.contains("\"rps\":1.00"));
        assert!(body.trim_end().ends_with('}'));
    }
}
