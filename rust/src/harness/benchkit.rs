//! Minimal benchmark harness (criterion is not available in the offline
//! image): warmup + timed repetitions with mean / min / throughput
//! reporting. Used by every `rust/benches/*.rs` target via `cargo bench`.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub min: Duration,
    /// Optional work units per iteration (flops, bytes, elements…).
    pub work_per_iter: Option<f64>,
    pub work_unit: &'static str,
    /// Pool thread count in effect while this bench ran (benches pinned
    /// via `pool::with_threads` record their pinned value, not the
    /// ambient one — essential for reading the scaling sweeps).
    pub threads: usize,
}

impl BenchResult {
    /// Work units per second at the mean time.
    pub fn rate(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / self.mean.as_secs_f64())
    }

    /// Mean nanoseconds per iteration.
    pub fn mean_ns(&self) -> f64 {
        self.mean.as_secs_f64() * 1e9
    }

    /// Minimum nanoseconds per iteration.
    pub fn min_ns(&self) -> f64 {
        self.min.as_secs_f64() * 1e9
    }

    /// One machine-readable `BENCH_*.json` entry.
    pub fn json_entry(&self) -> String {
        let work = match self.work_per_iter {
            Some(w) => format!("{w:.1}"),
            None => "null".to_string(),
        };
        let rate = match self.rate() {
            Some(r) => format!("{r:.3}"),
            None => "null".to_string(),
        };
        format!(
            "{{\"name\":\"{}\",\"iters\":{},\"threads\":{},\"mean_ns\":{:.1},\"min_ns\":{:.1},\"work_per_iter\":{},\"work_unit\":\"{}\",\"rate_per_s\":{}}}",
            json_escape(&self.name),
            self.iters,
            self.threads,
            self.mean_ns(),
            self.min_ns(),
            work,
            json_escape(self.work_unit),
            rate
        )
    }

    /// One aligned report line.
    pub fn line(&self) -> String {
        let rate = match self.rate() {
            Some(r) if r >= 1e9 => format!("  {:8.2} G{}/s", r / 1e9, self.work_unit),
            Some(r) if r >= 1e6 => format!("  {:8.2} M{}/s", r / 1e6, self.work_unit),
            Some(r) => format!("  {:8.2} {}/s", r, self.work_unit),
            None => String::new(),
        };
        format!(
            "{:<44} {:>10.3} ms/iter (min {:>8.3} ms, {} iters){}",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.iters,
            rate
        )
    }
}

/// Configuration for a bench run.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub min_time: Duration,
    pub max_iters: u32,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { min_time: Duration::from_millis(400), max_iters: 1000 }
    }
}

/// Time `f` until `opts.min_time` has elapsed (≥3 iterations), printing
/// and returning the measurement. A `std::hint::black_box` inside `f` is
/// the caller's responsibility.
pub fn bench<F: FnMut()>(name: &str, work_per_iter: Option<f64>, work_unit: &'static str, mut f: F) -> BenchResult {
    bench_opts(name, work_per_iter, work_unit, BenchOpts::default(), &mut f)
}

/// [`bench`] with explicit options.
pub fn bench_opts<F: FnMut()>(
    name: &str,
    work_per_iter: Option<f64>,
    work_unit: &'static str,
    opts: BenchOpts,
    f: &mut F,
) -> BenchResult {
    // warmup
    f();
    let mut iters = 0u32;
    let mut total = Duration::ZERO;
    let mut min = Duration::MAX;
    while (total < opts.min_time || iters < 3) && iters < opts.max_iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed();
        total += dt;
        min = min.min(dt);
        iters += 1;
    }
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters,
        min,
        work_per_iter,
        work_unit,
        threads: crate::runtime::pool::num_threads(),
    };
    println!("{}", result.line());
    result
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n### {title}");
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Write a machine-readable `BENCH_<tag>.json` so the perf trajectory
/// (EXPERIMENTS.md §Perf) can be tracked across PRs and checked in CI.
/// Hand-rolled JSON — the offline image has no serde.
pub fn write_json(path: &std::path::Path, tag: &str, results: &[BenchResult]) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(tag)));
    // ambient pool width; per-entry "threads" records each bench's pin
    s.push_str(&format!("  \"default_threads\": {},\n", crate::runtime::pool::num_threads()));
    s.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        s.push_str("    ");
        s.push_str(&r.json_entry());
        s.push_str(if i + 1 < results.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_opts(
            "spin",
            Some(1000.0),
            "op",
            BenchOpts { min_time: Duration::from_millis(5), max_iters: 50 },
            &mut || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
        );
        assert!(r.iters >= 3);
        assert!(r.mean > Duration::ZERO);
        assert!(r.rate().unwrap() > 0.0);
    }

    #[test]
    fn json_round_trips_structure() {
        let r = BenchResult {
            name: "gemm \"fast\"".to_string(),
            iters: 7,
            mean: Duration::from_micros(1500),
            min: Duration::from_micros(1200),
            work_per_iter: Some(1e6),
            work_unit: "MAC",
            threads: 3,
        };
        let entry = r.json_entry();
        assert!(entry.contains("\\\"fast\\\""), "quotes must be escaped: {entry}");
        assert!(entry.contains("\"iters\":7"));
        assert!(entry.contains("\"threads\":3"));
        assert!(entry.contains("\"work_unit\":\"MAC\""));
        let path = std::env::temp_dir().join("bfp_cnn_benchkit_test.json");
        write_json(&path, "unit-test", &[r]).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("{\n"));
        assert!(body.contains("\"bench\": \"unit-test\""));
        assert!(body.trim_end().ends_with('}'));
        let _ = std::fs::remove_file(&path);
    }
}
