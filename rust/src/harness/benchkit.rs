//! Minimal benchmark harness (criterion is not available in the offline
//! image): warmup + timed repetitions with mean / min / throughput
//! reporting. Used by every `rust/benches/*.rs` target via `cargo bench`.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub min: Duration,
    /// Optional work units per iteration (flops, bytes, elements…).
    pub work_per_iter: Option<f64>,
    pub work_unit: &'static str,
}

impl BenchResult {
    /// Work units per second at the mean time.
    pub fn rate(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / self.mean.as_secs_f64())
    }

    /// One aligned report line.
    pub fn line(&self) -> String {
        let rate = match self.rate() {
            Some(r) if r >= 1e9 => format!("  {:8.2} G{}/s", r / 1e9, self.work_unit),
            Some(r) if r >= 1e6 => format!("  {:8.2} M{}/s", r / 1e6, self.work_unit),
            Some(r) => format!("  {:8.2} {}/s", r, self.work_unit),
            None => String::new(),
        };
        format!(
            "{:<44} {:>10.3} ms/iter (min {:>8.3} ms, {} iters){}",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.iters,
            rate
        )
    }
}

/// Configuration for a bench run.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub min_time: Duration,
    pub max_iters: u32,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { min_time: Duration::from_millis(400), max_iters: 1000 }
    }
}

/// Time `f` until `opts.min_time` has elapsed (≥3 iterations), printing
/// and returning the measurement. A `std::hint::black_box` inside `f` is
/// the caller's responsibility.
pub fn bench<F: FnMut()>(name: &str, work_per_iter: Option<f64>, work_unit: &'static str, mut f: F) -> BenchResult {
    bench_opts(name, work_per_iter, work_unit, BenchOpts::default(), &mut f)
}

/// [`bench`] with explicit options.
pub fn bench_opts<F: FnMut()>(
    name: &str,
    work_per_iter: Option<f64>,
    work_unit: &'static str,
    opts: BenchOpts,
    f: &mut F,
) -> BenchResult {
    // warmup
    f();
    let mut iters = 0u32;
    let mut total = Duration::ZERO;
    let mut min = Duration::MAX;
    while (total < opts.min_time || iters < 3) && iters < opts.max_iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed();
        total += dt;
        min = min.min(dt);
        iters += 1;
    }
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters,
        min,
        work_per_iter,
        work_unit,
    };
    println!("{}", result.line());
    result
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n### {title}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_opts(
            "spin",
            Some(1000.0),
            "op",
            BenchOpts { min_time: Duration::from_millis(5), max_iters: 50 },
            &mut || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
        );
        assert!(r.iters >= 3);
        assert!(r.mean > Duration::ZERO);
        assert!(r.rate().unwrap() > 0.0);
    }
}
