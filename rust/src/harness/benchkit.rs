//! Minimal benchmark harness (criterion is not available in the offline
//! image): warmup + timed repetitions with mean / min / throughput
//! reporting. Used by every `rust/benches/*.rs` target via `cargo bench`.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub min: Duration,
    /// Optional work units per iteration (flops, bytes, elements…).
    pub work_per_iter: Option<f64>,
    pub work_unit: &'static str,
    /// Pool thread count in effect while this bench ran (benches pinned
    /// via `pool::with_threads` record their pinned value, not the
    /// ambient one — essential for reading the scaling sweeps).
    pub threads: usize,
}

impl BenchResult {
    /// Work units per second at the mean time.
    pub fn rate(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / self.mean.as_secs_f64())
    }

    /// Mean nanoseconds per iteration.
    pub fn mean_ns(&self) -> f64 {
        self.mean.as_secs_f64() * 1e9
    }

    /// Minimum nanoseconds per iteration.
    pub fn min_ns(&self) -> f64 {
        self.min.as_secs_f64() * 1e9
    }

    /// One machine-readable `BENCH_*.json` entry.
    pub fn json_entry(&self) -> String {
        let work = match self.work_per_iter {
            Some(w) => format!("{w:.1}"),
            None => "null".to_string(),
        };
        let rate = match self.rate() {
            Some(r) => format!("{r:.3}"),
            None => "null".to_string(),
        };
        format!(
            "{{\"name\":\"{}\",\"iters\":{},\"threads\":{},\"mean_ns\":{:.1},\"min_ns\":{:.1},\"work_per_iter\":{},\"work_unit\":\"{}\",\"rate_per_s\":{}}}",
            json_escape(&self.name),
            self.iters,
            self.threads,
            self.mean_ns(),
            self.min_ns(),
            work,
            json_escape(self.work_unit),
            rate
        )
    }

    /// One aligned report line.
    pub fn line(&self) -> String {
        let rate = match self.rate() {
            Some(r) if r >= 1e9 => format!("  {:8.2} G{}/s", r / 1e9, self.work_unit),
            Some(r) if r >= 1e6 => format!("  {:8.2} M{}/s", r / 1e6, self.work_unit),
            Some(r) => format!("  {:8.2} {}/s", r, self.work_unit),
            None => String::new(),
        };
        format!(
            "{:<44} {:>10.3} ms/iter (min {:>8.3} ms, {} iters){}",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.iters,
            rate
        )
    }
}

/// Configuration for a bench run.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub min_time: Duration,
    pub max_iters: u32,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { min_time: Duration::from_millis(400), max_iters: 1000 }
    }
}

/// Time `f` until `opts.min_time` has elapsed (≥3 iterations), printing
/// and returning the measurement. A `std::hint::black_box` inside `f` is
/// the caller's responsibility.
pub fn bench<F: FnMut()>(name: &str, work_per_iter: Option<f64>, work_unit: &'static str, mut f: F) -> BenchResult {
    bench_opts(name, work_per_iter, work_unit, BenchOpts::default(), &mut f)
}

/// [`bench`] with explicit options.
pub fn bench_opts<F: FnMut()>(
    name: &str,
    work_per_iter: Option<f64>,
    work_unit: &'static str,
    opts: BenchOpts,
    f: &mut F,
) -> BenchResult {
    // warmup
    f();
    let mut iters = 0u32;
    let mut total = Duration::ZERO;
    let mut min = Duration::MAX;
    while (total < opts.min_time || iters < 3) && iters < opts.max_iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed();
        total += dt;
        min = min.min(dt);
        iters += 1;
    }
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters,
        min,
        work_per_iter,
        work_unit,
        threads: crate::runtime::pool::num_threads(),
    };
    println!("{}", result.line());
    result
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n### {title}");
}

// the one shared JSON escaper lives in `report`; re-exported here so
// existing `benchkit::json_escape` callers keep working
pub(crate) use super::report::json_escape;

/// Write a machine-readable `BENCH_<tag>.json` so the perf trajectory
/// (EXPERIMENTS.md §Perf) can be tracked across PRs and checked in CI.
/// Hand-rolled JSON — the offline image has no serde.
pub fn write_json(path: &std::path::Path, tag: &str, results: &[BenchResult]) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(tag)));
    // ambient pool width; per-entry "threads" records each bench's pin
    s.push_str(&format!("  \"default_threads\": {},\n", crate::runtime::pool::num_threads()));
    s.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        s.push_str("    ");
        s.push_str(&r.json_entry());
        s.push_str(if i + 1 < results.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s)
}

/// One entry parsed back out of a committed `BENCH_*.json` baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct BaselineEntry {
    pub name: String,
    pub threads: usize,
    pub mean_ns: f64,
}

/// A parsed baseline file: the bench tag plus its recorded entries.
#[derive(Debug, Clone, Default)]
pub struct Baseline {
    pub tag: String,
    pub entries: Vec<BaselineEntry>,
}

/// Parse a `BENCH_*.json` previously written by [`write_json`]
/// (hand-rolled, like the writer — the offline image has no serde). The
/// format is line-oriented by construction: one `"bench"` header line
/// and one object per result line.
pub fn read_baseline(path: &std::path::Path) -> std::io::Result<Baseline> {
    let body = std::fs::read_to_string(path)?;
    let mut base = Baseline::default();
    for line in body.lines() {
        if base.tag.is_empty() {
            if let Some(tag) = json_str_field(line, "bench") {
                base.tag = tag;
                continue;
            }
        }
        if let Some(name) = json_str_field(line, "name") {
            let threads = json_num_field(line, "threads").unwrap_or(0.0) as usize;
            let Some(mean_ns) = json_num_field(line, "mean_ns") else { continue };
            base.entries.push(BaselineEntry { name, threads, mean_ns });
        }
    }
    Ok(base)
}

/// Extract a `"key":"string"` field from one JSON line, undoing
/// [`json_escape`].
fn json_str_field(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":");
    let mut rest = line[line.find(&pat)? + pat.len()..].trim_start();
    rest = rest.strip_prefix('"')?;
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => return Some(out),
            '\\' => match chars.next()? {
                'n' => out.push('\n'),
                't' => out.push('\t'),
                'u' => {
                    let hex: String = chars.by_ref().take(4).collect();
                    out.push(char::from_u32(u32::from_str_radix(&hex, 16).ok()?)?);
                }
                esc => out.push(esc), // \" and \\ (and tolerate others)
            },
            c => out.push(c),
        }
    }
    None
}

/// Extract a `"key":number` field from one JSON line.
fn json_num_field(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let rest = line[line.find(&pat)? + pat.len()..].trim_start();
    let end = rest
        .char_indices()
        .find(|(_, c)| !matches!(c, '0'..='9' | '-' | '+' | '.' | 'e' | 'E'))
        .map(|(i, _)| i)
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// A fresh result matched against its baseline entry (same name *and*
/// thread pin — numbers at different thread counts are not comparable).
#[derive(Debug, Clone)]
pub struct BenchDelta {
    pub name: String,
    pub threads: usize,
    pub base_mean_ns: f64,
    pub new_mean_ns: f64,
}

impl BenchDelta {
    /// Slowdown factor vs the baseline (>1 is slower).
    pub fn ratio(&self) -> f64 {
        self.new_mean_ns / self.base_mean_ns
    }

    /// Throughput regression beyond `tolerance` (e.g. 0.15 = 15%)?
    pub fn regressed(&self, tolerance: f64) -> bool {
        self.ratio() > 1.0 + tolerance
    }
}

/// Mean-time slowdown beyond this fraction counts as a regression in
/// [`report_baseline_diff`].
pub const REGRESSION_TOLERANCE: f64 = 0.15;

/// Match fresh results against a baseline by `(name, threads)`. Benches
/// present on only one side are skipped (the suite grows over PRs).
pub fn diff_against_baseline(results: &[BenchResult], base: &Baseline) -> Vec<BenchDelta> {
    results
        .iter()
        .filter_map(|r| {
            let b = base.entries.iter().find(|b| b.name == r.name && b.threads == r.threads)?;
            (b.mean_ns > 0.0).then(|| BenchDelta {
                name: r.name.clone(),
                threads: r.threads,
                base_mean_ns: b.mean_ns,
                new_mean_ns: r.mean_ns(),
            })
        })
        .collect()
}

/// Print the per-bench baseline deltas and return the number of
/// regressions beyond [`REGRESSION_TOLERANCE`] (callers exit non-zero
/// when this is > 0 and the baseline actually had matching entries).
pub fn report_baseline_diff(deltas: &[BenchDelta]) -> usize {
    let mut regressions = 0usize;
    println!("\n### baseline diff (mean ns/iter, >{:.0}% slower flagged)", REGRESSION_TOLERANCE * 100.0);
    for d in deltas {
        let flag = if d.regressed(REGRESSION_TOLERANCE) {
            regressions += 1;
            "  REGRESSION"
        } else {
            ""
        };
        println!(
            "{:<44} t{} {:>12.1} -> {:>12.1}  ({:+6.1}%){}",
            d.name,
            d.threads,
            d.base_mean_ns,
            d.new_mean_ns,
            (d.ratio() - 1.0) * 100.0,
            flag
        );
    }
    if deltas.is_empty() {
        println!("(no comparable entries)");
    }
    regressions
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_opts(
            "spin",
            Some(1000.0),
            "op",
            BenchOpts { min_time: Duration::from_millis(5), max_iters: 50 },
            &mut || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
        );
        assert!(r.iters >= 3);
        assert!(r.mean > Duration::ZERO);
        assert!(r.rate().unwrap() > 0.0);
    }

    #[test]
    fn json_round_trips_structure() {
        let r = BenchResult {
            name: "gemm \"fast\"".to_string(),
            iters: 7,
            mean: Duration::from_micros(1500),
            min: Duration::from_micros(1200),
            work_per_iter: Some(1e6),
            work_unit: "MAC",
            threads: 3,
        };
        let entry = r.json_entry();
        assert!(entry.contains("\\\"fast\\\""), "quotes must be escaped: {entry}");
        assert!(entry.contains("\"iters\":7"));
        assert!(entry.contains("\"threads\":3"));
        assert!(entry.contains("\"work_unit\":\"MAC\""));
        let path = std::env::temp_dir().join("bfp_cnn_benchkit_test.json");
        write_json(&path, "unit-test", &[r]).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("{\n"));
        assert!(body.contains("\"bench\": \"unit-test\""));
        assert!(body.trim_end().ends_with('}'));
        let _ = std::fs::remove_file(&path);
    }

    fn result(name: &str, threads: usize, mean_us: u64) -> BenchResult {
        BenchResult {
            name: name.to_string(),
            iters: 5,
            mean: Duration::from_micros(mean_us),
            min: Duration::from_micros(mean_us),
            work_per_iter: Some(1e6),
            work_unit: "MAC",
            threads,
        }
    }

    /// write_json → read_baseline round trip, including escaped names.
    #[test]
    fn baseline_roundtrips_through_json() {
        let results = vec![result("gemm \"tiled\"", 1, 1500), result("gemm \"tiled\"", 4, 600)];
        let path = std::env::temp_dir().join("bfp_cnn_baseline_roundtrip.json");
        write_json(&path, "hotpath", &results).unwrap();
        let base = read_baseline(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(base.tag, "hotpath");
        assert_eq!(base.entries.len(), 2);
        assert_eq!(base.entries[0].name, "gemm \"tiled\"");
        assert_eq!(base.entries[0].threads, 1);
        assert!((base.entries[0].mean_ns - 1_500_000.0).abs() < 0.5);
        assert_eq!(base.entries[1].threads, 4);
    }

    /// Diff matches on (name, threads), flags >15% slowdowns only.
    #[test]
    fn baseline_diff_flags_regressions() {
        let base = Baseline {
            tag: "hotpath".into(),
            entries: vec![
                BaselineEntry { name: "a".into(), threads: 1, mean_ns: 1_000_000.0 },
                BaselineEntry { name: "a".into(), threads: 4, mean_ns: 400_000.0 },
                BaselineEntry { name: "gone".into(), threads: 1, mean_ns: 1.0 },
            ],
        };
        let fresh = vec![
            result("a", 1, 1100),  // +10%: within tolerance
            result("a", 4, 600),   // +50% at t4: regression
            result("new", 1, 100), // not in baseline: skipped
        ];
        let deltas = diff_against_baseline(&fresh, &base);
        assert_eq!(deltas.len(), 2, "only (name, threads) matches compare");
        assert!(!deltas[0].regressed(REGRESSION_TOLERANCE));
        assert!(deltas[1].regressed(REGRESSION_TOLERANCE));
        assert_eq!(report_baseline_diff(&deltas), 1);
        // empty placeholder baseline: nothing comparable, no regressions
        let empty = Baseline { tag: "hotpath".into(), entries: vec![] };
        assert!(diff_against_baseline(&fresh, &empty).is_empty());
    }
}
