//! Table 3 — accuracy drop across the `L_W × L_I` mantissa-width grid.
//!
//! Measurement per DESIGN.md §4: LeNet / cifar use the build-time-trained
//! weights on their generated labelled datasets, so the drop is a true
//! `acc_fp32 − acc_bfp`. The ImageNet-class models keep their frozen
//! synthetic conv stacks (preserving BFP error propagation through the
//! real architectures) but get a **trained linear readout** on the
//! class-conditional imagenet-like task ([`super::readout`]), so their
//! logit margins — and hence the accuracy drops — have trained-network
//! semantics too. A pure flip-rate variant (no readout, labels = FP32
//! top-1) remains available via [`eval_set_for`] and is reported in
//! EXPERIMENTS.md as the conservative upper bound.

use super::report::{drop_cell, Table};
use crate::coordinator::engine::{forward_batch_ref, ExecMode};
use crate::models::{Model, ModelId};
use crate::quant::BfpConfig;
use crate::tensor::Tensor;
use std::path::Path;

/// A prepared evaluation set: inputs plus the FP32 reference outputs.
pub struct EvalSet {
    pub images: Vec<Tensor>,
    /// Ground-truth labels (trained nets) or FP32 top-1 (synthetic nets).
    pub labels: Vec<usize>,
    /// FP32 top-1 predictions.
    pub fp_top1: Vec<usize>,
    /// FP32 top-1 accuracy against `labels`.
    pub fp_acc: f64,
}

/// Run the FP32 reference once over the images.
pub fn prepare(model: &Model, images: Vec<Tensor>, labels: Option<Vec<usize>>) -> EvalSet {
    let logits = forward_batch_ref(model, &images, ExecMode::Fp32);
    let fp_top1: Vec<usize> = logits.iter().map(|l| argmax(&l.data)).collect();
    let labels = labels.unwrap_or_else(|| fp_top1.clone());
    let correct = fp_top1.iter().zip(&labels).filter(|(a, b)| a == b).count();
    let fp_acc = correct as f64 / labels.len().max(1) as f64;
    EvalSet { images, labels, fp_top1, fp_acc }
}

/// Top-1 accuracy drop of a BFP configuration against the eval set.
pub fn drop_for(model: &Model, set: &EvalSet, cfg: BfpConfig) -> f64 {
    let logits = forward_batch_ref(model, &set.images, ExecMode::Bfp(cfg));
    let correct = logits
        .iter()
        .zip(&set.labels)
        .filter(|(l, &label)| argmax(&l.data) == label)
        .count();
    set.fp_acc - correct as f64 / set.labels.len().max(1) as f64
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Build the evaluation model + set for a model id.
///
/// * LeNet / cifar: the build-time-trained networks on their generated
///   labelled datasets.
/// * ImageNet-class models: the frozen synthetic conv stack with a
///   **trained linear readout** on the class-conditional imagenet-like
///   task (DESIGN.md §4) — giving real logit margins, so "drop" has
///   trained-network semantics rather than random-projection flip rates.
pub fn prepare_model_and_set(
    id: ModelId,
    input_size: usize,
    n_images: usize,
    seed: u64,
    artifacts: &Path,
) -> (Model, EvalSet) {
    let model = id.build(input_size, seed, artifacts);
    match id {
        ModelId::Lenet => {
            let ds = crate::data::DigitDataset::generate(n_images, seed ^ 0xD161);
            let set = prepare(&model, ds.images, Some(ds.labels));
            (model, set)
        }
        ModelId::Cifar10 => {
            let ds = crate::data::TextureDataset::generate(n_images, seed ^ 0x7e57);
            let set = prepare(&model, ds.images, Some(ds.labels));
            (model, set)
        }
        _ => {
            let model = super::readout::with_trained_readout(model, 160, seed ^ 0x5EAD);
            let (images, labels) =
                crate::data::labeled_imagenet_like(n_images, input_size, seed ^ 0x11A6);
            let set = prepare(&model, images, Some(labels));
            (model, set)
        }
    }
}

/// Back-compat shim: eval set for an already-built model (small nets and
/// instrumentation paths that don't need the trained readout).
pub fn eval_set_for(id: ModelId, model: &Model, n_images: usize, seed: u64) -> EvalSet {
    match id {
        ModelId::Lenet => {
            let ds = crate::data::DigitDataset::generate(n_images, seed ^ 0xD161);
            prepare(model, ds.images, Some(ds.labels))
        }
        ModelId::Cifar10 => {
            let ds = crate::data::TextureDataset::generate(n_images, seed ^ 0x7e57);
            prepare(model, ds.images, Some(ds.labels))
        }
        _ => {
            let size = model.input_shape[1];
            let images = crate::data::imagenet_like_batch(n_images, size, seed ^ 0x11A6);
            prepare(model, images, None)
        }
    }
}

/// One Table 3 sub-grid: accuracy drop for every `(L_W, L_I)` pair.
pub fn run_model(id: ModelId, input_size: usize, n_images: usize, seed: u64, artifacts: &Path) -> Table {
    // The small trained nets are cheap and their drops are tiny (the
    // paper's mnist row bottoms out at ~0.01), so give them 4× the eval
    // set for resolution.
    let n_images = if id.is_imagenet_class() { n_images } else { n_images * 4 };
    let (model, set) = prepare_model_and_set(id, input_size, n_images, seed, artifacts);
    let widths = id.table3_widths();
    let mut header = vec!["L_W \\ L_I".to_string()];
    header.extend(widths.iter().map(|w| w.to_string()));
    let mut t = Table::new(
        format!("Table 3 — {} top-1 drop ({} images, fp32 acc {:.4})", model.name, n_images, set.fp_acc),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for &lw in &widths {
        let mut row = vec![lw.to_string()];
        for &li in &widths {
            let d = drop_for(&model, &set, BfpConfig::new(lw, li));
            row.push(drop_cell(d));
        }
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_grid_monotone_in_width() {
        let model = ModelId::Lenet.build(32, 1, Path::new("artifacts"));
        let set = eval_set_for(ModelId::Lenet, &model, 20, 7);
        let d3 = drop_for(&model, &set, BfpConfig::new(3, 3));
        let d6 = drop_for(&model, &set, BfpConfig::new(6, 6));
        // wider mantissas can't be (meaningfully) worse
        assert!(d6 <= d3 + 0.05, "d3={d3} d6={d6}");
        // 6-bit lenet should be essentially lossless (paper: 4-bit suffices)
        assert!(d6.abs() <= 0.05, "d6={d6}");
    }

    #[test]
    fn synthetic_labels_make_fp_acc_one() {
        let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
        let images = crate::data::DigitDataset::generate(5, 3).images;
        let set = prepare(&model, images, None);
        assert_eq!(set.fp_acc, 1.0);
        assert_eq!(set.labels, set.fp_top1);
    }

    #[test]
    fn table_renders_full_grid() {
        let t = run_model(ModelId::Lenet, 32, 5, 1, Path::new("artifacts"));
        assert_eq!(t.rows.len(), 4);
        assert_eq!(t.rows[0].len(), 5);
    }
}
