//! QoS serving report: per-class latency/downgrade tables plus per-lane
//! measured-vs-predicted NSR telemetry (EXPERIMENTS.md §QoS).

use super::report::{db, ms, pct, stage_table, Table};
use crate::coordinator::qos::QosReport;
use crate::coordinator::stage_rows;

/// Per-class serving table: request counts, latency percentiles,
/// downgrade and deadline-miss accounting.
pub fn class_table(report: &QosReport) -> Table {
    let header = [
        "class", "requests", "p50 ms", "p99 ms", "queue p50 ms", "downgrades", "downgrade %",
        "deadline misses", "timeouts", "failures",
    ];
    let mut t = Table::new("QoS per-class serving metrics", &header);
    for c in report.metrics.classes() {
        t.row(vec![
            c.label.clone(),
            c.requests.to_string(),
            ms(c.latency_p(50.0)),
            ms(c.latency_p(99.0)),
            ms(c.queue_wait_p(50.0)),
            c.downgrades.to_string(),
            pct(c.downgrade_rate()),
            c.deadline_misses.to_string(),
            c.timeouts.to_string(),
            c.failures.to_string(),
        ]);
    }
    t
}

/// Per-lane telemetry table: the precision step each lane ended on, its
/// predicted §4 bound, the streaming measured SNR, and hot-swap counts.
pub fn lane_table(report: &QosReport) -> Table {
    let mut t = Table::new(
        "QoS lane telemetry (measured vs predicted NSR)",
        &[
            "lane", "plan", "predicted dB", "measured dB", "probes", "batches", "swaps",
            "promotes", "ladder", "restarts", "state",
        ],
    );
    for l in &report.lanes {
        t.row(vec![
            l.label.clone(),
            l.plan.clone(),
            db(l.predicted_snr_db),
            db(l.measured_snr_db),
            l.probes.to_string(),
            l.batches.to_string(),
            l.swaps.to_string(),
            l.promotions.to_string(),
            format!("{}/{}", l.ladder_pos + 1, l.ladder_len),
            l.restarts.to_string(),
            if l.retired { "retired" } else { "live" }.to_string(),
        ]);
    }
    t
}

/// Per-tenant quota table (TCP front only; empty for in-process runs).
pub fn tenant_table(report: &QosReport) -> Table {
    let mut t = Table::new(
        "tenant quota accounting",
        &["tenant", "requests", "quota downgrades", "rejected", "over-quota %"],
    );
    for ten in report.metrics.tenants() {
        t.row(vec![
            ten.label.clone(),
            ten.requests.to_string(),
            ten.quota_downgrades.to_string(),
            ten.rejected.to_string(),
            pct(ten.over_quota_rate()),
        ]);
    }
    t
}

/// Print the full report (summary line + both tables).
pub fn print(report: &QosReport) {
    if report.worker_panic {
        println!("WARNING: serving worker panicked — this report is partial");
    }
    if report.metrics.lanes_retired > 0 {
        println!(
            "WARNING: {} lane(s) retired after exhausting their restart budget",
            report.metrics.lanes_retired
        );
    }
    println!("{}", report.metrics.summary());
    println!();
    class_table(report).print();
    println!();
    lane_table(report).print();
    if !report.metrics.tenants().is_empty() {
        println!();
        tenant_table(report).print();
    }
    // per-stage latency attribution, present only when tracing was armed
    // for the run (the recorder is empty otherwise)
    let spans = crate::obs::snapshot();
    if !spans.is_empty() {
        println!();
        stage_table(&stage_rows(&spans)).print();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::qos::LaneReport;
    use crate::coordinator::Metrics;
    use std::time::Duration;

    fn demo_report() -> QosReport {
        let mut metrics = Metrics::default();
        let ms = Duration::from_millis;
        metrics.record_class("gold", ms(4), Duration::ZERO, 2, false, false);
        metrics.record_class("economy", ms(40), ms(8), 4, true, true);
        metrics.wall_time = Duration::from_secs(1);
        QosReport {
            metrics,
            lanes: vec![LaneReport {
                label: "economy".into(),
                plan: "plan[26.0dB]".into(),
                predicted_snr_db: 26.0,
                measured_snr_db: 24.5,
                probes: 7,
                batches: 50,
                swaps: 1,
                promotions: 2,
                ladder_pos: 1,
                ladder_len: 4,
                restarts: 3,
                retired: false,
            }],
            worker_panic: false,
        }
    }

    #[test]
    fn tables_render_all_classes_and_lanes() {
        let r = demo_report();
        let ct = class_table(&r).render();
        assert!(ct.contains("gold"));
        assert!(ct.contains("economy"));
        assert!(ct.contains("100.0"), "downgrade rate column: {ct}");
        let lt = lane_table(&r).render();
        assert!(lt.contains("plan[26.0dB]"));
        assert!(lt.contains("24.5"));
        assert!(lt.contains("2/4"));
        assert!(lt.contains("promotes"), "promotion column present: {lt}");
        assert!(lt.contains("restarts"), "restart column present: {lt}");
        assert!(lt.contains("live"), "lane state column present: {lt}");
    }

    #[test]
    fn tenant_table_rows_follow_the_metrics() {
        let mut r = demo_report();
        assert_eq!(tenant_table(&r).render().lines().count(), 3, "no tenants, no rows");
        r.metrics.record_tenant("flood", true, false);
        r.metrics.record_tenant("flood", false, true);
        r.metrics.record_tenant("vip", false, false);
        let tt = tenant_table(&r).render();
        assert!(tt.contains("flood"));
        assert!(tt.contains("vip"));
        assert!(tt.contains("100.0"), "flood is fully over quota: {tt}");
    }
}
