//! Deterministic chaos scenarios for the resilience layer.
//!
//! Each scenario arms the seeded fault injector
//! ([`crate::runtime::faults`]) against a real serving fabric and then
//! *asserts recovery*, not just survival: SLO violations come back as
//! strings so the CLI (`bfp-cnn chaos`) can fail CI with an exact
//! explanation. Five scenarios cover the fault domains:
//!
//! * `kill-lane` — panic the economy executor on its 3rd and 4th
//!   batches (`panic:economy:3:2`). The supervisor must respawn the
//!   lane within its restart budget, exactly the two poisoned requests
//!   must fail with typed `ExecutorPanic` errors (nothing silently
//!   dropped), every other request must serve, and the gold lane's
//!   logits must be bit-identical to a no-fault run.
//! * `slow-lane` — a 25 ms latency spike on every economy batch
//!   (`delay:economy:25:1`). Everything still serves, no restarts; with
//!   per-lane executors the spike must stay contained in its lane
//!   (gold p50 < economy p50).
//! * `flaky-net` — hard-reset the first TCP connection and answer the
//!   second with a truncated frame (`reset:conn:1,truncate:conn:2`).
//!   The retrying client must recover with exactly two reconnects,
//!   serve every request with logits bit-identical to an in-process
//!   reference, and the health frame must then report every lane live.
//! * `bit-flip` — flip one mantissa bit of the first conv layer's entry
//!   in the shared weight cache on the gold lane's 3rd batch
//!   (`flip:weights:gold:<layer>:3`). Storage corruption, not in-flight
//!   corruption: every response must stay bit-identical to the
//!   no-fault run (lanes hold clean `Arc` views), and the background
//!   scrubber must detect the checksum mismatch, requantize the entry
//!   from the fp32 weights, and go quiet — exactly one repair, visible
//!   in the metrics.
//! * `poison-input` — the 3rd decoded request's payload goes non-finite
//!   after the frame CRC check (`nan:input:3`). The admission guard
//!   must refuse exactly that request with a typed `BadInput` error
//!   frame — never enqueueing it — while every other request serves
//!   bit-identically to an in-process reference.
//!
//! Everything is deterministic: fixed request sequences, seeded faults,
//! batch size 1 with zero linger, shedding and probing disabled — so a
//! scenario that fails in CI reproduces exactly on a laptop.

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::qos::SCRUB_PERIOD;
use crate::coordinator::{
    LaneSet, LaneStep, LogHistogram, QosClass, QosConfig, QosErrorKind, QosResult, QosServer,
    ShedPolicy, WorkerMode,
};
use crate::models::Model;
use crate::net::loadgen::RunStats;
use crate::net::{NetClient, NetServer, NetServerConfig, QuotaConfig, RetryPolicy, RetryingClient};
use crate::runtime::FaultInjector;
use crate::telemetry::MonitorConfig;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Requests per class in the lane scenarios.
const REQUESTS: usize = 8;

/// Requests driven through the retrying client in `flaky-net`.
const FLAKY_REQUESTS: usize = 16;

/// What one scenario suite observed: loadgen-shaped per-run stats (the
/// CLI mirrors them into `CHAOS_*.json`) plus every SLO violation an
/// operator would need to see (empty ⇒ the fabric recovered exactly as
/// specified).
pub struct ChaosOutcome {
    pub stats: Vec<RunStats>,
    pub violations: Vec<String>,
}

/// Uniform demo lanes (gold 9/9, standard 7/7, economy 5/5, no shed) —
/// the no-fault reference runs use the same set, so logits compare
/// bit-for-bit.
fn lanes() -> LaneSet {
    LaneSet::from_steps(
        LaneStep::uniform(9, 9),
        LaneStep::uniform(7, 7),
        LaneStep::uniform(5, 5),
        None,
    )
}

/// Deterministic serving config: batch size 1 with zero linger (fault
/// batch counters map 1:1 onto requests), shedding off (no pressure
/// downgrades, no idle-steal), telemetry probing off.
fn config(workers: WorkerMode, faults: Option<Arc<FaultInjector>>) -> QosConfig {
    QosConfig {
        policy: BatchPolicy { max_batch: 1, linger: Duration::ZERO },
        shed: ShedPolicy { enabled: false, queue_pressure: 0 },
        monitor: MonitorConfig { sample_every: 0, ..Default::default() },
        workers,
        faults,
        ..QosConfig::default()
    }
}

fn blank_stats(name: &str, tenant: &str, workers: WorkerMode) -> RunStats {
    RunStats {
        name: name.to_string(),
        tenant: tenant.to_string(),
        mode: workers.name(),
        sent: 0,
        ok: 0,
        errors: 0,
        timeouts: 0,
        retries: 0,
        downgraded: 0,
        quota_downgraded: 0,
        deadline_missed: 0,
        latency_us: LogHistogram::default(),
        wall: Duration::ZERO,
    }
}

/// Serve `n` requests of `class` through a no-fault fabric and return
/// the logits — the bit-exactness baseline the faulted runs must match.
fn reference_logits(
    model: &Model,
    pool: &[Tensor],
    class: QosClass,
    n: usize,
    workers: WorkerMode,
) -> Result<Vec<Tensor>> {
    let mut server = QosServer::start(model.clone(), &lanes(), config(workers, None));
    let logits = (0..n)
        .map(|i| Ok(server.infer(class, pool[i % pool.len()].clone())?.logits))
        .collect::<Result<Vec<Tensor>>>();
    server.shutdown();
    logits
}

/// `panic:economy:3:2`: the economy executor dies on its 3rd and 4th
/// batches. Asserts typed failure of exactly those two requests, full
/// recovery within the restart budget, and gold bit-exactness against
/// the no-fault run.
fn kill_lane(
    model: &Model,
    pool: &[Tensor],
    workers: WorkerMode,
    seed: u64,
) -> Result<(RunStats, Vec<String>)> {
    let mut v: Vec<String> = Vec::new();
    let gold_ref = reference_logits(model, pool, QosClass::Gold, REQUESTS, workers)?;

    let faults = Arc::new(FaultInjector::parse("panic:economy:3:2", seed)?);
    let mut server = QosServer::start(model.clone(), &lanes(), config(workers, Some(faults)));
    let mut stats = blank_stats("kill-lane", "chaos", workers);
    let mut failed: Vec<(QosClass, usize, QosErrorKind)> = Vec::new();
    let t0 = Instant::now();
    for i in 0..REQUESTS {
        for class in QosClass::ALL {
            stats.sent += 1;
            let sent = Instant::now();
            let outcome = server
                .submit(class, pool[i % pool.len()].clone())
                .context("the fabric must accept submits across injected panics")?
                .recv();
            match outcome {
                Ok(Ok(resp)) => {
                    stats.ok += 1;
                    stats.latency_us.record(sent.elapsed().as_micros() as u64);
                    if resp.downgraded {
                        stats.downgraded += 1;
                    }
                    if class == QosClass::Gold && resp.logits.data != gold_ref[i].data {
                        v.push(format!(
                            "kill-lane: gold request {i} logits diverge from the no-fault run"
                        ));
                    }
                }
                Ok(Err(e)) => {
                    stats.errors += 1;
                    failed.push((class, i, e.kind));
                }
                Err(_) => {
                    stats.errors += 1;
                    v.push(format!(
                        "kill-lane: {} request {i} was silently dropped (channel died)",
                        class.name()
                    ));
                }
            }
        }
    }
    stats.wall = t0.elapsed();
    let report = server.shutdown();

    let expected = vec![
        (QosClass::Economy, 2, QosErrorKind::ExecutorPanic),
        (QosClass::Economy, 3, QosErrorKind::ExecutorPanic),
    ];
    if failed != expected {
        v.push(format!(
            "kill-lane: expected exactly economy requests 2 and 3 (0-based) to fail with \
             executor-panic, got {failed:?}"
        ));
    }
    if report.metrics.lane_restarts != 2 {
        v.push(format!(
            "kill-lane: expected 2 supervisor restarts, report shows {}",
            report.metrics.lane_restarts
        ));
    }
    if report.metrics.lanes_retired != 0 {
        v.push(format!(
            "kill-lane: no lane should exhaust its restart budget, {} retired",
            report.metrics.lanes_retired
        ));
    }
    if report.worker_panic {
        v.push("kill-lane: the serving fabric died instead of supervising the panic".into());
    }
    let econ_failures = report.metrics.class("economy").map_or(0, |c| c.failures);
    if econ_failures != 2 {
        v.push(format!("kill-lane: report charges economy {econ_failures} failures, expected 2"));
    }
    if stats.ok + stats.errors != stats.sent {
        v.push("kill-lane: some requests never resolved".into());
    }
    Ok((stats, v))
}

/// `delay:economy:25:1`: every economy batch eats a 25 ms spike. All
/// requests must still serve with no restarts; with per-lane executors
/// the spike must stay contained in its lane (gold p50 < economy p50 —
/// the single-worker reference scheduler shares one thread, so the
/// containment SLO only applies per-lane).
fn slow_lane(
    model: &Model,
    pool: &[Tensor],
    workers: WorkerMode,
    seed: u64,
) -> Result<(Vec<RunStats>, Vec<String>)> {
    let mut v: Vec<String> = Vec::new();
    let faults = Arc::new(FaultInjector::parse("delay:economy:25:1", seed)?);
    let mut server = QosServer::start(model.clone(), &lanes(), config(workers, Some(faults)));
    let mut stats: Vec<RunStats> =
        QosClass::ALL.iter().map(|c| blank_stats("slow-lane", c.name(), workers)).collect();
    // per-class receiver lists: draining gold's (fast) responses first
    // keeps its recv-side latency honest — a cross-class drain order
    // would charge economy's 25 ms spikes to gold's measurements
    let mut pending: Vec<Vec<(Instant, Receiver<QosResult>)>> =
        QosClass::ALL.iter().map(|_| Vec::new()).collect();
    let t0 = Instant::now();
    for i in 0..REQUESTS {
        for (k, class) in QosClass::ALL.into_iter().enumerate() {
            stats[k].sent += 1;
            let rx = server.submit(class, pool[i % pool.len()].clone())?;
            pending[k].push((Instant::now(), rx));
        }
    }
    for (k, class_pending) in pending.into_iter().enumerate() {
        for (sent, rx) in class_pending {
            match rx.recv() {
                Ok(Ok(_)) => {
                    stats[k].ok += 1;
                    stats[k].latency_us.record(sent.elapsed().as_micros() as u64);
                }
                Ok(Err(e)) => {
                    stats[k].errors += 1;
                    v.push(format!("slow-lane: request failed under a pure latency fault: {e}"));
                }
                Err(_) => {
                    stats[k].errors += 1;
                    v.push("slow-lane: a request was silently dropped (channel died)".into());
                }
            }
        }
    }
    let wall = t0.elapsed();
    for s in &mut stats {
        s.wall = wall;
    }
    let report = server.shutdown();
    if report.metrics.lane_restarts != 0 || report.metrics.lanes_retired != 0 {
        v.push("slow-lane: latency spikes must not trigger restarts or retirement".into());
    }
    if matches!(workers, WorkerMode::PerLane { .. }) {
        let (gold, econ) = (stats[0].latency_p(50.0), stats[2].latency_p(50.0));
        if gold >= econ {
            v.push(format!(
                "slow-lane: economy's 25 ms spikes leaked into gold (gold p50 {gold:.2} ms >= \
                 economy p50 {econ:.2} ms)"
            ));
        }
    }
    Ok((stats, v))
}

/// `reset:conn:1,truncate:conn:2`: the first two TCP connections are
/// sabotaged. The retrying client must recover with exactly two
/// reconnects, serve every request bit-identically to an in-process
/// reference, and the health frame must then report every lane live.
fn flaky_net(
    model: &Model,
    pool: &[Tensor],
    workers: WorkerMode,
    seed: u64,
) -> Result<(RunStats, Vec<String>)> {
    let mut v: Vec<String> = Vec::new();
    let reference = reference_logits(model, pool, QosClass::Standard, FLAKY_REQUESTS, workers)?;

    let qos = QosServer::start(model.clone(), &lanes(), config(workers, None));
    let faults = Arc::new(FaultInjector::parse("reset:conn:1,truncate:conn:2", seed)?);
    let listener = std::net::TcpListener::bind("127.0.0.1:0").context("binding loopback")?;
    let net_config =
        NetServerConfig { max_conns: 16, quota: QuotaConfig::default(), faults: Some(faults) };
    let server = NetServer::start(listener, qos, net_config)?;

    let policy =
        RetryPolicy { attempts: 4, base: Duration::from_millis(5), cap: Duration::from_millis(40) };
    let mut client = RetryingClient::new(server.addr().to_string(), policy, seed);
    client.set_read_timeout(Some(Duration::from_secs(10)));
    let mut stats = blank_stats("flaky-net", "chaos", workers);
    let t0 = Instant::now();
    for (i, want) in reference.iter().enumerate() {
        stats.sent += 1;
        let sent = Instant::now();
        match client.infer("chaos", QosClass::Standard, pool[i % pool.len()].clone()) {
            Ok(resp) => {
                stats.ok += 1;
                stats.latency_us.record(sent.elapsed().as_micros() as u64);
                if resp.logits.data != want.data {
                    v.push(format!(
                        "flaky-net: request {i} logits diverge from the in-process reference"
                    ));
                }
            }
            Err(e) => {
                stats.errors += 1;
                v.push(format!("flaky-net: request {i} failed despite retries: {e:#}"));
            }
        }
    }
    stats.wall = t0.elapsed();
    stats.retries = client.retries;
    match client.health() {
        Ok(h) => {
            if h.lanes.len() != 3 || h.lanes.iter().any(|l| l.retired) {
                v.push(format!("flaky-net: health frame reports trouble: {:?}", h.lanes));
            }
        }
        Err(e) => v.push(format!("flaky-net: health frame failed: {e:#}")),
    }
    let report = server.shutdown_with_drain(Duration::from_millis(250));
    if client.retries != 2 {
        v.push(format!(
            "flaky-net: expected exactly 2 reconnects (reset + truncate), client performed {}",
            client.retries
        ));
    }
    if report.metrics.lane_restarts != 0 {
        v.push("flaky-net: connection faults must never restart a lane executor".into());
    }
    Ok((stats, v))
}

/// `flip:weights:gold:<first-conv>:3`: on the gold lane's 3rd batch,
/// one mantissa bit of the model's first conv layer's entry in the
/// shared weight cache is flipped — storage corruption, not in-flight
/// corruption: the lanes' active views share clean `Arc`s, so every
/// response must stay bit-identical to the no-fault run. The
/// background scrubber must wake on the cache generation bump, detect
/// the checksum mismatch, requantize the entry from the still-resident
/// fp32 weights, and go quiet: exactly one repair, visible in
/// `scrub_repairs`. A repair that were not bit-identical to a fresh
/// quantize would fail its checksum again on the next pass and be
/// repaired anew — quiescence is the proof.
fn bit_flip(
    model: &Model,
    pool: &[Tensor],
    workers: WorkerMode,
    seed: u64,
) -> Result<(RunStats, Vec<String>)> {
    let mut v: Vec<String> = Vec::new();
    let gold_ref = reference_logits(model, pool, QosClass::Gold, REQUESTS, workers)?;

    let mut layer: Option<String> = None;
    model.graph.visit_convs(&mut |c| {
        if layer.is_none() {
            layer = Some(c.name.clone());
        }
    });
    let layer = layer.context("bit-flip needs a model with at least one conv layer")?;
    let faults = Arc::new(FaultInjector::parse(&format!("flip:weights:gold:{layer}:3"), seed)?);
    let mut server = QosServer::start(model.clone(), &lanes(), config(workers, Some(faults)));
    let mut stats = blank_stats("bit-flip", "chaos", workers);
    let t0 = Instant::now();
    for (i, want) in gold_ref.iter().enumerate() {
        stats.sent += 1;
        let sent = Instant::now();
        match server.infer(QosClass::Gold, pool[i % pool.len()].clone()) {
            Ok(resp) => {
                stats.ok += 1;
                stats.latency_us.record(sent.elapsed().as_micros() as u64);
                if resp.logits.data != want.data {
                    v.push(format!(
                        "bit-flip: gold request {i} logits diverge from the no-fault run \
                         (in-flight views must not see store corruption)"
                    ));
                }
            }
            Err(e) => {
                stats.errors += 1;
                v.push(format!("bit-flip: gold request {i} failed: {e:#}"));
            }
        }
    }
    // detection SLO: the corruption bumped the cache generation, so the
    // scrubber's next tick must find and repair it — allow a generous
    // multiple of the period for slow CI machines
    let deadline = Instant::now() + SCRUB_PERIOD * 40;
    let mut repaired = server.metrics().scrub_repairs;
    while repaired == 0 && Instant::now() < deadline {
        std::thread::sleep(SCRUB_PERIOD / 5);
        repaired = server.metrics().scrub_repairs;
    }
    if repaired == 0 {
        v.push("bit-flip: the scrubber never repaired the flipped entry within its SLO".into());
    } else {
        // repair-is-bit-identical SLO by quiescence: a mis-repaired
        // entry would keep failing its checksum and re-repairing
        std::thread::sleep(SCRUB_PERIOD * 10);
        let m = server.metrics();
        if m.scrub_repairs != repaired {
            v.push(format!(
                "bit-flip: repaired entry failed re-verification ({} repairs after {repaired})",
                m.scrub_repairs
            ));
        }
    }
    stats.wall = t0.elapsed();
    let report = server.shutdown();
    if report.metrics.scrub_repairs != 1 {
        v.push(format!(
            "bit-flip: exactly one repair must show in the final report, got {}",
            report.metrics.scrub_repairs
        ));
    }
    if report.metrics.scrub_passes == 0 {
        v.push("bit-flip: the scrubber never completed a verification pass".into());
    }
    if report.metrics.lane_restarts != 0 || report.metrics.lanes_retired != 0 {
        v.push("bit-flip: store corruption must never restart or retire a lane".into());
    }
    if report.metrics.corrupt_outputs != 0 {
        v.push("bit-flip: no corrupt outputs should surface (lanes hold clean views)".into());
    }
    if stats.ok != stats.sent {
        v.push("bit-flip: every request must serve — the store, not the traffic, is hurt".into());
    }
    Ok((stats, v))
}

/// `nan:input:3`: the 3rd decoded request's payload goes non-finite
/// *after* the frame CRC check — modeling request memory corrupting
/// between transport and admission. The admission guard must refuse
/// exactly that request with a typed `BadInput` error frame (never
/// enqueueing it, never touching a lane), while every other request
/// serves bit-identically to an in-process reference.
fn poison_input(
    model: &Model,
    pool: &[Tensor],
    workers: WorkerMode,
    seed: u64,
) -> Result<(RunStats, Vec<String>)> {
    let mut v: Vec<String> = Vec::new();
    let reference = reference_logits(model, pool, QosClass::Standard, REQUESTS, workers)?;

    let qos = QosServer::start(model.clone(), &lanes(), config(workers, None));
    let faults = Arc::new(FaultInjector::parse("nan:input:3", seed)?);
    let listener = std::net::TcpListener::bind("127.0.0.1:0").context("binding loopback")?;
    let net_config =
        NetServerConfig { max_conns: 16, quota: QuotaConfig::default(), faults: Some(faults) };
    let server = NetServer::start(listener, qos, net_config)?;

    let mut client = NetClient::connect(server.addr()).context("connecting to the front")?;
    client.set_read_timeout(Some(Duration::from_secs(10)))?;
    let mut stats = blank_stats("poison-input", "chaos", workers);
    let mut failed: Vec<usize> = Vec::new();
    let t0 = Instant::now();
    for (i, want) in reference.iter().enumerate() {
        stats.sent += 1;
        let sent = Instant::now();
        match client.infer("chaos", QosClass::Standard, pool[i % pool.len()].clone()) {
            Ok(resp) => {
                stats.ok += 1;
                stats.latency_us.record(sent.elapsed().as_micros() as u64);
                if resp.logits.data != want.data {
                    v.push(format!(
                        "poison-input: request {i} logits diverge from the in-process reference"
                    ));
                }
            }
            Err(e) => {
                stats.errors += 1;
                failed.push(i);
                let msg = format!("{e:#}");
                if !msg.contains("BadInput") || !msg.contains("non-finite") {
                    v.push(format!("poison-input: request {i} failed with the wrong error: {msg}"));
                }
            }
        }
    }
    stats.wall = t0.elapsed();
    if failed != vec![2] {
        v.push(format!(
            "poison-input: exactly the 3rd request (0-based index 2) must fail, got {failed:?}"
        ));
    }
    let report = server.shutdown_with_drain(Duration::from_millis(250));
    if report.metrics.bad_inputs != 1 {
        v.push(format!(
            "poison-input: report counts {} bad inputs, expected exactly 1",
            report.metrics.bad_inputs
        ));
    }
    if report.metrics.total_requests as usize != REQUESTS - 1 {
        v.push(format!(
            "poison-input: the poisoned request must never be enqueued ({} served, expected {})",
            report.metrics.total_requests,
            REQUESTS - 1
        ));
    }
    if report.metrics.lane_restarts != 0 {
        v.push("poison-input: a refused input must never touch a lane executor".into());
    }
    Ok((stats, v))
}

/// Run the named scenario (`kill-lane`, `slow-lane`, `flaky-net`,
/// `bit-flip`, `poison-input`, or `all`) against `model`, driving
/// requests from `pool`. Returns the loadgen-shaped stats plus every
/// SLO violation.
pub fn run_scenarios(
    model: &Model,
    pool: &[Tensor],
    which: &str,
    workers: WorkerMode,
    seed: u64,
) -> Result<ChaosOutcome> {
    anyhow::ensure!(!pool.is_empty(), "chaos scenarios need at least one image");
    let all = which == "all";
    let mut out = ChaosOutcome { stats: Vec::new(), violations: Vec::new() };
    let mut matched = false;
    if all || which == "kill-lane" {
        matched = true;
        let (s, v) = kill_lane(model, pool, workers, seed)?;
        out.stats.push(s);
        out.violations.extend(v);
    }
    if all || which == "slow-lane" {
        matched = true;
        let (s, v) = slow_lane(model, pool, workers, seed)?;
        out.stats.extend(s);
        out.violations.extend(v);
    }
    if all || which == "flaky-net" {
        matched = true;
        let (s, v) = flaky_net(model, pool, workers, seed)?;
        out.stats.push(s);
        out.violations.extend(v);
    }
    if all || which == "bit-flip" {
        matched = true;
        let (s, v) = bit_flip(model, pool, workers, seed)?;
        out.stats.push(s);
        out.violations.extend(v);
    }
    if all || which == "poison-input" {
        matched = true;
        let (s, v) = poison_input(model, pool, workers, seed)?;
        out.stats.push(s);
        out.violations.extend(v);
    }
    anyhow::ensure!(
        matched,
        "unknown chaos scenario `{which}` (kill-lane|slow-lane|flaky-net|bit-flip|poison-input|all)"
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Block;

    fn tiny_model() -> Model {
        let mut rng = crate::data::Rng::new(11);
        Model {
            name: "tiny".into(),
            graph: Block::seq(vec![
                Block::Conv(crate::models::init::conv2d("c1", 4, 2, 3, 3, 1, 1, &mut rng)),
                Block::ReLU,
                Block::Conv(crate::models::init::conv2d("c2", 3, 4, 3, 3, 1, 1, &mut rng)),
                Block::Flatten,
            ]),
            input_shape: vec![2, 8, 8],
            num_classes: 0,
        }
    }

    fn pool() -> Vec<Tensor> {
        let mut rng = crate::data::Rng::new(5);
        (0..4).map(|_| Tensor::from_vec(rng.normal_vec(2 * 8 * 8, 1.0), &[2, 8, 8])).collect()
    }

    #[test]
    fn kill_lane_recovers_on_both_worker_modes() {
        for workers in [WorkerMode::Single, WorkerMode::PerLane { steal: true }] {
            let out =
                run_scenarios(&tiny_model(), &pool(), "kill-lane", workers, 7).expect("runs");
            assert!(
                out.violations.is_empty(),
                "kill-lane SLO violations under {}: {:?}",
                workers.name(),
                out.violations
            );
            assert_eq!(out.stats.len(), 1);
            assert_eq!(out.stats[0].sent, 24);
            assert_eq!(out.stats[0].ok, 22);
            assert_eq!(out.stats[0].errors, 2);
        }
    }

    #[test]
    fn bit_flip_detects_and_repairs_store_corruption() {
        let out =
            run_scenarios(&tiny_model(), &pool(), "bit-flip", WorkerMode::Single, 7).expect("runs");
        assert!(out.violations.is_empty(), "bit-flip SLO violations: {:?}", out.violations);
        assert_eq!(out.stats.len(), 1);
        assert_eq!(out.stats[0].sent, 8);
        assert_eq!(out.stats[0].ok, 8, "store corruption must not hurt traffic");
        assert_eq!(out.stats[0].errors, 0);
    }

    #[test]
    fn poison_input_fails_exactly_the_poisoned_request() {
        let out = run_scenarios(&tiny_model(), &pool(), "poison-input", WorkerMode::Single, 7)
            .expect("runs");
        assert!(out.violations.is_empty(), "poison-input SLO violations: {:?}", out.violations);
        assert_eq!(out.stats.len(), 1);
        assert_eq!(out.stats[0].sent, 8);
        assert_eq!(out.stats[0].ok, 7);
        assert_eq!(out.stats[0].errors, 1);
    }

    #[test]
    fn unknown_scenario_is_rejected() {
        let err = run_scenarios(&tiny_model(), &pool(), "meteor-strike", WorkerMode::Single, 1)
            .unwrap_err();
        assert!(err.to_string().contains("unknown chaos scenario"));
    }
}
