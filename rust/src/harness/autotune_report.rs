//! Report rendering for autotuned precision plans: the per-layer width
//! table (predicted vs measured SNR, bits saved vs uniform 8-bit) and
//! the planner's Pareto frontier.

use super::report::{db, Table};
use crate::autotune::PrecisionPlan;

/// The per-layer plan table.
pub fn plan_table(plan: &PrecisionPlan) -> Table {
    let mut t = Table::new(
        format!("Autotuned precision plan — {} (budget ≥ {:.2} dB)", plan.model, plan.budget_snr_db),
        &["layer", "L_W", "L_I", "pred SNR (dB)", "meas SNR (dB)", "traffic (kbit)", "vs 8/8"],
    );
    for l in &plan.layers {
        let base = l.traffic_bits_at(8, 8);
        let saving = if base > 0.0 { 100.0 * (1.0 - l.traffic_bits() / base) } else { 0.0 };
        t.row(vec![
            l.name.clone(),
            l.l_w.to_string(),
            l.l_i.to_string(),
            db(l.predicted_snr_db),
            db(l.measured_snr_db),
            format!("{:.1}", l.traffic_bits() / 1000.0),
            format!("{saving:+.1}%"),
        ]);
    }
    let base = plan.uniform_traffic_bits(8, 8);
    t.row(vec![
        "TOTAL".into(),
        "-".into(),
        "-".into(),
        db(plan.predicted_snr_db),
        db(plan.measured_snr_db),
        format!("{:.1}", plan.total_traffic_bits() / 1000.0),
        format!("{:+.1}%", 100.0 * (1.0 - plan.total_traffic_bits() / base.max(1e-12))),
    ]);
    t
}

/// The cost/quality frontier the greedy walk traced.
pub fn frontier_table(plan: &PrecisionPlan) -> Table {
    let mut t = Table::new(
        format!("Pareto frontier — {} ({} points)", plan.model, plan.frontier.len()),
        &["traffic (kbit)", "predicted SNR (dB)"],
    );
    for p in &plan.frontier {
        t.row(vec![format!("{:.1}", p.traffic_bits / 1000.0), db(p.predicted_snr_db)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::{LayerPlan, ParetoPoint};

    fn plan() -> PrecisionPlan {
        PrecisionPlan {
            model: "lenet".into(),
            budget_snr_db: 28.0,
            layers: vec![LayerPlan {
                name: "conv1".into(),
                l_w: 6,
                l_i: 7,
                m: 8,
                k: 25,
                n: 784,
                predicted_snr_db: 31.5,
                measured_snr_db: f64::NAN,
            }],
            predicted_snr_db: 31.5,
            measured_snr_db: f64::NAN,
            frontier: vec![ParetoPoint { traffic_bits: 2048.0, predicted_snr_db: 31.5 }],
        }
    }

    #[test]
    fn renders_plan_and_frontier() {
        let p = plan();
        let s = plan_table(&p).render();
        assert!(s.contains("conv1"), "{s}");
        assert!(s.contains("TOTAL"), "{s}");
        assert!(s.contains("31.5000"), "{s}");
        let f = frontier_table(&p).render();
        assert!(f.contains("2.0"), "{f}");
    }

    #[test]
    fn unmeasured_cells_render_as_dash() {
        let s = plan_table(&plan()).render();
        assert!(s.lines().any(|l| l.contains("conv1") && l.contains(" - ")), "{s}");
    }
}
