//! Table 1 — storage / complexity cost of the four partition schemes.
//!
//! The cost model is analytic; we evaluate it on the true VGG-16 layer
//! geometries at 224×224 (matching the paper's conv1_1 example with
//! M=64, K=9, N=50176) and report both the symbolic Table 1 rows and the
//! concrete per-layer totals.

use super::report::Table;
use crate::bfp::PartitionScheme;
use crate::tensor::Conv2dGeometry;

/// A named convolution geometry `(name, M, K, N)`.
pub type LayerGeom = (String, usize, usize, usize);

/// The 13 VGG-16 conv layers at 224×224 input (the paper's reference).
pub fn vgg16_geometries() -> Vec<LayerGeom> {
    let mut out = Vec::new();
    let mut size = 224usize;
    let mut in_ch = 3usize;
    for (stage, convs, ch) in crate::models::vgg::STAGES {
        for i in 1..=convs {
            let geo = Conv2dGeometry {
                in_channels: in_ch,
                in_h: size,
                in_w: size,
                kernel_h: 3,
                kernel_w: 3,
                stride: 1,
                padding: 1,
            };
            out.push((format!("conv{stage}_{i}"), ch, geo.k(), geo.n()));
            in_ch = ch;
        }
        size /= 2;
    }
    out
}

/// All four schemes for one geometry.
pub fn schemes() -> [PartitionScheme; 4] {
    [PartitionScheme::Eq2, PartitionScheme::Eq3, PartitionScheme::Eq4, PartitionScheme::Eq5]
}

/// Render the Table 1 reproduction for `(m, k, n)` at widths `l_w`/`l_i`.
pub fn run_for_layer(name: &str, m: usize, k: usize, n: usize, l_w: u32, l_i: u32) -> Table {
    let mut t = Table::new(
        format!("Table 1 — {name} (M={m}, K={k}, N={n}, L_W={l_w}, L_I={l_i}, L_e=8)"),
        &["scheme", "AL_W' (bits)", "AL_I' (bits)", "NBE", "W total (KiB)", "I total (KiB)", "fp32 ratio"],
    );
    for s in schemes() {
        let c = s.cost(m, k, n, l_w, l_i, 8);
        let fp32_bits = 32.0 * (m * k + k * n) as f64;
        let bfp_bits = (c.total_bits_w + c.total_bits_i) as f64;
        t.row(vec![
            format!("{s:?}"),
            format!("{:.4}", c.avg_len_w),
            format!("{:.4}", c.avg_len_i),
            format!("{}", c.num_block_exponents),
            format!("{:.1}", c.total_bits_w as f64 / 8192.0),
            format!("{:.1}", c.total_bits_i as f64 / 8192.0),
            format!("{:.2}x", fp32_bits / bfp_bits),
        ]);
    }
    t
}

/// The full Table 1 run: the paper's conv1_1 example plus network totals.
pub fn run(l_w: u32, l_i: u32) -> Vec<Table> {
    let mut tables = Vec::new();
    // The paper's quoted example shape (its K=9 counts only the 3×3
    // spatial taps of conv1_1); the network totals below use the true
    // im2col K = C·kh·kw.
    tables.push(run_for_layer("VGG-16 conv1_1 (paper's quoted shape)", 64, 9, 50176, l_w, l_i));

    // network-wide totals per scheme
    let mut totals = Table::new(
        format!("Table 1b — whole-network VGG-16 totals (L_W={l_w}, L_I={l_i}, L_e=8)"),
        &["scheme", "W+I total (MiB)", "NBE total", "traffic vs fp32"],
    );
    let geoms = vgg16_geometries();
    for s in schemes() {
        let mut bits = 0f64;
        let mut nbe = 0usize;
        let mut fp32_bits = 0f64;
        for (_, m, k, n) in &geoms {
            let c = s.cost(*m, *k, *n, l_w, l_i, 8);
            bits += (c.total_bits_w + c.total_bits_i) as f64;
            nbe += c.num_block_exponents;
            fp32_bits += 32.0 * (m * k + k * n) as f64;
        }
        totals.row(vec![
            format!("{s:?}"),
            format!("{:.1}", bits / 8.0 / 1024.0 / 1024.0),
            format!("{nbe}"),
            format!("{:.3}x", bits / fp32_bits),
        ]);
    }
    tables.push(totals);
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv1_1_matches_paper_example() {
        let g = vgg16_geometries();
        // The paper's §3.3 example quotes "M=64, K=9, N=50176" — its K
        // counts only the 3×3 spatial taps. The actual im2col inner
        // dimension includes the 3 input channels: K = 3·3·3 = 27.
        assert_eq!(g[0], ("conv1_1".to_string(), 64, 27, 50176));
        // paper: N much greater than M (50176/64 ≈ 784)
        assert!(g[0].3 > 700 * g[0].1);
    }

    #[test]
    fn thirteen_layers() {
        assert_eq!(vgg16_geometries().len(), 13);
    }

    #[test]
    fn eq4_strictly_cheaper_than_eq3_in_exponent_storage() {
        for (_, m, k, n) in vgg16_geometries() {
            let c3 = PartitionScheme::Eq3.cost(m, k, n, 8, 8, 8);
            let c4 = PartitionScheme::Eq4.cost(m, k, n, 8, 8, 8);
            assert!(c4.num_block_exponents < c3.num_block_exponents);
        }
    }

    #[test]
    fn bfp_beats_fp32_storage_4x() {
        // 8-bit BFP ≈ 4× smaller than fp32
        let t = run(8, 8);
        assert_eq!(t.len(), 2);
        let rendered = t[1].render();
        assert!(rendered.contains("0.25"), "expected ~0.25x traffic: {rendered}");
    }
}
