//! Figure 3 — normalized-magnitude energy distribution of the early
//! VGG-16 layers (conv1_1, conv1_2, conv2_1, conv2_2).
//!
//! The paper uses this plot to explain conv1_2's outsized theory-vs-
//! experiment deviation: its output energy concentrates near the maximum
//! magnitude (strong filter/input correlation), breaking the independence
//! assumption of §4.2.

use super::report::Table;
use crate::analysis::energy::EnergyHistogram;
use crate::models::{Model, ModelId};
use crate::nn::graph::Executor;
use crate::nn::{ops, BatchNorm, Conv2d, Dense, Fp32Exec};
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::path::Path;

/// FP32 executor that additionally captures named conv outputs.
pub struct CaptureExec {
    inner: Fp32Exec,
    pub wanted: Vec<String>,
    pub captured: HashMap<String, Vec<f32>>,
}

impl CaptureExec {
    pub fn new(wanted: &[&str]) -> Self {
        Self { inner: Fp32Exec, wanted: wanted.iter().map(|s| s.to_string()).collect(), captured: HashMap::new() }
    }
}

impl Executor for CaptureExec {
    type T = Tensor;
    fn conv(&mut self, layer: &Conv2d, x: Tensor) -> Tensor {
        let out = self.inner.conv(layer, x);
        if self.wanted.iter().any(|w| w == &layer.name) {
            self.captured.entry(layer.name.clone()).or_default().extend_from_slice(&out.data);
        }
        out
    }
    fn dense(&mut self, layer: &Dense, x: Tensor) -> Tensor {
        self.inner.dense(layer, x)
    }
    fn batch_norm(&mut self, layer: &BatchNorm, x: Tensor) -> Tensor {
        self.inner.batch_norm(layer, x)
    }
    fn relu(&mut self, x: Tensor) -> Tensor {
        ops::relu(&x)
    }
    fn max_pool(&mut self, n: &str, k: usize, s: usize, p: usize, x: Tensor) -> Tensor {
        self.inner.max_pool(n, k, s, p, x)
    }
    fn avg_pool(&mut self, n: &str, k: usize, s: usize, p: usize, x: Tensor) -> Tensor {
        self.inner.avg_pool(n, k, s, p, x)
    }
    fn global_avg_pool(&mut self, x: Tensor) -> Tensor {
        self.inner.global_avg_pool(x)
    }
    fn flatten(&mut self, x: Tensor) -> Tensor {
        ops::flatten(&x)
    }
    fn add(&mut self, a: Tensor, b: Tensor) -> Tensor {
        ops::add(&a, &b)
    }
    fn concat(&mut self, parts: Vec<Tensor>) -> Tensor {
        ops::concat_channels(&parts)
    }
    fn softmax(&mut self, x: Tensor) -> Tensor {
        ops::softmax(&x)
    }
    fn fork(&mut self, x: &Tensor) -> Tensor {
        x.clone()
    }
}

/// The four layers Figure 3 plots.
pub const FIG3_LAYERS: [&str; 4] = ["conv1_1", "conv1_2", "conv2_1", "conv2_2"];

/// Capture the Figure 3 layer outputs over a batch.
pub fn capture(model: &Model, n_images: usize, seed: u64) -> HashMap<String, Vec<f32>> {
    let size = model.input_shape[1];
    let images = crate::data::imagenet_like_batch(n_images, size, seed ^ 0xF163);
    let mut exec = CaptureExec::new(&FIG3_LAYERS);
    for img in &images {
        model.graph.execute(img.clone(), &mut exec);
    }
    exec.captured
}

/// Render the Figure 3 reproduction: per-layer energy fraction in the
/// normalized-magnitude buckets of [0.8, 1.0] (the paper's plotted range).
pub fn run(input_size: usize, n_images: usize, seed: u64, artifacts: &Path) -> Table {
    let model = ModelId::Vgg16.build(input_size, seed, artifacts);
    let captured = capture(&model, n_images, seed);
    let bins = 50; // 0.02-wide buckets; [0.8, 1.0] = last 10
    let mut t = Table::new(
        format!("Figure 3 — energy distribution at normalized magnitude ≥ 0.8 ({n_images} images)"),
        &["layer", "0.80-0.84", "0.84-0.88", "0.88-0.92", "0.92-0.96", "0.96-1.00", "total ≥0.8"],
    );
    for layer in FIG3_LAYERS {
        let values = captured.get(layer).map(|v| v.as_slice()).unwrap_or(&[]);
        let h = EnergyHistogram::compute(values, bins);
        let bucket = |lo: f64| -> f64 {
            h.edges
                .iter()
                .zip(&h.fractions)
                .filter(|(e, _)| **e >= lo - 1e-9 && **e < lo + 0.04 - 1e-9)
                .map(|(_, f)| f)
                .sum()
        };
        let tail = h.tail_energy(0.8);
        t.row(vec![
            layer.to_string(),
            format!("{:.4}", bucket(0.80)),
            format!("{:.4}", bucket(0.84)),
            format!("{:.4}", bucket(0.88)),
            format!("{:.4}", bucket(0.92)),
            format!("{:.4}", bucket(0.96)),
            format!("{tail:.4}"),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn captures_all_four_layers() {
        let model = ModelId::Vgg16.build(32, 1, Path::new("artifacts"));
        let cap = capture(&model, 1, 2);
        for l in FIG3_LAYERS {
            assert!(cap.contains_key(l), "missing {l}");
            assert!(!cap[l].is_empty());
        }
    }

    #[test]
    fn table_has_four_rows() {
        let t = run(32, 1, 3, Path::new("artifacts"));
        assert_eq!(t.rows.len(), 4);
    }
}
