//! Experiment drivers that regenerate every table and figure of the
//! paper's evaluation section (see DESIGN.md §3 for the index).

pub mod autotune_report;
pub mod benchkit;
pub mod chaos;
pub mod fig3;
pub mod net_report;
pub mod qos_report;
pub mod readout;
pub mod report;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;

pub use report::Table;
