//! Linear-readout training on frozen conv features (DESIGN.md §4).
//!
//! The large networks carry synthetic conv weights; to give their
//! accuracy numbers trained-network semantics we fit the final dense
//! layer (softmax regression) on the class-conditional dataset of
//! [`crate::data::labeled`]. The conv stack — the part BFP perturbs —
//! stays frozen, so quantization-error propagation is unchanged while
//! logit margins become realistic.

use crate::models::Model;
use crate::nn::{Block, Dense, Fp32Exec};
use crate::tensor::Tensor;

/// Split a sequential model into (feature extractor, final dense).
/// Returns `None` if the graph does not end in a Dense layer.
pub fn split_trailing_dense(graph: Block) -> Option<(Block, Dense)> {
    match graph {
        Block::Seq(mut items) => match items.pop()? {
            Block::Dense(d) => Some((Block::Seq(items), d)),
            last => {
                items.push(last);
                None
            }
        },
        _ => None,
    }
}

/// Train a softmax-regression head on precomputed features.
/// Plain full-batch gradient descent; features are L2-normalised
/// internally for conditioning.
pub fn train_linear_head(
    features: &[Vec<f32>],
    labels: &[usize],
    classes: usize,
    epochs: usize,
    lr: f32,
) -> Dense {
    assert_eq!(features.len(), labels.len());
    let n = features.len();
    let dim = features[0].len();
    // normalise features to unit RMS (shared scale, preserved at eval)
    let rms = (features.iter().flat_map(|f| f.iter()).map(|&v| (v as f64).powi(2)).sum::<f64>()
        / (n * dim) as f64)
        .sqrt()
        .max(1e-12) as f32;
    let mut w = vec![0f32; classes * dim];
    let mut b = vec![0f32; classes];
    let mut probs = vec![0f32; classes];
    for _ in 0..epochs {
        let mut gw = vec![0f32; classes * dim];
        let mut gb = vec![0f32; classes];
        for (f, &y) in features.iter().zip(labels) {
            // logits
            let mut maxv = f32::NEG_INFINITY;
            for c in 0..classes {
                let row = &w[c * dim..(c + 1) * dim];
                let mut acc = b[c];
                for (wv, fv) in row.iter().zip(f) {
                    acc += wv * fv / rms;
                }
                probs[c] = acc;
                maxv = maxv.max(acc);
            }
            let mut sum = 0f32;
            for p in probs.iter_mut() {
                *p = (*p - maxv).exp();
                sum += *p;
            }
            for (c, p) in probs.iter_mut().enumerate() {
                *p /= sum;
                let err = *p - if c == y { 1.0 } else { 0.0 };
                gb[c] += err;
                let grow = &mut gw[c * dim..(c + 1) * dim];
                for (g, fv) in grow.iter_mut().zip(f) {
                    *g += err * fv / rms;
                }
            }
        }
        let scale = lr / n as f32;
        for (wv, g) in w.iter_mut().zip(&gw) {
            *wv -= scale * g;
        }
        for (bv, g) in b.iter_mut().zip(&gb) {
            *bv -= scale * g;
        }
    }
    // fold the RMS normalisation into the weights
    for wv in w.iter_mut() {
        *wv /= rms;
    }
    Dense::new("readout", Tensor::from_vec(w, &[classes, dim]), b)
}

/// Replace a model's final dense layer with a head trained on the
/// labelled imagenet-like task. Returns the new model (10 classes) or
/// the original when the graph has no trailing dense.
pub fn with_trained_readout(model: Model, n_train: usize, seed: u64) -> Model {
    let size = model.input_shape[1];
    let Some((prefix, _)) = split_trailing_dense(model.graph) else {
        panic!("model {} does not end in a dense layer", model.name);
    };
    let (images, labels) = crate::data::labeled::labeled_imagenet_like(n_train, size, seed);
    let features: Vec<Vec<f32>> = images
        .iter()
        .map(|img| prefix.execute(img.clone(), &mut Fp32Exec).data)
        .collect();
    let head = train_linear_head(&features, &labels, 10, 1000, 2.0);
    let mut items = match prefix {
        Block::Seq(items) => items,
        other => vec![other],
    };
    items.push(Block::Dense(head));
    Model {
        name: model.name,
        graph: Block::Seq(items),
        input_shape: model.input_shape,
        num_classes: 10,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn split_returns_prefix_and_head() {
        let d = Dense::new("fc", Tensor::from_vec(vec![1.0; 4], &[2, 2]), vec![]);
        let g = Block::Seq(vec![Block::ReLU, Block::Dense(d)]);
        let (prefix, head) = split_trailing_dense(g).unwrap();
        assert_eq!(head.name, "fc");
        assert!(matches!(prefix, Block::Seq(items) if items.len() == 1));
    }

    #[test]
    fn split_rejects_non_dense_tail() {
        let g = Block::Seq(vec![Block::ReLU]);
        assert!(split_trailing_dense(g).is_none());
    }

    #[test]
    fn linear_head_learns_separable_data() {
        // two gaussian blobs in 8-d
        let mut rng = Rng::new(4);
        let mut feats = Vec::new();
        let mut labels = Vec::new();
        for i in 0..60 {
            let c = i % 2;
            let mut f = rng.normal_vec(8, 0.3);
            f[0] += if c == 0 { 1.0 } else { -1.0 };
            feats.push(f);
            labels.push(c);
        }
        let head = train_linear_head(&feats, &labels, 2, 200, 1.0);
        let correct = feats
            .iter()
            .zip(&labels)
            .filter(|(f, &y)| {
                let out = head.forward_fp32(&Tensor::from_vec((*f).clone(), &[8]));
                (out.data[1] > out.data[0]) as usize == y
            })
            .count();
        assert!(correct >= 55, "linear head only {correct}/60");
    }

    #[test]
    fn readout_makes_vgg_accurate() {
        // tiny check: trained readout beats chance on held-out data
        let model = crate::models::ModelId::Vgg16.build(32, 1, std::path::Path::new("artifacts"));
        let model = with_trained_readout(model, 160, 7);
        let (images, labels) = crate::data::labeled::labeled_imagenet_like(30, 32, 991);
        let correct = images
            .iter()
            .zip(&labels)
            .filter(|(img, &y)| {
                let out = model.graph.execute((*img).clone(), &mut Fp32Exec);
                let pred = out
                    .data
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                pred == y
            })
            .count();
        assert!(correct >= 9, "readout vgg only {correct}/30 (chance = 3)");
    }
}
