//! Minimal fixed-width table rendering for harness output, plus the
//! shared cell formatters (`ms`, `pct`, `db`) and JSON escaping that
//! every report module uses — one definition, so the qos/net/bench
//! readouts cannot drift apart column by column.

use crate::coordinator::StageRow;

/// A simple printable table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self { title: title.into(), header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..cols {
                line.push_str(&format!("{:>w$}  ", cells[i], w = widths[i]));
            }
            line.trim_end().to_string() + "\n"
        };
        out.push_str(&fmt_row(&self.header));
        out.push_str(&format!("{}\n", "-".repeat(widths.iter().sum::<usize>() + 2 * cols)));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a dB value like the paper's tables ("—" for NaN).
pub fn db(v: f64) -> String {
    if v.is_nan() {
        "-".to_string()
    } else if v.is_infinite() {
        "inf".to_string()
    } else {
        format!("{v:.4}")
    }
}

/// Format an accuracy-drop cell like Table 3 (4 decimal places, signed).
pub fn drop_cell(v: f64) -> String {
    format!("{v:.4}")
}

/// Format a millisecond latency cell (two decimals, the column style
/// shared by the qos and loadgen tables).
pub fn ms(v: f64) -> String {
    format!("{v:.2}")
}

/// Format a rate in `[0, 1]` as a percentage cell (one decimal).
pub fn pct(fraction: f64) -> String {
    format!("{:.1}", 100.0 * fraction)
}

/// Escape a string for inclusion in hand-rolled JSON output (the
/// offline image has no serde; every report writer shares this).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Per-(lane, stage) latency attribution table from flight-recorder
/// span rows — the single definition used by `qos_report`, the `top`
/// dashboard and anything else that prints stage breakdowns.
pub fn stage_table(rows: &[StageRow]) -> Table {
    let mut t = Table::new(
        "stage latency attribution (from span flight recorder, ms)",
        &["lane", "stage", "spans", "p50", "p99", "max"],
    );
    for r in rows {
        t.row(vec![
            r.lane.clone(),
            r.stage.to_string(),
            r.hist.count().to_string(),
            ms(r.hist.percentile(50.0) / 1000.0),
            ms(r.hist.percentile(99.0) / 1000.0),
            ms(r.hist.max() as f64 / 1000.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("100"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn db_formatting() {
        assert_eq!(db(f64::NAN), "-");
        assert_eq!(db(26.7227), "26.7227");
    }

    #[test]
    fn shared_cell_formatters() {
        assert_eq!(ms(4.236), "4.24");
        assert_eq!(pct(0.3333), "33.3");
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn stage_table_converts_us_to_ms() {
        let mut hist = crate::coordinator::LogHistogram::default();
        for v in [1000, 2000, 3000] {
            hist.record(v);
        }
        let rows = vec![StageRow { lane: "gold".into(), stage: "gemm", hist }];
        let s = stage_table(&rows).render();
        assert!(s.contains("gold"));
        assert!(s.contains("gemm"));
        assert!(s.contains('3'), "span count: {s}");
        // max 3000 µs renders as 3.00 ms, not 3000
        assert!(s.contains("3.00"), "ms conversion: {s}");
        assert!(!s.contains("3000"), "raw µs must not leak: {s}");
    }
}
