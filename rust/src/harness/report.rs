//! Minimal fixed-width table rendering for harness output.

/// A simple printable table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self { title: title.into(), header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..cols {
                line.push_str(&format!("{:>w$}  ", cells[i], w = widths[i]));
            }
            line.trim_end().to_string() + "\n"
        };
        out.push_str(&fmt_row(&self.header));
        out.push_str(&format!("{}\n", "-".repeat(widths.iter().sum::<usize>() + 2 * cols)));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a dB value like the paper's tables ("—" for NaN).
pub fn db(v: f64) -> String {
    if v.is_nan() {
        "-".to_string()
    } else if v.is_infinite() {
        "inf".to_string()
    } else {
        format!("{v:.4}")
    }
}

/// Format an accuracy-drop cell like Table 3 (4 decimal places, signed).
pub fn drop_cell(v: f64) -> String {
    format!("{v:.4}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("100"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn db_formatting() {
        assert_eq!(db(f64::NAN), "-");
        assert_eq!(db(26.7227), "26.7227");
    }
}
