//! Table 4 — experimental vs theoretical per-layer SNR on VGG-16.
//!
//! Runs the dual (FP32 ∥ BFP) instrumented forward over a batch, then
//! prints the three SNR columns: measured ("ex"), single-layer model
//! (eq. 18) and multi-layer model (eqs. 19–20 with the §4.3 propagation).

use super::report::{db, Table};
use crate::analysis::instrument::{InstrumentExec, LayerKind, LayerRecord};
use crate::analysis::multi_layer::{propagate_multi_layer, MultiLayerRow};
use crate::models::{Model, ModelId};
use crate::quant::BfpConfig;
use std::path::Path;

/// Full Table 4 data: per-layer records plus the multi-layer rows.
pub struct Table4Data {
    pub records: Vec<LayerRecord>,
    pub multi: Vec<MultiLayerRow>,
}

/// Gather the instrumented statistics over `n_images`.
pub fn gather(model: &Model, cfg: BfpConfig, n_images: usize, seed: u64) -> Table4Data {
    let size = model.input_shape[1];
    let images = crate::data::imagenet_like_batch(n_images, size, seed ^ 0x7AB1E4);
    let mut exec = InstrumentExec::new(cfg);
    for img in &images {
        exec.run_image(&model.graph, img);
    }
    let records = exec.finish();
    let multi = propagate_multi_layer(&records);
    Table4Data { records, multi }
}

/// Render Table 4 in the paper's layout: one row per (layer, quantity).
pub fn render(data: &Table4Data, title: &str) -> Table {
    let mut t = Table::new(title, &["layer", "", "ex SNR", "single SNR", "multi SNR"]);
    let mut multi_iter = data.multi.iter();
    let mut first_conv = true;
    for rec in &data.records {
        match rec.kind {
            LayerKind::Conv => {
                let m = multi_iter.next();
                let (m_in, m_w, m_out) = match (first_conv, m) {
                    // the paper leaves the first conv's multi column "—"
                    (true, _) => (f64::NAN, f64::NAN, f64::NAN),
                    (false, Some(r)) => (r.input_snr_db, r.weight_snr_db, r.output_snr_db),
                    (false, None) => (f64::NAN, f64::NAN, f64::NAN),
                };
                first_conv = false;
                t.row(vec![rec.name.clone(), "input".into(), db(rec.input_snr_ex_db), db(rec.input_snr_single_db), db(m_in)]);
                t.row(vec!["".into(), "weight".into(), db(rec.weight_snr_ex_db), db(rec.weight_snr_single_db), db(m_w)]);
                t.row(vec!["".into(), "output".into(), db(rec.output_snr_ex_db), db(rec.output_snr_single_db), db(m_out)]);
            }
            LayerKind::Relu => {
                t.row(vec!["".into(), "ReLU".into(), db(rec.output_snr_ex_db), "-".into(), "-".into()]);
            }
            LayerKind::Pool => {
                t.row(vec![rec.name.clone(), "max".into(), db(rec.output_snr_ex_db), "-".into(), "-".into()]);
            }
        }
    }
    t
}

/// Largest |theory − experiment| deviation over all conv outputs — the
/// paper's headline "< 8.9 dB" claim (using the multi-layer model).
pub fn max_deviation(data: &Table4Data) -> f64 {
    let mut max_dev = 0f64;
    let mut multi_iter = data.multi.iter();
    let mut first = true;
    for rec in data.records.iter().filter(|r| r.kind == LayerKind::Conv) {
        let m = multi_iter.next();
        if first {
            first = false;
            continue; // first conv has no multi prediction (matches paper)
        }
        if let Some(m) = m {
            let dev = (m.output_snr_db - rec.output_snr_ex_db).abs();
            if dev.is_finite() {
                max_dev = max_dev.max(dev);
            }
        }
    }
    max_dev
}

/// Convenience: the whole Table 4 experiment on VGG-16.
pub fn run(input_size: usize, n_images: usize, seed: u64, artifacts: &Path) -> (Table, f64) {
    let model = ModelId::Vgg16.build(input_size, seed, artifacts);
    let data = gather(&model, BfpConfig::paper_default(), n_images, seed);
    let dev = max_deviation(&data);
    let t = render(
        &data,
        &format!("Table 4 — VGG-16 per-layer SNR, L_W=L_I=8 ({n_images} images); max multi-vs-ex deviation {dev:.2} dB"),
    );
    (t, dev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelId;

    #[test]
    fn vgg_table4_small_run() {
        let model = ModelId::Vgg16.build(32, 1, Path::new("artifacts"));
        let data = gather(&model, BfpConfig::paper_default(), 1, 3);
        // 13 convs, 13+2 relus (fc relus counted too), 5 pools
        let convs = data.records.iter().filter(|r| r.kind == LayerKind::Conv).count();
        assert_eq!(convs, 13);
        assert_eq!(data.multi.len(), 13);
        // theory vs experiment within the paper's tolerance band
        let dev = max_deviation(&data);
        assert!(dev < 12.0, "multi model deviation {dev} dB too large");
        let t = render(&data, "t4");
        assert!(t.rows.len() > 13 * 3);
    }
}
