//! Dual-forward instrumentation: run FP32 and BFP paths in lock-step and
//! record per-layer signal/error energies — the machinery behind Table 4's
//! "ex SNR" column and the statistics the §4 theory consumes.
//!
//! Energies accumulate across a whole batch of images (the paper gathers
//! 20 iterations × batch 50); SNRs are computed from the energy totals at
//! reporting time.

use super::snr::{quant_error_variance, snr_db, theoretical_per_row_snr};
use crate::bfp::{bfp_gemm, max_exponent, BfpMatrix};
use crate::nn::graph::Executor;
use crate::nn::prepared::WeightCache;
use crate::nn::{ops, BatchNorm, Conv2d, Dense};
use crate::quant::{BfpConfig, LayerSchedule};
use crate::tensor::{avg_pool2d, global_avg_pool, max_pool2d, Tensor};

/// Which Table 4 row family a record belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Relu,
    Pool,
}

/// Finished per-layer record (all values in dB; non-applicable fields are
/// `f64::NAN`, matching the "—" cells of Table 4).
#[derive(Debug, Clone)]
pub struct LayerRecord {
    pub name: String,
    pub kind: LayerKind,
    /// Measured input SNR (conv rows): FP32 im2col vs block-formatted BFP im2col.
    pub input_snr_ex_db: f64,
    /// Measured weight quantization SNR (conv rows).
    pub weight_snr_ex_db: f64,
    /// Measured output SNR: FP32 output vs BFP output (all rows).
    pub output_snr_ex_db: f64,
    /// Single-layer theoretical input SNR — eqs. (9)–(10).
    pub input_snr_single_db: f64,
    /// Single-layer theoretical weight SNR — eqs. (11)–(13).
    pub weight_snr_single_db: f64,
    /// Single-layer theoretical output SNR — eq. (18).
    pub output_snr_single_db: f64,
}

#[derive(Debug, Clone, Default)]
struct Accum {
    name: String,
    kind: Option<LayerKind>,
    // measured energies
    sig_in: f64,
    err_in: f64,
    sig_w: f64,
    err_w: f64,
    sig_out: f64,
    err_out: f64,
    // single-layer theory accumulators
    theory_in_sig: f64,
    theory_in_noise: f64,
    theory_w_snr_db: f64,
    w_done: bool,
}

/// The dual executor. Thread a `(fp32, bfp)` pair of tensors through the
/// graph; conv layers run both data flows and record everything.
///
/// Precision is a per-layer [`LayerSchedule`], so the same machinery
/// measures the paper's uniform sweeps ([`InstrumentExec::new`]) and the
/// mixed-precision plans of [`crate::autotune`]
/// ([`InstrumentExec::with_schedule`]).
pub struct InstrumentExec {
    pub schedule: LayerSchedule,
    accums: Vec<Accum>,
    cursor: usize,
    relu_count: usize,
    /// Weights are static: quantize once per `(layer, weight format)`
    /// instead of once per image — and, via
    /// [`InstrumentExec::with_schedule_and_cache`], once per autotune
    /// refinement *loop* instead of once per candidate.
    cache: WeightCache,
}

/// The edge state: FP32 tensor and its BFP-path twin.
#[derive(Clone)]
pub struct DualTensor {
    pub fp: Tensor,
    pub bfp: Tensor,
}

impl InstrumentExec {
    /// Uniform precision across every conv layer.
    pub fn new(cfg: BfpConfig) -> Self {
        Self::with_schedule(LayerSchedule::uniform(cfg))
    }

    /// Per-layer precision (dual-forward measurement of a mixed plan).
    pub fn with_schedule(schedule: LayerSchedule) -> Self {
        Self::with_schedule_and_cache(schedule, WeightCache::default())
    }

    /// [`InstrumentExec::with_schedule`] seeded with an existing weight
    /// cache, so repeated measurements (the autotuner's refine loop) skip
    /// quantizing layers whose config is unchanged from prior candidates.
    pub fn with_schedule_and_cache(schedule: LayerSchedule, cache: WeightCache) -> Self {
        Self { schedule, accums: Vec::new(), cursor: 0, relu_count: 0, cache }
    }

    /// Recover the weight cache to seed the next measurement.
    pub fn into_cache(self) -> WeightCache {
        self.cache
    }

    /// Run one image through the model, accumulating statistics.
    pub fn run_image(&mut self, graph: &crate::nn::Block, input: &Tensor) -> DualTensor {
        self.cursor = 0;
        self.relu_count = 0;
        graph.execute(DualTensor { fp: input.clone(), bfp: input.clone() }, self)
    }

    fn slot(&mut self, name: &str, kind: LayerKind) -> &mut Accum {
        if self.cursor == self.accums.len() {
            self.accums.push(Accum { name: name.to_string(), kind: Some(kind), ..Default::default() });
        }
        let a = &mut self.accums[self.cursor];
        debug_assert_eq!(a.name, name, "instrumentation order diverged");
        self.cursor += 1;
        a
    }

    /// Finish: convert accumulated energies to dB records.
    pub fn finish(&self) -> Vec<LayerRecord> {
        self.accums
            .iter()
            .map(|a| {
                let kind = a.kind.unwrap_or(LayerKind::Conv);
                let (in_ex, w_ex, in_single, w_single) = if kind == LayerKind::Conv {
                    (
                        snr_db(a.sig_in, a.err_in),
                        snr_db(a.sig_w, a.err_w),
                        snr_db(a.theory_in_sig, a.theory_in_noise),
                        a.theory_w_snr_db,
                    )
                } else {
                    (f64::NAN, f64::NAN, f64::NAN, f64::NAN)
                };
                let out_single = if kind == LayerKind::Conv {
                    super::single_layer::output_snr_db(in_single, w_single)
                } else {
                    f64::NAN
                };
                LayerRecord {
                    name: a.name.clone(),
                    kind,
                    input_snr_ex_db: in_ex,
                    weight_snr_ex_db: w_ex,
                    output_snr_ex_db: snr_db(a.sig_out, a.err_out),
                    input_snr_single_db: in_single,
                    weight_snr_single_db: w_single,
                    output_snr_single_db: out_single,
                }
            })
            .collect()
    }
}

fn energy_pair(reference: &[f32], distorted: &[f32]) -> (f64, f64) {
    let mut sig = 0f64;
    let mut err = 0f64;
    for (&a, &b) in reference.iter().zip(distorted) {
        sig += (a as f64) * (a as f64);
        err += ((b - a) as f64) * ((b - a) as f64);
    }
    (sig, err)
}

impl Executor for InstrumentExec {
    type T = DualTensor;

    fn conv(&mut self, layer: &Conv2d, x: DualTensor) -> DualTensor {
        let cfg = self.schedule.for_layer(&layer.name);
        // FP32 reference path
        let fp_out = layer.forward_fp32(&x.fp);

        // BFP path, expanded so intermediates can be measured
        let (col_bfp, geo) = layer.im2col(&x.bfp);
        let (col_fp, _) = layer.im2col(&x.fp);
        let (m, k, n) = (layer.out_channels(), geo.k(), geo.n());
        debug_assert_eq!(layer.weights.len(), m * k);
        let wq = self.cache.get_or_quantize(layer, cfg).wq;
        let iq = BfpMatrix::quantize(&col_bfp, k, n, cfg.i_format(), cfg.scheme.i_axis());

        // measured input SNR: clean FP32 signal vs the BFP path's
        // quantized input (inherited error + fresh quantization)
        let iq_back = iq.to_f32();
        let (sig_in, err_in) = energy_pair(&col_fp, &iq_back);

        // single-layer theory on the clean signal (eqs. 9–10)
        let theory_noise = max_exponent(&col_fp)
            .map(|eps| quant_error_variance(cfg.i_format(), eps) * col_fp.len() as f64)
            .unwrap_or(0.0);
        let theory_sig: f64 = col_fp.iter().map(|&v| (v as f64) * (v as f64)).sum();

        // integer-domain GEMM + bias (the Figure 2 data flow)
        let mut out = bfp_gemm(&wq, &iq).data;
        if !layer.bias.is_empty() {
            for (oc, &b) in layer.bias.iter().enumerate() {
                for v in &mut out[oc * n..(oc + 1) * n] {
                    *v += b;
                }
            }
        }
        let bfp_out = Tensor::from_vec(out, &[m, geo.out_h(), geo.out_w()]);
        let (sig_out, err_out) = energy_pair(&fp_out.data, &bfp_out.data);

        let name = layer.name.clone();
        let w_fmt = cfg.w_format();
        let a = self.slot(&name, LayerKind::Conv);
        a.sig_in += sig_in;
        a.err_in += err_in;
        a.theory_in_sig += theory_sig;
        a.theory_in_noise += theory_noise;
        a.sig_out += sig_out;
        a.err_out += err_out;
        if !a.w_done {
            let (sig_w, err_w) = energy_pair(&layer.weights.data, &wq.to_f32());
            a.sig_w = sig_w;
            a.err_w = err_w;
            a.theory_w_snr_db = theoretical_per_row_snr(&layer.weights.data, m, k, w_fmt);
            a.w_done = true;
        }

        DualTensor { fp: fp_out, bfp: bfp_out }
    }

    fn dense(&mut self, layer: &Dense, x: DualTensor) -> DualTensor {
        // FC layers stay FP32 in the paper's port; no record.
        DualTensor { fp: layer.forward_fp32(&x.fp), bfp: layer.forward_fp32(&x.bfp) }
    }

    fn batch_norm(&mut self, layer: &BatchNorm, x: DualTensor) -> DualTensor {
        DualTensor { fp: layer.forward(&x.fp), bfp: layer.forward(&x.bfp) }
    }

    fn relu(&mut self, x: DualTensor) -> DualTensor {
        let fp = ops::relu(&x.fp);
        let bfp = ops::relu(&x.bfp);
        let (sig, err) = energy_pair(&fp.data, &bfp.data);
        self.relu_count += 1;
        let name = format!("relu_{}", self.relu_count);
        let a = self.slot(&name, LayerKind::Relu);
        a.sig_out += sig;
        a.err_out += err;
        DualTensor { fp, bfp }
    }

    fn max_pool(&mut self, name: &str, k: usize, s: usize, p: usize, x: DualTensor) -> DualTensor {
        let fp = max_pool2d(&x.fp, k, s, p);
        let bfp = max_pool2d(&x.bfp, k, s, p);
        let (sig, err) = energy_pair(&fp.data, &bfp.data);
        let a = self.slot(name, LayerKind::Pool);
        a.sig_out += sig;
        a.err_out += err;
        DualTensor { fp, bfp }
    }

    fn avg_pool(&mut self, name: &str, k: usize, s: usize, p: usize, x: DualTensor) -> DualTensor {
        let fp = avg_pool2d(&x.fp, k, s, p);
        let bfp = avg_pool2d(&x.bfp, k, s, p);
        let (sig, err) = energy_pair(&fp.data, &bfp.data);
        let a = self.slot(name, LayerKind::Pool);
        a.sig_out += sig;
        a.err_out += err;
        DualTensor { fp, bfp }
    }

    fn global_avg_pool(&mut self, x: DualTensor) -> DualTensor {
        DualTensor { fp: global_avg_pool(&x.fp), bfp: global_avg_pool(&x.bfp) }
    }

    fn flatten(&mut self, x: DualTensor) -> DualTensor {
        DualTensor { fp: ops::flatten(&x.fp), bfp: ops::flatten(&x.bfp) }
    }

    fn add(&mut self, a: DualTensor, b: DualTensor) -> DualTensor {
        DualTensor { fp: ops::add(&a.fp, &b.fp), bfp: ops::add(&a.bfp, &b.bfp) }
    }

    fn concat(&mut self, parts: Vec<DualTensor>) -> DualTensor {
        let fps: Vec<Tensor> = parts.iter().map(|p| p.fp.clone()).collect();
        let bfps: Vec<Tensor> = parts.iter().map(|p| p.bfp.clone()).collect();
        DualTensor { fp: ops::concat_channels(&fps), bfp: ops::concat_channels(&bfps) }
    }

    fn softmax(&mut self, x: DualTensor) -> DualTensor {
        DualTensor { fp: ops::softmax(&x.fp), bfp: ops::softmax(&x.bfp) }
    }

    fn fork(&mut self, x: &DualTensor) -> DualTensor {
        x.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::nn::Block;

    fn two_conv_model(seed: u64) -> Block {
        let mut rng = Rng::new(seed);
        Block::seq(vec![
            Block::Conv(crate::models::init::conv2d("conv1", 8, 2, 3, 3, 1, 1, &mut rng)),
            Block::ReLU,
            Block::MaxPool { name: "pool1".into(), k: 2, s: 2, p: 0 },
            Block::Conv(crate::models::init::conv2d("conv2", 8, 8, 3, 3, 1, 1, &mut rng)),
            Block::ReLU,
        ])
    }

    fn image(seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::from_vec(rng.normal_vec(2 * 12 * 12, 1.0), &[2, 12, 12])
    }

    #[test]
    fn records_in_graph_order() {
        let m = two_conv_model(1);
        let mut exec = InstrumentExec::new(BfpConfig::paper_default());
        exec.run_image(&m, &image(2));
        let recs = exec.finish();
        let names: Vec<&str> = recs.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["conv1", "relu_1", "pool1", "conv2", "relu_2"]);
    }

    #[test]
    fn accumulates_across_images() {
        let m = two_conv_model(1);
        let mut exec = InstrumentExec::new(BfpConfig::paper_default());
        for s in 0..4 {
            exec.run_image(&m, &image(s));
        }
        let recs = exec.finish();
        assert_eq!(recs.len(), 5);
        for r in recs.iter().filter(|r| r.kind == LayerKind::Conv) {
            assert!(r.input_snr_ex_db.is_finite());
            assert!(r.output_snr_ex_db.is_finite());
        }
    }

    /// The single-layer theory should predict the measured quantization
    /// SNRs to within ~1.5 dB on the first layer (no inherited error).
    #[test]
    fn first_layer_theory_close_to_measurement() {
        let m = two_conv_model(3);
        let mut exec = InstrumentExec::new(BfpConfig::paper_default());
        for s in 0..3 {
            exec.run_image(&m, &image(100 + s));
        }
        let recs = exec.finish();
        let c1 = &recs[0];
        assert!(
            (c1.input_snr_single_db - c1.input_snr_ex_db).abs() < 1.5,
            "input theory {} vs ex {}",
            c1.input_snr_single_db,
            c1.input_snr_ex_db
        );
        assert!(
            (c1.weight_snr_single_db - c1.weight_snr_ex_db).abs() < 1.5,
            "weight theory {} vs ex {}",
            c1.weight_snr_single_db,
            c1.weight_snr_ex_db
        );
    }

    /// Second conv's measured input SNR must be lower than the fresh-
    /// quantization theory alone predicts (it inherits layer-1 error).
    #[test]
    fn inherited_error_visible_at_layer2() {
        let m = two_conv_model(5);
        let mut exec = InstrumentExec::new(BfpConfig::new(6, 6));
        for s in 0..3 {
            exec.run_image(&m, &image(200 + s));
        }
        let recs = exec.finish();
        let c2 = recs.iter().find(|r| r.name == "conv2").unwrap();
        assert!(
            c2.input_snr_ex_db < c2.input_snr_single_db + 0.5,
            "ex {} should sit below single-layer theory {}",
            c2.input_snr_ex_db,
            c2.input_snr_single_db
        );
    }

    /// ReLU must pass SNR through roughly unchanged (§4.4).
    #[test]
    fn relu_preserves_snr() {
        let m = two_conv_model(7);
        let mut exec = InstrumentExec::new(BfpConfig::paper_default());
        for s in 0..3 {
            exec.run_image(&m, &image(300 + s));
        }
        let recs = exec.finish();
        let conv_out = recs[0].output_snr_ex_db;
        let relu_out = recs[1].output_snr_ex_db;
        assert!((conv_out - relu_out).abs() < 1.5, "conv {conv_out} vs relu {relu_out}");
    }
}
