//! The multi-layer error-propagation model of §4.3.
//!
//! Layer `l`'s BFP input carries two noise terms relative to the clean
//! FP32 signal `Y`: the error inherited from the previous layer's output
//! (`σ₁² = η₁·E(Y²)`) and the fresh block-formatting quantization error
//! (`σ₂²`). Eq. (19) measures the fresh error against the carried signal:
//! `η₂ = σ₂² / (E(Y²) + σ₁²)`. The total input NSR is then
//!
//! ```text
//! η_in = (σ₁² + σ₂²) / E(Y²) = η₁ + η₂ + η₁·η₂
//! ```
//!
//! **Paper erratum**: eq. (20) prints `η = η₂ + η₁η₂`, dropping the
//! standalone `η₁` term. Back-solving the paper's own Table 4 numbers
//! (e.g. conv1_2 multi input 26.7227 dB from conv1_1 output 39.8845 dB and
//! single-layer input 26.9376 dB) reproduces the table only with the full
//! `η₁ + η₂ + η₁η₂`; we implement that and flag the erratum here and in
//! EXPERIMENTS.md.
//!
//! Propagation rules decoded from Table 4:
//! * ReLU passes NSR through unchanged (§4.4's uniform-sign argument).
//! * After a pooling layer the model re-anchors on the pool's *measured*
//!   output SNR (§4.4 "we take the output SNR of pooling layer as the
//!   input SNR of next layer") — pooling's effect is not modelled.
//! * Weight SNR uses the single-layer theoretical value (weights carry no
//!   inherited error).

use super::instrument::{LayerKind, LayerRecord};
use super::single_layer::output_nsr;
use super::snr::{db_to_nsr, nsr_to_db};

/// One conv row of the multi-layer model (Table 4's "multi SNR" column).
#[derive(Debug, Clone)]
pub struct MultiLayerRow {
    pub name: String,
    /// Multi-model input SNR (dB).
    pub input_snr_db: f64,
    /// Weight SNR (theoretical, same as single-layer column).
    pub weight_snr_db: f64,
    /// Multi-model output SNR (dB).
    pub output_snr_db: f64,
}

/// Fresh-quantization NSR `η₂` given the single-layer input NSR and the
/// inherited NSR `η₁` — eq. (19) rearranged: the fresh error variance is
/// unchanged, but eq. (19) normalises it by the carried energy
/// `E(Y²)·(1 + η₁)`.
pub fn eta2(eta_single_input: f64, eta1: f64) -> f64 {
    eta_single_input / (1.0 + eta1)
}

/// Total input NSR: `η₁ + η₂ + η₁·η₂` (corrected eq. 20 — see module doc).
pub fn total_input_nsr(eta1: f64, eta2: f64) -> f64 {
    eta1 + eta2 + eta1 * eta2
}

/// Run the §4.3 propagation over an instrumented layer sequence
/// (as recorded by [`super::instrument::InstrumentExec`] on a sequential
/// network such as VGG-16).
///
/// For each conv layer the model consumes:
/// * its single-layer theoretical input SNR (fresh quantization),
/// * its theoretical weight SNR,
/// * the measured output SNR of any pooling layer crossed since the
///   previous conv (the model re-anchors there).
pub fn propagate_multi_layer(records: &[LayerRecord]) -> Vec<MultiLayerRow> {
    let mut rows = Vec::new();
    // NSR of the signal arriving at the next conv (None before the first).
    let mut carried: Option<f64> = None;
    for rec in records {
        match rec.kind {
            LayerKind::Conv => {
                let eta_single_in = db_to_nsr(rec.input_snr_single_db);
                let (input_nsr, input_snr_db) = match carried {
                    None => (eta_single_in, rec.input_snr_single_db),
                    Some(eta1) => {
                        let e2 = eta2(eta_single_in, eta1);
                        let total = total_input_nsr(eta1, e2);
                        (total, nsr_to_db(total))
                    }
                };
                let eta_w = db_to_nsr(rec.weight_snr_single_db);
                let out_nsr = output_nsr(input_nsr, eta_w);
                rows.push(MultiLayerRow {
                    name: rec.name.clone(),
                    input_snr_db,
                    weight_snr_db: rec.weight_snr_single_db,
                    output_snr_db: nsr_to_db(out_nsr),
                });
                carried = Some(out_nsr);
            }
            LayerKind::Relu => {
                // NSR unchanged through ReLU (§4.4).
            }
            LayerKind::Pool => {
                // Re-anchor on the measured pool output SNR.
                if rec.output_snr_ex_db.is_finite() {
                    carried = Some(db_to_nsr(rec.output_snr_ex_db));
                }
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reproduce the paper's own Table 4 chain for conv1_1 → conv1_2 from
    /// the published single-layer numbers — the strongest evidence for the
    /// erratum-corrected eq. (20).
    #[test]
    fn paper_table4_conv1_2_chain() {
        // conv1_1: single input 41.8047, weight 44.3538 → output 39.8845
        let out1 = output_nsr(db_to_nsr(41.8047), db_to_nsr(44.3538));
        assert!((nsr_to_db(out1) - 39.8845).abs() < 0.01);
        // conv1_2 multi input from single input 26.9376:
        let eta1 = out1;
        let e2 = eta2(db_to_nsr(26.9376), eta1);
        let total = total_input_nsr(eta1, e2);
        let multi_in_db = nsr_to_db(total);
        assert!((multi_in_db - 26.7227).abs() < 0.03, "{multi_in_db}");
        // conv1_2 multi output with weight 37.3569 → 26.3628
        let out2 = nsr_to_db(output_nsr(total, db_to_nsr(37.3569)));
        assert!((out2 - 26.3628).abs() < 0.03, "{out2}");
    }

    /// Crossing pool1 re-anchors on the measured pool SNR: the paper's
    /// conv2_1 multi input (28.5668) follows from pool1's ex SNR (36.3581)
    /// and conv2_1's single input (29.3567).
    #[test]
    fn paper_table4_pool_reanchor() {
        let eta1 = db_to_nsr(36.3581);
        let e2 = eta2(db_to_nsr(29.3567), eta1);
        let multi_in = nsr_to_db(total_input_nsr(eta1, e2));
        assert!((multi_in - 28.5668).abs() < 0.03, "{multi_in}");
    }

    /// The literal (erratum) eq. 20 `η₂ + η₁η₂` would NOT reproduce the
    /// table — it collapses to ~the single-layer value.
    #[test]
    fn erratum_formula_fails_table4() {
        let eta1 = output_nsr(db_to_nsr(41.8047), db_to_nsr(44.3538));
        let e2 = eta2(db_to_nsr(26.9376), eta1);
        let literal = nsr_to_db(e2 + eta1 * e2);
        assert!((literal - 26.7227).abs() > 0.15, "literal formula unexpectedly matches: {literal}");
    }

    #[test]
    fn propagation_on_synthetic_records() {
        use crate::analysis::instrument::{LayerKind, LayerRecord};
        let conv = |name: &str, single_in: f64, w: f64| LayerRecord {
            name: name.into(),
            kind: LayerKind::Conv,
            input_snr_ex_db: 0.0,
            weight_snr_ex_db: 0.0,
            output_snr_ex_db: 0.0,
            input_snr_single_db: single_in,
            weight_snr_single_db: w,
            output_snr_single_db: 0.0,
        };
        let pool = |name: &str, ex: f64| LayerRecord {
            name: name.into(),
            kind: LayerKind::Pool,
            input_snr_ex_db: 0.0,
            weight_snr_ex_db: 0.0,
            output_snr_ex_db: ex,
            input_snr_single_db: 0.0,
            weight_snr_single_db: 0.0,
            output_snr_single_db: 0.0,
        };
        let recs = vec![conv("c1", 40.0, 44.0), conv("c2", 27.0, 37.0), pool("p1", 36.0), conv("c3", 29.0, 35.0)];
        let rows = propagate_multi_layer(&recs);
        assert_eq!(rows.len(), 3);
        // first conv: multi == single
        assert!((rows[0].input_snr_db - 40.0).abs() < 1e-9);
        // later convs are strictly noisier than their single-layer inputs
        assert!(rows[1].input_snr_db < 27.0);
        assert!(rows[2].input_snr_db < 29.0);
        // output always noisier than input
        for r in &rows {
            assert!(r.output_snr_db < r.input_snr_db);
        }
    }
}
