//! Normalized-magnitude energy distributions — Figure 3.
//!
//! The paper explains its worst theory-vs-experiment deviation (conv1_2,
//! 8.9 dB) by showing that strongly filter-correlated layers concentrate
//! their output *energy* at large normalized magnitudes. The histogram
//! here reproduces that diagnostic: bucket |x|/max|x| and accumulate x²
//! per bucket, normalised to sum 1.

/// An energy histogram over normalized magnitude `|x|/max|x| ∈ [0, 1]`.
#[derive(Debug, Clone)]
pub struct EnergyHistogram {
    /// Left edge of each bucket (uniform width).
    pub edges: Vec<f64>,
    /// Energy fraction per bucket (sums to 1 for nonzero input).
    pub fractions: Vec<f64>,
}

impl EnergyHistogram {
    /// Build a `bins`-bucket histogram of the energy distribution.
    pub fn compute(values: &[f32], bins: usize) -> Self {
        assert!(bins > 0);
        let max = values.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let mut energy = vec![0f64; bins];
        let mut total = 0f64;
        if max > 0.0 {
            for &v in values {
                let e = (v as f64) * (v as f64);
                let idx = (((v.abs() / max) as f64) * bins as f64).min(bins as f64 - 1.0) as usize;
                energy[idx] += e;
                total += e;
            }
        }
        if total > 0.0 {
            for e in &mut energy {
                *e /= total;
            }
        }
        let edges = (0..bins).map(|i| i as f64 / bins as f64).collect();
        Self { edges, fractions: energy }
    }

    /// Fraction of total energy at normalized magnitude ≥ `threshold`
    /// (Figure 3 plots the [0.8, 1.0] region).
    pub fn tail_energy(&self, threshold: f64) -> f64 {
        self.edges
            .iter()
            .zip(&self.fractions)
            .filter(|(e, _)| **e + 1.0 / self.edges.len() as f64 > threshold + 1e-12)
            .map(|(_, f)| f)
            .sum()
    }
}

/// Correlation proxy used in §4.4's discussion: layers whose filters
/// strongly match their inputs produce outputs with a heavy large-value
/// energy tail. Returns the [0.8, 1.0] tail fraction.
pub fn large_value_energy_fraction(values: &[f32]) -> f64 {
    EnergyHistogram::compute(values, 50).tail_energy(0.8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn fractions_sum_to_one() {
        let mut rng = Rng::new(1);
        let xs: Vec<f32> = rng.normal_vec(10_000, 2.0);
        let h = EnergyHistogram::compute(&xs, 20);
        let sum: f64 = h.fractions.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn all_equal_values_land_in_top_bucket() {
        let xs = vec![3.0f32; 100];
        let h = EnergyHistogram::compute(&xs, 10);
        assert!((h.fractions[9] - 1.0).abs() < 1e-12);
        assert!((h.tail_energy(0.8) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_input_is_all_zero() {
        let h = EnergyHistogram::compute(&[0.0; 10], 10);
        assert!(h.fractions.iter().all(|&f| f == 0.0));
    }

    #[test]
    fn heavy_tail_detected() {
        // one large value among small noise holds most of the energy
        let mut rng = Rng::new(2);
        let mut xs: Vec<f32> = rng.normal_vec(1000, 0.01);
        xs.push(10.0);
        let frac = large_value_energy_fraction(&xs);
        assert!(frac > 0.9, "{frac}");
    }

    #[test]
    fn gaussian_tail_is_light() {
        let mut rng = Rng::new(3);
        let xs: Vec<f32> = rng.normal_vec(100_000, 1.0);
        let frac = large_value_energy_fraction(&xs);
        assert!(frac < 0.2, "{frac}");
    }
}
