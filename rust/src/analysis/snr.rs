//! SNR/NSR arithmetic and the §4.1 quantization-error theory.

use crate::bfp::{max_exponent, BfpFormat};

/// `SNR[dB] = 10·log10(signal_energy / noise_energy)` (eq. 9 shape).
/// Returns `f64::INFINITY` for zero noise.
pub fn snr_db(signal_energy: f64, noise_energy: f64) -> f64 {
    if noise_energy <= 0.0 {
        return f64::INFINITY;
    }
    10.0 * (signal_energy / noise_energy).log10()
}

/// NSR `η = 10^(-SNR/10)` (the conversion below eq. 15).
pub fn db_to_nsr(snr_db: f64) -> f64 {
    10f64.powf(-snr_db / 10.0)
}

/// `SNR[dB] = -10·log10(η)`.
pub fn nsr_to_db(nsr: f64) -> f64 {
    -10.0 * nsr.log10()
}

/// Theoretical quantization-error variance of a block with exponent `ε`
/// under `fmt` — eq. (8): `σ² = 2^(-2·Lm)/12 · 2^(2ε)` with
/// `Lm = fmt.frac_bits()` (the deterministic-exponent case, eq. 7).
pub fn quant_error_variance(fmt: BfpFormat, eps: i32) -> f64 {
    fmt.error_variance(eps)
}

/// The general eq. (6) variance: quantization-error variance when the
/// block exponent is a random variable with PMF `p(γ_i)` over exponent
/// levels — `σ² = 2^(-2·Lm)/12 · Σ_i p_i · 2^(2γ_i)`. Eq. (7)/(8) is the
/// deterministic special case (`p = δ_ε`), recovered exactly when the PMF
/// has a single unit mass.
pub fn pmf_error_variance(fmt: BfpFormat, exponent_pmf: &[(i32, f64)]) -> f64 {
    let total: f64 = exponent_pmf.iter().map(|(_, p)| p).sum();
    assert!((total - 1.0).abs() < 1e-9, "PMF must sum to 1, got {total}");
    let lm = fmt.frac_bits();
    exponent_pmf
        .iter()
        .map(|&(gamma, p)| p * 2f64.powi(2 * (gamma - lm)) / 12.0)
        .sum()
}

/// Estimate the block-exponent PMF empirically from a stream of blocks —
/// feed each block's max exponent; returns `(γ, p)` pairs for
/// [`pmf_error_variance`]. This is how eq. (6) is used when the input
/// distribution (not a concrete batch) is the design input.
pub fn estimate_exponent_pmf(block_exponents: &[i32]) -> Vec<(i32, f64)> {
    let mut counts = std::collections::BTreeMap::new();
    for &e in block_exponents {
        *counts.entry(e).or_insert(0usize) += 1;
    }
    let n = block_exponents.len().max(1) as f64;
    counts.into_iter().map(|(e, c)| (e, c as f64 / n)).collect()
}

/// Theoretical SNR of block-formatting `values` as ONE block under `fmt`
/// (eqs. 9–10): `E(Y²) / σ²`.
pub fn theoretical_block_snr(values: &[f32], fmt: BfpFormat) -> f64 {
    let Some(eps) = max_exponent(values) else {
        return f64::INFINITY;
    };
    let e_y2 = values.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / values.len() as f64;
    snr_db(e_y2, quant_error_variance(fmt, eps))
}

/// Theoretical averaged SNR of a per-row block-formatted matrix
/// (eqs. 11–13): `Σ_m E(X_m²) / Σ_m σ_wm²`.
pub fn theoretical_per_row_snr(data: &[f32], rows: usize, cols: usize, fmt: BfpFormat) -> f64 {
    assert_eq!(data.len(), rows * cols);
    let mut sum_e = 0f64;
    let mut sum_sigma = 0f64;
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        let e_x2 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / cols as f64;
        sum_e += e_x2;
        if let Some(eps) = max_exponent(row) {
            sum_sigma += quant_error_variance(fmt, eps);
        }
    }
    snr_db(sum_e, sum_sigma)
}

/// Measured SNR between a reference signal and its distorted version.
pub fn measured_snr(signal: &[f32], distorted: &[f32]) -> f64 {
    assert_eq!(signal.len(), distorted.len());
    let sig: f64 = signal.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let err: f64 = signal.iter().zip(distorted).map(|(&a, &b)| ((b - a) as f64).powi(2)).sum();
    snr_db(sig, err)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::dequantize;
    use crate::data::Rng;

    #[test]
    fn db_conversions_roundtrip() {
        for snr in [0.0, 10.0, 23.7, 40.0] {
            assert!((nsr_to_db(db_to_nsr(snr)) - snr).abs() < 1e-12);
        }
        assert!((db_to_nsr(10.0) - 0.1).abs() < 1e-15);
        assert!((db_to_nsr(20.0) - 0.01).abs() < 1e-15);
    }

    #[test]
    fn snr_db_basics() {
        assert_eq!(snr_db(100.0, 1.0), 20.0);
        assert!(snr_db(1.0, 0.0).is_infinite());
    }

    /// The eq. (8) theory must predict the measured quantization SNR of a
    /// uniform block to within a fraction of a dB.
    #[test]
    fn theory_matches_measurement_uniform() {
        let mut rng = Rng::new(1);
        let fmt = BfpFormat::new(8);
        let xs: Vec<f32> = (0..200_000).map(|_| rng.uniform_range(-1.9, 1.9) as f32).collect();
        let theory = theoretical_block_snr(&xs, fmt);
        let measured = measured_snr(&xs, &dequantize(&xs, fmt));
        assert!(
            (theory - measured).abs() < 0.3,
            "theory {theory:.2} dB vs measured {measured:.2} dB"
        );
    }

    /// Gaussian data: rounding error is still ±Δ/2-uniform, so eq. (8)
    /// stays accurate even though the signal is not uniform.
    #[test]
    fn theory_matches_measurement_gaussian() {
        let mut rng = Rng::new(2);
        let fmt = BfpFormat::new(9);
        let xs: Vec<f32> = rng.normal_vec(200_000, 0.25);
        let theory = theoretical_block_snr(&xs, fmt);
        let measured = measured_snr(&xs, &dequantize(&xs, fmt));
        assert!(
            (theory - measured).abs() < 0.5,
            "theory {theory:.2} dB vs measured {measured:.2} dB"
        );
    }

    #[test]
    fn per_row_beats_whole_when_rows_differ_in_scale() {
        // rows at wildly different scales: per-row theory must predict
        // higher SNR than whole-matrix theory
        let mut rng = Rng::new(3);
        let rows = 32;
        let cols = 256;
        let mut data = Vec::new();
        for r in 0..rows {
            let scale = 2f64.powi(-(r as i32 % 8));
            data.extend(rng.normal_vec(cols, scale * 0.3));
        }
        let fmt = BfpFormat::new(8);
        let per_row = theoretical_per_row_snr(&data, rows, cols, fmt);
        let whole = theoretical_block_snr(&data, fmt);
        assert!(per_row > whole + 3.0, "per_row {per_row:.1} vs whole {whole:.1}");
    }

    #[test]
    fn pmf_variance_degenerates_to_eq8() {
        let fmt = BfpFormat::new(8);
        let v6 = pmf_error_variance(fmt, &[(3, 1.0)]);
        assert!((v6 - quant_error_variance(fmt, 3)).abs() < 1e-18);
    }

    #[test]
    fn pmf_variance_mixes_levels() {
        let fmt = BfpFormat::new(8);
        let mixed = pmf_error_variance(fmt, &[(0, 0.5), (2, 0.5)]);
        let lo = quant_error_variance(fmt, 0);
        let hi = quant_error_variance(fmt, 2);
        assert!((mixed - 0.5 * (lo + hi)).abs() < 1e-18);
        assert!(mixed > lo && mixed < hi);
    }

    #[test]
    fn pmf_estimation_from_blocks() {
        let pmf = estimate_exponent_pmf(&[1, 1, 2, 3]);
        assert_eq!(pmf, vec![(1, 0.5), (2, 0.25), (3, 0.25)]);
        // eq. (6) over the estimated PMF == average of per-block eq. (8)
        let fmt = BfpFormat::new(8);
        let via_pmf = pmf_error_variance(fmt, &pmf);
        let direct: f64 = [1, 1, 2, 3].iter().map(|&e| quant_error_variance(fmt, e)).sum::<f64>() / 4.0;
        assert!((via_pmf - direct).abs() < 1e-18);
    }

    #[test]
    fn stochastic_rounding_unbiased_lower_snr() {
        use crate::bfp::format::Rounding;
        use crate::bfp::BfpFormat as F;
        let mut rng = Rng::new(77);
        let xs: Vec<f32> = (0..100_000).map(|_| rng.uniform_range(0.5, 1.9) as f32).collect();
        let fmt_s = F { total_bits: 8, rounding: Rounding::Stochastic };
        let ys = dequantize(&xs, fmt_s);
        let bias: f64 =
            xs.iter().zip(&ys).map(|(a, b)| (b - a) as f64).sum::<f64>() / xs.len() as f64;
        let step = F::new(8).step(0) as f64;
        // unbiased like round-off (|bias| ≪ step), unlike truncation
        assert!(bias.abs() < step * 0.05, "stochastic bias {bias} vs step {step}");
        // but ~2× the error energy (variance Δ²/6 vs Δ²/12)
        let e_sto: f64 = xs.iter().zip(&ys).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let yn = dequantize(&xs, F::new(8));
        let e_rnd: f64 = xs.iter().zip(&yn).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let ratio = e_sto / e_rnd;
        assert!((1.5..3.0).contains(&ratio), "energy ratio {ratio}");
    }

    #[test]
    fn wider_mantissa_raises_snr_6db_per_bit() {
        let mut rng = Rng::new(4);
        let xs: Vec<f32> = rng.normal_vec(100_000, 0.5);
        let s8 = theoretical_block_snr(&xs, BfpFormat::new(8));
        let s9 = theoretical_block_snr(&xs, BfpFormat::new(9));
        assert!(((s9 - s8) - 6.02).abs() < 0.1, "Δ={}", s9 - s8);
    }
}
