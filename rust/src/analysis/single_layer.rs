//! The single-layer error model of §4.2.
//!
//! For the inner product of block-formatted vectors, NSRs add (eq. 16):
//! `η_r = η_P + η_Q`, so the output NSR of a conv layer is
//! `η_O = η_I' + η_W'` (eq. 17) and in dB (eq. 18):
//!
//! ```text
//! SNR_O = SNR_I' + SNR_W' − 10·log10(10^(SNR_I'/10) + 10^(SNR_W'/10))
//! ```

use super::snr::{db_to_nsr, nsr_to_db};

/// Combine input and weight SNRs into the output SNR — eq. (18).
pub fn output_snr_db(snr_input_db: f64, snr_weight_db: f64) -> f64 {
    nsr_to_db(db_to_nsr(snr_input_db) + db_to_nsr(snr_weight_db))
}

/// NSR form of eq. (16)/(17): `η_O = η_I + η_W`.
pub fn output_nsr(nsr_input: f64, nsr_weight: f64) -> f64 {
    nsr_input + nsr_weight
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::gemm::f32_gemm;
    use crate::bfp::{bfp_gemm, BfpFormat, BfpMatrix};
    use crate::bfp::partition::BlockAxis;
    use crate::data::Rng;

    #[test]
    fn eq18_closed_forms() {
        // equal SNRs: output is 3.01 dB below either input
        let o = output_snr_db(30.0, 30.0);
        assert!((o - (30.0 - 10.0 * 2f64.log10())).abs() < 1e-9, "{o}");
        // one side much cleaner: output approaches the dirty side
        let o = output_snr_db(20.0, 60.0);
        assert!((o - 20.0).abs() < 0.05, "{o}");
    }

    #[test]
    fn eq18_symmetry() {
        assert!((output_snr_db(25.0, 33.0) - output_snr_db(33.0, 25.0)).abs() < 1e-12);
    }

    /// End-to-end check of the §4.2 chain: predict a BFP GEMM's output NSR
    /// from the measured input/weight quantization NSRs and compare with
    /// the actually measured output NSR. Statistical independence of the
    /// operands makes eq. (18) accurate to ~1 dB at these sizes.
    #[test]
    fn eq18_predicts_real_gemm() {
        let mut rng = Rng::new(10);
        let (m, k, n) = (64, 288, 196);
        let w: Vec<f32> = rng.laplacian_vec(m * k, 0.06);
        let i: Vec<f32> = rng.normal_vec(k * n, 1.2);
        let fmt_w = BfpFormat::new(8);
        let fmt_i = BfpFormat::new(8);
        let wq = BfpMatrix::quantize(&w, m, k, fmt_w, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&i, k, n, fmt_i, BlockAxis::Whole);

        // measured quantization SNRs
        let snr_w = super::super::snr::measured_snr(&w, &wq.to_f32());
        let snr_i = super::super::snr::measured_snr(&i, &iq.to_f32());

        // measured output SNR
        let mut exact = vec![0f32; m * n];
        f32_gemm(&w, &i, m, k, n, &mut exact);
        let bfp = bfp_gemm(&wq, &iq);
        let snr_o_measured = super::super::snr::measured_snr(&exact, &bfp.data);

        let snr_o_theory = output_snr_db(snr_i, snr_w);
        assert!(
            (snr_o_theory - snr_o_measured).abs() < 1.5,
            "theory {snr_o_theory:.2} dB vs measured {snr_o_measured:.2} dB"
        );
    }
}
