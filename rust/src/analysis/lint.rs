//! The `bfp-cnn lint` driver: walk the repo's own Rust sources, run the
//! [`super::rules`] passes, and diff the findings against a committed
//! grandfather baseline (`rust/analysis/baseline.txt`).
//!
//! The baseline holds one key per tolerated violation —
//! `path:rule:<trimmed source line>` — deliberately line-number-free so
//! unrelated edits above a grandfathered site do not churn the file.
//! `--fix-baseline` rewrites it from the current findings; the goal
//! state (and the committed state) is an *empty* baseline, every
//! invariant holding tree-wide.

use super::lex::{lex, Line};
use super::rules::{run_all, Violation};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Lexed + raw views of every linted file, keyed by `rust/`-relative
/// path (`src/net/server.rs`).
pub struct SourceTree {
    pub lexed: BTreeMap<String, Vec<Line>>,
    raw: BTreeMap<String, Vec<String>>,
}

/// Locate the repo root (the directory containing `rust/Cargo.toml`):
/// the compile-time manifest dir when it still exists (normal case —
/// the binary runs in the workspace it was built in), else walk up from
/// the current directory.
pub fn repo_root() -> Option<PathBuf> {
    if let Some(manifest) = option_env!("CARGO_MANIFEST_DIR") {
        let p = Path::new(manifest);
        if p.join("Cargo.toml").is_file() {
            if let Some(root) = p.parent() {
                return Some(root.to_path_buf());
            }
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        if cur.join("rust").join("Cargo.toml").is_file() {
            return Some(cur);
        }
        if !cur.pop() {
            return None;
        }
    }
}

fn walk_dir(dir: &Path, rust_root: &Path, in_test: bool, tree: &mut SourceTree) -> Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            // fixture trees contain deliberate violations for the
            // linter's own tests — never lint them as project code
            if name == "fixtures" {
                continue;
            }
            walk_dir(&path, rust_root, in_test, tree)?;
            continue;
        }
        if !name.ends_with(".rs") {
            continue;
        }
        let src = fs::read_to_string(&path).with_context(|| format!("reading {name}"))?;
        let rel = path
            .strip_prefix(rust_root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        tree.raw.insert(rel.clone(), src.lines().map(str::to_string).collect());
        tree.lexed.insert(rel, lex(&src, in_test));
    }
    Ok(())
}

/// Lex every `.rs` file under `rust/src` and `rust/tests` (fixture
/// directories and the vendored `rust/anyhow` excluded).
pub fn collect_sources(root: &Path) -> Result<SourceTree> {
    let rust_root = root.join("rust");
    let mut tree = SourceTree { lexed: BTreeMap::new(), raw: BTreeMap::new() };
    walk_dir(&rust_root.join("src"), &rust_root, false, &mut tree)?;
    let tests = rust_root.join("tests");
    if tests.is_dir() {
        walk_dir(&tests, &rust_root, true, &mut tree)?;
    }
    Ok(tree)
}

/// Stable baseline key for a finding: `path:rule:<trimmed line text>`.
/// Line-number-free so edits elsewhere in the file don't churn it.
pub fn baseline_key(v: &Violation, tree: &SourceTree) -> String {
    let text = tree
        .raw
        .get(&v.path)
        .and_then(|ls| ls.get(v.line.saturating_sub(1) as usize))
        .map(|s| s.trim())
        .unwrap_or("");
    format!("{}:{}:{}", v.path, v.rule, text)
}

/// Parse a baseline file: one key per line, `#` comments and blank
/// lines ignored. A missing file is an empty baseline.
pub fn load_baseline(path: &Path) -> BTreeSet<String> {
    let Ok(text) = fs::read_to_string(path) else {
        return BTreeSet::new();
    };
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

fn write_baseline(path: &Path, keys: &BTreeSet<String>) -> Result<()> {
    let mut out = String::new();
    out.push_str("# bfp-cnn lint grandfather baseline.\n");
    out.push_str("# One `path:rule:<trimmed line>` key per tolerated violation;\n");
    out.push_str("# regenerate with `bfp-cnn lint --fix-baseline`. Keep me empty.\n");
    for k in keys {
        out.push_str(k);
        out.push('\n');
    }
    fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn write_json(
    path: &Path,
    violations: &[Violation],
    baselined: &BTreeSet<String>,
    tree: &SourceTree,
    files: usize,
    stale: usize,
) -> Result<()> {
    let mut rows = Vec::new();
    for v in violations {
        let grandfathered = baselined.contains(&baseline_key(v, tree));
        rows.push(format!(
            "    {{\"path\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\", \
             \"baselined\": {}}}",
            json_escape(&v.path),
            v.line,
            json_escape(v.rule),
            json_escape(&v.message),
            grandfathered
        ));
    }
    let new = violations
        .iter()
        .filter(|v| !baselined.contains(&baseline_key(v, tree)))
        .count();
    let body = format!(
        "{{\n  \"files_scanned\": {},\n  \"total\": {},\n  \"new\": {},\n  \
         \"stale_baseline\": {},\n  \"violations\": [\n{}\n  ]\n}}\n",
        files,
        violations.len(),
        new,
        stale,
        rows.join(",\n")
    );
    fs::write(path, body).with_context(|| format!("writing {}", path.display()))
}

/// Run the linter against the working tree. Returns the process exit
/// code: 0 when no *new* (non-baselined) violations were found, 2
/// otherwise. `fix_baseline` rewrites the baseline instead of failing;
/// `json` additionally writes a machine-readable report.
pub fn cli(fix_baseline: bool, json: Option<&Path>) -> Result<i32> {
    let Some(root) = repo_root() else {
        bail!("cannot locate the repo root (no rust/Cargo.toml above the current directory)");
    };
    let tree = collect_sources(&root)?;
    let violations = run_all(&tree.lexed);
    let files = tree.lexed.len();

    let baseline_path = root.join("rust").join("analysis").join("baseline.txt");
    let baseline = load_baseline(&baseline_path);
    let current: BTreeSet<String> = violations.iter().map(|v| baseline_key(v, &tree)).collect();
    let stale: Vec<&String> = baseline.difference(&current).collect();

    if fix_baseline {
        if let Some(dir) = baseline_path.parent() {
            fs::create_dir_all(dir)?;
        }
        write_baseline(&baseline_path, &current)?;
        println!(
            "baseline rewritten: {} entr{} ({})",
            current.len(),
            if current.len() == 1 { "y" } else { "ies" },
            baseline_path.display()
        );
        if let Some(p) = json {
            write_json(p, &violations, &current, &tree, files, 0)?;
        }
        return Ok(0);
    }

    let mut new = 0usize;
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    for v in &violations {
        if baseline.contains(&baseline_key(v, &tree)) {
            continue;
        }
        new += 1;
        writeln!(out, "{v}")?;
    }
    for s in &stale {
        eprintln!("warning: stale baseline entry (violation no longer fires): {s}");
    }
    if let Some(p) = json {
        write_json(p, &violations, &baseline, &tree, files, stale.len())?;
    }
    eprintln!(
        "lint: {} violation{} ({} new, {} baselined, {} stale) in {} files",
        violations.len(),
        if violations.len() == 1 { "" } else { "s" },
        new,
        violations.len() - new,
        stale.len(),
        files
    );
    Ok(if new == 0 { 0 } else { 2 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_parse_ignores_comments_and_blanks() {
        let dir = std::env::temp_dir().join("bfp_lint_baseline_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("baseline.txt");
        fs::write(&p, "# header\n\nsrc/a.rs:bare-sleep:thread::sleep(d);\n").unwrap();
        let b = load_baseline(&p);
        assert_eq!(b.len(), 1);
        assert!(b.contains("src/a.rs:bare-sleep:thread::sleep(d);"));
        // round-trip through the writer
        write_baseline(&p, &b).unwrap();
        assert_eq!(load_baseline(&p), b);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
