//! Comment/string-aware line lexer for the project linter.
//!
//! Splits a Rust source file into [`Line`]s whose `code` field has every
//! comment and string-literal *body* masked with spaces (delimiters are
//! kept so column positions and brace counts survive), and whose
//! `comment` field collects the comment text that appeared on the line.
//! On top of the mask it tracks brace depth to mark `#[cfg(test)]` /
//! `mod tests` regions, so rules can skip test code without parsing.
//!
//! This is deliberately a lexer, not a parser: the rules in
//! [`super::rules`] are line-oriented heuristics, and masking is exactly
//! the fidelity they need (an `unsafe` inside a string or doc comment
//! must not trip the SAFETY rule; a `{` inside a char literal must not
//! skew the depth that decides where a test module ends).

/// One source line, post-masking.
#[derive(Debug, Clone)]
pub struct Line {
    /// 1-based line number.
    pub number: u32,
    /// The line's code with comment and string bodies replaced by
    /// spaces (same length in chars as the original, minus nothing —
    /// delimiters like `"` and `//`'s columns are preserved as `"` and
    /// two spaces respectively).
    pub code: String,
    /// Concatenated comment text that appeared on this line, including
    /// the `//` / `/*` markers.
    pub comment: String,
    /// True when the line sits inside `#[cfg(test)]` / `mod tests`
    /// scope (or the whole file is a test file, e.g. under `tests/`).
    pub in_test: bool,
}

#[derive(PartialEq)]
enum State {
    Normal,
    LineComment,
    Block,
    Str,
    RawStr,
    Char,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// `\bmod\s+tests\s*$` over accumulated masked code.
fn ends_with_mod_tests(code: &str) -> bool {
    let t = code.trim_end();
    let Some(rest) = t.strip_suffix("tests") else {
        return false;
    };
    if !rest.ends_with(|c: char| c.is_whitespace()) {
        return false;
    }
    let Some(head) = rest.trim_end().strip_suffix("mod") else {
        return false;
    };
    match head.chars().next_back() {
        None => true,
        Some(c) => !is_ident(c),
    }
}

/// Lex `src` into masked lines. `file_in_test` marks every line of the
/// file as test code (used for files under `tests/`).
pub fn lex(src: &str, file_in_test: bool) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut state = State::Normal;
    let mut block_depth = 0usize;
    let mut raw_hashes = 0usize;
    let mut depth = 0i64;
    let mut pending_test = false;
    // brace depths at which test regions opened
    let mut test_stack: Vec<i64> = Vec::new();
    let mut cur_code = String::new();
    let mut cur_comment = String::new();
    let mut lineno: u32 = 1;
    let mut line_started_in_test = file_in_test;

    macro_rules! flush {
        () => {{
            let in_test = file_in_test || line_started_in_test || !test_stack.is_empty();
            lines.push(Line {
                number: lineno,
                code: std::mem::take(&mut cur_code),
                comment: std::mem::take(&mut cur_comment),
                in_test,
            });
            lineno += 1;
            line_started_in_test = file_in_test || !test_stack.is_empty();
        }};
    }

    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if state == State::LineComment {
                state = State::Normal;
            }
            flush!();
            i += 1;
            continue;
        }
        match state {
            State::LineComment => {
                cur_comment.push(c);
                cur_code.push(' ');
                i += 1;
                continue;
            }
            State::Block => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    block_depth += 1;
                    cur_comment.push_str("/*");
                    cur_code.push_str("  ");
                    i += 2;
                    continue;
                }
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    block_depth -= 1;
                    cur_comment.push_str("*/");
                    cur_code.push_str("  ");
                    i += 2;
                    if block_depth == 0 {
                        state = State::Normal;
                    }
                    continue;
                }
                cur_comment.push(c);
                cur_code.push(' ');
                i += 1;
                continue;
            }
            State::Str => {
                if c == '\\' {
                    // keep a `\` at end-of-line from swallowing the
                    // newline (string line-continuation)
                    if chars.get(i + 1) == Some(&'\n') {
                        cur_code.push(' ');
                        i += 1;
                        continue;
                    }
                    for _ in 0..2.min(n - i) {
                        cur_code.push(' ');
                    }
                    i += 2;
                    continue;
                }
                if c == '"' {
                    cur_code.push('"');
                    state = State::Normal;
                    i += 1;
                    continue;
                }
                cur_code.push(' ');
                i += 1;
                continue;
            }
            State::RawStr => {
                if c == '"' && (1..=raw_hashes).all(|k| chars.get(i + k) == Some(&'#')) {
                    cur_code.push('"');
                    for _ in 0..raw_hashes {
                        cur_code.push('#');
                    }
                    i += 1 + raw_hashes;
                    state = State::Normal;
                    continue;
                }
                cur_code.push(' ');
                i += 1;
                continue;
            }
            State::Char => {
                if c == '\\' {
                    if chars.get(i + 1) == Some(&'\n') {
                        cur_code.push(' ');
                        i += 1;
                        continue;
                    }
                    cur_code.push_str("  ");
                    i += 2;
                    continue;
                }
                if c == '\'' {
                    cur_code.push('\'');
                    state = State::Normal;
                    i += 1;
                    continue;
                }
                cur_code.push(' ');
                i += 1;
                continue;
            }
            State::Normal => {}
        }

        // normal state
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            state = State::LineComment;
            cur_code.push_str("  ");
            cur_comment.push_str("//");
            i += 2;
            continue;
        }
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            state = State::Block;
            block_depth = 1;
            cur_code.push_str("  ");
            cur_comment.push_str("/*");
            i += 2;
            continue;
        }
        if c == '"' {
            // raw string? scan back over the `#`s to `r` / `br`
            let mut j = i as i64 - 1;
            let mut hashes = 0usize;
            while j >= 0 && chars[j as usize] == '#' {
                hashes += 1;
                j -= 1;
            }
            let mut is_raw = false;
            if j >= 0 && chars[j as usize] == 'r' {
                let mut k = j - 1;
                if k >= 0 && chars[k as usize] == 'b' {
                    k -= 1;
                }
                if k < 0 || !is_ident(chars[k as usize]) {
                    is_raw = true;
                }
            }
            if is_raw {
                state = State::RawStr;
                raw_hashes = hashes;
            } else {
                state = State::Str;
            }
            cur_code.push('"');
            i += 1;
            continue;
        }
        if c == '\'' {
            let nxt = chars.get(i + 1).copied().unwrap_or('\0');
            let nxt2 = chars.get(i + 2).copied().unwrap_or('\0');
            if nxt == '\\' || (nxt2 == '\'' && nxt != '\'') {
                state = State::Char;
                cur_code.push('\'');
                i += 1;
                continue;
            }
            // lifetime or loop label: leave as-is
            cur_code.push('\'');
            i += 1;
            continue;
        }
        // brace / test tracking happens only on real code chars
        match c {
            '{' => {
                if pending_test {
                    test_stack.push(depth);
                    pending_test = false;
                }
                depth += 1;
            }
            '}' => {
                depth -= 1;
                if test_stack.last() == Some(&depth) {
                    test_stack.pop();
                }
            }
            ';' => {
                pending_test = false;
            }
            _ => {}
        }
        cur_code.push(c);
        i += 1;
        // test-region markers are detected on the accumulated masked
        // code so `#[cfg(test)]` inside a string cannot open a region
        if cur_code.ends_with("#[cfg(test)]") || ends_with_mod_tests(&cur_code) {
            pending_test = true;
            line_started_in_test = true;
        }
    }
    if !cur_code.is_empty() || !cur_comment.is_empty() {
        flush!();
    }
    lines
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_strings_and_comments() {
        let src = "let x = \"unsafe { }\"; // unsafe in comment\nunsafe { y() }\n";
        let lines = lex(src, false);
        assert_eq!(lines.len(), 2);
        assert!(!lines[0].code.contains("unsafe"));
        assert!(lines[0].comment.contains("unsafe in comment"));
        assert!(lines[1].code.contains("unsafe"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* one /* two */ still */ b\nc\n";
        let lines = lex(src, false);
        assert!(lines[0].code.starts_with('a'));
        assert!(lines[0].code.trim_end().ends_with('b'));
        assert!(!lines[0].code.contains("one"));
        assert_eq!(lines[1].code, "c");
    }

    #[test]
    fn raw_strings_with_hashes() {
        let src = "let r = r#\"quote \" inside\"#; after();\n";
        let lines = lex(src, false);
        assert!(lines[0].code.contains("after()"));
        assert!(!lines[0].code.contains("inside"));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let src = "let c = '{'; fn f<'a>(x: &'a str) {}\n";
        let lines = lex(src, false);
        // the brace inside the char literal must be masked...
        assert!(!lines[0].code.contains("'{'"));
        // ...while the lifetimes stay as code
        assert!(lines[0].code.contains("<'a>"));
    }

    #[test]
    fn cfg_test_region_tracking() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn live2() {}\n";
        let lines = lex(src, false);
        assert!(!lines[0].in_test);
        assert!(lines[1].in_test);
        assert!(lines[2].in_test);
        assert!(lines[3].in_test);
        assert!(lines[4].in_test);
        assert!(!lines[5].in_test);
    }

    #[test]
    fn string_backslash_newline_keeps_line_numbers() {
        let src = "let s = \"a\\\nb\";\nafter();\n";
        let lines = lex(src, false);
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[2].code, "after();");
        assert_eq!(lines[2].number, 3);
    }
}
