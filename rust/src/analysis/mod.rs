//! The paper's §4 three-stage error-analysis model plus the empirical
//! instrumentation that validates it (Table 4, Figure 3).
//!
//! * [`snr`] — SNR/NSR conversions and the quantization-error theory of
//!   §4.1 (eqs. 8–13).
//! * [`single_layer`] — the single-layer output-SNR model (eq. 18).
//! * [`multi_layer`] — the multi-layer propagation model (eqs. 19–20).
//! * [`instrument`] — the dual (FP32 ∥ BFP) forward pass that gathers the
//!   experimental SNRs and the per-layer statistics the theory consumes.
//! * [`energy`] — normalized-magnitude energy histograms (Figure 3).
//!
//! It also hosts the project's *self*-analysis — the invariant linter
//! behind `bfp-cnn lint`:
//!
//! * [`lex`] — comment/string-aware line lexer with `#[cfg(test)]` /
//!   `mod tests` region tracking.
//! * [`rules`] — the rule passes (SAFETY comments on `unsafe`, clock
//!   discipline, atomic-ordering justifications, serving-path unwrap
//!   bans, lock-nesting annotations, wire-protocol exhaustiveness).
//! * [`lint`] — the driver: repo walk, grandfather baseline, JSON
//!   report, CLI entry point.

pub mod energy;
pub mod instrument;
pub mod lex;
pub mod lint;
pub mod multi_layer;
pub mod rules;
pub mod single_layer;
pub mod snr;

pub use instrument::{InstrumentExec, LayerKind, LayerRecord};
pub use multi_layer::{propagate_multi_layer, MultiLayerRow};
pub use snr::{db_to_nsr, nsr_to_db, snr_db};
