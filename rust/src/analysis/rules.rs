//! The project-invariant rule passes behind `bfp-cnn lint`.
//!
//! Each rule is a line-oriented heuristic over the masked [`Line`]s
//! produced by [`super::lex`]. Paths are repo-relative with the `rust/`
//! prefix stripped (`src/net/server.rs`), so rules can scope themselves
//! to the serving modules, exempt `obs::clock`, and so on — and so the
//! fixture tests can lint an in-memory string under any pretend path.
//!
//! Escape hatches, all grep-able:
//! * `// LINT-ALLOW: <rule-id> — reason` on the flagged line or in the
//!   comment block directly above silences that one site.
//! * `// SAFETY:` (or a `# Safety` doc section) satisfies the unsafe
//!   rule; `// LOCK-ORDER:` satisfies the lock-nesting rule.

use super::lex::Line;
use std::collections::BTreeMap;
use std::fmt;

/// One finding: `path:line: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Violation {
    pub path: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// Serving-path modules where `unwrap()/expect()` is a lint error.
const SERVING: [&str; 4] = ["src/coordinator/", "src/net/", "src/runtime/", "src/nn/prepared.rs"];
/// Methods returning poison-carrying `Result`s whose unwrap is idiomatic.
const POISON_METHODS: [&str; 3] = ["lock", "wait", "wait_timeout"];
const ALLOW: &str = "LINT-ALLOW:";
const ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte offsets of `word` in `code` with non-identifier boundaries on
/// both sides (`\bword\b`).
fn word_positions(code: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for (pos, _) in code.match_indices(word) {
        let before_ok = code[..pos].chars().next_back().is_none_or(|c| !is_ident(c));
        let after_ok = code[pos + word.len()..].chars().next().is_none_or(|c| !is_ident(c));
        if before_ok && after_ok {
            out.push(pos);
        }
    }
    out
}

fn contains_word(code: &str, word: &str) -> bool {
    !word_positions(code, word).is_empty()
}

/// Occurrences of `pattern` followed by optional whitespace and `(` —
/// `\bpattern\s*\(`, the boundary applying only when the pattern starts
/// with an identifier char (so `.lock` matches mid-chain).
fn pattern_then_paren(code: &str, pattern: &str) -> usize {
    let needs_boundary = pattern.chars().next().is_some_and(is_ident);
    let mut count = 0;
    for (pos, _) in code.match_indices(pattern) {
        let prev_ok =
            !needs_boundary || code[..pos].chars().next_back().is_none_or(|c| !is_ident(c));
        if prev_ok && code[pos + pattern.len()..].trim_start().starts_with('(') {
            count += 1;
        }
    }
    count
}

/// `Ordering::X` mentions on the line (any of the five variants).
fn ordering_mentions(code: &str) -> Vec<&'static str> {
    let mut out = Vec::new();
    for (pos, _) in code.match_indices("Ordering::") {
        let rest = &code[pos + "Ordering::".len()..];
        for v in ORDERINGS {
            if !rest.starts_with(v) {
                continue;
            }
            if rest[v.len()..].chars().next().is_none_or(|c| !is_ident(c)) {
                out.push(v);
            }
        }
    }
    out
}

fn comment_text(cm: &str) -> &str {
    cm.trim_matches(|c: char| matches!(c, '/' | ' ' | '\t' | '*' | '!'))
}

/// Allow marker for `rule` on the same line or in the contiguous block
/// of comment-only lines directly above.
fn allowed(lines: &[Line], idx: usize, rule: &str) -> bool {
    let cm = &lines[idx].comment;
    if cm.contains(ALLOW) && cm.contains(rule) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let ln = &lines[j];
        if !ln.code.trim().is_empty() || ln.comment.trim().is_empty() {
            break;
        }
        if ln.comment.contains(ALLOW) && ln.comment.contains(rule) {
            return true;
        }
    }
    false
}

/// Comment on the same line, the preceding line, or above an unbroken
/// run of lines that themselves satisfy `in_run` (a comment block above
/// a run of atomic ops justifies the whole run). Returns the whole
/// contiguous comment block, joined.
fn justifying_comment(
    lines: &[Line],
    idx: usize,
    in_run: impl Fn(&str) -> bool,
) -> Option<String> {
    let mut j = idx as i64;
    while j >= 0 {
        let ju = j as usize;
        if !comment_text(&lines[ju].comment).is_empty() {
            let mut parts = vec![lines[ju].comment.clone()];
            let mut k = j - 1;
            while k >= 0 {
                let ln = &lines[k as usize];
                if !ln.code.trim().is_empty() || ln.comment.trim().is_empty() {
                    break;
                }
                parts.push(ln.comment.clone());
                k -= 1;
            }
            parts.reverse();
            return Some(parts.join(" "));
        }
        if ju == idx || in_run(&lines[ju].code) {
            j -= 1;
            continue;
        }
        break;
    }
    None
}

/// R1 `unsafe-safety`: every `unsafe` site carries a `// SAFETY:`
/// comment (or sits under a `# Safety` doc section) on the same line or
/// above it, across comment / attribute / blank lines. Applies to test
/// code too — a test's unsafe is no safer.
pub fn rule_unsafe_safety(path: &str, lines: &[Line], out: &mut Vec<Violation>) {
    for (idx, ln) in lines.iter().enumerate() {
        if !contains_word(&ln.code, "unsafe") {
            continue;
        }
        let mut ok = false;
        let mut j = idx as i64;
        while j >= 0 {
            let lj = &lines[j as usize];
            if lj.comment.contains("SAFETY:") || lj.comment.contains("# Safety") {
                ok = true;
                break;
            }
            let code = lj.code.trim();
            if j as usize != idx && !code.is_empty() && !code.starts_with("#[") {
                break;
            }
            j -= 1;
        }
        if !ok {
            out.push(Violation {
                path: path.to_string(),
                line: ln.number,
                rule: "unsafe-safety",
                message: "`unsafe` without a SAFETY comment".to_string(),
            });
        }
    }
}

/// R2 `clock-source`: `Instant::now()` / `SystemTime::now()` belong in
/// `obs::clock` (so chaos tests and drills can warp time). The bench /
/// chaos harness measures real wall-clock SLOs and is exempt.
pub fn rule_clock_source(path: &str, lines: &[Line], out: &mut Vec<Violation>) {
    if path == "src/obs/clock.rs" || path.starts_with("src/harness/") {
        return;
    }
    for (idx, ln) in lines.iter().enumerate() {
        if ln.in_test {
            continue;
        }
        if (ln.code.contains("Instant::now") || ln.code.contains("SystemTime::now"))
            && !allowed(lines, idx, "clock-source")
        {
            out.push(Violation {
                path: path.to_string(),
                line: ln.number,
                rule: "clock-source",
                message: "raw time source outside obs::clock (use Clock::now)".to_string(),
            });
        }
    }
}

/// R3 `bare-sleep`: `thread::sleep` in serving code ignores mocked
/// time; use `Clock::sleep` or allow-list with a justification.
pub fn rule_bare_sleep(path: &str, lines: &[Line], out: &mut Vec<Violation>) {
    if path == "src/obs/clock.rs" || path.starts_with("src/harness/") {
        return;
    }
    for (idx, ln) in lines.iter().enumerate() {
        if ln.in_test {
            continue;
        }
        if pattern_then_paren(&ln.code, "thread::sleep") > 0 && !allowed(lines, idx, "bare-sleep")
        {
            out.push(Violation {
                path: path.to_string(),
                line: ln.number,
                rule: "bare-sleep",
                message: "bare thread::sleep (use Clock::sleep or allow-list)".to_string(),
            });
        }
    }
}

/// R4 `ordering-comment`: every atomic `Ordering::*` site carries a
/// justification comment; `SeqCst` additionally needs its rationale to
/// mention `SeqCst` (why the strongest order, or why not downgraded).
pub fn rule_ordering_comment(path: &str, lines: &[Line], out: &mut Vec<Violation>) {
    for (idx, ln) in lines.iter().enumerate() {
        if ln.in_test {
            continue;
        }
        let mentions = ordering_mentions(&ln.code);
        if mentions.is_empty() {
            continue;
        }
        let cm = justifying_comment(lines, idx, |code| !ordering_mentions(code).is_empty());
        let Some(cm) = cm else {
            out.push(Violation {
                path: path.to_string(),
                line: ln.number,
                rule: "ordering-comment",
                message: "atomic Ordering without a justification comment".to_string(),
            });
            continue;
        };
        if mentions.contains(&"SeqCst") && !cm.contains("SeqCst") {
            out.push(Violation {
                path: path.to_string(),
                line: ln.number,
                rule: "ordering-comment",
                message: "SeqCst without downgrade rationale mentioning SeqCst".to_string(),
            });
        }
    }
}

/// Does the `.unwrap()` / `.expect(` at (`idx`, byte `col`) chain
/// directly off a poison-carrying call (`.lock()` / `.wait()` /
/// `.wait_timeout()`)? Matched backwards across lines through the
/// closing paren of the preceding call.
fn poison_chained(lines: &[Line], idx: usize, col: usize) -> bool {
    let mut li = idx;
    let mut before: Vec<char> = lines[idx].code[..col].trim_end().chars().collect();
    while before.is_empty() {
        if li == 0 {
            return false;
        }
        li -= 1;
        let t = lines[li].code.trim_end();
        if t.trim().is_empty() {
            continue;
        }
        before = t.chars().collect();
    }
    if before.last() != Some(&')') {
        return false;
    }
    // backwards paren match, possibly across lines
    let mut depth = 0i64;
    let mut text = before;
    let mut row = li;
    let mut pos = text.len() as i64 - 1;
    loop {
        match text[pos as usize] {
            ')' => depth += 1,
            '(' => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
        pos -= 1;
        while pos < 0 {
            if row == 0 {
                return false;
            }
            row -= 1;
            text = lines[row].code.chars().collect();
            pos = text.len() as i64 - 1;
        }
    }
    // `.method` directly before the matched `(`?
    let head: String = text[..pos as usize].iter().collect();
    let head = head.trim_end();
    let rev_ident: String = head.chars().rev().take_while(|&c| is_ident(c)).collect();
    let ident: String = rev_ident.chars().rev().collect();
    if ident.is_empty() || !head[..head.len() - ident.len()].ends_with('.') {
        return false;
    }
    POISON_METHODS.contains(&ident.as_str())
}

/// R5 `serving-unwrap`: no `unwrap()/expect()` on serving paths —
/// return a typed error instead. Mutex/Condvar poison unwraps are
/// idiomatic and excluded structurally.
pub fn rule_serving_unwrap(path: &str, lines: &[Line], out: &mut Vec<Violation>) {
    if !SERVING.iter().any(|p| path.starts_with(p) || path == *p) {
        return;
    }
    for (idx, ln) in lines.iter().enumerate() {
        if ln.in_test {
            continue;
        }
        let mut sites: Vec<usize> = Vec::new();
        sites.extend(ln.code.match_indices(".unwrap()").map(|(p, _)| p));
        sites.extend(ln.code.match_indices(".expect(").map(|(p, _)| p));
        sites.sort_unstable();
        for col in sites {
            if poison_chained(lines, idx, col) || allowed(lines, idx, "serving-unwrap") {
                continue;
            }
            out.push(Violation {
                path: path.to_string(),
                line: ln.number,
                rule: "serving-unwrap",
                message: "unwrap/expect on a serving path (return a typed error)".to_string(),
            });
        }
    }
}

/// R6 `lock-order`: a fn taking two or more `.lock()`s is a deadlock
/// candidate — annotate the intended order with `// LOCK-ORDER:` (in
/// the fn or in the comment block above its signature).
pub fn rule_lock_order(path: &str, lines: &[Line], out: &mut Vec<Violation>) {
    struct Frame {
        start: usize,
        depth: i64,
        locks: usize,
        lock_lines: Vec<u32>,
    }
    let mut depth = 0i64;
    let mut fn_stack: Vec<Frame> = Vec::new();
    let mut pending_fn: Option<(usize, i64)> = None;
    let mut results: Vec<(Frame, usize)> = Vec::new();
    for (idx, ln) in lines.iter().enumerate() {
        if !ln.in_test {
            // `\bfn\s+name` — a fn signature starts (the last match on
            // the line wins)
            for pos in word_positions(&ln.code, "fn") {
                let rest = &ln.code[pos + 2..];
                let trimmed = rest.trim_start();
                let has_ws = trimmed.len() < rest.len();
                if has_ws && trimmed.chars().next().is_some_and(|c| c.is_alphabetic() || c == '_')
                {
                    pending_fn = Some((idx, depth));
                }
            }
        }
        for ch in ln.code.chars() {
            match ch {
                '{' => {
                    if pending_fn.is_some_and(|(_, d)| d == depth) {
                        let (start, _) = pending_fn.take().unwrap_or((0, 0));
                        let f = Frame { start, depth, locks: 0, lock_lines: Vec::new() };
                        fn_stack.push(f);
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if fn_stack.last().map(|f| f.depth) == Some(depth) {
                        if let Some(f) = fn_stack.pop() {
                            results.push((f, idx));
                        }
                    }
                }
                ';' => {
                    if pending_fn.is_some_and(|(_, d)| d == depth) {
                        pending_fn = None;
                    }
                }
                _ => {}
            }
        }
        if !ln.in_test {
            let cnt = pattern_then_paren(&ln.code, ".lock");
            if cnt > 0 {
                if let Some(f) = fn_stack.last_mut() {
                    f.locks += cnt;
                    f.lock_lines.push(ln.number);
                }
            }
        }
    }
    for (f, end_idx) in results {
        if f.locks < 2 {
            continue;
        }
        // accept the annotation inside the fn or in the contiguous
        // comment/attribute block directly above its signature
        let mut scan_from = f.start;
        let mut k = f.start as i64 - 1;
        while k >= 0 {
            let ln = &lines[k as usize];
            let code = ln.code.trim();
            if code.starts_with("#[") || (code.is_empty() && !ln.comment.trim().is_empty()) {
                scan_from = k as usize;
                k -= 1;
                continue;
            }
            break;
        }
        let annotated = (scan_from..=end_idx).any(|j| lines[j].comment.contains("LOCK-ORDER:"));
        if !annotated {
            out.push(Violation {
                path: path.to_string(),
                line: lines[f.start].number,
                rule: "lock-order",
                message: format!(
                    "{} .lock() calls in one fn (lines {:?}) without LOCK-ORDER comment",
                    f.locks, f.lock_lines
                ),
            });
        }
    }
}

/// R7 `wire-exhaustive`: cross-file protocol exhaustiveness — every
/// `QosErrorKind` variant maps to a wire `ErrorCode` in `net::server`,
/// and every `KIND_*` frame tag in `net::proto` is referenced beyond
/// its declaration (encode + decode) and exercised by an
/// `encode_<kind>(` round-trip in proto's tests.
pub fn rule_wire_exhaustive(files: &BTreeMap<String, Vec<Line>>, out: &mut Vec<Violation>) {
    let (Some(qos), Some(server), Some(proto)) = (
        files.get("src/coordinator/qos.rs"),
        files.get("src/net/server.rs"),
        files.get("src/net/proto.rs"),
    ) else {
        out.push(Violation {
            path: "src/net/proto.rs".to_string(),
            line: 1,
            rule: "wire-exhaustive",
            message: "missing cross-file inputs".to_string(),
        });
        return;
    };
    let joined = |ls: &[Line]| ls.iter().map(|l| l.code.as_str()).collect::<Vec<_>>().join("\n");

    // QosErrorKind variants → explicit mapping mentions in net::server
    let qos_code = joined(qos);
    let mut variants: Vec<String> = Vec::new();
    if let Some(pos) = qos_code.find("pub enum QosErrorKind") {
        let after = qos_code[pos + "pub enum QosErrorKind".len()..].trim_start();
        if let Some(body) = after.strip_prefix('{') {
            let body = body.split("\n}").next().unwrap_or("");
            for line in body.lines() {
                let t = line.trim_start();
                if t.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
                    variants.push(t.chars().take_while(|&c| is_ident(c)).collect());
                }
            }
        }
    }
    let server_code = joined(server);
    for v in &variants {
        if !server_code.contains(&format!("QosErrorKind::{v}")) {
            out.push(Violation {
                path: "src/net/server.rs".to_string(),
                line: 1,
                rule: "wire-exhaustive",
                message: format!(
                    "QosErrorKind::{v} has no explicit ErrorCode mapping in net::server"
                ),
            });
        }
    }

    // wire frame tags: declaration + ≥2 uses + a test round-trip
    let nontest: Vec<&str> =
        proto.iter().filter(|l| !l.in_test).map(|l| l.code.as_str()).collect();
    let proto_nontest = nontest.join("\n");
    let test: Vec<&str> = proto.iter().filter(|l| l.in_test).map(|l| l.code.as_str()).collect();
    let proto_test = test.join("\n");
    let mut kinds: Vec<String> = Vec::new();
    for (pos, _) in proto_nontest.match_indices("const KIND_") {
        let ident_start = pos + "const ".len();
        let ident: String = proto_nontest[ident_start..]
            .chars()
            .take_while(|&c| c.is_ascii_uppercase() || c == '_')
            .collect();
        let rest = proto_nontest[ident_start + ident.len()..].trim_start();
        if let Some(r) = rest.strip_prefix(':') {
            if r.trim_start().starts_with("u8") {
                kinds.push(ident);
            }
        }
    }
    for kind in kinds {
        let uses = word_positions(&proto_nontest, &kind).len().saturating_sub(1);
        if uses < 2 {
            out.push(Violation {
                path: "src/net/proto.rs".to_string(),
                line: 1,
                rule: "wire-exhaustive",
                message: format!("{kind} lacks encode+decode references ({uses} uses)"),
            });
        }
        let enc = format!("encode_{}", kind["KIND_".len()..].to_lowercase());
        if pattern_then_paren(&proto_test, &enc) == 0 {
            out.push(Violation {
                path: "src/net/proto.rs".to_string(),
                line: 1,
                rule: "wire-exhaustive",
                message: format!("{kind}: no test mention of {enc}()"),
            });
        }
    }
}

/// Run every rule over a lexed tree (keys are `rust/`-relative paths
/// with `/` separators). Returns findings sorted by path/line/rule.
pub fn run_all(files: &BTreeMap<String, Vec<Line>>) -> Vec<Violation> {
    let mut out = Vec::new();
    for (path, lines) in files {
        rule_unsafe_safety(path, lines, &mut out);
        if path.starts_with("src/") {
            rule_clock_source(path, lines, &mut out);
            rule_bare_sleep(path, lines, &mut out);
            rule_ordering_comment(path, lines, &mut out);
            rule_serving_unwrap(path, lines, &mut out);
            rule_lock_order(path, lines, &mut out);
        }
    }
    rule_wire_exhaustive(files, &mut out);
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lex::lex;

    fn lint_one(path: &str, src: &str) -> Vec<Violation> {
        let lines = lex(src, false);
        let mut out = Vec::new();
        rule_unsafe_safety(path, &lines, &mut out);
        rule_clock_source(path, &lines, &mut out);
        rule_bare_sleep(path, &lines, &mut out);
        rule_ordering_comment(path, &lines, &mut out);
        rule_serving_unwrap(path, &lines, &mut out);
        rule_lock_order(path, &lines, &mut out);
        out
    }

    fn rules_of(vs: &[Violation]) -> Vec<&'static str> {
        vs.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let bad = "fn f(p: *mut u8) { unsafe { p.write(0) } }\n";
        assert_eq!(rules_of(&lint_one("src/x.rs", bad)), ["unsafe-safety"]);
        let ok = "fn f(p: *mut u8) {\n    // SAFETY: p is valid\n    unsafe { p.write(0) }\n}\n";
        assert!(lint_one("src/x.rs", ok).is_empty());
        // the word inside a string or comment is not code
        let masked = "fn f() { log(\"unsafe stuff\"); } // unsafe-sounding\n";
        assert!(lint_one("src/x.rs", masked).is_empty());
    }

    #[test]
    fn clock_source_scoped_and_allowed() {
        let bad = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(rules_of(&lint_one("src/net/x.rs", bad)), ["clock-source"]);
        // exempt locations
        assert!(lint_one("src/obs/clock.rs", bad).is_empty());
        assert!(lint_one("src/harness/bench.rs", bad).is_empty());
        // allow marker in the comment block above
        let ok = "fn f() {\n    // LINT-ALLOW: clock-source — operator timer\n    let t = Instant::now();\n}\n";
        assert!(lint_one("src/net/x.rs", ok).is_empty());
        // test code is exempt
        let test = "#[cfg(test)]\nmod tests {\n    fn f() { let t = Instant::now(); }\n}\n";
        assert!(lint_one("src/net/x.rs", test).is_empty());
    }

    #[test]
    fn bare_sleep_flagged() {
        let bad = "fn f() { std::thread::sleep(d); }\n";
        assert_eq!(rules_of(&lint_one("src/coordinator/x.rs", bad)), ["bare-sleep"]);
        let ok = "fn f() { Clock::sleep(d); }\n";
        assert!(lint_one("src/coordinator/x.rs", ok).is_empty());
    }

    #[test]
    fn ordering_needs_comment_and_seqcst_rationale() {
        let bad = "fn f() { a.store(true, Ordering::Relaxed); }\n";
        assert_eq!(rules_of(&lint_one("src/x.rs", bad)), ["ordering-comment"]);
        let ok = "fn f() {\n    // Relaxed: independent counter\n    a.store(true, Ordering::Relaxed);\n}\n";
        assert!(lint_one("src/x.rs", ok).is_empty());
        // a comment block justifies an unbroken run of atomic lines
        let run = "fn f() {\n    // Relaxed ×2: gauges\n    a.store(1, Ordering::Relaxed);\n    b.store(2, Ordering::Relaxed);\n}\n";
        assert!(lint_one("src/x.rs", run).is_empty());
        // SeqCst with a comment that never says why SeqCst
        let sc = "fn f() {\n    // stop flag\n    a.store(true, Ordering::SeqCst);\n}\n";
        let vs = lint_one("src/x.rs", sc);
        assert_eq!(rules_of(&vs), ["ordering-comment"]);
        assert!(vs[0].message.contains("SeqCst"));
        let sc_ok = "fn f() {\n    // SeqCst: cold path, keep total order\n    a.store(true, Ordering::SeqCst);\n}\n";
        assert!(lint_one("src/x.rs", sc_ok).is_empty());
    }

    #[test]
    fn serving_unwrap_scoped_with_poison_exclusion() {
        let bad = "fn f() { let v = parse().unwrap(); }\n";
        assert_eq!(rules_of(&lint_one("src/net/x.rs", bad)), ["serving-unwrap"]);
        // outside serving modules the rule does not apply
        assert!(lint_one("src/bfp/x.rs", bad).is_empty());
        // mutex poison unwraps are idiomatic
        let poison = "fn f() { let g = m.lock().unwrap(); }\n";
        assert!(lint_one("src/net/x.rs", poison).is_empty());
        // ...including split across lines
        let ml = "fn f() {\n    let g = m\n        .lock()\n        .unwrap();\n}\n";
        assert!(lint_one("src/net/x.rs", ml).is_empty());
        // expect() chained off a non-poison call still flags
        let exp = "fn f() { let v = m.take().expect(\"gone\"); }\n";
        assert_eq!(rules_of(&lint_one("src/net/x.rs", exp)), ["serving-unwrap"]);
    }

    #[test]
    fn lock_order_heuristic() {
        let bad = "fn f() {\n    let a = x.lock().unwrap();\n    let b = y.lock().unwrap();\n}\n";
        let vs = lint_one("src/x.rs", bad);
        assert_eq!(rules_of(&vs), ["lock-order"]);
        let ok = "// LOCK-ORDER: x before y, always\nfn f() {\n    let a = x.lock().unwrap();\n    let b = y.lock().unwrap();\n}\n";
        assert!(lint_one("src/x.rs", ok).is_empty());
        // one lock is fine
        let one = "fn f() { let a = x.lock().unwrap(); }\n";
        assert!(lint_one("src/x.rs", one).is_empty());
    }

    #[test]
    fn wire_exhaustive_cross_file() {
        let qos = "pub enum QosErrorKind {\n    Timeout,\n    Draining,\n}\n";
        let server_ok = "fn map() { let _ = (QosErrorKind::Timeout, QosErrorKind::Draining); }\n";
        let server_bad = "fn map() { let _ = QosErrorKind::Timeout; }\n";
        let proto = "pub const KIND_PING: u8 = 1;\nfn enc() { w(KIND_PING); }\nfn dec() { r(KIND_PING); }\n#[cfg(test)]\nmod tests {\n    fn t() { encode_ping(1); }\n}\n";
        let mk = |server: &str| {
            let mut files = BTreeMap::new();
            files.insert("src/coordinator/qos.rs".to_string(), lex(qos, false));
            files.insert("src/net/server.rs".to_string(), lex(server, false));
            files.insert("src/net/proto.rs".to_string(), lex(proto, false));
            let mut out = Vec::new();
            rule_wire_exhaustive(&files, &mut out);
            out
        };
        assert!(mk(server_ok).is_empty());
        let vs = mk(server_bad);
        assert_eq!(vs.len(), 1);
        assert!(vs[0].message.contains("Draining"));
    }
}
