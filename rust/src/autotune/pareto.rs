//! Pareto frontier over (traffic bits ↓, predicted SNR ↑) plan points.
//!
//! The greedy planner walks one trajectory through width space; every
//! visited assignment is a candidate trade-off. The frontier keeps the
//! non-dominated subset so callers (CLI, reports) can show the whole
//! cost/quality curve, not just the budget-selected endpoint.

use super::plan::ParetoPoint;

/// Maintains the set of non-dominated `(traffic_bits, predicted_snr_db)`
/// points. A point dominates another when it is no more expensive AND no
/// noisier, strictly better in at least one.
#[derive(Debug, Clone, Default)]
pub struct ParetoFront {
    points: Vec<ParetoPoint>,
}

fn dominates(a: &ParetoPoint, b: &ParetoPoint) -> bool {
    let no_worse = a.traffic_bits <= b.traffic_bits && a.predicted_snr_db >= b.predicted_snr_db;
    let better = a.traffic_bits < b.traffic_bits || a.predicted_snr_db > b.predicted_snr_db;
    no_worse && better
}

impl ParetoFront {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a candidate; returns true if it survives (is non-dominated).
    pub fn insert(&mut self, p: ParetoPoint) -> bool {
        if self.points.iter().any(|q| dominates(q, &p) || *q == p) {
            return false;
        }
        self.points.retain(|q| !dominates(&p, q));
        self.points.push(p);
        true
    }

    /// The frontier sorted by ascending traffic cost.
    pub fn into_sorted(mut self) -> Vec<ParetoPoint> {
        self.points.sort_by(|a, b| a.traffic_bits.total_cmp(&b.traffic_bits));
        self.points
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// Select up to `k` spread operating points from a frontier for a lane
/// set, returned **safest first** (descending predicted SNR). The safest
/// and cheapest points are always included; the rest are evenly spaced
/// along the (traffic-sorted) curve. Duplicate SNR levels collapse, so
/// the result may be shorter than `k` on a short or flat frontier.
pub fn select_lane_points(frontier: &[ParetoPoint], k: usize) -> Vec<ParetoPoint> {
    if frontier.is_empty() || k == 0 {
        return Vec::new();
    }
    let mut sorted = frontier.to_vec();
    sorted.sort_by(|a, b| a.traffic_bits.total_cmp(&b.traffic_bits));
    let n = sorted.len();
    let picks: Vec<usize> = if k == 1 {
        vec![n - 1] // one lane: take the safest (most expensive) point
    } else {
        (0..k).map(|j| (j as f64 * (n - 1) as f64 / (k - 1) as f64).round() as usize).collect()
    };
    let mut out: Vec<ParetoPoint> = Vec::new();
    for idx in picks {
        let p = sorted[idx.min(n - 1)];
        if !out.iter().any(|q| q.predicted_snr_db == p.predicted_snr_db) {
            out.push(p);
        }
    }
    out.sort_by(|a, b| b.predicted_snr_db.total_cmp(&a.predicted_snr_db));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(bits: f64, snr: f64) -> ParetoPoint {
        ParetoPoint { traffic_bits: bits, predicted_snr_db: snr }
    }

    #[test]
    fn keeps_non_dominated() {
        let mut f = ParetoFront::new();
        assert!(f.insert(p(100.0, 30.0)));
        assert!(f.insert(p(80.0, 25.0))); // cheaper but noisier: survives
        assert!(f.insert(p(120.0, 35.0))); // pricier but cleaner: survives
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn drops_dominated_insert() {
        let mut f = ParetoFront::new();
        f.insert(p(100.0, 30.0));
        assert!(!f.insert(p(110.0, 29.0))); // pricier AND noisier
        assert!(!f.insert(p(100.0, 30.0))); // duplicate
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn evicts_newly_dominated() {
        let mut f = ParetoFront::new();
        f.insert(p(100.0, 30.0));
        f.insert(p(120.0, 32.0));
        assert!(f.insert(p(90.0, 33.0))); // dominates both
        assert_eq!(f.len(), 1);
        assert_eq!(f.into_sorted(), vec![p(90.0, 33.0)]);
    }

    #[test]
    fn lane_points_spread_and_ordered_safest_first() {
        let frontier: Vec<ParetoPoint> =
            (0..10).map(|i| p(100.0 + 10.0 * i as f64, 20.0 + i as f64)).collect();
        let lanes = select_lane_points(&frontier, 3);
        assert_eq!(lanes.len(), 3);
        // safest first, and endpoints always included
        assert_eq!(lanes[0].predicted_snr_db, 29.0);
        assert_eq!(lanes[2].predicted_snr_db, 20.0);
        assert!(lanes[0].predicted_snr_db > lanes[1].predicted_snr_db);
        assert!(lanes[1].predicted_snr_db > lanes[2].predicted_snr_db);
    }

    #[test]
    fn lane_points_degenerate_inputs() {
        assert!(select_lane_points(&[], 3).is_empty());
        let one = vec![p(100.0, 30.0)];
        assert_eq!(select_lane_points(&one, 3), one);
        // one lane from a long frontier: the safest point
        let frontier: Vec<ParetoPoint> =
            (0..5).map(|i| p(100.0 + i as f64, 20.0 + i as f64)).collect();
        assert_eq!(select_lane_points(&frontier, 1), vec![p(104.0, 24.0)]);
        // k larger than the frontier: every distinct point, no panic
        assert_eq!(select_lane_points(&one, 10).len(), 1);
    }

    #[test]
    fn sorted_by_cost() {
        let mut f = ParetoFront::new();
        f.insert(p(300.0, 40.0));
        f.insert(p(100.0, 20.0));
        f.insert(p(200.0, 30.0));
        let v = f.into_sorted();
        assert_eq!(v.iter().map(|q| q.traffic_bits as u64).collect::<Vec<_>>(), vec![100, 200, 300]);
    }
}
