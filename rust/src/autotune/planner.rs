//! The NSR-guided mixed-precision planner.
//!
//! Greedy bit-stripping over the analytic surrogate: start every conv
//! layer at a generous uniform width, then repeatedly remove the single
//! mantissa bit (one layer, weight or activation side) with the best
//! predicted-NSR-per-traffic-bit ratio, until the next removal would sink
//! the predicted network output SNR below the budget. Because the
//! candidate ranking never consults the budget, the trajectory is
//! identical across budgets — a tighter budget simply stops earlier,
//! which makes the planner deterministic and bit-monotone by
//! construction (tested below).
//!
//! The surrogate is the paper's own §4 theory ([`predict_chain`]); the
//! cost is the Table 1 storage/traffic model
//! ([`crate::quant::hw_cost::layer_traffic_bits`]). After the analytic
//! walk, [`autotune`] refines against reality: it measures the plan with
//! the dual-forward instrumentation and, if the measured SNR misses the
//! budget, re-plans with a tightened surrogate budget until it fits.

use super::calibrate::{predict_chain, CalibExec, ConvCalibration};
use super::measure::measure_schedule_cached;
use super::pareto::ParetoFront;
use super::plan::{LayerPlan, ParetoPoint, PrecisionPlan};
use crate::analysis::snr::nsr_to_db;
use crate::models::Model;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Planner knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlannerOptions {
    /// Starting (and maximum) mantissa width, incl. sign.
    pub max_width: u32,
    /// Narrowest width the planner may assign, incl. sign.
    pub min_width: u32,
    /// Measured-refinement rounds (0 = analytic plan only).
    pub refine_rounds: u32,
}

impl Default for PlannerOptions {
    fn default() -> Self {
        Self { max_width: 10, min_width: 3, refine_rounds: 3 }
    }
}

impl PlannerOptions {
    /// The candidate width grid statistics must cover.
    pub fn width_grid(&self) -> Vec<u32> {
        (self.min_width..=self.max_width).collect()
    }
}

/// Which side of a conv layer a strip step narrows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Knob {
    Weight,
    Input,
}

fn traffic_of(c: &ConvCalibration, l_w: u32, l_i: u32) -> f64 {
    crate::quant::hw_cost::layer_traffic_bits(
        c.m,
        c.k,
        c.n,
        l_w,
        l_i,
        crate::bfp::PartitionScheme::Eq4,
        super::plan::EXPONENT_BITS,
    )
}

fn total_traffic(convs: &[ConvCalibration], widths: &[(u32, u32)]) -> f64 {
    convs.iter().zip(widths).map(|(c, &(w, i))| traffic_of(c, w, i)).sum()
}

/// One applied strip of the greedy walk, with the post-strip state the
/// budget test and the frontier need.
#[derive(Debug, Clone, Copy)]
struct StripStep {
    idx: usize,
    knob: Knob,
    /// Predicted whole-chain NSR after applying this strip.
    nsr: f64,
    /// Total traffic after applying this strip.
    traffic_bits: f64,
}

/// The full budget-independent greedy trajectory: the candidate ranking
/// never consults the budget, so a single walk to the bottom of the
/// width grid determines the plan for *every* budget — a tighter budget
/// is just an earlier stop along `steps` ([`materialize_plan`]).
#[derive(Debug, Clone)]
struct GreedyWalk {
    start_nsr: f64,
    start_traffic: f64,
    steps: Vec<StripStep>,
}

/// Walk the greedy bit-strip trajectory over `convs` all the way down:
/// repeatedly apply the single-bit strip (one layer, weight or input
/// side) with the best predicted-NSR-per-saved-traffic-bit score until
/// every knob sits at `min_width`.
fn greedy_walk(convs: &[ConvCalibration], opts: &PlannerOptions) -> GreedyWalk {
    assert!(!convs.is_empty(), "model has no conv layers to plan");
    assert!(opts.min_width >= 2 && opts.min_width <= opts.max_width);

    let mut widths: Vec<(u32, u32)> = vec![(opts.max_width, opts.max_width); convs.len()];
    let (_, mut cur_nsr) = predict_chain(convs, &widths);
    let mut walk = GreedyWalk {
        start_nsr: cur_nsr,
        start_traffic: total_traffic(convs, &widths),
        steps: Vec::new(),
    };

    loop {
        // rank every legal single-bit strip by ΔNSR per saved traffic bit
        let mut best: Option<(f64, usize, Knob, f64, f64)> = None; // (score, idx, knob, new_nsr, new_traffic)
        for idx in 0..convs.len() {
            for knob in [Knob::Weight, Knob::Input] {
                let (l_w, l_i) = widths[idx];
                let cand = match knob {
                    Knob::Weight if l_w > opts.min_width => (l_w - 1, l_i),
                    Knob::Input if l_i > opts.min_width => (l_w, l_i - 1),
                    _ => continue,
                };
                let saved = traffic_of(&convs[idx], widths[idx].0, widths[idx].1)
                    - traffic_of(&convs[idx], cand.0, cand.1);
                if saved <= 0.0 {
                    continue;
                }
                let mut trial = widths.clone();
                trial[idx] = cand;
                let (_, nsr) = predict_chain(convs, &trial);
                let score = (nsr - cur_nsr).max(0.0) / saved;
                let new_traffic = total_traffic(convs, &trial);
                match best {
                    Some((s, ..)) if score >= s => {}
                    _ => best = Some((score, idx, knob, nsr, new_traffic)),
                }
            }
        }
        let Some((_, idx, knob, new_nsr, new_traffic)) = best else {
            break; // everything is at min_width
        };
        match knob {
            Knob::Weight => widths[idx].0 -= 1,
            Knob::Input => widths[idx].1 -= 1,
        }
        cur_nsr = new_nsr;
        walk.steps.push(StripStep { idx, knob, nsr: new_nsr, traffic_bits: new_traffic });
    }
    walk
}

/// Replay a recorded walk up to `budget_snr_db` and build the plan at
/// the stopping point — by construction the exact plan the pre-recorded
/// planner produced for that budget (the stop rule, the frontier points
/// and the final `predict_chain` all see identical f64 state).
fn materialize_plan(
    model_name: &str,
    convs: &[ConvCalibration],
    budget_snr_db: f64,
    opts: &PlannerOptions,
    walk: &GreedyWalk,
) -> PrecisionPlan {
    let mut widths: Vec<(u32, u32)> = vec![(opts.max_width, opts.max_width); convs.len()];
    let mut front = ParetoFront::new();
    front.insert(ParetoPoint {
        traffic_bits: walk.start_traffic,
        predicted_snr_db: nsr_to_db(walk.start_nsr),
    });
    for step in &walk.steps {
        if nsr_to_db(step.nsr) < budget_snr_db {
            break; // this strip would violate the budget
        }
        match step.knob {
            Knob::Weight => widths[step.idx].0 -= 1,
            Knob::Input => widths[step.idx].1 -= 1,
        }
        front.insert(ParetoPoint {
            traffic_bits: step.traffic_bits,
            predicted_snr_db: nsr_to_db(step.nsr),
        });
    }

    let (per_layer_db, final_nsr) = predict_chain(convs, &widths);
    let layers = convs
        .iter()
        .zip(&widths)
        .zip(&per_layer_db)
        .map(|((c, &(l_w, l_i)), &snr)| LayerPlan {
            name: c.name.clone(),
            l_w,
            l_i,
            m: c.m,
            k: c.k,
            n: c.n,
            predicted_snr_db: snr,
            measured_snr_db: f64::NAN,
        })
        .collect();
    PrecisionPlan {
        model: model_name.to_string(),
        budget_snr_db,
        layers,
        predicted_snr_db: nsr_to_db(final_nsr),
        measured_snr_db: f64::NAN,
        frontier: front.into_sorted(),
    }
}

/// Pure analytic planning over pre-gathered calibration statistics.
///
/// Deterministic: same stats + same budget + same options → same plan.
pub fn plan_with_stats(
    model_name: &str,
    convs: &[ConvCalibration],
    budget_snr_db: f64,
    opts: &PlannerOptions,
) -> PrecisionPlan {
    materialize_plan(model_name, convs, budget_snr_db, opts, &greedy_walk(convs, opts))
}

/// Gather calibration statistics for `model` over `calib` images.
pub fn calibrate(model: &Model, calib: &[Tensor], opts: &PlannerOptions) -> Result<Vec<ConvCalibration>> {
    ensure!(!calib.is_empty(), "autotune needs a non-empty calibration set");
    ensure!(
        opts.min_width >= 2 && opts.min_width <= opts.max_width && opts.max_width <= 24,
        "width bounds must satisfy 2 <= min ({}) <= max ({}) <= 24",
        opts.min_width,
        opts.max_width
    );
    let mut exec = CalibExec::new(&opts.width_grid());
    for img in calib {
        ensure!(
            img.shape == model.input_shape,
            "calibration image shape {:?} != model input {:?}",
            img.shape,
            model.input_shape
        );
        exec.run_image(&model.graph, img);
    }
    let convs = exec.finish();
    ensure!(!convs.is_empty(), "model {} has no conv layers to plan", model.name);
    Ok(convs)
}

/// Surrogate-predicted conv-stack output SNR (dB) at a uniform width —
/// the natural default budget ("match uniform 8/8 quality with fewer
/// bits").
pub fn uniform_predicted_snr_db(convs: &[ConvCalibration], width: u32) -> f64 {
    let (_, nsr) = predict_chain(convs, &vec![(width, width); convs.len()]);
    nsr_to_db(nsr)
}

/// Plan the precision lane set of a QoS serving fabric: walk the greedy
/// trajectory to the bottom of the width grid (no budget) to chart the
/// full cost/quality frontier, select `k` spread operating points
/// ([`crate::autotune::pareto::select_lane_points`]), and re-plan at each
/// point's predicted SNR. Returns plans **safest first** (Gold → Economy
/// order). Because the greedy walk is budget-monotone (tested below), the
/// lane plans nest: a safer lane never carries fewer bits on any layer,
/// so a telemetry hot-swap to the next-safer plan is always a widening.
/// The greedy trajectory is budget-independent, so the walk runs
/// **once**; the full-frontier chart and every lane plan are then
/// materialized from the recorded trajectory at replay cost (`k+1`
/// `predict_chain` calls instead of `k+1` full walks).
pub fn plan_lane_set(
    model_name: &str,
    convs: &[ConvCalibration],
    k: usize,
    opts: &PlannerOptions,
) -> Vec<PrecisionPlan> {
    let walk = greedy_walk(convs, opts);
    let full = materialize_plan(model_name, convs, f64::NEG_INFINITY, opts, &walk);
    super::pareto::select_lane_points(&full.frontier, k)
        .iter()
        .map(|p| materialize_plan(model_name, convs, p.predicted_snr_db, opts, &walk))
        .collect()
}

/// The full predict → measure → refine loop: the autotuner entry point.
///
/// Plans analytically against `budget_snr_db` (minimum acceptable conv-
/// stack output SNR), then measures the plan with the dual-forward
/// instrumentation on the same calibration set. If measurement misses
/// the budget (the surrogate ignores pooling re-anchoring, so it can be
/// a little optimistic), the surrogate budget is tightened by the
/// deficit and planning repeats — each round only ever *adds* bits back.
pub fn autotune(
    model: &Model,
    calib: &[Tensor],
    budget_snr_db: f64,
    opts: &PlannerOptions,
) -> Result<PrecisionPlan> {
    let convs = calibrate(model, calib, opts)?;
    Ok(autotune_with_stats(model, calib, &convs, budget_snr_db, opts))
}

/// [`autotune`] over pre-gathered calibration statistics (lets callers
/// calibrate once, derive a budget from the stats, then plan).
pub fn autotune_with_stats(
    model: &Model,
    calib: &[Tensor],
    convs: &[ConvCalibration],
    budget_snr_db: f64,
    opts: &PlannerOptions,
) -> PrecisionPlan {
    let mut margin = 0.0f64;
    // one budget-independent walk reused by every refinement round
    let walk = greedy_walk(convs, opts);
    let mut plan = materialize_plan(&model.name, convs, budget_snr_db, opts, &walk);
    // one weight cache across all refinement candidates: layers whose
    // widths survive from round to round are never re-quantized
    let mut wcache = crate::nn::prepared::WeightCache::default();
    for round in 0..=opts.refine_rounds {
        let measurement = measure_schedule_cached(model, calib, &plan.to_schedule(), &mut wcache);
        plan.measured_snr_db = measurement.conv_out_snr_db;
        for (l, (name, snr)) in plan.layers.iter_mut().zip(&measurement.per_layer) {
            debug_assert_eq!(&l.name, name);
            l.measured_snr_db = *snr;
        }
        let deficit = budget_snr_db - measurement.conv_out_snr_db;
        if deficit <= 0.05 || round == opts.refine_rounds {
            break; // budget met (within measurement noise) or out of rounds
        }
        margin += deficit + 0.25;
        let stricter = materialize_plan(&model.name, convs, budget_snr_db + margin, opts, &walk);
        let unchanged = stricter
            .layers
            .iter()
            .zip(&plan.layers)
            .all(|(a, b)| a.l_w == b.l_w && a.l_i == b.l_i);
        if unchanged {
            break; // widths are maxed out — the budget is simply infeasible
        }
        plan = PrecisionPlan { budget_snr_db, ..stricter };
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelId;
    use std::path::Path;

    fn lenet() -> Model {
        ModelId::Lenet.build(32, 1, Path::new("/nonexistent"))
    }

    fn calib_images(n: usize, seed: u64) -> Vec<Tensor> {
        crate::data::DigitDataset::generate(n, seed).images
    }

    fn stats() -> Vec<ConvCalibration> {
        calibrate(&lenet(), &calib_images(3, 42), &PlannerOptions::default()).unwrap()
    }

    /// Width assignment + predictions of a plan, NaN-free (the measured
    /// fields are NaN before refinement, and NaN != NaN would defeat a
    /// whole-struct `assert_eq!`).
    fn plan_key(p: &PrecisionPlan) -> Vec<(String, u32, u32, u64)> {
        p.layers
            .iter()
            .map(|l| (l.name.clone(), l.l_w, l.l_i, l.predicted_snr_db.to_bits()))
            .collect()
    }

    #[test]
    fn planner_is_deterministic() {
        let convs = stats();
        let a = plan_with_stats("lenet", &convs, 30.0, &PlannerOptions::default());
        let b = plan_with_stats("lenet", &convs, 30.0, &PlannerOptions::default());
        assert_eq!(plan_key(&a), plan_key(&b));
        assert_eq!(a.predicted_snr_db.to_bits(), b.predicted_snr_db.to_bits());
        assert_eq!(a.frontier.len(), b.frontier.len());
        // and across independent calibration runs on the same data
        let c = plan_with_stats("lenet", &stats(), 30.0, &PlannerOptions::default());
        assert_eq!(plan_key(&a), plan_key(&c));
    }

    #[test]
    fn tighter_budget_never_fewer_bits() {
        let convs = stats();
        let opts = PlannerOptions::default();
        let mut prev_bits: Option<u32> = None;
        // ascending SNR budget = tightening quality requirement
        for budget in [10.0, 20.0, 30.0, 40.0, 50.0] {
            let p = plan_with_stats("lenet", &convs, budget, &opts);
            let bits = p.total_width_bits();
            if let Some(pb) = prev_bits {
                assert!(bits >= pb, "budget {budget}: {bits} bits < {pb} bits");
            }
            prev_bits = Some(bits);
        }
    }

    #[test]
    fn plan_respects_width_bounds_and_predicts_budget() {
        let convs = stats();
        let opts = PlannerOptions::default();
        let p = plan_with_stats("lenet", &convs, 28.0, &opts);
        for l in &p.layers {
            assert!(l.l_w >= opts.min_width && l.l_w <= opts.max_width);
            assert!(l.l_i >= opts.min_width && l.l_i <= opts.max_width);
        }
        assert!(
            p.predicted_snr_db >= 28.0,
            "plan predicts {} dB under a 28 dB budget",
            p.predicted_snr_db
        );
        assert!(!p.frontier.is_empty());
    }

    #[test]
    fn strips_below_start_width() {
        let convs = stats();
        let p = plan_with_stats("lenet", &convs, 20.0, &PlannerOptions::default());
        let start_bits = 2 * 10 * convs.len() as u32;
        assert!(p.total_width_bits() < start_bits, "planner stripped nothing");
    }

    /// Lane-set planning: safest-first ordering, nested width
    /// assignments (a safer lane never has fewer bits on any layer), and
    /// strictly decreasing traffic toward the cheap lanes.
    #[test]
    fn lane_set_plans_nest_safest_first() {
        let convs = stats();
        let lanes = plan_lane_set("lenet", &convs, 3, &PlannerOptions::default());
        assert!(
            (2..=3).contains(&lanes.len()),
            "expected up to 3 distinct lanes, got {}",
            lanes.len()
        );
        for pair in lanes.windows(2) {
            let (safe, cheap) = (&pair[0], &pair[1]);
            assert!(safe.predicted_snr_db >= cheap.predicted_snr_db);
            assert!(safe.total_traffic_bits() > cheap.total_traffic_bits());
            for (a, b) in safe.layers.iter().zip(&cheap.layers) {
                assert!(a.l_w >= b.l_w && a.l_i >= b.l_i, "lane plans do not nest at {}", a.name);
            }
        }
        // plan.lane_budgets on the full frontier agrees with the lane set
        let full = plan_with_stats("lenet", &convs, f64::NEG_INFINITY, &PlannerOptions::default());
        let budgets = full.lane_budgets(3);
        assert_eq!(budgets.len(), lanes.len());
        for (b, lane) in budgets.iter().zip(&lanes) {
            assert!(
                lane.predicted_snr_db >= *b,
                "lane predicts {} under budget {b}",
                lane.predicted_snr_db
            );
        }
    }

    /// The single-walk lane-set path must produce exactly the plans a
    /// fresh per-budget walk produces — widths, predictions and frontier
    /// bit-for-bit (the recorded trajectory is budget-independent).
    #[test]
    fn lane_set_single_walk_matches_per_budget_plans() {
        let convs = stats();
        let opts = PlannerOptions::default();
        let lanes = plan_lane_set("lenet", &convs, 3, &opts);
        assert!(!lanes.is_empty());
        for lane in &lanes {
            let fresh = plan_with_stats("lenet", &convs, lane.budget_snr_db, &opts);
            assert_eq!(plan_key(lane), plan_key(&fresh));
            assert_eq!(lane.predicted_snr_db.to_bits(), fresh.predicted_snr_db.to_bits());
            assert_eq!(lane.frontier.len(), fresh.frontier.len());
            for (a, b) in lane.frontier.iter().zip(&fresh.frontier) {
                assert_eq!(a.traffic_bits.to_bits(), b.traffic_bits.to_bits());
                assert_eq!(a.predicted_snr_db.to_bits(), b.predicted_snr_db.to_bits());
            }
        }
    }

    #[test]
    fn autotune_end_to_end_meets_measured_budget() {
        let model = lenet();
        let images = calib_images(4, 7);
        let budget = 26.0;
        let plan = autotune(&model, &images, budget, &PlannerOptions::default()).unwrap();
        assert!(plan.measured_snr_db.is_finite());
        assert!(
            plan.measured_snr_db >= budget - 1.0,
            "measured {} dB misses budget {budget} dB",
            plan.measured_snr_db
        );
        for l in &plan.layers {
            assert!(l.measured_snr_db.is_finite(), "layer {} unmeasured", l.name);
        }
    }

    #[test]
    fn rejects_empty_calibration() {
        assert!(autotune(&lenet(), &[], 30.0, &PlannerOptions::default()).is_err());
    }
}
