//! Calibration pass: gather the per-layer statistics the analytic NSR
//! surrogate consumes, in ONE fp32 forward per calibration image.
//!
//! The paper's §4 theory needs only width-independent signal statistics:
//! per conv layer, the im2col matrix's energy and block exponent (for the
//! eq. 8–10 input quantization noise at any candidate `L_I`) and the
//! weight matrix's per-row SNR at each candidate `L_W` (eqs. 11–13).
//! Collecting them once lets the planner evaluate thousands of width
//! assignments without touching the network again — the surrogate chains
//! the stats through the §4.3 multi-layer propagation
//! ([`predict_chain`]).

use crate::analysis::multi_layer::{eta2, total_input_nsr};
use crate::analysis::single_layer::output_nsr;
use crate::analysis::snr::{db_to_nsr, nsr_to_db, quant_error_variance, theoretical_per_row_snr};
use crate::bfp::gemm::f32_gemm;
use crate::bfp::{max_exponent, BfpFormat};
use crate::nn::graph::Executor;
use crate::nn::{ops, BatchNorm, Block, Conv2d, Dense};
use crate::tensor::{avg_pool2d, global_avg_pool, max_pool2d, Tensor};
use std::collections::BTreeMap;

/// Width-independent quantization statistics of one conv layer,
/// accumulated over the calibration set.
#[derive(Debug, Clone)]
pub struct ConvCalibration {
    pub name: String,
    /// GEMM geometry `W_{M×K} · I_{K×N}`.
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Σ over images of the im2col signal energy.
    in_sig: f64,
    /// Per candidate `L_I`: Σ over images of the eq. (8) noise energy.
    in_noise: BTreeMap<u32, f64>,
    /// Per candidate `L_W`: theoretical per-row weight SNR (dB).
    weight_snr_db: BTreeMap<u32, f64>,
}

impl ConvCalibration {
    /// Fresh-quantization input NSR at activation width `l_i` (eqs. 9–10).
    pub fn input_nsr(&self, l_i: u32) -> f64 {
        let noise = self.in_noise.get(&l_i).copied().unwrap_or(f64::NAN);
        if self.in_sig <= 0.0 {
            return 0.0;
        }
        noise / self.in_sig
    }

    /// Weight quantization NSR at weight width `l_w` (eqs. 11–13).
    pub fn weight_nsr(&self, l_w: u32) -> f64 {
        db_to_nsr(self.weight_snr_db.get(&l_w).copied().unwrap_or(f64::NAN))
    }
}

/// FP32 calibration executor: normal fp32 inference, recording surrogate
/// statistics at every conv layer for a fixed candidate-width set.
pub struct CalibExec {
    widths: Vec<u32>,
    convs: Vec<ConvCalibration>,
    cursor: usize,
}

impl CalibExec {
    /// `widths`: the candidate mantissa widths (incl. sign) the planner
    /// may assign — statistics are gathered for each.
    pub fn new(widths: &[u32]) -> Self {
        assert!(!widths.is_empty(), "need at least one candidate width");
        Self { widths: widths.to_vec(), convs: Vec::new(), cursor: 0 }
    }

    /// Run one calibration image, accumulating statistics.
    pub fn run_image(&mut self, graph: &Block, input: &Tensor) -> Tensor {
        self.cursor = 0;
        graph.execute(input.clone(), self)
    }

    /// Finished per-conv statistics in execution order.
    pub fn finish(self) -> Vec<ConvCalibration> {
        self.convs
    }
}

impl Executor for CalibExec {
    type T = Tensor;

    fn conv(&mut self, layer: &Conv2d, x: Tensor) -> Tensor {
        let (col, geo) = layer.im2col(&x);
        let (m, k, n) = (layer.out_channels(), geo.k(), geo.n());

        if self.cursor == self.convs.len() {
            // first image: create the slot and compute the (image-
            // independent) weight statistics once per candidate width
            let mut weight_snr_db = BTreeMap::new();
            for &w in &self.widths {
                weight_snr_db
                    .insert(w, theoretical_per_row_snr(&layer.weights.data, m, k, BfpFormat::new(w)));
            }
            self.convs.push(ConvCalibration {
                name: layer.name.clone(),
                m,
                k,
                n,
                in_sig: 0.0,
                in_noise: self.widths.iter().map(|&w| (w, 0.0)).collect(),
                weight_snr_db,
            });
        }
        let slot = &mut self.convs[self.cursor];
        debug_assert_eq!(slot.name, layer.name, "calibration order diverged");
        self.cursor += 1;

        // input statistics: whole-matrix block exponent (eq. 4's I axis)
        slot.in_sig += col.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        if let Some(eps) = max_exponent(&col) {
            for (&w, noise) in slot.in_noise.iter_mut() {
                *noise += quant_error_variance(BfpFormat::new(w), eps) * col.len() as f64;
            }
        }

        // continue the fp32 forward from the already-built im2col
        let mut out = vec![0f32; m * n];
        f32_gemm(&layer.weights.data, &col, m, k, n, &mut out);
        if !layer.bias.is_empty() {
            for (oc, &b) in layer.bias.iter().enumerate() {
                for v in &mut out[oc * n..(oc + 1) * n] {
                    *v += b;
                }
            }
        }
        Tensor::from_vec(out, &[m, geo.out_h(), geo.out_w()])
    }

    fn dense(&mut self, layer: &Dense, x: Tensor) -> Tensor {
        layer.forward_fp32(&x)
    }
    fn batch_norm(&mut self, layer: &BatchNorm, x: Tensor) -> Tensor {
        layer.forward(&x)
    }
    fn relu(&mut self, x: Tensor) -> Tensor {
        ops::relu(&x)
    }
    fn max_pool(&mut self, _name: &str, k: usize, s: usize, p: usize, x: Tensor) -> Tensor {
        max_pool2d(&x, k, s, p)
    }
    fn avg_pool(&mut self, _name: &str, k: usize, s: usize, p: usize, x: Tensor) -> Tensor {
        avg_pool2d(&x, k, s, p)
    }
    fn global_avg_pool(&mut self, x: Tensor) -> Tensor {
        global_avg_pool(&x)
    }
    fn flatten(&mut self, x: Tensor) -> Tensor {
        ops::flatten(&x)
    }
    fn add(&mut self, a: Tensor, b: Tensor) -> Tensor {
        ops::add(&a, &b)
    }
    fn concat(&mut self, parts: Vec<Tensor>) -> Tensor {
        ops::concat_channels(&parts)
    }
    fn softmax(&mut self, x: Tensor) -> Tensor {
        ops::softmax(&x)
    }
    fn fork(&mut self, x: &Tensor) -> Tensor {
        x.clone()
    }
}

/// Chain per-layer width assignments through the §4.3 multi-layer model.
///
/// `widths[i]` is the `(L_W, L_I)` pair of conv `i` (execution order,
/// matching `convs`). Pooling/ReLU between convs is treated as
/// NSR-preserving (§4.4's argument; the table-4 pool re-anchor needs a
/// measured SNR, which a surrogate by definition doesn't have — the
/// dual-forward refinement step covers the residual).
///
/// Returns the per-conv predicted *output* SNR (dB) and the final conv
/// output NSR (linear).
pub fn predict_chain(convs: &[ConvCalibration], widths: &[(u32, u32)]) -> (Vec<f64>, f64) {
    assert_eq!(convs.len(), widths.len());
    let mut per_layer = Vec::with_capacity(convs.len());
    let mut carried: Option<f64> = None;
    for (c, &(l_w, l_i)) in convs.iter().zip(widths) {
        let eta_single_in = c.input_nsr(l_i);
        let input_nsr = match carried {
            None => eta_single_in,
            Some(eta1) => total_input_nsr(eta1, eta2(eta_single_in, eta1)),
        };
        let out = output_nsr(input_nsr, c.weight_nsr(l_w));
        per_layer.push(nsr_to_db(out));
        carried = Some(out);
    }
    (per_layer, carried.unwrap_or(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::models::init;

    fn two_conv_model(seed: u64) -> Block {
        let mut rng = Rng::new(seed);
        Block::seq(vec![
            Block::Conv(init::conv2d("conv1", 8, 2, 3, 3, 1, 1, &mut rng)),
            Block::ReLU,
            Block::MaxPool { name: "pool1".into(), k: 2, s: 2, p: 0 },
            Block::Conv(init::conv2d("conv2", 8, 8, 3, 3, 1, 1, &mut rng)),
            Block::ReLU,
        ])
    }

    fn image(seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::from_vec(rng.normal_vec(2 * 12 * 12, 1.0), &[2, 12, 12])
    }

    #[test]
    fn gathers_stats_in_order() {
        let m = two_conv_model(1);
        let mut exec = CalibExec::new(&[6, 8, 10]);
        for s in 0..3 {
            exec.run_image(&m, &image(s));
        }
        let convs = exec.finish();
        assert_eq!(convs.len(), 2);
        assert_eq!(convs[0].name, "conv1");
        assert_eq!(convs[1].name, "conv2");
        for c in &convs {
            assert!(c.in_sig > 0.0);
            // 6 dB/bit: each extra mantissa bit quarters the noise power
            let r = c.input_nsr(6) / c.input_nsr(8);
            assert!((r - 16.0).abs() < 1e-6, "ratio {r}");
            assert!(c.weight_nsr(6) > c.weight_nsr(8));
        }
    }

    /// The surrogate must agree with the single-layer theory the
    /// instrumented dual forward computes (same formulas, same stats).
    #[test]
    fn surrogate_matches_instrumented_theory_on_first_layer() {
        let m = two_conv_model(3);
        let mut calib = CalibExec::new(&[8]);
        let mut inst = crate::analysis::InstrumentExec::new(crate::quant::BfpConfig::paper_default());
        for s in 0..3 {
            calib.run_image(&m, &image(100 + s));
            inst.run_image(&m, &image(100 + s));
        }
        let convs = calib.finish();
        let recs = inst.finish();
        let c1 = &recs[0];
        let calib_in_db = nsr_to_db(convs[0].input_nsr(8));
        assert!(
            (calib_in_db - c1.input_snr_single_db).abs() < 1e-9,
            "calib {calib_in_db} vs instrument {}",
            c1.input_snr_single_db
        );
        let (per_layer, _) = predict_chain(&convs, &[(8, 8), (8, 8)]);
        assert!(
            (per_layer[0] - c1.output_snr_single_db).abs() < 1e-9,
            "chain {} vs single-layer {}",
            per_layer[0],
            c1.output_snr_single_db
        );
    }

    #[test]
    fn chain_widths_move_final_nsr() {
        let m = two_conv_model(5);
        let mut exec = CalibExec::new(&[4, 6, 8, 10]);
        for s in 0..2 {
            exec.run_image(&m, &image(200 + s));
        }
        let convs = exec.finish();
        let (_, wide) = predict_chain(&convs, &[(10, 10), (10, 10)]);
        let (_, narrow) = predict_chain(&convs, &[(4, 4), (4, 4)]);
        assert!(narrow > wide * 100.0, "narrow {narrow} vs wide {wide}");
        // narrowing only the *last* layer hurts less than the first
        let (_, late) = predict_chain(&convs, &[(10, 10), (6, 6)]);
        let (_, early) = predict_chain(&convs, &[(6, 6), (10, 10)]);
        assert!(late > wide && early > wide);
    }
}
