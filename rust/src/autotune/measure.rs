//! Dual-forward measurement of a per-layer schedule — the empirical half
//! of the planner's predict → measure → refine loop.

use crate::analysis::instrument::{InstrumentExec, LayerKind};
use crate::models::Model;
use crate::nn::prepared::WeightCache;
use crate::quant::LayerSchedule;
use crate::tensor::Tensor;

/// Measured SNRs of one schedule over a calibration set.
#[derive(Debug, Clone)]
pub struct PlanMeasurement {
    /// Per conv layer (execution order): measured output SNR in dB.
    pub per_layer: Vec<(String, f64)>,
    /// Output SNR of the last conv layer — the quantity the §4.3
    /// surrogate predicts.
    pub conv_out_snr_db: f64,
    /// End-to-end SNR at the network output (through the fp32 dense
    /// tail), for reporting.
    pub logits_snr_db: f64,
}

/// Run the instrumented dual forward (fp32 ∥ scheduled BFP) over
/// `images` and aggregate the measured SNRs.
pub fn measure_schedule(model: &Model, images: &[Tensor], schedule: &LayerSchedule) -> PlanMeasurement {
    measure_schedule_cached(model, images, schedule, &mut WeightCache::default())
}

/// [`measure_schedule`] threading a persistent [`WeightCache`] through:
/// the refine loop re-measures the full network once per candidate
/// schedule, and most layers keep their widths between candidates, so
/// their quantized weights come straight from the cache.
pub fn measure_schedule_cached(
    model: &Model,
    images: &[Tensor],
    schedule: &LayerSchedule,
    cache: &mut WeightCache,
) -> PlanMeasurement {
    assert!(!images.is_empty(), "measurement needs at least one image");
    let mut exec = InstrumentExec::with_schedule_and_cache(schedule.clone(), std::mem::take(cache));
    let mut out_sig = 0f64;
    let mut out_err = 0f64;
    for img in images {
        let dual = exec.run_image(&model.graph, img);
        for (&a, &b) in dual.fp.data.iter().zip(&dual.bfp.data) {
            out_sig += (a as f64) * (a as f64);
            out_err += ((b - a) as f64) * ((b - a) as f64);
        }
    }
    let records = exec.finish();
    *cache = exec.into_cache();
    let per_layer: Vec<(String, f64)> = records
        .iter()
        .filter(|r| r.kind == LayerKind::Conv)
        .map(|r| (r.name.clone(), r.output_snr_ex_db))
        .collect();
    let conv_out_snr_db = per_layer.last().map(|(_, s)| *s).unwrap_or(f64::INFINITY);
    PlanMeasurement {
        per_layer,
        conv_out_snr_db,
        logits_snr_db: crate::analysis::snr_db(out_sig, out_err),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelId;
    use crate::quant::BfpConfig;
    use std::path::Path;

    fn lenet_and_images() -> (Model, Vec<Tensor>) {
        let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
        let images = crate::data::DigitDataset::generate(3, 11).images;
        (model, images)
    }

    #[test]
    fn measures_every_conv() {
        let (model, images) = lenet_and_images();
        let sched = LayerSchedule::uniform(BfpConfig::paper_default());
        let m = measure_schedule(&model, &images, &sched);
        assert_eq!(m.per_layer.len(), 2);
        assert_eq!(m.per_layer[0].0, "conv1");
        assert_eq!(m.per_layer[1].0, "conv2");
        assert!(m.conv_out_snr_db.is_finite());
        assert!(m.logits_snr_db.is_finite());
    }

    #[test]
    fn wider_schedule_measures_cleaner() {
        let (model, images) = lenet_and_images();
        let narrow = measure_schedule(&model, &images, &LayerSchedule::uniform(BfpConfig::new(5, 5)));
        let wide = measure_schedule(&model, &images, &LayerSchedule::uniform(BfpConfig::new(10, 10)));
        assert!(
            wide.conv_out_snr_db > narrow.conv_out_snr_db + 6.0,
            "wide {} vs narrow {}",
            wide.conv_out_snr_db,
            narrow.conv_out_snr_db
        );
    }

    /// A persistent cache across candidate schedules must not change the
    /// measurement, and must actually get hits on unchanged layers.
    #[test]
    fn cached_measurement_matches_fresh() {
        let (model, images) = lenet_and_images();
        let a = LayerSchedule::uniform(BfpConfig::new(7, 7));
        let b = a.clone().with_layer("conv2", BfpConfig::new(5, 5));
        let mut cache = WeightCache::default();
        let am_cached = measure_schedule_cached(&model, &images, &a, &mut cache);
        let bm_cached = measure_schedule_cached(&model, &images, &b, &mut cache);
        // conv1 kept its config between candidates → cache hit
        assert!(cache.hits() > 0, "no cache hits across candidates");
        let am = measure_schedule(&model, &images, &a);
        let bm = measure_schedule(&model, &images, &b);
        assert_eq!(am.conv_out_snr_db.to_bits(), am_cached.conv_out_snr_db.to_bits());
        assert_eq!(bm.conv_out_snr_db.to_bits(), bm_cached.conv_out_snr_db.to_bits());
        assert_eq!(am.logits_snr_db.to_bits(), am_cached.logits_snr_db.to_bits());
        assert_eq!(bm.logits_snr_db.to_bits(), bm_cached.logits_snr_db.to_bits());
    }

    #[test]
    fn mixed_schedule_sits_between_uniforms() {
        let (model, images) = lenet_and_images();
        let lo = measure_schedule(&model, &images, &LayerSchedule::uniform(BfpConfig::new(5, 5)));
        let hi = measure_schedule(&model, &images, &LayerSchedule::uniform(BfpConfig::new(9, 9)));
        let mixed = measure_schedule(
            &model,
            &images,
            &LayerSchedule::uniform(BfpConfig::new(5, 5)).with_layer("conv1", BfpConfig::new(9, 9)),
        );
        assert!(mixed.conv_out_snr_db > lo.conv_out_snr_db - 0.5);
        assert!(mixed.conv_out_snr_db < hi.conv_out_snr_db + 0.5);
    }
}
