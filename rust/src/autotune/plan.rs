//! The serializable output of the mixed-precision planner.
//!
//! A [`PrecisionPlan`] is the artifact the autotuner hands to the serving
//! stack: per-conv-layer mantissa widths with the predicted (analytic
//! surrogate) and measured (dual-forward) output SNRs, plus the Table 1
//! traffic cost relative to the uniform 8-bit baseline. Plans round-trip
//! through a line-oriented text format (the same spirit as the `.bfpw`
//! weight interchange) so the CLI can emit them and the server can load
//! them later.

use crate::bfp::PartitionScheme;
use crate::quant::{hw_cost, BfpConfig, LayerSchedule};
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

/// Exponent width assumed by the traffic cost model (the paper uses
/// 8-bit block exponents throughout).
pub const EXPONENT_BITS: u32 = 8;

/// One conv layer's slot in a [`PrecisionPlan`].
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    pub name: String,
    /// Weight mantissa bits (incl. sign).
    pub l_w: u32,
    /// Activation mantissa bits (incl. sign).
    pub l_i: u32,
    /// GEMM geometry `W_{M×K}·I_{K×N}` (drives the traffic cost).
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Surrogate-predicted output SNR at this layer (dB, multi-layer
    /// propagation up to and including this conv).
    pub predicted_snr_db: f64,
    /// Dual-forward measured output SNR (dB); NaN until measured.
    pub measured_snr_db: f64,
}

impl LayerPlan {
    /// Table 1 storage/traffic bits this layer moves per inference.
    pub fn traffic_bits(&self) -> f64 {
        hw_cost::layer_traffic_bits(
            self.m,
            self.k,
            self.n,
            self.l_w,
            self.l_i,
            PartitionScheme::Eq4,
            EXPONENT_BITS,
        )
    }

    /// Traffic of the same geometry at a uniform width pair.
    pub fn traffic_bits_at(&self, l_w: u32, l_i: u32) -> f64 {
        hw_cost::layer_traffic_bits(self.m, self.k, self.n, l_w, l_i, PartitionScheme::Eq4, EXPONENT_BITS)
    }
}

/// A point on the planner's cost/quality trade-off curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParetoPoint {
    /// Total Table 1 traffic bits per inference across all conv layers.
    pub traffic_bits: f64,
    /// Surrogate-predicted network output SNR (dB).
    pub predicted_snr_db: f64,
}

/// The autotuner's product: per-layer widths + predictions + cost.
#[derive(Debug, Clone, PartialEq)]
pub struct PrecisionPlan {
    pub model: String,
    /// The SNR floor (dB) the plan was asked to respect.
    pub budget_snr_db: f64,
    /// Per-conv-layer width assignment, in execution order.
    pub layers: Vec<LayerPlan>,
    /// Surrogate-predicted network output SNR (dB, last conv).
    pub predicted_snr_db: f64,
    /// Dual-forward measured network output SNR (dB, last conv);
    /// NaN until the calibration measurement has run.
    pub measured_snr_db: f64,
    /// The planner's cost/quality frontier (greedy trajectory, dominated
    /// points pruned).
    pub frontier: Vec<ParetoPoint>,
}

impl PrecisionPlan {
    /// Convert to the executable per-layer schedule (default 8/8 for any
    /// layer the plan doesn't name — e.g. dense layers stay at the paper
    /// default if `quantize_dense` is ever enabled).
    pub fn to_schedule(&self) -> LayerSchedule {
        LayerSchedule::from_pairs(
            BfpConfig::paper_default(),
            self.layers.iter().map(|l| (l.name.clone(), BfpConfig::new(l.l_w, l.l_i))),
        )
    }

    /// Sum of per-layer mantissa width pairs (the "plan size" in bits,
    /// independent of geometry).
    pub fn total_width_bits(&self) -> u32 {
        self.layers.iter().map(|l| l.l_w + l.l_i).sum()
    }

    /// Total Table 1 traffic bits per inference.
    pub fn total_traffic_bits(&self) -> f64 {
        self.layers.iter().map(|l| l.traffic_bits()).sum()
    }

    /// Traffic of the uniform-width baseline on the same geometries.
    pub fn uniform_traffic_bits(&self, l_w: u32, l_i: u32) -> f64 {
        self.layers.iter().map(|l| l.traffic_bits_at(l_w, l_i)).sum()
    }

    /// Budgets (predicted-SNR floors, safest first) for a `k`-lane QoS
    /// serving set drawn from this plan's frontier: each budget re-plans
    /// to one lane's operating point
    /// ([`crate::autotune::planner::plan_lane_set`] does this from raw
    /// calibration stats when no plan file exists yet).
    pub fn lane_budgets(&self, k: usize) -> Vec<f64> {
        super::pareto::select_lane_points(&self.frontier, k)
            .iter()
            .map(|p| p.predicted_snr_db)
            .collect()
    }

    /// Fraction of the uniform 8/8 traffic this plan saves (0.12 = 12%).
    pub fn savings_vs_uniform8(&self) -> f64 {
        let base = self.uniform_traffic_bits(8, 8);
        if base <= 0.0 {
            return 0.0;
        }
        1.0 - self.total_traffic_bits() / base
    }

    // ---- text serialization ------------------------------------------

    /// Render to the `bfp-plan-v1` text format.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str("bfp-plan-v1\n");
        out.push_str(&format!("model {}\n", self.model));
        out.push_str(&format!("budget_snr_db {}\n", self.budget_snr_db));
        out.push_str(&format!("predicted_snr_db {}\n", self.predicted_snr_db));
        out.push_str(&format!("measured_snr_db {}\n", self.measured_snr_db));
        for l in &self.layers {
            out.push_str(&format!(
                "layer {} lw {} li {} m {} k {} n {} predicted_snr_db {} measured_snr_db {}\n",
                l.name, l.l_w, l.l_i, l.m, l.k, l.n, l.predicted_snr_db, l.measured_snr_db
            ));
        }
        for p in &self.frontier {
            out.push_str(&format!("pareto {} {}\n", p.traffic_bits, p.predicted_snr_db));
        }
        out
    }

    /// Parse the `bfp-plan-v1` text format.
    pub fn parse(text: &str) -> Result<Self> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        ensure!(lines.next() == Some("bfp-plan-v1"), "missing bfp-plan-v1 header");
        let mut model = None;
        let mut budget = f64::NAN;
        let mut predicted = f64::NAN;
        let mut measured = f64::NAN;
        let mut layers = Vec::new();
        let mut frontier = Vec::new();
        for line in lines {
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("model") => model = Some(parts.next().context("model line missing name")?.to_string()),
                Some("budget_snr_db") => budget = parse_f64(parts.next(), "budget_snr_db")?,
                Some("predicted_snr_db") => predicted = parse_f64(parts.next(), "predicted_snr_db")?,
                Some("measured_snr_db") => measured = parse_f64(parts.next(), "measured_snr_db")?,
                Some("layer") => layers.push(parse_layer(line)?),
                Some("pareto") => {
                    let bits = parse_f64(parts.next(), "pareto bits")?;
                    let snr = parse_f64(parts.next(), "pareto snr")?;
                    frontier.push(ParetoPoint { traffic_bits: bits, predicted_snr_db: snr });
                }
                Some(other) => bail!("unknown plan line kind: {other}"),
                None => {}
            }
        }
        Ok(Self {
            model: model.context("plan missing model line")?,
            budget_snr_db: budget,
            layers,
            predicted_snr_db: predicted,
            measured_snr_db: measured,
            frontier,
        })
    }

    /// Write the plan to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_text())
            .with_context(|| format!("writing plan to {}", path.display()))?;
        Ok(())
    }

    /// Load a plan from a file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing plan {}", path.display()))
    }
}

fn parse_f64(tok: Option<&str>, what: &str) -> Result<f64> {
    let t = tok.with_context(|| format!("missing {what} value"))?;
    if t == "NaN" {
        return Ok(f64::NAN);
    }
    t.parse::<f64>().with_context(|| format!("bad {what} value {t}"))
}

fn expect_kv<'a>(toks: &[&'a str], key: &str, idx: usize) -> Result<&'a str> {
    ensure!(toks[idx] == key, "layer line: expected `{key}` at token {idx}, got `{}`", toks[idx]);
    Ok(toks[idx + 1])
}

fn parse_layer(line: &str) -> Result<LayerPlan> {
    let toks: Vec<&str> = line.split_whitespace().collect();
    ensure!(toks.len() == 16 && toks[0] == "layer", "malformed layer line: {line}");
    Ok(LayerPlan {
        name: toks[1].to_string(),
        l_w: expect_kv(&toks, "lw", 2)?.parse().context("bad lw")?,
        l_i: expect_kv(&toks, "li", 4)?.parse().context("bad li")?,
        m: expect_kv(&toks, "m", 6)?.parse().context("bad m")?,
        k: expect_kv(&toks, "k", 8)?.parse().context("bad k")?,
        n: expect_kv(&toks, "n", 10)?.parse().context("bad n")?,
        predicted_snr_db: parse_f64(Some(expect_kv(&toks, "predicted_snr_db", 12)?), "layer predicted")?,
        measured_snr_db: parse_f64(Some(expect_kv(&toks, "measured_snr_db", 14)?), "layer measured")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_plan() -> PrecisionPlan {
        PrecisionPlan {
            model: "lenet".into(),
            budget_snr_db: 28.5,
            layers: vec![
                LayerPlan {
                    name: "conv1".into(),
                    l_w: 7,
                    l_i: 8,
                    m: 8,
                    k: 25,
                    n: 784,
                    predicted_snr_db: 40.25,
                    measured_snr_db: f64::NAN,
                },
                LayerPlan {
                    name: "conv2".into(),
                    l_w: 5,
                    l_i: 6,
                    m: 16,
                    k: 200,
                    n: 196,
                    predicted_snr_db: 30.5,
                    measured_snr_db: 30.1,
                },
            ],
            predicted_snr_db: 30.5,
            measured_snr_db: f64::NAN,
            frontier: vec![ParetoPoint { traffic_bits: 1000.0, predicted_snr_db: 30.5 }],
        }
    }

    #[test]
    fn text_round_trip() {
        let p = demo_plan();
        let q = PrecisionPlan::parse(&p.to_text()).unwrap();
        assert_eq!(q.model, "lenet");
        assert_eq!(q.layers.len(), 2);
        assert_eq!(q.layers[0].l_w, 7);
        assert_eq!(q.layers[1].l_i, 6);
        assert!((q.budget_snr_db - 28.5).abs() < 1e-12);
        assert!(q.layers[0].measured_snr_db.is_nan());
        assert!((q.layers[1].measured_snr_db - 30.1).abs() < 1e-12);
        assert_eq!(q.frontier.len(), 1);
    }

    #[test]
    fn schedule_carries_widths() {
        let s = demo_plan().to_schedule();
        assert_eq!(s.for_layer("conv1"), BfpConfig::new(7, 8));
        assert_eq!(s.for_layer("conv2"), BfpConfig::new(5, 6));
        assert_eq!(s.for_layer("fc1"), BfpConfig::paper_default());
    }

    #[test]
    fn traffic_below_uniform8() {
        let p = demo_plan();
        assert!(p.total_traffic_bits() < p.uniform_traffic_bits(8, 8));
        assert!(p.savings_vs_uniform8() > 0.0);
        assert_eq!(p.total_width_bits(), 7 + 8 + 5 + 6);
    }

    #[test]
    fn rejects_garbage() {
        assert!(PrecisionPlan::parse("nope").is_err());
        assert!(PrecisionPlan::parse("bfp-plan-v1\nmystery 1").is_err());
    }

    #[test]
    fn file_round_trip() {
        let p = demo_plan();
        let dir = std::env::temp_dir().join("bfp_cnn_plan_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lenet.plan");
        p.save(&path).unwrap();
        let q = PrecisionPlan::load(&path).unwrap();
        // field-wise compare: measured fields are NaN, and NaN != NaN
        // would defeat a whole-struct assert_eq!
        assert_eq!(q.layers.len(), p.layers.len());
        for (a, b) in q.layers.iter().zip(&p.layers) {
            assert_eq!((a.name.as_str(), a.l_w, a.l_i, a.m, a.k, a.n),
                       (b.name.as_str(), b.l_w, b.l_i, b.m, b.k, b.n));
            assert_eq!(a.predicted_snr_db.to_bits(), b.predicted_snr_db.to_bits());
            assert_eq!(a.measured_snr_db.is_nan(), b.measured_snr_db.is_nan());
        }
        std::fs::remove_file(&path).ok();
    }
}
