//! NSR-guided mixed-precision autotuning — the design loop the paper's
//! abstract promises ("the NSR upper bound … provides the promising
//! guidance for BFP based CNN engine design"), closed.
//!
//! Given a model and an output-SNR budget, the autotuner searches
//! per-layer `(L_W, L_I)` mantissa widths using the paper's own §4 error
//! theory as a fast analytic surrogate, then verifies and refines the
//! result empirically:
//!
//! 1. [`calibrate`] — one fp32 forward per calibration image gathers the
//!    width-independent signal statistics (im2col energy + block
//!    exponents, per-row weight SNRs) each conv layer contributes to the
//!    eq. (8)–(13) quantization noise model.
//! 2. [`planner::plan_with_stats`] — greedy bit-stripping: repeatedly
//!    remove the mantissa bit with the best predicted-NSR-per-traffic-bit
//!    score (§4.3 multi-layer propagation over the stats ÷ Table 1
//!    storage model) until the budget binds. The walk's visited
//!    trade-offs form a Pareto frontier ([`pareto::ParetoFront`]).
//! 3. [`measure::measure_schedule`] — the dual-forward instrumentation
//!    measures the chosen plan; if reality misses the budget the
//!    surrogate budget tightens and planning repeats ([`autotune`]).
//!
//! The product is a serializable [`PrecisionPlan`]; `plan.to_schedule()`
//! yields the [`crate::quant::LayerSchedule`] that
//! [`crate::coordinator::engine::ExecMode::Mixed`] executes in the
//! serving stack.

pub mod calibrate;
pub mod measure;
pub mod pareto;
pub mod plan;
pub mod planner;

pub use calibrate::{predict_chain, CalibExec, ConvCalibration};
pub use measure::{measure_schedule, measure_schedule_cached, PlanMeasurement};
pub use pareto::{select_lane_points, ParetoFront};
pub use plan::{LayerPlan, ParetoPoint, PrecisionPlan};
pub use planner::{
    autotune, autotune_with_stats, calibrate, plan_lane_set, plan_with_stats,
    uniform_predicted_snr_db, PlannerOptions,
};
