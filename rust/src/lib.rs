//! # bfp-cnn — Block Floating Point arithmetic for CNN accelerators
//!
//! Reproduction of *"Computation Error Analysis of Block Floating Point
//! Arithmetic Oriented Convolution Neural Network Accelerator Design"*
//! (Song, Liu & Wang — AAAI 2018).
//!
//! The crate is organised as the three-layer stack described in `DESIGN.md`:
//!
//! * [`bfp`] — the numeric substrate: block formatting (shared-exponent
//!   quantization), exact fixed-point GEMM over aligned mantissas (the
//!   naive reference in [`bfp::gemm`] and the cache-blocked,
//!   register-tiled production microkernel with its fused
//!   im2col→quantize→pack pipeline in [`bfp::kernel`] — bit-identical
//!   by the §3.4 exactness argument), and the matrix-partition schemes
//!   of the paper's eqs. (2)–(5) with their storage cost model (Table 1).
//! * [`tensor`] + [`nn`] + [`models`] — a from-scratch CNN inference stack
//!   (im2col convolution, pooling, batch-norm, residual / inception
//!   composition) plus structural definitions of the six networks the
//!   paper evaluates (VGG-16, ResNet-18/50, GoogLeNet, LeNet/mnist,
//!   CIFAR-10).
//! * [`analysis`] — the paper's §4 three-stage error model: quantization
//!   SNR (eqs. 8–13), single-layer output SNR (eq. 18) and multi-layer
//!   propagation (eqs. 19–20), along with the empirical dual-forward
//!   instrumentation that produces Table 4 and Figure 3.
//! * [`autotune`] — the NSR-guided mixed-precision planner: uses the §4
//!   theory as an analytic surrogate to search per-layer `(L_W, L_I)`
//!   widths against an output-SNR budget, scores candidates with the
//!   Table 1 traffic model, refines with dual-forward measurement and
//!   emits a serializable [`autotune::PrecisionPlan`] whose
//!   [`quant::LayerSchedule`] the serving stack executes per layer
//!   (`ExecMode::Mixed`).
//! * [`coordinator`] + [`runtime`] — the serving layer: a batched
//!   inference engine that can execute either the pure-Rust path or the
//!   AOT-compiled JAX/Pallas artifacts through PJRT. Steady-state serving
//!   uses [`nn::prepared`] (weight quantization cached per
//!   `(layer, config)`, scratch-arena workspaces) on the zero-dependency
//!   scoped thread pool in [`runtime::pool`] (`BFP_NUM_THREADS`), with
//!   output bit-identical to the serial path at every thread count. The
//!   QoS precision router ([`coordinator::qos`]) serves multiple lanes —
//!   one [`nn::prepared::PreparedModel`] per latency/quality class, all
//!   over one shared weight cache — with earliest-deadline-first
//!   class-pure batching and pressure-driven downgrades.
//! * [`telemetry`] — online NSR telemetry: Welford-streamed BFP-vs-f32
//!   probe forwards per lane, hot-swapping a lane to the next-safer
//!   frontier plan when the measured SNR breaks its plan's predicted
//!   §4 bound, and walking it back toward the frontier after a
//!   sustained healthy window (hysteresis-guarded re-promotion).
//! * [`net`] — the networked serving fabric: a zero-dependency TCP
//!   front (length-prefixed binary framing, per-connection reader and
//!   writer threads, per-tenant token-bucket quotas) over the QoS
//!   router, plus the open-loop, coordinated-omission-free load
//!   generator and its scenario suite.
//! * [`obs`] — zero-dependency observability: one monotonic clock, a
//!   lock-free span flight recorder threaded through every serving
//!   stage (queue→assemble→forward→im2col/pack/gemm→reply, tagged with
//!   lane / layer / BFP widths), Chrome/Perfetto trace export, and the
//!   per-stage latency attribution behind `qos_report` and the `Stats`
//!   wire frame.
//! * [`harness`] — drivers that regenerate every table and figure of the
//!   paper's evaluation section.
//! * [`data`] — synthetic workload generators (procedural digit / texture
//!   datasets, ImageNet-statistics activation generators) substituting for
//!   the proprietary datasets per `DESIGN.md` §4.
//!
//! Project invariants (SAFETY comments on every `unsafe`, clock
//! discipline, ordering justifications, serving-path unwrap bans) are
//! machine-checked by `bfp-cnn lint` — see [`analysis::lint`].

// every `unsafe` operation must sit in its own explicitly-audited block
#![deny(unsafe_op_in_unsafe_fn)]
// and every unsafe block carries a `// SAFETY:` comment (also enforced,
// with more context, by `bfp-cnn lint`)
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod analysis;
pub mod autotune;
pub mod bfp;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod models;
pub mod net;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod telemetry;
pub mod tensor;

pub use bfp::{BfpBlock, BfpFormat, Rounding};
pub use quant::BfpConfig;
pub use tensor::Tensor;
