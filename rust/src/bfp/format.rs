//! Word-width bookkeeping for BFP blocks.
//!
//! The paper's tables quote mantissa lengths `L_W` / `L_I` *including the
//! sign bit* (Table 3 caption). With `L` total bits the mantissa layout is
//!
//! ```text
//!   [ sign | 1 integer bit | L-2 fractional bits ]
//! ```
//!
//! because the block-maximum element has mantissa `m ∈ [1, 2)` (one integer
//! bit) and every other element is right-shifted below it. The
//! quantization step of a block with exponent `ε` is therefore
//! `Δ = 2^(ε - (L-2)) = 2^(ε - frac_bits)`, which is exactly the step that
//! appears in the paper's eq. (8) variance `σ² = 2^(-2·Lm)/12 · 2^(2ε)`
//! with `Lm = frac_bits`.


/// Block-exponent sentinel for an all-zero block (no finite nonzero
/// value → no exponent). One definition shared by every quantization and
/// GEMM path so the zero-block bit-equality between the naive and tiled
/// kernels can never drift.
pub(crate) const ZERO_EXP: i32 = i32::MIN / 2;

/// Exponents at or below this floor are treated as all-zero markers by
/// the GEMM rescale steps (strictly between valid exponents and
/// [`ZERO_EXP`], so sums of a valid exponent with a sentinel still land
/// below it).
pub(crate) const ZERO_EXP_FLOOR: i32 = i32::MIN / 4;

/// Rounding mode applied to the bits shifted out during block formatting.
///
/// §3.1: truncation produces DC (biased) errors that accumulate layer by
/// layer; round-off produces zero-mean noise. The paper uses round-off; we
/// keep truncation for the ablation bench.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Rounding {
    /// Round to nearest, ties away from zero (the paper's "round off").
    #[default]
    Nearest,
    /// Truncate toward zero (drop the out-shifted bits).
    Truncate,
    /// Stochastic rounding (Gupta et al. 2015, §2 related work): round up
    /// with probability equal to the dropped fraction. Deterministic
    /// hash-based implementation (the value's own bit pattern seeds the
    /// threshold), so results stay reproducible.
    Stochastic,
}

/// A BFP word-width definition: total mantissa bits including the sign bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BfpFormat {
    /// Total mantissa bits **including** the sign bit — the paper's
    /// `L_W` / `L_I` as quoted in Table 3.
    pub total_bits: u32,
    /// Rounding mode for the out-shifted bits.
    pub rounding: Rounding,
}

impl BfpFormat {
    /// A format with `total_bits` mantissa bits (incl. sign) and round-off.
    pub fn new(total_bits: u32) -> Self {
        assert!(
            (2..=24).contains(&total_bits),
            "BFP mantissa width must be in [2, 24] bits incl. sign, got {total_bits}"
        );
        Self { total_bits, rounding: Rounding::Nearest }
    }

    /// Same width, truncating rounding.
    pub fn truncating(total_bits: u32) -> Self {
        Self { rounding: Rounding::Truncate, ..Self::new(total_bits) }
    }

    /// Fractional bits of the aligned mantissa: `total_bits - 2`
    /// (one sign bit, one integer bit).
    #[inline]
    pub fn frac_bits(&self) -> i32 {
        self.total_bits as i32 - 2
    }

    /// Largest representable integer mantissa magnitude: `2^(L-1) - 1`.
    #[inline]
    pub fn max_mantissa(&self) -> i32 {
        (1i32 << (self.total_bits - 1)) - 1
    }

    /// Quantization step `Δ = 2^(ε - frac_bits)` of a block with
    /// exponent `ε`.
    #[inline]
    pub fn step(&self, block_exponent: i32) -> f32 {
        exp2i(block_exponent - self.frac_bits())
    }

    /// Theoretical quantization-error variance of a block with exponent
    /// `ε` — the paper's eq. (8): `σ² = Δ²/12 = 2^(2(ε - Lm))/12` with
    /// `Lm = frac_bits`.
    #[inline]
    pub fn error_variance(&self, block_exponent: i32) -> f64 {
        let step = 2f64.powi(block_exponent - self.frac_bits());
        step * step / 12.0
    }
}

/// Stochastic rounding: floor(x + u) with a deterministic per-value
/// uniform u ∈ [0,1) derived by hashing the value's bit pattern.
/// Unbiased in expectation over value ensembles; reproducible.
#[inline]
pub fn round_stochastic(x: f32) -> f32 {
    let mut h = x.to_bits().wrapping_mul(0x9E3779B9);
    h ^= h >> 16;
    h = h.wrapping_mul(0x85EBCA6B);
    h ^= h >> 13;
    let u = (h >> 8) as f32 / (1u32 << 24) as f32; // [0, 1)
    (x + u).floor()
}

/// Round half away from zero, vectorizer-friendly (§Perf).
///
/// `f32::round` lowers to a libm call that blocks SIMD; this sequence
/// (abs → +0.5 → trunc → copysign) compiles to `vroundps` + bit ops.
/// Identical to `f32::round` for all |x| < 2^23 — guaranteed here because
/// quantized mantissas are bounded by 2^23 (format width ≤ 24 bits).
#[inline(always)]
pub fn round_half_away(x: f32) -> f32 {
    (x.abs() + 0.5).trunc().copysign(x)
}

/// `2^e` as f32 via exponent-field construction (fast, exact for
/// `e ∈ [-126, 127]`; falls back to `powi` outside the normal range).
#[inline]
pub fn exp2i(e: i32) -> f32 {
    if (-126..=127).contains(&e) {
        f32::from_bits(((e + 127) as u32) << 23)
    } else {
        2f32.powi(e)
    }
}

/// `2^e` as f64 via bit construction: exact for every representable
/// exponent, including subnormals (`e ∈ [-1074, -1023]`, where a `powi`
/// fallback may flush to zero via `1/2^|e|` overflow); saturates to
/// `0.0` / `inf` outside `[-1074, 1023]`. GEMM rescaling uses this so
/// extreme block-exponent sums that overflow or underflow the f32
/// exponent range survive the multiply (§Perf).
#[inline]
pub fn exp2i64(e: i32) -> f64 {
    if (-1022..=1023).contains(&e) {
        f64::from_bits(((e as i64 + 1023) as u64) << 52)
    } else if (-1074..-1022).contains(&e) {
        // subnormal: single significand bit at position e + 1074
        f64::from_bits(1u64 << (e + 1074))
    } else if e > 1023 {
        f64::INFINITY
    } else {
        0.0
    }
}

/// `floor(log2(|x|))` of a finite nonzero f32, i.e. the unbiased binary
/// exponent, extracted from the bit pattern. Returns `None` for zero
/// (zeros carry no exponent and never constrain the block maximum).
/// Subnormals are handled by normalising through multiplication.
#[inline]
pub fn exponent_of(x: f32) -> Option<i32> {
    if x == 0.0 || !x.is_finite() {
        return None;
    }
    let bits = x.to_bits();
    let biased = ((bits >> 23) & 0xFF) as i32;
    if biased == 0 {
        // Subnormal: scale up by 2^64 (exact) and correct.
        let scaled = x * exp2i(64);
        let b = ((scaled.to_bits() >> 23) & 0xFF) as i32;
        Some(b - 127 - 64)
    } else {
        Some(biased - 127)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponent_of_powers_of_two() {
        for e in -126..=127 {
            let x = exp2i(e);
            assert_eq!(exponent_of(x), Some(e), "2^{e}");
            assert_eq!(exponent_of(-x), Some(e), "-2^{e}");
        }
    }

    #[test]
    fn exponent_of_general_values() {
        assert_eq!(exponent_of(1.5), Some(0));
        assert_eq!(exponent_of(3.0), Some(1));
        assert_eq!(exponent_of(0.75), Some(-1));
        assert_eq!(exponent_of(-5.25), Some(2));
        assert_eq!(exponent_of(0.0), None);
        assert_eq!(exponent_of(f32::INFINITY), None);
        assert_eq!(exponent_of(f32::NAN), None);
    }

    #[test]
    fn exponent_of_subnormals() {
        let tiny = f32::from_bits(1); // smallest subnormal, 2^-149
        assert_eq!(exponent_of(tiny), Some(-149));
        let sub = f32::from_bits(0x0040_0000); // 2^-127
        assert_eq!(exponent_of(sub), Some(-127));
    }

    #[test]
    fn exp2i_matches_powi() {
        for e in [-149, -126, -1, 0, 1, 10, 127] {
            assert_eq!(exp2i(e), 2f32.powi(e), "e={e}");
        }
    }

    #[test]
    fn exp2i64_exact_across_whole_range() {
        // normal range agrees with powi (both exact here)
        for e in [-1022, -200, -149, -1, 0, 1, 64, 150, 1023] {
            assert_eq!(exp2i64(e), 2f64.powi(e), "e={e}");
        }
        // subnormals asserted against raw bit patterns, not powi — the
        // powi expansion 1/2^|e| can overflow to inf and yield 0 here
        assert_eq!(exp2i64(-1074).to_bits(), 1, "smallest subnormal");
        assert_eq!(exp2i64(-1030).to_bits(), 1u64 << 44);
        assert!(exp2i64(-1030) > 0.0 && exp2i64(-1023) > 0.0);
        assert_eq!(exp2i64(-1023), exp2i64(-1022) / 2.0);
        // saturation
        assert_eq!(exp2i64(-1075), 0.0);
        assert_eq!(exp2i64(1024), f64::INFINITY);
    }

    #[test]
    fn format_derived_quantities() {
        let f = BfpFormat::new(8); // paper's 8-bit incl. sign
        assert_eq!(f.frac_bits(), 6);
        assert_eq!(f.max_mantissa(), 127);
        assert_eq!(f.step(0), exp2i(-6));
        // eq. (8) with ε=0, Lm=6: 2^-12 / 12
        let v = f.error_variance(0);
        assert!((v - 2f64.powi(-12) / 12.0).abs() < 1e-18);
    }

    #[test]
    #[should_panic]
    fn format_rejects_too_narrow() {
        BfpFormat::new(1);
    }

    #[test]
    fn paper_example_widths() {
        // §3.4: the worked example uses L=3 excluding sign → total 4.
        let f = BfpFormat::new(4);
        assert_eq!(f.frac_bits(), 2);
        assert_eq!(f.max_mantissa(), 7);
    }
}
