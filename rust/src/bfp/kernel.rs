//! Cache-blocked, register-tiled BFP GEMM microkernel with a fused
//! im2col→quantize→pack activation pipeline — the serving hot path.
//!
//! ## Why re-tiling is free (bit-exactly)
//!
//! The §3.4 width plan makes every lane's arithmetic *exact*: products
//! fit the multiplier, chunk sums stay below 2^24 in the f32 lane, and
//! integer/f64 accumulation is exact to the accumulator width. Sums of
//! exactly-representable values are associative, so **any** re-tiling of
//! the reduction produces bit-identical output to the naive ikj loop in
//! [`crate::bfp::gemm`] — the only constraint is that each f32-lane
//! accumulation segment spans at most [`crate::bfp::gemm::f32_lane_chunk`]
//! products. That retained naive kernel is the test reference
//! (`rust/tests/tiled_kernel.rs` sweeps the scheme × width × thread
//! matrix).
//!
//! ## Structure
//!
//! * **Packing.** Weights are packed once into `MR`-row panels, K-major
//!   ([`pack_weights_f32`] / [`pack_weights_i32`]; cached per layer by
//!   [`crate::nn::prepared::WeightCache`]). Activations are packed into
//!   `NR`-column panels ([`ActPanels`]) — by [`ActPanels::pack_im2col`]
//!   on the conv path, which emits `NC`-wide im2col tiles
//!   ([`crate::tensor::im2col::im2col_tile`]) and quantizes them
//!   **directly into the panels**: the full `K×N` f32 column buffer, the
//!   intermediate `K×N` i32 mantissa matrix and the separate i32→f32
//!   repack pass of the naive pipeline all disappear (per-image staging
//!   shrinks from ~3·K·N to one K·NC tile plus the packed operand).
//! * **Microkernel.** An `MR×NR` register accumulator block streams both
//!   panels K-major. The f32 lane accumulates `KC ≤ chunk` segments in
//!   f32 and flushes each segment into an f64 accumulator (both steps
//!   exact); the integer lanes accumulate straight through K.
//! * **Blocking & parallelism.** Output is carved into `MC×NC` tiles,
//!   distributed in 2D over the [`pool`] workers
//!   ([`crate::runtime::pool::parallel_tasks`]); inside a tile, an
//!   `NR`-panel's B strip (`K·NR` elements, L1-resident) is reused
//!   across all `MC/MR` weight panels. Each task owns a disjoint output
//!   tile and every tile's value is exact, so output is bit-identical
//!   for every thread count and task schedule.

use super::format::{exp2i, exp2i64, exponent_of, round_half_away, round_stochastic, BfpFormat, Rounding};
use super::gemm::AccLane;
use super::partition::{BfpMatrix, BlockAxis};
// The lane dispatch rule is owned by the naive reference kernel so both
// kernels can never disagree on which accumulator runs a config.
pub use super::gemm::{select_lane, Lane};
use crate::runtime::pool;
use crate::tensor::im2col::{im2col_tile, im2col_whole_exponent, Conv2dGeometry};

/// Register-tile rows (weight panel height).
pub const MR: usize = 4;
/// Register-tile columns (activation panel width).
pub const NR: usize = 8;
/// Output rows per parallel task block.
pub const MC: usize = 64;
/// Output columns per parallel task block — also the fused pipeline's
/// im2col tile width.
pub const NC: usize = 256;
/// K-segment length for the f32 lane's chunked accumulation (clamped to
/// the exactness chunk at runtime); integer lanes stream the full K.
pub const KC: usize = 512;

const _: () = assert!(MC % MR == 0 && NC % NR == 0, "blocks must tile evenly into register tiles");

use super::format::{ZERO_EXP, ZERO_EXP_FLOOR};

/// Weight mantissas packed into `MR`-row panels for the microkernel,
/// in the representation the selected lane consumes.
#[derive(Debug, Clone, Copy)]
pub enum WeightPanels<'a> {
    /// f32-materialised panels (the [`Lane::F32`] fast lane).
    F32(&'a [f32]),
    /// Raw i32 mantissa panels (both integer lanes).
    Int(&'a [i32]),
}

/// Length of a packed weight-panel buffer for an `m×k` matrix.
pub fn weight_panels_len(m: usize, k: usize) -> usize {
    m.div_ceil(MR) * MR * k
}

/// Pack an `M×K` weight matrix into `MR`-row panels, K-major within each
/// panel (`data[p·K·MR + kk·MR + r] = W[p·MR + r, kk]`), elements mapped
/// through `conv`. Rows past `M` pad with zero mantissas — zero products
/// leave every exact sum unchanged, so padded tails cost a few MACs but
/// never a bit.
fn pack_weights<T: Copy + Default>(w: &BfpMatrix, conv: impl Fn(i32) -> T) -> Vec<T> {
    assert!(!matches!(w.axis, BlockAxis::PerCol), "weight matrix must be blocked Whole or PerRow");
    let (m, k) = (w.rows, w.cols);
    let mut out = vec![T::default(); weight_panels_len(m, k)];
    for p in 0..m.div_ceil(MR) {
        let base = p * k * MR;
        for kk in 0..k {
            for r in 0..MR.min(m - p * MR) {
                out[base + kk * MR + r] = conv(w.mantissas[(p * MR + r) * k + kk]);
            }
        }
    }
    out
}

/// [`pack_weights`] with the mantissas materialised as exact f32 (the
/// [`Lane::F32`] fast lane).
pub fn pack_weights_f32(w: &BfpMatrix) -> Vec<f32> {
    pack_weights(w, |v| v as f32)
}

/// [`pack_weights`] keeping the mantissas as i32 (integer lanes).
pub fn pack_weights_i32(w: &BfpMatrix) -> Vec<i32> {
    pack_weights(w, |v| v)
}

/// Quantized activations packed into `NR`-column panels, K-major within
/// each panel (`data[q·K·NR + kk·NR + j] = I'[kk, q·NR + j]`), with the
/// block exponents the rescale step needs. Buffers only grow (workspace
/// semantics): every slot of the active region — including column
/// padding — is rewritten on each pack, so reuse never leaks state.
#[derive(Debug, Default)]
pub struct ActPanels {
    k: usize,
    n: usize,
    axis: BlockAxis,
    frac_bits: i32,
    lane_f32: bool,
    /// `[ε]` for `Whole`, `[ε_0 … ε_{n-1}]` for `PerCol` (`ZERO_EXP`
    /// marks an all-zero block, as in [`BfpMatrix`]).
    exponents: Vec<i32>,
    f32_data: Vec<f32>,
    i32_data: Vec<i32>,
    // per-tile scratch for the PerCol exponent scan
    col_max_bits: Vec<u32>,
    col_inv_steps: Vec<f32>,
}

impl ActPanels {
    /// An empty panel set; buffers grow on first pack.
    pub fn new() -> Self {
        Self::default()
    }

    /// Logical inner dimension `K`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Logical column count `N`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Block axis of the packed operand.
    pub fn axis(&self) -> BlockAxis {
        self.axis
    }

    /// Fractional mantissa bits of the packed operand.
    pub fn frac_bits(&self) -> i32 {
        self.frac_bits
    }

    /// Block exponents (layout per [`ActPanels::exponents`] docs).
    pub fn exponents(&self) -> &[i32] {
        &self.exponents
    }

    /// High-water mark of the packed-panel buffers, in elements.
    pub fn capacity(&self) -> usize {
        self.f32_data.len().max(self.i32_data.len())
    }

    /// Elements the current `(k, n)` shape occupies in the panel buffer
    /// (columns padded up to `NR`).
    pub fn active_len(&self) -> usize {
        self.n.div_ceil(NR) * self.k * NR
    }

    /// Active f32 panel data (empty when packed for an integer lane) —
    /// equality checks in the bit-exactness tests.
    pub fn f32_panels(&self) -> &[f32] {
        if self.lane_f32 {
            &self.f32_data[..self.active_len()]
        } else {
            &[]
        }
    }

    /// Active i32 panel data (empty when packed for the f32 lane).
    pub fn i32_panels(&self) -> &[i32] {
        if self.lane_f32 {
            &[]
        } else {
            &self.i32_data[..self.active_len()]
        }
    }

    fn begin(&mut self, k: usize, n: usize, axis: BlockAxis, frac_bits: i32, lane: Lane) {
        assert!(!matches!(axis, BlockAxis::PerRow), "activations must be blocked Whole or PerCol");
        self.k = k;
        self.n = n;
        self.axis = axis;
        self.frac_bits = frac_bits;
        self.lane_f32 = lane.is_f32();
        self.exponents.clear();
        let len = self.active_len();
        if self.lane_f32 {
            if self.f32_data.len() < len {
                self.f32_data.resize(len, 0.0);
            }
        } else if self.i32_data.len() < len {
            self.i32_data.resize(len, 0);
        }
    }

    /// Pack an already-quantized matrix (the unfused / reference path,
    /// and non-conv GEMM callers).
    pub fn pack_matrix(&mut self, i: &BfpMatrix, lane: Lane) {
        self.begin(i.rows, i.cols, i.axis, i.frac_bits, lane);
        self.exponents.extend_from_slice(&i.exponents);
        let (k, n) = (self.k, self.n);
        for q in 0..n.div_ceil(NR) {
            let base = q * k * NR;
            let jw = NR.min(n - q * NR);
            for kk in 0..k {
                let src = &i.mantissas[kk * n + q * NR..kk * n + q * NR + jw];
                let off = base + kk * NR;
                if self.lane_f32 {
                    let dst = &mut self.f32_data[off..off + NR];
                    for (d, &v) in dst.iter_mut().zip(src) {
                        *d = v as f32;
                    }
                    dst[jw..].fill(0.0);
                } else {
                    let dst = &mut self.i32_data[off..off + NR];
                    dst[..jw].copy_from_slice(src);
                    dst[jw..].fill(0);
                }
            }
        }
    }

    /// The fused conv pipeline: expand one image into `NC`-wide im2col
    /// tiles, exponent-scan and quantize each tile, and write the
    /// mantissas straight into packed panels. Produces exponents and
    /// mantissas bit-identical to
    /// `im2col → BfpMatrix::requantize → pack_matrix` (tested in
    /// `tests/tiled_kernel.rs`) without ever holding the `K×N` matrix:
    /// `tile` is the only staging buffer and never exceeds `K×NC`.
    pub fn pack_im2col(
        &mut self,
        img: &[f32],
        geo: &Conv2dGeometry,
        fmt: BfpFormat,
        axis: BlockAxis,
        lane: Lane,
        tile: &mut Vec<f32>,
    ) {
        // the pack span covers the whole fused pipeline; the im2col_tile
        // calls inside cut their own nested im2col spans
        let _span = crate::obs::span(crate::obs::Stage::Pack);
        let (k, n) = (geo.k(), geo.n());
        self.begin(k, n, axis, fmt.frac_bits(), lane);
        let max_m = fmt.max_mantissa();
        match axis {
            BlockAxis::Whole => {
                // the Whole-axis exponent is known from the source image
                // before any tile exists (coverage scan) — the global
                // data dependency that would otherwise force two passes
                let eps = im2col_whole_exponent(img, geo).unwrap_or(ZERO_EXP);
                self.exponents.push(eps);
                if eps == ZERO_EXP {
                    let len = self.active_len();
                    if self.lane_f32 {
                        self.f32_data[..len].fill(0.0);
                    } else {
                        self.i32_data[..len].fill(0);
                    }
                    return;
                }
                let inv = exp2i(self.frac_bits - eps);
                self.for_each_tile(img, geo, tile, |this, tile, c0, cw| {
                    this.fill_block(tile, c0, cw, |_| inv, max_m, fmt.rounding);
                });
            }
            BlockAxis::PerCol => {
                self.exponents.resize(n, ZERO_EXP);
                let frac = self.frac_bits;
                self.for_each_tile(img, geo, tile, |this, tile, c0, cw| {
                    // per-column max-|bits| scan of the tile — each
                    // column is fully contained in its tile, so the
                    // eq. (3)/(5) exponents are tile-local
                    this.col_max_bits.clear();
                    this.col_max_bits.resize(cw, 0);
                    for kk in 0..this.k {
                        let row = &tile[kk * cw..(kk + 1) * cw];
                        for (mb, &v) in this.col_max_bits.iter_mut().zip(row) {
                            if v.is_finite() {
                                let b = v.to_bits() & 0x7FFF_FFFF;
                                if b > *mb {
                                    *mb = b;
                                }
                            }
                        }
                    }
                    this.col_inv_steps.clear();
                    this.col_inv_steps.resize(cw, 0.0);
                    for j in 0..cw {
                        if this.col_max_bits[j] != 0 {
                            let e = exponent_of(f32::from_bits(this.col_max_bits[j])).unwrap();
                            this.exponents[c0 + j] = e;
                            this.col_inv_steps[j] = exp2i(frac - e);
                        }
                    }
                    let inv_steps = std::mem::take(&mut this.col_inv_steps);
                    this.fill_block(tile, c0, cw, |j| inv_steps[j], max_m, fmt.rounding);
                    this.col_inv_steps = inv_steps;
                });
            }
            BlockAxis::PerRow => unreachable!("rejected by begin()"),
        }
    }

    /// Drive `f` over the image's im2col tiles (`NC` columns at a time).
    fn for_each_tile(
        &mut self,
        img: &[f32],
        geo: &Conv2dGeometry,
        tile: &mut Vec<f32>,
        mut f: impl FnMut(&mut Self, &[f32], usize, usize),
    ) {
        let (k, n) = (self.k, self.n);
        let mut c0 = 0usize;
        while c0 < n {
            let cw = NC.min(n - c0);
            if tile.len() < k * cw {
                tile.resize(k * cw, 0.0);
            }
            im2col_tile(img, geo, c0, cw, &mut tile[..k * cw]);
            f(self, &tile[..k * cw], c0, cw);
            c0 += cw;
        }
    }

    /// Quantize one staged tile (columns `[c0, c0+cw)`, row-major
    /// `K×cw`) into the packed panels. `inv(j)` is the column's exact
    /// `1/Δ` (0.0 for all-zero blocks, reproducing the naive path's
    /// `0·x` mantissas bit-for-bit, NaN inputs included).
    fn fill_block(
        &mut self,
        tile: &[f32],
        c0: usize,
        cw: usize,
        inv: impl Fn(usize) -> f32 + Copy,
        max_m: i32,
        rounding: Rounding,
    ) {
        match rounding {
            Rounding::Nearest => self.fill_rounded(tile, c0, cw, inv, max_m, round_half_away),
            Rounding::Truncate => self.fill_rounded(tile, c0, cw, inv, max_m, |x: f32| x.trunc()),
            Rounding::Stochastic => self.fill_rounded(tile, c0, cw, inv, max_m, round_stochastic),
        }
    }

    fn fill_rounded(
        &mut self,
        tile: &[f32],
        c0: usize,
        cw: usize,
        inv: impl Fn(usize) -> f32 + Copy,
        max_m: i32,
        round: impl Fn(f32) -> f32 + Copy,
    ) {
        debug_assert_eq!(c0 % NR, 0, "tiles start on a panel boundary (NC is a multiple of NR)");
        let k = self.k;
        let mut lj0 = 0usize;
        while lj0 < cw {
            let q = (c0 + lj0) / NR;
            let jw = NR.min(cw - lj0);
            let base = q * k * NR;
            for kk in 0..k {
                let src = &tile[kk * cw + lj0..kk * cw + lj0 + jw];
                let off = base + kk * NR;
                if self.lane_f32 {
                    let dst = &mut self.f32_data[off..off + NR];
                    for jj in 0..jw {
                        let qv = (round(src[jj] * inv(lj0 + jj)) as i32).clamp(-max_m, max_m);
                        dst[jj] = qv as f32;
                    }
                    dst[jw..].fill(0.0);
                } else {
                    let dst = &mut self.i32_data[off..off + NR];
                    for jj in 0..jw {
                        dst[jj] = (round(src[jj] * inv(lj0 + jj)) as i32).clamp(-max_m, max_m);
                    }
                    dst[jw..].fill(0);
                }
            }
            lj0 += NR;
        }
    }
}

/// The tiled fixed-point GEMM `O = W'·I'` over packed operands. Output
/// is bit-identical to [`crate::bfp::gemm::bfp_gemm`] on the same
/// quantized matrices (see the module docs for why), at every thread
/// count.
pub fn gemm_tiled(w: &BfpMatrix, panels: WeightPanels<'_>, acts: &ActPanels, out: &mut [f32]) {
    let _span = crate::obs::span(crate::obs::Stage::Gemm);
    let (m, k, n) = (w.rows, w.cols, acts.n);
    assert_eq!(k, acts.k, "GEMM inner dimension mismatch");
    assert_eq!(out.len(), m * n, "output buffer shape mismatch");
    assert!(!matches!(w.axis, BlockAxis::PerCol), "weight matrix must be blocked Whole or PerRow");
    if m == 0 || n == 0 {
        return;
    }
    let lane = select_lane(w.frac_bits, acts.frac_bits, k);
    let panels_len = weight_panels_len(m, k);
    match panels {
        WeightPanels::F32(p) => {
            assert!(lane.is_f32(), "f32 weight panels but lane {lane:?} selected");
            assert_eq!(p.len(), panels_len, "weight panel shape mismatch");
        }
        WeightPanels::Int(p) => {
            assert!(!lane.is_f32(), "i32 weight panels but lane {lane:?} selected");
            assert_eq!(p.len(), panels_len, "weight panel shape mismatch");
        }
    }
    assert_eq!(acts.lane_f32, lane.is_f32(), "activation panels packed for the wrong lane");

    let nblocks = n.div_ceil(NC);
    let tasks = m.div_ceil(MC) * nblocks;
    let outp = OutPtr(out.as_mut_ptr());
    let work = m.saturating_mul(k).saturating_mul(n);
    pool::parallel_tasks(tasks, work, |t| {
        let (mb, nb) = (t / nblocks, t % nblocks);
        let (r0, r1) = (mb * MC, ((mb + 1) * MC).min(m));
        let (c0, c1) = (nb * NC, ((nb + 1) * NC).min(n));
        match (lane, panels) {
            (Lane::F32 { chunk }, WeightPanels::F32(wp)) => {
                // SAFETY: this task exclusively owns rows [r0, r1) ×
                // cols [c0, c1) of `out` — the (mb, nb) grid tiles the
                // output disjointly, and `block_f32` writes only there.
                unsafe { block_f32(w, wp, acts, outp, r0, r1, c0, c1, chunk) }
            }
            (Lane::I32, WeightPanels::Int(wp)) => {
                // SAFETY: same disjoint-tile ownership as the f32 arm.
                unsafe { block_int::<i32>(w, wp, acts, outp, r0, r1, c0, c1) }
            }
            (Lane::I64, WeightPanels::Int(wp)) => {
                // SAFETY: same disjoint-tile ownership as the f32 arm.
                unsafe { block_int::<i64>(w, wp, acts, outp, r0, r1, c0, c1) }
            }
            _ => unreachable!("panel kind verified against lane above"),
        }
    });
}

/// Convenience wrapper packing both operands and running [`gemm_tiled`]
/// (tests, benches, the per-call conv path).
pub fn bfp_gemm_tiled(w: &BfpMatrix, i: &BfpMatrix, out: &mut [f32]) {
    let lane = select_lane(w.frac_bits, i.frac_bits, w.cols);
    let mut acts = ActPanels::new();
    acts.pack_matrix(i, lane);
    if lane.is_f32() {
        gemm_tiled(w, WeightPanels::F32(&pack_weights_f32(w)), &acts, out);
    } else {
        gemm_tiled(w, WeightPanels::Int(&pack_weights_i32(w)), &acts, out);
    }
}

/// Raw output pointer shared across tile tasks (each task writes a
/// disjoint tile — see the SAFETY note at the spawn site).
#[derive(Clone, Copy)]
struct OutPtr(*mut f32);
// SAFETY: the wrapper carries a plain address; it may move between
// threads because every task writes a disjoint tile of the buffer
// behind it (the `gemm_tiled` grid) for the buffer's whole lifetime.
unsafe impl Send for OutPtr {}
// SAFETY: `&OutPtr` only exposes the copied address; the disjoint-tile
// contract above makes concurrent use across threads sound.
unsafe impl Sync for OutPtr {}

/// f32-lane block: `MR×NR` register tiles, `KC`-segmented (≤ `chunk`)
/// f32 accumulation flushed into f64 per segment — the exact mirror of
/// the naive lane's chunked reduction, re-associated.
///
/// # Safety
/// The caller guarantees rows `[r0, r1)` × cols `[c0, c1)` of the
/// `w.rows × acts.n` output behind `out` are owned by this task.
#[allow(clippy::too_many_arguments)]
unsafe fn block_f32(
    w: &BfpMatrix,
    wp: &[f32],
    acts: &ActPanels,
    out: OutPtr,
    r0: usize,
    r1: usize,
    c0: usize,
    c1: usize,
    chunk: usize,
) {
    let k = w.cols;
    let kc = KC.min(chunk);
    for q in (c0 / NR)..c1.div_ceil(NR) {
        let bpanel = &acts.f32_data[q * k * NR..(q + 1) * k * NR];
        let cbase = q * NR;
        let cols = NR.min(c1 - cbase);
        for p in (r0 / MR)..r1.div_ceil(MR) {
            let apanel = &wp[p * k * MR..(p + 1) * k * MR];
            let mut acc64 = [[0f64; NR]; MR];
            let mut k0 = 0usize;
            while k0 < k {
                let k1 = (k0 + kc).min(k);
                let mut acc = [[0f32; NR]; MR];
                for kk in k0..k1 {
                    let a = &apanel[kk * MR..kk * MR + MR];
                    let b = &bpanel[kk * NR..kk * NR + NR];
                    for r in 0..MR {
                        let wv = a[r];
                        for jj in 0..NR {
                            acc[r][jj] += wv * b[jj];
                        }
                    }
                }
                for (a64, a32) in acc64.iter_mut().zip(&acc) {
                    for (x, &y) in a64.iter_mut().zip(a32) {
                        *x += y as f64;
                    }
                }
                k0 = k1;
            }
            let rbase = p * MR;
            // SAFETY: the tile [rbase, rbase+rows) × [cbase, cbase+cols)
            // is inside this task's [r0, r1) × [c0, c1) ownership region.
            unsafe { store_tile(out, w, acts, rbase, MR.min(r1 - rbase), cbase, cols, &acc64) };
        }
    }
}

/// Integer-lane block (`A` = i32 or i64): exact integer accumulation is
/// associative at any grouping, so the register tile streams the whole K.
///
/// # Safety
/// The caller guarantees rows `[r0, r1)` × cols `[c0, c1)` of the
/// `w.rows × acts.n` output behind `out` are owned by this task.
#[allow(clippy::too_many_arguments)]
unsafe fn block_int<A: AccLane>(
    w: &BfpMatrix,
    wp: &[i32],
    acts: &ActPanels,
    out: OutPtr,
    r0: usize,
    r1: usize,
    c0: usize,
    c1: usize,
) {
    let k = w.cols;
    for q in (c0 / NR)..c1.div_ceil(NR) {
        let bpanel = &acts.i32_data[q * k * NR..(q + 1) * k * NR];
        let cbase = q * NR;
        let cols = NR.min(c1 - cbase);
        for p in (r0 / MR)..r1.div_ceil(MR) {
            let apanel = &wp[p * k * MR..(p + 1) * k * MR];
            let mut acc = [[A::default(); NR]; MR];
            for kk in 0..k {
                let a = &apanel[kk * MR..kk * MR + MR];
                let b = &bpanel[kk * NR..kk * NR + NR];
                for r in 0..MR {
                    let wv = a[r];
                    for jj in 0..NR {
                        acc[r][jj] += A::mul(wv, b[jj]);
                    }
                }
            }
            let mut acc64 = [[0f64; NR]; MR];
            for (a64, arow) in acc64.iter_mut().zip(&acc) {
                for (x, &y) in a64.iter_mut().zip(arow) {
                    *x = y.to_f64();
                }
            }
            let rbase = p * MR;
            // SAFETY: the tile [rbase, rbase+rows) × [cbase, cbase+cols)
            // is inside this task's [r0, r1) × [c0, c1) ownership region.
            unsafe { store_tile(out, w, acts, rbase, MR.min(r1 - rbase), cbase, cols, &acc64) };
        }
    }
}

/// Rescale an accumulator tile and store the valid `rows×cols` region —
/// per element the exact expression of the naive kernel
/// (`(acc_f64 · 2^{ε_W+ε_I−f_W−f_I}) as f32`, zero blocks → +0.0).
///
/// # Safety
/// The caller guarantees rows `[r0, r0+rows)` × cols `[c0, c0+cols)` of
/// the `w.rows × acts.n` output behind `out` are owned by this task.
#[allow(clippy::too_many_arguments)]
unsafe fn store_tile(
    out: OutPtr,
    w: &BfpMatrix,
    acts: &ActPanels,
    r0: usize,
    rows: usize,
    c0: usize,
    cols: usize,
    acc: &[[f64; NR]; MR],
) {
    let n = acts.n;
    for (r, arow) in acc.iter().enumerate().take(rows) {
        let gr = r0 + r;
        let we = match w.axis {
            BlockAxis::Whole => w.exponents[0],
            BlockAxis::PerRow => w.exponents[gr],
            BlockAxis::PerCol => unreachable!(),
        };
        // SAFETY: gr < w.rows and c0 + cols ≤ n (caller contract), so
        // the row slice lies inside the output allocation and inside
        // this task's exclusively-owned tile.
        let orow = unsafe { std::slice::from_raw_parts_mut(out.0.add(gr * n + c0), cols) };
        if we <= ZERO_EXP_FLOOR {
            orow.fill(0.0);
            continue;
        }
        match acts.axis {
            BlockAxis::Whole => {
                let ie = acts.exponents[0];
                if ie <= ZERO_EXP_FLOOR {
                    orow.fill(0.0);
                    continue;
                }
                let scale = exp2i64(we + ie - w.frac_bits - acts.frac_bits);
                for (o, &a) in orow.iter_mut().zip(arow) {
                    *o = (a * scale) as f32;
                }
            }
            BlockAxis::PerCol => {
                for (jj, (o, &a)) in orow.iter_mut().zip(arow).enumerate() {
                    let ie = acts.exponents[c0 + jj];
                    *o = if ie <= ZERO_EXP_FLOOR {
                        0.0
                    } else {
                        (a * exp2i64(we + ie - w.frac_bits - acts.frac_bits)) as f32
                    };
                }
            }
            BlockAxis::PerRow => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::gemm::bfp_gemm;
    use crate::bfp::partition::PartitionScheme;

    fn mat(seed: u64, len: usize, scale: f32) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 11) as f32 / (1u64 << 53) as f32 * 2.0 - 0.5) * scale
            })
            .collect()
    }

    #[test]
    fn lane_selection_matches_naive_dispatch() {
        // 8-bit: f32 lane; 12-bit: i32 (chunk < 32); 16-bit + large K: i64
        assert!(matches!(select_lane(6, 6, 100), Lane::F32 { .. }));
        assert_eq!(select_lane(10, 10, 100), Lane::I32);
        assert_eq!(select_lane(14, 14, 5000), Lane::I64);
    }

    /// §3.4 worked example through the tiled kernel.
    #[test]
    fn paper_worked_example_product() {
        let fmt = BfpFormat::new(4);
        let w = BfpMatrix::quantize(&[0.5, 1.25], 1, 2, fmt, BlockAxis::PerRow);
        let i = BfpMatrix::quantize(&[1.25, 1.25, 2.5, 5.0], 2, 2, fmt, BlockAxis::Whole);
        let mut out = vec![0f32; 2];
        bfp_gemm_tiled(&w, &i, &mut out);
        assert_eq!(out, vec![17.0 / 4.0, 27.0 / 4.0]);
    }

    /// Tiled output equals the retained naive kernel bit-for-bit on a
    /// tail-heavy shape across every scheme (the full matrix sweep lives
    /// in tests/tiled_kernel.rs).
    #[test]
    fn tiled_matches_naive_reference() {
        let (m, k, n) = (7, 23, 13); // all non-multiples of MR/NR/KC
        let w = mat(1, m * k, 1.5);
        let i = mat(2, k * n, 3.0);
        for scheme in [PartitionScheme::Eq2, PartitionScheme::Eq3, PartitionScheme::Eq4, PartitionScheme::Eq5] {
            let fmt = BfpFormat::new(8);
            let wq = BfpMatrix::quantize(&w, m, k, fmt, scheme.w_axis());
            let iq = BfpMatrix::quantize(&i, k, n, fmt, scheme.i_axis());
            let want = bfp_gemm(&wq, &iq).data;
            let mut got = vec![0f32; m * n];
            bfp_gemm_tiled(&wq, &iq, &mut got);
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits(), "{scheme:?}");
            }
        }
    }

    /// ActPanels reuse across shapes/axes/lanes must leave no stale data.
    #[test]
    fn act_panels_reuse_is_clean() {
        let mut acts = ActPanels::new();
        let fmt = BfpFormat::new(8);
        let big = BfpMatrix::quantize(&mat(3, 40 * 30, 2.0), 40, 30, fmt, BlockAxis::Whole);
        acts.pack_matrix(&big, Lane::F32 { chunk: 64 });
        // smaller PerCol pack over the same buffers
        let small = BfpMatrix::quantize(&mat(4, 5 * 7, 1.0), 5, 7, fmt, BlockAxis::PerCol);
        acts.pack_matrix(&small, Lane::F32 { chunk: 64 });
        let mut fresh = ActPanels::new();
        fresh.pack_matrix(&small, Lane::F32 { chunk: 64 });
        assert_eq!(acts.exponents, fresh.exponents);
        assert_eq!(acts.f32_data[..acts.active_len()], fresh.f32_data[..fresh.active_len()]);
    }
}
