//! The [`BfpBlock`] container: integer mantissas sharing one exponent.

use super::format::{exp2i, BfpFormat};

/// A block of numbers in block-floating-point representation.
///
/// Every element's value is `mantissas[i] * 2^(exponent - frac_bits)`,
/// i.e. the mantissas are plain integers in
/// `[-(2^(L-1)-1), 2^(L-1)-1]` and the whole block shares the scale
/// `2^(exponent - frac_bits)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BfpBlock {
    /// Shared block exponent `ε = max_i floor(log2 |x_i|)`.
    pub exponent: i32,
    /// Fractional bits of the aligned mantissas (`L - 2`).
    pub frac_bits: i32,
    /// Aligned integer mantissas.
    pub mantissas: Vec<i32>,
}

impl BfpBlock {
    /// An all-zero block of length `n` (exponent is a don't-care; we pin it
    /// to the minimum so the scale underflows to zero consistently).
    pub fn zeros(n: usize, fmt: BfpFormat) -> Self {
        Self { exponent: super::format::ZERO_EXP, frac_bits: fmt.frac_bits(), mantissas: vec![0; n] }
    }

    /// Number of elements in the block.
    pub fn len(&self) -> usize {
        self.mantissas.len()
    }

    /// Whether the block is empty.
    pub fn is_empty(&self) -> bool {
        self.mantissas.is_empty()
    }

    /// The shared scale factor `2^(ε - frac_bits)`.
    #[inline]
    pub fn scale(&self) -> f32 {
        exp2i(self.exponent - self.frac_bits)
    }

    /// Reconstruct element `i` as f32.
    #[inline]
    pub fn value(&self, i: usize) -> f32 {
        self.mantissas[i] as f32 * self.scale()
    }

    /// Reconstruct the whole block as f32 values.
    pub fn to_f32(&self) -> Vec<f32> {
        let s = self.scale();
        self.mantissas.iter().map(|&m| m as f32 * s).collect()
    }

    /// Reconstruct into a caller-provided slice (no allocation).
    pub fn write_f32(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.mantissas.len());
        let s = self.scale();
        for (o, &m) in out.iter_mut().zip(&self.mantissas) {
            *o = m as f32 * s;
        }
    }

    /// Storage cost in bits of this block under format `fmt`:
    /// `n·L` mantissa bits + `L_e` exponent bits (the Table 1 accounting,
    /// with `L_e = 8` matching the f32 exponent field).
    pub fn storage_bits(&self, fmt: BfpFormat) -> usize {
        self.mantissas.len() * fmt.total_bits as usize + 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::quantize::block_format;

    #[test]
    fn zeros_reconstruct_to_zero() {
        let b = BfpBlock::zeros(5, BfpFormat::new(8));
        assert_eq!(b.to_f32(), vec![0.0; 5]);
        assert_eq!(b.len(), 5);
        assert!(!b.is_empty());
    }

    #[test]
    fn value_matches_to_f32() {
        let xs = [0.5f32, -1.25, 0.03125, 2.0];
        let b = block_format(&xs, BfpFormat::new(10));
        let all = b.to_f32();
        for i in 0..xs.len() {
            assert_eq!(b.value(i), all[i]);
        }
    }

    #[test]
    fn write_f32_no_alloc_matches() {
        let xs = [3.0f32, -0.75, 0.0, 1.5];
        let b = block_format(&xs, BfpFormat::new(8));
        let mut out = [0f32; 4];
        b.write_f32(&mut out);
        assert_eq!(out.to_vec(), b.to_f32());
    }

    #[test]
    fn storage_bits_accounting() {
        let b = BfpBlock::zeros(64, BfpFormat::new(8));
        assert_eq!(b.storage_bits(BfpFormat::new(8)), 64 * 8 + 8);
    }
}
