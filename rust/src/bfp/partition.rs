//! Matrix partition schemes for BFP block formatting — the paper's
//! eqs. (2)–(5) — plus the Table 1 storage / block-exponent cost model.
//!
//! The im2col'd convolution is `O_{M×N} = W_{M×K} · I_{K×N}` (eq. 2).
//! The four ways to choose BFP blocks over `W` and `I`:
//!
//! | scheme | `W` blocks | `I` blocks | paper |
//! |--------|-----------|-----------|-------|
//! | [`PartitionScheme::Eq2`] | whole matrix | whole matrix | eq. (2) |
//! | [`PartitionScheme::Eq3`] | per row      | per column   | eq. (3) |
//! | [`PartitionScheme::Eq4`] | per row      | whole matrix | eq. (4) — the paper's choice |
//! | [`PartitionScheme::Eq5`] | whole matrix | per column   | eq. (5) |

use super::format::{exp2i, BfpFormat};
use super::quantize::{apply_round, max_exponent, quantize_slice};

/// How a matrix is carved into BFP blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BlockAxis {
    /// One block for the whole matrix.
    #[default]
    Whole,
    /// One block per row vector.
    PerRow,
    /// One block per column vector.
    PerCol,
}

/// The four matrix-partition schemes of §3.3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionScheme {
    /// Eq. (2): `W` and `I` each block-formatted as a whole.
    Eq2,
    /// Eq. (3): `W` per row, `I` per column (vector-wise).
    Eq3,
    /// Eq. (4): `W` per row, `I` as a whole — the paper's chosen tradeoff.
    #[default]
    Eq4,
    /// Eq. (5): `W` as a whole, `I` per column.
    Eq5,
}

impl PartitionScheme {
    /// Block axis applied to the weight matrix `W`.
    pub fn w_axis(&self) -> BlockAxis {
        match self {
            PartitionScheme::Eq2 | PartitionScheme::Eq5 => BlockAxis::Whole,
            PartitionScheme::Eq3 | PartitionScheme::Eq4 => BlockAxis::PerRow,
        }
    }

    /// Block axis applied to the input matrix `I`.
    pub fn i_axis(&self) -> BlockAxis {
        match self {
            PartitionScheme::Eq2 | PartitionScheme::Eq4 => BlockAxis::Whole,
            PartitionScheme::Eq3 | PartitionScheme::Eq5 => BlockAxis::PerCol,
        }
    }

    /// Table 1 cost row for matrices `W_{M×K}`, `I_{K×N}` with mantissa
    /// widths `l_w` / `l_i` (incl. sign) and exponent width `l_e`.
    pub fn cost(&self, m: usize, k: usize, n: usize, l_w: u32, l_i: u32, l_e: u32) -> PartitionCost {
        let (lw, li, le) = (l_w as f64, l_i as f64, l_e as f64);
        // Average stored length per number: mantissa bits (incl. sign)
        // plus the block exponent amortised over the block size.
        // (The paper's "1 + L + Le/n" counts the sign separately; our L
        // already includes it, so AL = L + Le/block.)
        let (al_w, al_i, nbe) = match self {
            PartitionScheme::Eq2 => (lw + le / (m * k) as f64, li + le / (k * n) as f64, 2),
            PartitionScheme::Eq3 => (lw + le / k as f64, li + le / k as f64, m + n),
            PartitionScheme::Eq4 => (lw + le / k as f64, li + le / (k * n) as f64, 1 + m),
            PartitionScheme::Eq5 => (lw + le / (m * k) as f64, li + le / k as f64, 1 + n),
        };
        PartitionCost {
            scheme: *self,
            avg_len_w: al_w,
            avg_len_i: al_i,
            num_block_exponents: nbe,
            total_bits_w: (al_w * (m * k) as f64).round() as usize,
            total_bits_i: (al_i * (k * n) as f64).round() as usize,
            block_format_ops: nbe,
        }
    }
}

/// One row of Table 1: the storage and bookkeeping cost of a scheme.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartitionCost {
    pub scheme: PartitionScheme,
    /// Average stored bits per `W` entry (`AL_W'` in Table 1).
    pub avg_len_w: f64,
    /// Average stored bits per `I` entry (`AL_I'` in Table 1).
    pub avg_len_i: f64,
    /// Number of block exponents that must be stored (`NBE`).
    pub num_block_exponents: usize,
    /// Total `W` storage in bits.
    pub total_bits_w: usize,
    /// Total `I` storage in bits.
    pub total_bits_i: usize,
    /// Number of block-formatting scans required.
    pub block_format_ops: usize,
}

/// A matrix quantized to BFP under a chosen block axis.
///
/// Mantissas are stored row-major regardless of the block axis; the
/// exponent table has one entry per block (1, `rows`, or `cols`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BfpMatrix {
    pub rows: usize,
    pub cols: usize,
    pub axis: BlockAxis,
    pub frac_bits: i32,
    /// Row-major integer mantissas.
    pub mantissas: Vec<i32>,
    /// Block exponents: `[ε]` for `Whole`, `[ε_0 … ε_{rows-1}]` for
    /// `PerRow`, `[ε_0 … ε_{cols-1}]` for `PerCol`. `i32::MIN/2` marks an
    /// all-zero block.
    pub exponents: Vec<i32>,
}

impl BfpMatrix {
    /// An empty placeholder to [`BfpMatrix::requantize`] into — the
    /// prepared-serving workspace holds one per arena so the hot path
    /// reuses the mantissa/exponent allocations across layers and images.
    pub fn empty() -> Self {
        Self { rows: 0, cols: 0, axis: BlockAxis::Whole, frac_bits: 0, mantissas: Vec::new(), exponents: Vec::new() }
    }

    /// Quantize a row-major `rows×cols` f32 matrix under `fmt` and `axis`.
    pub fn quantize(data: &[f32], rows: usize, cols: usize, fmt: BfpFormat, axis: BlockAxis) -> Self {
        let mut out = Self::empty();
        out.requantize(data, rows, cols, fmt, axis);
        out
    }

    /// [`BfpMatrix::quantize`] in place, reusing this matrix's buffers.
    /// Produces results identical to a fresh `quantize` call.
    pub fn requantize(&mut self, data: &[f32], rows: usize, cols: usize, fmt: BfpFormat, axis: BlockAxis) {
        assert_eq!(data.len(), rows * cols, "matrix shape mismatch");
        let frac = fmt.frac_bits();
        let max_m = fmt.max_mantissa();
        let round = fmt.rounding;
        self.rows = rows;
        self.cols = cols;
        self.axis = axis;
        self.frac_bits = frac;
        self.mantissas.clear();
        self.mantissas.resize(rows * cols, 0);
        self.exponents.clear();
        let mantissas = &mut self.mantissas;
        let exponents = &mut self.exponents;
        let zero_exp = super::format::ZERO_EXP;
        match axis {
            BlockAxis::Whole => {
                let eps = max_exponent(data).unwrap_or(zero_exp);
                exponents.push(eps);
                if eps != zero_exp {
                    quantize_slice(data, mantissas, frac, eps, max_m, round);
                }
            }
            BlockAxis::PerRow => {
                exponents.resize(rows, zero_exp);
                for r in 0..rows {
                    let row = &data[r * cols..(r + 1) * cols];
                    if let Some(eps) = max_exponent(row) {
                        exponents[r] = eps;
                        quantize_slice(row, &mut mantissas[r * cols..(r + 1) * cols], frac, eps, max_m, round);
                    }
                }
            }
            BlockAxis::PerCol => {
                exponents.resize(cols, zero_exp);
                // column-wise max exponent
                let mut max_bits = vec![0u32; cols];
                for r in 0..rows {
                    for c in 0..cols {
                        let v = data[r * cols + c];
                        if v.is_finite() {
                            let b = v.to_bits() & 0x7FFF_FFFF;
                            if b > max_bits[c] {
                                max_bits[c] = b;
                            }
                        }
                    }
                }
                for c in 0..cols {
                    if max_bits[c] != 0 {
                        exponents[c] =
                            super::format::exponent_of(f32::from_bits(max_bits[c])).unwrap();
                    }
                }
                let inv_steps: Vec<f32> = exponents
                    .iter()
                    .map(|&e| if e == zero_exp { 0.0 } else { exp2i(frac - e) })
                    .collect();
                for r in 0..rows {
                    for c in 0..cols {
                        let scaled = data[r * cols + c] * inv_steps[c];
                        let q = apply_round(scaled, round) as i32;
                        mantissas[r * cols + c] = q.clamp(-max_m, max_m);
                    }
                }
            }
        }
    }

    /// Block exponent governing entry `(r, c)`.
    #[inline]
    pub fn exponent_at(&self, r: usize, c: usize) -> i32 {
        match self.axis {
            BlockAxis::Whole => self.exponents[0],
            BlockAxis::PerRow => self.exponents[r],
            BlockAxis::PerCol => self.exponents[c],
        }
    }

    /// Dequantize back to f32 (row-major).
    pub fn to_f32(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                let e = self.exponent_at(r, c);
                let s = if e <= super::format::ZERO_EXP { 0.0 } else { exp2i(e - self.frac_bits) };
                out[r * self.cols + c] = self.mantissas[r * self.cols + c] as f32 * s;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_matrix(rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols).map(|i| ((i as f32 * 0.7).sin() * 3.0) + 0.1).collect()
    }

    #[test]
    fn scheme_axes_match_paper() {
        assert_eq!(PartitionScheme::Eq2.w_axis(), BlockAxis::Whole);
        assert_eq!(PartitionScheme::Eq2.i_axis(), BlockAxis::Whole);
        assert_eq!(PartitionScheme::Eq3.w_axis(), BlockAxis::PerRow);
        assert_eq!(PartitionScheme::Eq3.i_axis(), BlockAxis::PerCol);
        assert_eq!(PartitionScheme::Eq4.w_axis(), BlockAxis::PerRow);
        assert_eq!(PartitionScheme::Eq4.i_axis(), BlockAxis::Whole);
        assert_eq!(PartitionScheme::Eq5.w_axis(), BlockAxis::Whole);
        assert_eq!(PartitionScheme::Eq5.i_axis(), BlockAxis::PerCol);
    }

    /// Table 1 identities for VGG-16 conv1_1 (M=64, K=9, N=50176).
    #[test]
    fn table1_vgg_conv1_1() {
        let (m, k, n) = (64usize, 9usize, 50176usize);
        let (lw, li, le) = (8u32, 8u32, 8u32);
        let c2 = PartitionScheme::Eq2.cost(m, k, n, lw, li, le);
        let c3 = PartitionScheme::Eq3.cost(m, k, n, lw, li, le);
        let c4 = PartitionScheme::Eq4.cost(m, k, n, lw, li, le);
        let c5 = PartitionScheme::Eq5.cost(m, k, n, lw, li, le);
        assert_eq!(c2.num_block_exponents, 2);
        assert_eq!(c3.num_block_exponents, m + n);
        assert_eq!(c4.num_block_exponents, 1 + m);
        assert_eq!(c5.num_block_exponents, 1 + n);
        // eq3/eq5 store hundreds of times more exponents than eq2/eq4
        assert!(c3.num_block_exponents > 100 * c4.num_block_exponents);
        assert!(c5.num_block_exponents > 100 * c4.num_block_exponents);
        // per-row W amortises the exponent over K only
        assert!((c4.avg_len_w - (8.0 + 8.0 / 9.0)).abs() < 1e-12);
        assert!((c4.avg_len_i - (8.0 + 8.0 / (9.0 * 50176.0))).abs() < 1e-12);
        assert!((c2.avg_len_w - (8.0 + 8.0 / (64.0 * 9.0))).abs() < 1e-12);
    }

    #[test]
    fn whole_axis_single_exponent_is_global_max() {
        let data = sample_matrix(4, 5);
        let q = BfpMatrix::quantize(&data, 4, 5, BfpFormat::new(8), BlockAxis::Whole);
        assert_eq!(q.exponents.len(), 1);
        assert_eq!(q.exponents[0], max_exponent(&data).unwrap());
    }

    #[test]
    fn per_row_exponents_are_row_maxima() {
        let data = vec![1.0f32, 0.1, 0.2, 8.0, 0.3, 0.4];
        let q = BfpMatrix::quantize(&data, 2, 3, BfpFormat::new(8), BlockAxis::PerRow);
        assert_eq!(q.exponents, vec![0, 3]);
    }

    #[test]
    fn per_col_exponents_are_col_maxima() {
        let data = vec![1.0f32, 0.1, 0.2, 8.0, 0.3, 0.4];
        let q = BfpMatrix::quantize(&data, 2, 3, BfpFormat::new(8), BlockAxis::PerCol);
        assert_eq!(q.exponents, vec![3, -2, -2]); // col maxima: 8.0, 0.3, 0.4
    }

    #[test]
    fn finer_partitions_are_no_less_accurate() {
        // per-row quantization error ≤ whole-matrix error (row maxima ≤ global max)
        let mut data = sample_matrix(16, 16);
        data[0] = 100.0; // one large outlier hurts the Whole scheme
        let fmt = BfpFormat::new(8);
        let err = |axis| {
            let q = BfpMatrix::quantize(&data, 16, 16, fmt, axis);
            let back = q.to_f32();
            data.iter().zip(&back).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
        };
        assert!(err(BlockAxis::PerRow) <= err(BlockAxis::Whole) + 1e-12);
    }

    #[test]
    fn zero_rows_handled() {
        let data = vec![0.0f32, 0.0, 1.0, 2.0];
        let q = BfpMatrix::quantize(&data, 2, 2, BfpFormat::new(8), BlockAxis::PerRow);
        let back = q.to_f32();
        assert_eq!(&back[0..2], &[0.0, 0.0]);
        assert!((back[2] - 1.0).abs() < 0.02 && (back[3] - 2.0).abs() < 0.02);
    }

    /// In-place requantization over a reused buffer must equal a fresh
    /// quantize, across shrinking/growing shapes and every axis (no stale
    /// mantissas or exponents may survive).
    #[test]
    fn requantize_reuse_matches_fresh() {
        let mut reused = BfpMatrix::quantize(&sample_matrix(16, 16), 16, 16, BfpFormat::new(6), BlockAxis::PerRow);
        for (rows, cols, bits, axis) in [
            (4usize, 5usize, 8u32, BlockAxis::Whole),
            (9, 3, 10, BlockAxis::PerRow),
            (2, 11, 5, BlockAxis::PerCol),
            (12, 12, 8, BlockAxis::PerRow),
            (1, 1, 4, BlockAxis::Whole),
        ] {
            let data = sample_matrix(rows, cols);
            reused.requantize(&data, rows, cols, BfpFormat::new(bits), axis);
            let fresh = BfpMatrix::quantize(&data, rows, cols, BfpFormat::new(bits), axis);
            assert_eq!(reused, fresh, "{rows}x{cols} bits={bits} axis={axis:?}");
        }
    }

    #[test]
    fn dequantize_roundtrip_reasonable() {
        let data = sample_matrix(8, 8);
        for axis in [BlockAxis::Whole, BlockAxis::PerRow, BlockAxis::PerCol] {
            let q = BfpMatrix::quantize(&data, 8, 8, BfpFormat::new(12), axis);
            let back = q.to_f32();
            for (a, b) in data.iter().zip(&back) {
                assert!((a - b).abs() < 0.01, "{a} vs {b} axis={axis:?}");
            }
        }
    }
}
