//! Block formatting: the BFP quantization procedure of §3.1 / eq. (1).
//!
//! 1. Scan the block for the maximum exponent `ε = max_i floor(log2 |x_i|)`.
//! 2. Express every element as an integer mantissa at scale
//!    `2^(ε - frac_bits)`: `q_i = round(x_i / Δ)` with `Δ = 2^(ε - f)` —
//!    this is exactly "right-shift the mantissa by `ε - e_i` and round the
//!    out-shifted bits".
//! 3. Saturate at `±(2^(L-1) - 1)` (a round-up of the block maximum from
//!    `m = 1.11…1` would otherwise need one extra bit; real hardware
//!    saturates).

use super::block::BfpBlock;
use super::format::{exp2i, exponent_of, round_half_away, round_stochastic, BfpFormat, Rounding};

/// Maximum exponent over a slice — the block exponent `ε` (eq. of §3.1).
/// Returns `None` if the slice contains no finite nonzero value.
pub fn max_exponent(values: &[f32]) -> Option<i32> {
    // The binary exponent is monotone in |x| for finite floats, so the max
    // exponent is the exponent of the max |x|. Comparing payload bits
    // (sign cleared) avoids per-element exponent extraction.
    let mut max_abs_bits: u32 = 0;
    for &v in values {
        if v.is_finite() {
            let b = v.to_bits() & 0x7FFF_FFFF;
            if b > max_abs_bits {
                max_abs_bits = b;
            }
        }
    }
    if max_abs_bits == 0 {
        None
    } else {
        exponent_of(f32::from_bits(max_abs_bits))
    }
}

/// Block-format `values` into a [`BfpBlock`] under `fmt`.
pub fn block_format(values: &[f32], fmt: BfpFormat) -> BfpBlock {
    let mut block = BfpBlock::zeros(values.len(), fmt);
    quantize_into(values, fmt, &mut block);
    block
}

/// Block-format into an existing block (no allocation when the length
/// matches). The hot-path entry point used by the GEMM pipeline.
pub fn quantize_into(values: &[f32], fmt: BfpFormat, block: &mut BfpBlock) {
    block.frac_bits = fmt.frac_bits();
    block.mantissas.resize(values.len(), 0);
    let Some(eps) = max_exponent(values) else {
        block.exponent = super::format::ZERO_EXP;
        block.mantissas.fill(0);
        return;
    };
    block.exponent = eps;
    quantize_slice(values, &mut block.mantissas, fmt.frac_bits(), eps, fmt.max_mantissa(), fmt.rounding);
}

/// Quantize-dequantize round trip: the BFP approximation `x'` of `x`.
/// This is what the accuracy experiments apply to weights / activations.
pub fn dequantize(values: &[f32], fmt: BfpFormat) -> Vec<f32> {
    block_format(values, fmt).to_f32()
}

/// One element of eq. (1): scale by `1/Δ`, round per `mode`, saturate.
/// Every quantization path in the crate ([`quantize_into`],
/// [`crate::bfp::partition::BfpMatrix::requantize`], the fused
/// im2col→pack pipeline in [`crate::bfp::kernel`]) reduces to this exact
/// f32 instruction sequence, so they agree bit-for-bit by construction.
#[inline(always)]
pub(crate) fn apply_round(x: f32, mode: Rounding) -> f32 {
    match mode {
        Rounding::Nearest => round_half_away(x),
        Rounding::Truncate => x.trunc(),
        Rounding::Stochastic => round_stochastic(x),
    }
}

/// Quantize a contiguous slice that shares one block exponent `eps`
/// (rounding dispatched once, not per element — the inner loops
/// vectorize). Shared by the `Whole`/`PerRow` matrix paths and the
/// fused activation pipeline.
#[inline]
pub(crate) fn quantize_slice(src: &[f32], dst: &mut [i32], frac: i32, eps: i32, max_m: i32, round: Rounding) {
    let inv_step = exp2i(frac - eps);
    match round {
        Rounding::Nearest => {
            for (q, &v) in dst.iter_mut().zip(src) {
                *q = (round_half_away(v * inv_step) as i32).clamp(-max_m, max_m);
            }
        }
        Rounding::Truncate => {
            for (q, &v) in dst.iter_mut().zip(src) {
                *q = ((v * inv_step).trunc() as i32).clamp(-max_m, max_m);
            }
        }
        Rounding::Stochastic => {
            for (q, &v) in dst.iter_mut().zip(src) {
                *q = (round_stochastic(v * inv_step) as i32).clamp(-max_m, max_m);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §3.4 worked example: I = [[1.01e0, 1.01e0], [1.01e1, 1.01e2]] (bin),
    /// L_I = 3 excl. sign → total 4 bits. Expect ε=2 and mantissas
    /// (0.01, 0.01, 0.11, 1.01) i.e. q = (1, 1, 3, 5) at frac_bits=2.
    #[test]
    fn paper_worked_example_input_matrix() {
        let m101 = 1.25f32; // (1.01)_2
        let xs = [m101, m101, m101 * 2.0, m101 * 4.0];
        let fmt = BfpFormat::new(4);
        let b = block_format(&xs, fmt);
        assert_eq!(b.exponent, 2);
        assert_eq!(b.frac_bits, 2);
        assert_eq!(b.mantissas, vec![1, 1, 3, 5]);
    }

    /// §3.4 worked example: W = [1.00e-1, 1.01e0] → ε=0,
    /// mantissas (0.10, 1.01) = (2, 5).
    #[test]
    fn paper_worked_example_weight_matrix() {
        let xs = [0.5f32, 1.25];
        let b = block_format(&xs, BfpFormat::new(4));
        assert_eq!(b.exponent, 0);
        assert_eq!(b.mantissas, vec![2, 5]);
    }

    #[test]
    fn max_exponent_basic() {
        assert_eq!(max_exponent(&[0.5, -3.0, 1.0]), Some(1));
        assert_eq!(max_exponent(&[0.0, 0.0]), None);
        assert_eq!(max_exponent(&[]), None);
        assert_eq!(max_exponent(&[f32::NAN, 2.0]), Some(1));
    }

    #[test]
    fn error_bounded_by_half_step() {
        let fmt = BfpFormat::new(8);
        let xs: Vec<f32> = (0..1000).map(|i| ((i * 2654435761u64 as usize) as f32).sin() * 7.3).collect();
        let b = block_format(&xs, fmt);
        let step = fmt.step(b.exponent);
        let ys = b.to_f32();
        for (x, y) in xs.iter().zip(&ys) {
            // round-off: |err| ≤ Δ/2 (+ tiny slack for the saturated max)
            assert!(
                (x - y).abs() <= step * 0.5 + step * 1e-3 || (x - y).abs() <= step,
                "x={x} y={y} step={step}"
            );
        }
    }

    #[test]
    fn truncation_biases_toward_zero() {
        let fmt = BfpFormat::truncating(8);
        let xs = [0.777f32, 1.999, -0.333, 1.0];
        let b = block_format(&xs, fmt);
        for (x, y) in xs.iter().zip(b.to_f32()) {
            assert!(y.abs() <= x.abs() + 1e-7, "truncation must not grow magnitude");
        }
    }

    #[test]
    fn exact_values_roundtrip_losslessly() {
        // Values already on the quantization grid survive unchanged.
        let fmt = BfpFormat::new(8); // frac_bits = 6
        let step = fmt.step(0); // block exp will be 0 (max |x| in [1,2))
        let xs = [1.0f32, 0.5, step * 17.0, -step * 40.0];
        let b = block_format(&xs, fmt);
        assert_eq!(b.to_f32(), xs.to_vec());
    }

    #[test]
    fn wide_format_is_near_lossless() {
        let fmt = BfpFormat::new(24);
        let xs = [0.123456f32, -3.14159, 0.577215, 1.41421];
        let ys = dequantize(&xs, fmt);
        for (x, y) in xs.iter().zip(&ys) {
            assert!((x - y).abs() <= (x.abs() + 1.0) * 1e-6);
        }
    }

    #[test]
    fn all_zero_block() {
        let b = block_format(&[0.0, 0.0, 0.0], BfpFormat::new(8));
        assert_eq!(b.to_f32(), vec![0.0; 3]);
    }

    #[test]
    fn saturation_at_max_mantissa() {
        let fmt = BfpFormat::new(4); // max_mantissa = 7, frac = 2
        // 1.999… has mantissa ~(1.1111)_2; rounding to 2 frac bits would
        // give (10.00)_2 = 8 — must saturate to 7.
        let xs = [1.99f32, 1.0];
        let b = block_format(&xs, fmt);
        assert_eq!(b.exponent, 0);
        assert_eq!(b.mantissas[0], 7);
    }

    #[test]
    fn quantize_into_reuses_buffer() {
        let fmt = BfpFormat::new(8);
        let mut b = BfpBlock::zeros(4, fmt);
        quantize_into(&[1.0, 2.0, 3.0, 4.0], fmt, &mut b);
        let first = b.clone();
        quantize_into(&[1.0, 2.0, 3.0, 4.0], fmt, &mut b);
        assert_eq!(b, first);
    }

    #[test]
    fn negative_values_symmetric() {
        let fmt = BfpFormat::new(8);
        let xs = [1.3f32, -1.3, 0.7, -0.7];
        let b = block_format(&xs, fmt);
        assert_eq!(b.mantissas[0], -b.mantissas[1]);
        assert_eq!(b.mantissas[2], -b.mantissas[3]);
    }
}
