//! Block floating point (BFP) numeric substrate.
//!
//! A BFP block is a group of `n` numbers that share a single exponent — the
//! maximum exponent in the group (§3.1 of the paper). Every member's
//! mantissa is right-shifted by the difference between the block exponent
//! and its own exponent ("block formatting", eq. (1)), so all subsequent
//! arithmetic on the block happens in plain fixed point.
//!
//! Submodules:
//! * [`format`] — word-width bookkeeping ([`BfpFormat`]): how many mantissa
//!   bits (the paper's `L_W` / `L_I`, *including* the sign bit, matching
//!   Table 3's convention) and the derived quantization step.
//! * [`block`] — the [`BfpBlock`] container: integer mantissas + shared
//!   exponent, with exact dequantization.
//! * [`quantize`] — block formatting itself: exponent extraction via f32
//!   bit manipulation, round-off vs truncation (§3.1 discusses why
//!   round-off wins; we implement both so the ablation bench can show it).
//! * [`gemm`] — the Figure 2 data flow: exact fixed-point multiply-
//!   accumulate over two blocks with the §3.4 bit-width guarantees
//!   (naive ikj kernels — the bit-exact reference).
//! * [`kernel`] — the production GEMM: cache-blocked, register-tiled
//!   microkernel over packed mantissa panels, with the fused
//!   im2col→quantize→pack activation pipeline. Bit-identical to
//!   [`gemm`] by the §3.4 exactness argument.
//! * [`partition`] — the eq. (2)–(5) matrix partition schemes and the
//!   Table 1 storage / block-exponent cost model.

pub mod block;
pub mod format;
pub mod gemm;
pub mod kernel;
pub mod partition;
pub mod quantize;

pub use block::BfpBlock;
pub use format::{exponent_of, BfpFormat, Rounding};
pub use gemm::{
    bfp_gemm, bfp_gemm_into, bfp_gemm_into_prepared, f32_lane_chunk, pack_mantissas, BfpGemmOutput,
    GemmScratch,
};
pub use kernel::{
    bfp_gemm_tiled, gemm_tiled, pack_weights_f32, pack_weights_i32, select_lane, ActPanels, Lane,
    WeightPanels,
};
pub use partition::{BfpMatrix, PartitionCost, PartitionScheme};
pub use quantize::{block_format, dequantize, max_exponent, quantize_into};
