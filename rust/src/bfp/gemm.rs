//! The Figure 2 data flow: fixed-point GEMM over block-formatted matrices.
//!
//! `O = W'·I'` is computed entirely in the integer domain:
//! `M'_O = M'_W · M'_I` with `ε_O = ε_W + ε_I` per block pair. §3.4 gives
//! the bit-width rules that make the integer MAC *exact* (no rounding
//! inside the accumulation):
//!
//! * multiplier width ≥ `L_W + L_I + 2` bits (incl. sign),
//! * accumulator width ≥ `L_W + L_I + 2 + ⌊log2 K⌋` bits.
//!
//! [`crate::quant::widths`] plans those widths; this module picks an
//! `i32` or `i64` accumulator lane accordingly and the result is bit-exact
//! against an arbitrary-precision reference (see the proptests).
//!
//! The serving hot path runs the cache-blocked microkernel in
//! [`crate::bfp::kernel`]; the row-at-a-time ikj kernels here are
//! retained as the bit-exact reference the tiled kernel is tested
//! against (`rust/tests/tiled_kernel.rs`), and still serve the
//! instrumentation paths that want plain [`BfpMatrix`] operands.

use super::format::exp2i64;
use super::partition::{BfpMatrix, BlockAxis};
use crate::runtime::pool;
use std::sync::Mutex;

/// Reusable accumulator rows for the row-panel kernels below. Each
/// worker checks one set out per panel and returns it after; the pool
/// grows to the peak worker count (capped) and then stops allocating,
/// where the accumulators used to be allocated fresh inside every panel
/// closure of every GEMM call. (A process-wide pool, not a thread-local:
/// the scoped pool spawns fresh OS threads per parallel region, so
/// thread-locals would never be revisited.)
#[derive(Default)]
pub(crate) struct PanelAcc {
    f32v: Vec<f32>,
    f64v: Vec<f64>,
    i32v: Vec<i32>,
    i64v: Vec<i64>,
}

static PANEL_ACC_POOL: Mutex<Vec<PanelAcc>> = Mutex::new(Vec::new());

fn take_panel_acc() -> PanelAcc {
    PANEL_ACC_POOL.lock().map(|mut p| p.pop().unwrap_or_default()).unwrap_or_default()
}

fn put_panel_acc(acc: PanelAcc) {
    if let Ok(mut p) = PANEL_ACC_POOL.lock() {
        // idle sets are bounded by the pool's own thread cap
        if p.len() < 64 {
            p.push(acc);
        }
    }
}

/// Grow-only view: resize to at least `n` and hand back the `n` prefix.
/// Contents are stale from previous use; callers fully overwrite.
fn grown<T: Copy + Default>(v: &mut Vec<T>, n: usize) -> &mut [T] {
    if v.len() < n {
        v.resize(n, T::default());
    }
    &mut v[..n]
}

/// Result of a BFP GEMM: f32 output plus the bookkeeping the error
/// analysis wants (block exponents actually used).
#[derive(Debug, Clone)]
pub struct BfpGemmOutput {
    pub rows: usize,
    pub cols: usize,
    /// Row-major f32 reconstruction of `O ≈ W·I`.
    pub data: Vec<f32>,
}

/// Fixed-point GEMM `O = W'·I'` between two quantized matrices.
///
/// `w` is `M×K`, `i` is `K×N`. Any combination of block axes is accepted
/// as long as the scale of a product term depends only on `(row, col)` of
/// the output — i.e. `w` is `Whole`/`PerRow` and `i` is `Whole`/`PerCol`,
/// which covers all four schemes of §3.3 (for eq. 3 the per-row /
/// per-column vectors are exactly the inner-product operands).
pub fn bfp_gemm(w: &BfpMatrix, i: &BfpMatrix) -> BfpGemmOutput {
    let mut out = vec![0f32; w.rows * i.cols];
    bfp_gemm_into(w, i, &mut out);
    BfpGemmOutput { rows: w.rows, cols: i.cols, data: out }
}

/// [`bfp_gemm`] writing into a caller-provided buffer (hot path).
pub fn bfp_gemm_into(w: &BfpMatrix, i: &BfpMatrix, out: &mut [f32]) {
    let mut scratch = GemmScratch::default();
    bfp_gemm_into_prepared(w, None, i, out, &mut scratch);
}

/// Reusable mantissa-staging buffers for the f32-lane GEMM. The prepared
/// serving path keeps one per [`crate::nn::prepared::Workspace`] so the
/// per-call `i32 → f32` materialisation reuses its allocation.
#[derive(Debug, Default)]
pub struct GemmScratch {
    wf: Vec<f32>,
    if_: Vec<f32>,
}

/// Does the exact f32-mantissa lane apply at these fractional widths?
/// Returns the K-chunk length over which f32 partial sums stay exact
/// (products ≤ 2^(prod_bits−1), sums bounded by 2^24), or `None` when the
/// integer lanes must run. [`crate::nn::prepared`] uses this to decide
/// whether pre-packing a weight panel to f32 will pay off.
pub fn f32_lane_chunk(w_frac_bits: i32, i_frac_bits: i32) -> Option<usize> {
    let prod_bits = (w_frac_bits + 1) + (i_frac_bits + 1) + 1;
    let max_prod = 1i64 << (prod_bits - 1).min(62);
    let chunk = ((1i64 << 24) / max_prod.max(1)) as usize;
    (chunk >= 32).then_some(chunk)
}

/// Which exact accumulator lane a `(L_W, L_I, K)` combination runs.
/// **The single dispatch rule**: this naive reference kernel
/// ([`bfp_gemm_into_prepared`]) and the tiled microkernel
/// ([`crate::bfp::kernel::gemm_tiled`]) both match on [`select_lane`],
/// so the reference always exercises the lane that ships.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Integer-valued f32 mantissa MACs, exact in segments of `chunk`
    /// products, accumulated across segments in f64.
    F32 {
        /// Maximum exact f32 accumulation segment length.
        chunk: usize,
    },
    /// Plain i32 multiply-accumulate (acc width ≤ 31 bits).
    I32,
    /// Widening i64 multiply-accumulate.
    I64,
}

impl Lane {
    /// Does this lane consume f32-materialised mantissa panels?
    pub fn is_f32(self) -> bool {
        matches!(self, Lane::F32 { .. })
    }
}

/// Pick the accumulator lane for fractional widths and inner dimension
/// `k` — §3.4: products need `l_w + l_i + 2` bits, accumulation adds
/// `⌊log2 K⌋ + 1`.
pub fn select_lane(w_frac_bits: i32, i_frac_bits: i32, k: usize) -> Lane {
    if let Some(chunk) = f32_lane_chunk(w_frac_bits, i_frac_bits) {
        return Lane::F32 { chunk };
    }
    let prod_bits = (w_frac_bits + 1) + (i_frac_bits + 1) + 1;
    let acc_bits = prod_bits + (usize::BITS - k.leading_zeros()) as i32;
    if acc_bits <= 31 {
        Lane::I32
    } else {
        Lane::I64
    }
}

/// Materialise a matrix's integer mantissas as exact f32 values — the
/// "packed panel" a [`crate::nn::prepared::PreparedModel`] caches per
/// conv layer so the hot loop never re-converts static weights.
pub fn pack_mantissas(m: &BfpMatrix) -> Vec<f32> {
    m.mantissas.iter().map(|&v| v as f32).collect()
}

fn pack_into(mantissas: &[i32], out: &mut Vec<f32>) {
    out.clear();
    out.extend(mantissas.iter().map(|&v| v as f32));
}

/// [`bfp_gemm_into`] with optional pre-packed f32 weight mantissas
/// (`w_packed`, produced by [`pack_mantissas`]) and caller-owned scratch.
/// Row panels run on the [`pool`] workers; each output row is computed
/// with the exact serial instruction sequence (same K-chunk order), so
/// the result is bit-identical for every thread count.
pub fn bfp_gemm_into_prepared(
    w: &BfpMatrix,
    w_packed: Option<&[f32]>,
    i: &BfpMatrix,
    out: &mut [f32],
    scratch: &mut GemmScratch,
) {
    assert_eq!(w.cols, i.rows, "GEMM inner dimension mismatch");
    assert!(
        !matches!(w.axis, BlockAxis::PerCol),
        "weight matrix must be blocked Whole or PerRow (schemes eq2–eq5)"
    );
    assert!(
        !matches!(i.axis, BlockAxis::PerRow),
        "input matrix must be blocked Whole or PerCol (schemes eq2–eq5)"
    );
    let (m, k, n) = (w.rows, w.cols, i.cols);
    assert_eq!(out.len(), m * n);

    // §3.4 width plan via the shared lane rule ([`select_lane`]). The
    // f32 fast path (§Perf) runs integer-valued f32 mantissa MACs: a
    // product of two mantissas is ≤ 2^(prod_bits-1) and stays exact in
    // f32; partial sums over a K-chunk stay exact while they remain
    // ≤ 2^24; chunk sums are then accumulated in f64 (integers exact to
    // 2^53). FMA-friendly f32 lanes beat the i32 multiply (vpmulld)
    // substantially — see EXPERIMENTS.md §Perf — while remaining
    // bit-exact.
    match select_lane(w.frac_bits, i.frac_bits, k) {
        Lane::F32 { chunk } => gemm_f32_mantissa(w, w_packed, i, out, m, k, n, chunk, scratch),
        Lane::I32 => gemm_lanes::<i32>(w, i, out, m, k, n),
        Lane::I64 => gemm_lanes::<i64>(w, i, out, m, k, n),
    }
}

/// Exact f32-mantissa GEMM with chunked-K f64 accumulation (see the
/// exactness argument at the call site). Input mantissas are materialised
/// as f32 once per call (into `scratch`); weight mantissas come pre-packed
/// from the prepared-model cache when available. The inner loops are plain
/// f32 MACs that the auto-vectorizer turns into FMA lanes. Rescaling is
/// done per element in f64 with an f64-constructed power of two, so
/// extreme block-exponent sums neither overflow to `inf`/NaN nor flush
/// representable subnormal outputs to zero.
#[allow(clippy::too_many_arguments)]
fn gemm_f32_mantissa(
    w: &BfpMatrix,
    w_packed: Option<&[f32]>,
    i: &BfpMatrix,
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    chunk: usize,
    scratch: &mut GemmScratch,
) {
    let zero_exp_floor = super::format::ZERO_EXP_FLOOR;
    pack_into(&i.mantissas, &mut scratch.if_);
    if w_packed.is_none() {
        pack_into(&w.mantissas, &mut scratch.wf);
    }
    let wf: &[f32] = match w_packed {
        Some(p) => {
            assert_eq!(p.len(), m * k, "pre-packed weight panel shape mismatch");
            p
        }
        None => &scratch.wf,
    };
    let if_: &[f32] = &scratch.if_;
    let single_chunk = k <= chunk;
    pool::parallel_row_panels(out, m, n, k.saturating_mul(n), |r0, panel| {
        let mut panel_acc = take_panel_acc();
        let PanelAcc { f32v, f64v, .. } = &mut panel_acc;
        let acc32 = grown(f32v, n);
        let acc64 = grown(f64v, if single_chunk { 0 } else { n });
        for (pr, orow) in panel.chunks_mut(n).enumerate() {
            let r = r0 + pr;
            let wrow = &wf[r * k..(r + 1) * k];
            if single_chunk {
                // common case: the whole K panel stays exact in f32
                acc32.fill(0.0);
                for (kk, &wv) in wrow.iter().enumerate() {
                    if wv == 0.0 {
                        continue;
                    }
                    let irow = &if_[kk * n..(kk + 1) * n];
                    for (a, &iv) in acc32.iter_mut().zip(irow) {
                        *a += wv * iv;
                    }
                }
            } else {
                acc64.fill(0.0);
                let mut k0 = 0usize;
                while k0 < k {
                    let k1 = (k0 + chunk).min(k);
                    acc32.fill(0.0);
                    for kk in k0..k1 {
                        let wv = wrow[kk];
                        if wv == 0.0 {
                            continue;
                        }
                        let irow = &if_[kk * n..(kk + 1) * n];
                        for (a, &iv) in acc32.iter_mut().zip(irow) {
                            *a += wv * iv;
                        }
                    }
                    for (a64, &a32) in acc64.iter_mut().zip(acc32.iter()) {
                        *a64 += a32 as f64;
                    }
                    k0 = k1;
                }
            }
            let we = match w.axis {
                BlockAxis::Whole => w.exponents[0],
                BlockAxis::PerRow => w.exponents[r],
                BlockAxis::PerCol => unreachable!(),
            };
            if we <= zero_exp_floor {
                orow.fill(0.0);
                continue;
            }
            match i.axis {
                BlockAxis::Whole => {
                    let ie = i.exponents[0];
                    if ie <= zero_exp_floor {
                        orow.fill(0.0);
                        continue;
                    }
                    let scale = exp2i64(we + ie - w.frac_bits - i.frac_bits);
                    if single_chunk {
                        for (o, &a) in orow.iter_mut().zip(acc32.iter()) {
                            *o = (a as f64 * scale) as f32;
                        }
                    } else {
                        for (o, &a) in orow.iter_mut().zip(acc64.iter()) {
                            *o = (a * scale) as f32;
                        }
                    }
                }
                BlockAxis::PerCol => {
                    for (j, (o, &ie)) in orow.iter_mut().zip(&i.exponents).enumerate() {
                        let a = if single_chunk { acc32[j] as f64 } else { acc64[j] };
                        *o = if ie <= zero_exp_floor {
                            0.0
                        } else {
                            (a * exp2i64(we + ie - w.frac_bits - i.frac_bits)) as f32
                        };
                    }
                }
                BlockAxis::PerRow => unreachable!(),
            }
        }
        put_panel_acc(panel_acc);
    });
}

/// Integer accumulator lane abstraction (i32 fast path / i64 wide path),
/// shared with the tiled microkernel in [`crate::bfp::kernel`].
pub(crate) trait AccLane: Copy + Default + Send + Sync + std::ops::AddAssign {
    fn mul(a: i32, b: i32) -> Self;
    fn to_f64(self) -> f64;
    /// This lane's per-worker accumulator row from the scratch set.
    fn panel_scratch(acc: &mut PanelAcc, n: usize) -> &mut [Self];
}
impl AccLane for i32 {
    #[inline(always)]
    fn mul(a: i32, b: i32) -> Self {
        a * b
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn panel_scratch(acc: &mut PanelAcc, n: usize) -> &mut [Self] {
        grown(&mut acc.i32v, n)
    }
}
impl AccLane for i64 {
    #[inline(always)]
    fn mul(a: i32, b: i32) -> Self {
        a as i64 * b as i64
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn panel_scratch(acc: &mut PanelAcc, n: usize) -> &mut [Self] {
        grown(&mut acc.i64v, n)
    }
}

fn gemm_lanes<A: AccLane>(w: &BfpMatrix, i: &BfpMatrix, out: &mut [f32], m: usize, k: usize, n: usize) {
    let zero_exp_floor = super::format::ZERO_EXP_FLOOR;
    // Accumulate one output row at a time in integer lanes (ikj order —
    // streams through I row-major, vectorizes the inner j loop). Rows are
    // independent, so panels parallelize with bit-identical results.
    pool::parallel_row_panels(out, m, n, k.saturating_mul(n), |r0, panel| {
        let mut panel_acc = take_panel_acc();
        let acc: &mut [A] = A::panel_scratch(&mut panel_acc, n);
        for (pr, orow) in panel.chunks_mut(n).enumerate() {
            let r = r0 + pr;
            for a in acc.iter_mut() {
                *a = A::default();
            }
            let wrow = &w.mantissas[r * k..(r + 1) * k];
            for (kk, &wv) in wrow.iter().enumerate() {
                if wv == 0 {
                    continue;
                }
                let irow = &i.mantissas[kk * n..(kk + 1) * n];
                for (a, &iv) in acc.iter_mut().zip(irow) {
                    *a += A::mul(wv, iv);
                }
            }
            // Rescale: ε_O = ε_W(row) + ε_I(col); frac bits add. The scale
            // is an exact f64 power of two and the multiply runs in f64, so
            // wide i64 accumulations keep their precision and extreme
            // exponent sums behave (see gemm_f32_mantissa).
            let we = match w.axis {
                BlockAxis::Whole => w.exponents[0],
                BlockAxis::PerRow => w.exponents[r],
                BlockAxis::PerCol => unreachable!(),
            };
            if we <= zero_exp_floor {
                orow.fill(0.0);
                continue;
            }
            match i.axis {
                BlockAxis::Whole => {
                    let ie = i.exponents[0];
                    if ie <= zero_exp_floor {
                        orow.fill(0.0);
                        continue;
                    }
                    let scale = exp2i64(we + ie - w.frac_bits - i.frac_bits);
                    for (o, a) in orow.iter_mut().zip(acc.iter()) {
                        *o = (a.to_f64() * scale) as f32;
                    }
                }
                BlockAxis::PerCol => {
                    for ((o, a), &ie) in orow.iter_mut().zip(acc.iter()).zip(&i.exponents) {
                        *o = if ie <= zero_exp_floor {
                            0.0
                        } else {
                            (a.to_f64() * exp2i64(we + ie - w.frac_bits - i.frac_bits)) as f32
                        };
                    }
                }
                BlockAxis::PerRow => unreachable!(),
            }
        }
        put_panel_acc(panel_acc);
    });
}

/// Plain f32 GEMM reference (`O = W·I`), used as the "floating point"
/// baseline throughout the experiments. Parallelized over row panels;
/// each row keeps the serial accumulation order (bit-identical output).
pub fn f32_gemm(w: &[f32], i: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(w.len(), m * k);
    assert_eq!(i.len(), k * n);
    assert_eq!(out.len(), m * n);
    pool::parallel_row_panels(out, m, n, k.saturating_mul(n), |r0, panel| {
        for (pr, orow) in panel.chunks_mut(n).enumerate() {
            let r = r0 + pr;
            orow.fill(0.0);
            let wrow = &w[r * k..(r + 1) * k];
            for (kk, &wv) in wrow.iter().enumerate() {
                if wv == 0.0 {
                    continue;
                }
                let irow = &i[kk * n..(kk + 1) * n];
                for (o, &iv) in orow.iter_mut().zip(irow) {
                    *o += wv * iv;
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::format::BfpFormat;
    use crate::bfp::partition::PartitionScheme;

    fn mat(seed: u64, len: usize, scale: f32) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 11) as f32 / (1u64 << 53) as f32 * 2.0 - 0.5) * scale
            })
            .collect()
    }

    /// §3.4 worked example: O = W'·I' with the paper's 4-bit blocks.
    #[test]
    fn paper_worked_example_product() {
        let fmt = BfpFormat::new(4);
        let w = BfpMatrix::quantize(&[0.5, 1.25], 1, 2, fmt, BlockAxis::PerRow);
        let i = BfpMatrix::quantize(&[1.25, 1.25, 2.5, 5.0], 2, 2, fmt, BlockAxis::Whole);
        // mantissas: W=(2,5) ε=0 f=2; I=((1,1),(3,5)) ε=2 f=2
        // integer O = (2·1+5·3, 2·1+5·5) = (17, 27); scale 2^(0+2-2-2)=2^-2
        let o = bfp_gemm(&w, &i);
        assert_eq!(o.data, vec![17.0 / 4.0, 27.0 / 4.0]);
    }

    #[test]
    fn bfp_gemm_approximates_f32_gemm() {
        let (m, k, n) = (8, 32, 16);
        let w = mat(1, m * k, 2.0);
        let i = mat(2, k * n, 4.0);
        let mut exact = vec![0f32; m * n];
        f32_gemm(&w, &i, m, k, n, &mut exact);
        for scheme in [PartitionScheme::Eq2, PartitionScheme::Eq3, PartitionScheme::Eq4, PartitionScheme::Eq5] {
            let fmt = BfpFormat::new(12);
            let wq = BfpMatrix::quantize(&w, m, k, fmt, scheme.w_axis());
            let iq = BfpMatrix::quantize(&i, k, n, fmt, scheme.i_axis());
            let o = bfp_gemm(&wq, &iq);
            let energy: f64 = exact.iter().map(|x| (*x as f64).powi(2)).sum();
            let err: f64 = exact.iter().zip(&o.data).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            assert!(err / energy < 1e-4, "scheme {scheme:?}: NSR {}", err / energy);
        }
    }

    /// The integer MAC must be *exact*: dequantized GEMM of the quantized
    /// matrices equals f32 GEMM of the dequantized matrices (products are
    /// representable, f32 sums of integer-valued terms < 2^24 are exact).
    #[test]
    fn fixed_point_mac_is_exact() {
        let (m, k, n) = (4, 9, 7);
        let w = mat(3, m * k, 1.0);
        let i = mat(4, k * n, 8.0);
        let fmt = BfpFormat::new(8);
        let wq = BfpMatrix::quantize(&w, m, k, fmt, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&i, k, n, fmt, BlockAxis::Whole);
        let o = bfp_gemm(&wq, &iq);
        let wd = wq.to_f32();
        let id = iq.to_f32();
        let mut expect = vec![0f32; m * n];
        f32_gemm(&wd, &id, m, k, n, &mut expect);
        for (a, b) in o.data.iter().zip(&expect) {
            assert_eq!(a, b, "fixed-point GEMM must be bit-exact");
        }
    }

    #[test]
    fn wide_accumulator_path() {
        // Force acc_bits > 31: wide mantissas + large K.
        let (m, k, n) = (2, 5000, 3);
        let w = mat(5, m * k, 1.0);
        let i = mat(6, k * n, 1.0);
        let fmt = BfpFormat::new(16);
        let wq = BfpMatrix::quantize(&w, m, k, fmt, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&i, k, n, fmt, BlockAxis::Whole);
        let o = bfp_gemm(&wq, &iq);
        let mut exact = vec![0f32; m * n];
        f32_gemm(&w, &i, m, k, n, &mut exact);
        for (a, b) in o.data.iter().zip(&exact) {
            assert!((a - b).abs() / (b.abs() + 1.0) < 1e-3);
        }
    }

    #[test]
    fn zero_weight_matrix_gives_zero_output() {
        let fmt = BfpFormat::new(8);
        let wq = BfpMatrix::quantize(&[0.0; 6], 2, 3, fmt, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&mat(7, 12, 1.0), 3, 4, fmt, BlockAxis::Whole);
        let o = bfp_gemm(&wq, &iq);
        assert!(o.data.iter().all(|&x| x == 0.0));
    }

    /// Regression for the single-chunk rescale path: the per-row scale
    /// used to be narrowed to f32 before the multiply, flushing outputs
    /// to zero whenever the block-exponent sum fell below the f32
    /// exponent range even though the products themselves are
    /// representable (subnormal) f32 values.
    #[test]
    fn single_chunk_rescale_survives_near_denormal_scales() {
        use crate::bfp::format::exp2i;
        let fmt = BfpFormat::new(8); // frac_bits = 6 → f32 lane, chunk ≫ K
        let (m, k, n) = (2usize, 8usize, 3usize);
        // w ~ 2^-100, i ~ 2^-40 → combined scale ≈ 2^-152 (underflows the
        // f32 exponent range) while outputs land near 2^-135 (valid f32
        // subnormals).
        let w: Vec<f32> = (0..m * k).map(|j| ((j % 5) as f32 - 2.0) * exp2i(-100)).collect();
        let i: Vec<f32> = (0..k * n).map(|j| ((j % 7) as f32 - 3.0) * exp2i(-40)).collect();
        let wq = BfpMatrix::quantize(&w, m, k, fmt, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&i, k, n, fmt, BlockAxis::Whole);
        let o = bfp_gemm(&wq, &iq);
        assert!(o.data.iter().any(|&x| x != 0.0), "tiny-scale output flushed to zero: {:?}", o.data);
        // f64 integer reference
        for r in 0..m {
            for c in 0..n {
                let mut acc: i128 = 0;
                for kk in 0..k {
                    acc += wq.mantissas[r * k + kk] as i128 * iq.mantissas[kk * n + c] as i128;
                }
                let expect = (acc as f64
                    * exp2i64(wq.exponents[r] + iq.exponents[0] - wq.frac_bits - iq.frac_bits))
                    as f32;
                let got = o.data[r * n + c];
                let tol = expect.abs() as f64 * 1e-3 + 1e-44;
                assert!(
                    ((got - expect) as f64).abs() <= tol,
                    "O[{r},{c}] = {got:e} vs {expect:e}"
                );
            }
        }
    }

    /// With an overflowing block-exponent sum and a fully cancelled
    /// accumulator, the old `acc * f32::INFINITY` rescale produced NaN;
    /// the f64 path must yield an exact 0.
    #[test]
    fn overflowing_scale_with_cancellation_is_zero_not_nan() {
        use crate::bfp::format::exp2i;
        let fmt = BfpFormat::new(8);
        // row [2^105, -2^105] against identical input rows ⇒ acc = 0, but
        // the combined scale 2^(105+39-12) = 2^132 overflows f32.
        let w = vec![exp2i(105), -exp2i(105)];
        let i = vec![exp2i(39), exp2i(39), exp2i(39), exp2i(39)];
        let wq = BfpMatrix::quantize(&w, 1, 2, fmt, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&i, 2, 2, fmt, BlockAxis::Whole);
        let o = bfp_gemm(&wq, &iq);
        for &x in &o.data {
            assert!(x == 0.0, "cancelled overflow-scale output must be 0, got {x}");
        }
    }

    /// Pre-packed weight panels and caller-owned scratch must reproduce
    /// the plain entry point bit-for-bit.
    #[test]
    fn prepacked_weights_match_plain_path() {
        let (m, k, n) = (6, 40, 11);
        let w = mat(8, m * k, 1.5);
        let i = mat(9, k * n, 3.0);
        let fmt = BfpFormat::new(8);
        let wq = BfpMatrix::quantize(&w, m, k, fmt, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&i, k, n, fmt, BlockAxis::Whole);
        assert!(f32_lane_chunk(wq.frac_bits, iq.frac_bits).is_some());
        let plain = bfp_gemm(&wq, &iq).data;
        let packed = pack_mantissas(&wq);
        let mut out = vec![0f32; m * n];
        let mut scratch = GemmScratch::default();
        bfp_gemm_into_prepared(&wq, Some(&packed), &iq, &mut out, &mut scratch);
        for (a, b) in plain.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // scratch reuse across differently-shaped calls must not leak
        let wq2 = BfpMatrix::quantize(&mat(10, 3 * 5, 1.0), 3, 5, fmt, BlockAxis::PerRow);
        let iq2 = BfpMatrix::quantize(&mat(11, 5 * 2, 1.0), 5, 2, fmt, BlockAxis::Whole);
        let mut out2 = vec![0f32; 3 * 2];
        bfp_gemm_into_prepared(&wq2, None, &iq2, &mut out2, &mut scratch);
        let fresh = bfp_gemm(&wq2, &iq2).data;
        for (a, b) in fresh.iter().zip(&out2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    #[should_panic]
    fn rejects_mismatched_inner_dim() {
        let fmt = BfpFormat::new(8);
        let wq = BfpMatrix::quantize(&[1.0; 6], 2, 3, fmt, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&[1.0; 8], 4, 2, fmt, BlockAxis::Whole);
        bfp_gemm(&wq, &iq);
    }
}
