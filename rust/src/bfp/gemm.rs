//! The Figure 2 data flow: fixed-point GEMM over block-formatted matrices.
//!
//! `O = W'·I'` is computed entirely in the integer domain:
//! `M'_O = M'_W · M'_I` with `ε_O = ε_W + ε_I` per block pair. §3.4 gives
//! the bit-width rules that make the integer MAC *exact* (no rounding
//! inside the accumulation):
//!
//! * multiplier width ≥ `L_W + L_I + 2` bits (incl. sign),
//! * accumulator width ≥ `L_W + L_I + 2 + ⌊log2 K⌋` bits.
//!
//! [`crate::quant::widths`] plans those widths; this module picks an
//! `i32` or `i64` accumulator lane accordingly and the result is bit-exact
//! against an arbitrary-precision reference (see the proptests).

use super::format::exp2i;
use super::partition::{BfpMatrix, BlockAxis};

/// Result of a BFP GEMM: f32 output plus the bookkeeping the error
/// analysis wants (block exponents actually used).
#[derive(Debug, Clone)]
pub struct BfpGemmOutput {
    pub rows: usize,
    pub cols: usize,
    /// Row-major f32 reconstruction of `O ≈ W·I`.
    pub data: Vec<f32>,
}

/// Fixed-point GEMM `O = W'·I'` between two quantized matrices.
///
/// `w` is `M×K`, `i` is `K×N`. Any combination of block axes is accepted
/// as long as the scale of a product term depends only on `(row, col)` of
/// the output — i.e. `w` is `Whole`/`PerRow` and `i` is `Whole`/`PerCol`,
/// which covers all four schemes of §3.3 (for eq. 3 the per-row /
/// per-column vectors are exactly the inner-product operands).
pub fn bfp_gemm(w: &BfpMatrix, i: &BfpMatrix) -> BfpGemmOutput {
    let mut out = vec![0f32; w.rows * i.cols];
    bfp_gemm_into(w, i, &mut out);
    BfpGemmOutput { rows: w.rows, cols: i.cols, data: out }
}

/// [`bfp_gemm`] writing into a caller-provided buffer (hot path).
pub fn bfp_gemm_into(w: &BfpMatrix, i: &BfpMatrix, out: &mut [f32]) {
    assert_eq!(w.cols, i.rows, "GEMM inner dimension mismatch");
    assert!(
        !matches!(w.axis, BlockAxis::PerCol),
        "weight matrix must be blocked Whole or PerRow (schemes eq2–eq5)"
    );
    assert!(
        !matches!(i.axis, BlockAxis::PerRow),
        "input matrix must be blocked Whole or PerCol (schemes eq2–eq5)"
    );
    let (m, k, n) = (w.rows, w.cols, i.cols);
    assert_eq!(out.len(), m * n);

    // §3.4 width plan: products fit in lw+li+2 bits, sums add ⌊log2 K⌋.
    // Mantissa magnitudes are < 2^(frac_bits+1).
    let prod_bits = (w.frac_bits + 1) + (i.frac_bits + 1) + 1;
    let acc_bits = prod_bits + (usize::BITS - k.leading_zeros()) as i32;
    // Fast path (§Perf): integer-valued f32 mantissa GEMM. A product of
    // two mantissas is ≤ 2^(prod_bits-1) and stays exact in f32; partial
    // sums over a K-chunk stay exact while they remain ≤ 2^24; chunk sums
    // are then accumulated in f64 (integers exact to 2^53). FMA-friendly
    // f32 lanes beat the i32 multiply (vpmulld) substantially — see
    // EXPERIMENTS.md §Perf — while remaining bit-exact.
    let max_prod = 1i64 << (prod_bits - 1).min(62);
    let chunk = ((1i64 << 24) / max_prod.max(1)) as usize;
    if chunk >= 32 {
        gemm_f32_mantissa(w, i, out, m, k, n, chunk);
    } else if acc_bits <= 31 {
        gemm_lanes::<i32>(w, i, out, m, k, n);
    } else {
        gemm_lanes::<i64>(w, i, out, m, k, n);
    }
}

/// Exact f32-mantissa GEMM with chunked-K f64 accumulation (see the
/// exactness argument at the call site). Mantissas are materialised as
/// f32 once per call; the inner loops are plain f32 MACs that the
/// auto-vectorizer turns into FMA lanes.
fn gemm_f32_mantissa(w: &BfpMatrix, i: &BfpMatrix, out: &mut [f32], m: usize, k: usize, n: usize, chunk: usize) {
    let zero_exp_floor = i32::MIN / 4;
    let wf: Vec<f32> = w.mantissas.iter().map(|&v| v as f32).collect();
    let if_: Vec<f32> = i.mantissas.iter().map(|&v| v as f32).collect();
    let single_chunk = k <= chunk;
    let mut acc32 = vec![0f32; n];
    let mut acc64 = vec![0f64; if single_chunk { 0 } else { n }];
    for r in 0..m {
        let wrow = &wf[r * k..(r + 1) * k];
        if single_chunk {
            // common case: the whole K panel stays exact in f32
            acc32.fill(0.0);
            for (kk, &wv) in wrow.iter().enumerate() {
                if wv == 0.0 {
                    continue;
                }
                let irow = &if_[kk * n..(kk + 1) * n];
                for (a, &iv) in acc32.iter_mut().zip(irow) {
                    *a += wv * iv;
                }
            }
        } else {
            acc64.fill(0.0);
            let mut k0 = 0usize;
            while k0 < k {
                let k1 = (k0 + chunk).min(k);
                acc32.fill(0.0);
                for kk in k0..k1 {
                    let wv = wrow[kk];
                    if wv == 0.0 {
                        continue;
                    }
                    let irow = &if_[kk * n..(kk + 1) * n];
                    for (a, &iv) in acc32.iter_mut().zip(irow) {
                        *a += wv * iv;
                    }
                }
                for (a64, &a32) in acc64.iter_mut().zip(&acc32) {
                    *a64 += a32 as f64;
                }
                k0 = k1;
            }
        }
        let we = match w.axis {
            BlockAxis::Whole => w.exponents[0],
            BlockAxis::PerRow => w.exponents[r],
            BlockAxis::PerCol => unreachable!(),
        };
        let orow = &mut out[r * n..(r + 1) * n];
        if we <= zero_exp_floor {
            orow.fill(0.0);
            continue;
        }
        match i.axis {
            BlockAxis::Whole => {
                let ie = i.exponents[0];
                let scale = if ie <= zero_exp_floor {
                    0.0
                } else {
                    exp2i(we + ie - w.frac_bits - i.frac_bits) as f64
                };
                if single_chunk {
                    let s32 = scale as f32;
                    for (o, &a) in orow.iter_mut().zip(&acc32) {
                        *o = a * s32;
                    }
                } else {
                    for (o, &a) in orow.iter_mut().zip(&acc64) {
                        *o = (a * scale) as f32;
                    }
                }
            }
            BlockAxis::PerCol => {
                for (j, (o, &ie)) in orow.iter_mut().zip(&i.exponents).enumerate() {
                    let a = if single_chunk { acc32[j] as f64 } else { acc64[j] };
                    *o = if ie <= zero_exp_floor {
                        0.0
                    } else {
                        (a * exp2i(we + ie - w.frac_bits - i.frac_bits) as f64) as f32
                    };
                }
            }
            BlockAxis::PerRow => unreachable!(),
        }
    }
}

/// Integer accumulator lane abstraction (i32 fast path / i64 wide path).
trait AccLane: Copy + Default + std::ops::AddAssign {
    fn mul(a: i32, b: i32) -> Self;
    fn to_f32(self) -> f32;
}
impl AccLane for i32 {
    #[inline(always)]
    fn mul(a: i32, b: i32) -> Self {
        a * b
    }
    #[inline(always)]
    fn to_f32(self) -> f32 {
        self as f32
    }
}
impl AccLane for i64 {
    #[inline(always)]
    fn mul(a: i32, b: i32) -> Self {
        a as i64 * b as i64
    }
    #[inline(always)]
    fn to_f32(self) -> f32 {
        self as f32
    }
}

fn gemm_lanes<A: AccLane>(w: &BfpMatrix, i: &BfpMatrix, out: &mut [f32], m: usize, k: usize, n: usize) {
    let zero_exp_floor = i32::MIN / 4;
    // Accumulate one output row at a time in integer lanes (ikj order —
    // streams through I row-major, vectorizes the inner j loop).
    let mut acc: Vec<A> = vec![A::default(); n];
    for r in 0..m {
        for a in acc.iter_mut() {
            *a = A::default();
        }
        let wrow = &w.mantissas[r * k..(r + 1) * k];
        for (kk, &wv) in wrow.iter().enumerate() {
            if wv == 0 {
                continue;
            }
            let irow = &i.mantissas[kk * n..(kk + 1) * n];
            for (a, &iv) in acc.iter_mut().zip(irow) {
                *a += A::mul(wv, iv);
            }
        }
        // Rescale: ε_O = ε_W(row) + ε_I(col); frac bits add.
        let we = match w.axis {
            BlockAxis::Whole => w.exponents[0],
            BlockAxis::PerRow => w.exponents[r],
            BlockAxis::PerCol => unreachable!(),
        };
        let orow = &mut out[r * n..(r + 1) * n];
        if we <= zero_exp_floor {
            orow.fill(0.0);
            continue;
        }
        match i.axis {
            BlockAxis::Whole => {
                let ie = i.exponents[0];
                let scale = if ie <= zero_exp_floor {
                    0.0
                } else {
                    exp2i(we + ie - w.frac_bits - i.frac_bits)
                };
                for (o, a) in orow.iter_mut().zip(&acc) {
                    *o = a.to_f32() * scale;
                }
            }
            BlockAxis::PerCol => {
                for ((o, a), &ie) in orow.iter_mut().zip(&acc).zip(&i.exponents) {
                    *o = if ie <= zero_exp_floor {
                        0.0
                    } else {
                        a.to_f32() * exp2i(we + ie - w.frac_bits - i.frac_bits)
                    };
                }
            }
            BlockAxis::PerRow => unreachable!(),
        }
    }
}

/// Plain f32 GEMM reference (`O = W·I`), used as the "floating point"
/// baseline throughout the experiments.
pub fn f32_gemm(w: &[f32], i: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(w.len(), m * k);
    assert_eq!(i.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for r in 0..m {
        let wrow = &w[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        for (kk, &wv) in wrow.iter().enumerate() {
            if wv == 0.0 {
                continue;
            }
            let irow = &i[kk * n..(kk + 1) * n];
            for (o, &iv) in orow.iter_mut().zip(irow) {
                *o += wv * iv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::format::BfpFormat;
    use crate::bfp::partition::PartitionScheme;

    fn mat(seed: u64, len: usize, scale: f32) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 11) as f32 / (1u64 << 53) as f32 * 2.0 - 0.5) * scale
            })
            .collect()
    }

    /// §3.4 worked example: O = W'·I' with the paper's 4-bit blocks.
    #[test]
    fn paper_worked_example_product() {
        let fmt = BfpFormat::new(4);
        let w = BfpMatrix::quantize(&[0.5, 1.25], 1, 2, fmt, BlockAxis::PerRow);
        let i = BfpMatrix::quantize(&[1.25, 1.25, 2.5, 5.0], 2, 2, fmt, BlockAxis::Whole);
        // mantissas: W=(2,5) ε=0 f=2; I=((1,1),(3,5)) ε=2 f=2
        // integer O = (2·1+5·3, 2·1+5·5) = (17, 27); scale 2^(0+2-2-2)=2^-2
        let o = bfp_gemm(&w, &i);
        assert_eq!(o.data, vec![17.0 / 4.0, 27.0 / 4.0]);
    }

    #[test]
    fn bfp_gemm_approximates_f32_gemm() {
        let (m, k, n) = (8, 32, 16);
        let w = mat(1, m * k, 2.0);
        let i = mat(2, k * n, 4.0);
        let mut exact = vec![0f32; m * n];
        f32_gemm(&w, &i, m, k, n, &mut exact);
        for scheme in [PartitionScheme::Eq2, PartitionScheme::Eq3, PartitionScheme::Eq4, PartitionScheme::Eq5] {
            let fmt = BfpFormat::new(12);
            let wq = BfpMatrix::quantize(&w, m, k, fmt, scheme.w_axis());
            let iq = BfpMatrix::quantize(&i, k, n, fmt, scheme.i_axis());
            let o = bfp_gemm(&wq, &iq);
            let energy: f64 = exact.iter().map(|x| (*x as f64).powi(2)).sum();
            let err: f64 = exact.iter().zip(&o.data).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            assert!(err / energy < 1e-4, "scheme {scheme:?}: NSR {}", err / energy);
        }
    }

    /// The integer MAC must be *exact*: dequantized GEMM of the quantized
    /// matrices equals f32 GEMM of the dequantized matrices (products are
    /// representable, f32 sums of integer-valued terms < 2^24 are exact).
    #[test]
    fn fixed_point_mac_is_exact() {
        let (m, k, n) = (4, 9, 7);
        let w = mat(3, m * k, 1.0);
        let i = mat(4, k * n, 8.0);
        let fmt = BfpFormat::new(8);
        let wq = BfpMatrix::quantize(&w, m, k, fmt, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&i, k, n, fmt, BlockAxis::Whole);
        let o = bfp_gemm(&wq, &iq);
        let wd = wq.to_f32();
        let id = iq.to_f32();
        let mut expect = vec![0f32; m * n];
        f32_gemm(&wd, &id, m, k, n, &mut expect);
        for (a, b) in o.data.iter().zip(&expect) {
            assert_eq!(a, b, "fixed-point GEMM must be bit-exact");
        }
    }

    #[test]
    fn wide_accumulator_path() {
        // Force acc_bits > 31: wide mantissas + large K.
        let (m, k, n) = (2, 5000, 3);
        let w = mat(5, m * k, 1.0);
        let i = mat(6, k * n, 1.0);
        let fmt = BfpFormat::new(16);
        let wq = BfpMatrix::quantize(&w, m, k, fmt, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&i, k, n, fmt, BlockAxis::Whole);
        let o = bfp_gemm(&wq, &iq);
        let mut exact = vec![0f32; m * n];
        f32_gemm(&w, &i, m, k, n, &mut exact);
        for (a, b) in o.data.iter().zip(&exact) {
            assert!((a - b).abs() / (b.abs() + 1.0) < 1e-3);
        }
    }

    #[test]
    fn zero_weight_matrix_gives_zero_output() {
        let fmt = BfpFormat::new(8);
        let wq = BfpMatrix::quantize(&[0.0; 6], 2, 3, fmt, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&mat(7, 12, 1.0), 3, 4, fmt, BlockAxis::Whole);
        let o = bfp_gemm(&wq, &iq);
        assert!(o.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn rejects_mismatched_inner_dim() {
        let fmt = BfpFormat::new(8);
        let wq = BfpMatrix::quantize(&[1.0; 6], 2, 3, fmt, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&[1.0; 8], 4, 2, fmt, BlockAxis::Whole);
        bfp_gemm(&wq, &iq);
    }
}
