//! Pooling operators (single image, CHW) with symmetric zero padding.
//!
//! Max pooling treats padded cells as absent (−∞), average pooling counts
//! only valid cells — matching Caffe's semantics, which the paper's
//! experiments ran on.

use super::tensor::Tensor;

/// Max pooling with square window `k`, stride `s`, symmetric padding `p`.
pub fn max_pool2d(img: &Tensor, k: usize, s: usize, p: usize) -> Tensor {
    pool2d(img, k, s, p, true)
}

/// Average pooling with square window `k`, stride `s`, padding `p`
/// (padded cells excluded from the mean).
pub fn avg_pool2d(img: &Tensor, k: usize, s: usize, p: usize) -> Tensor {
    pool2d(img, k, s, p, false)
}

fn pool2d(img: &Tensor, k: usize, s: usize, p: usize, is_max: bool) -> Tensor {
    assert_eq!(img.ndim(), 3, "pool2d expects [C,H,W]");
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    assert!(h + 2 * p >= k && w + 2 * p >= k, "pool window {k} larger than padded input {h}x{w}+{p}");
    let oh = (h + 2 * p - k) / s + 1;
    let ow = (w + 2 * p - k) / s + 1;
    let mut out = Tensor::zeros(&[c, oh, ow]);
    for ch in 0..c {
        let plane = &img.data[ch * h * w..(ch + 1) * h * w];
        let oplane = &mut out.data[ch * oh * ow..(ch + 1) * oh * ow];
        for oy in 0..oh {
            for ox in 0..ow {
                let y0 = (oy * s) as isize - p as isize;
                let x0 = (ox * s) as isize - p as isize;
                let mut acc = if is_max { f32::NEG_INFINITY } else { 0.0 };
                let mut count = 0usize;
                for ky in 0..k as isize {
                    let iy = y0 + ky;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k as isize {
                        let ix = x0 + kx;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let v = plane[iy as usize * w + ix as usize];
                        if is_max {
                            acc = acc.max(v);
                        } else {
                            acc += v;
                        }
                        count += 1;
                    }
                }
                oplane[oy * ow + ox] = if is_max {
                    if count == 0 {
                        0.0
                    } else {
                        acc
                    }
                } else if count == 0 {
                    0.0
                } else {
                    acc / count as f32
                };
            }
        }
    }
    out
}

/// Global average pooling: `[C,H,W] -> [C]`.
pub fn global_avg_pool(img: &Tensor) -> Tensor {
    assert_eq!(img.ndim(), 3);
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    let mut out = Tensor::zeros(&[c]);
    for ch in 0..c {
        let plane = &img.data[ch * h * w..(ch + 1) * h * w];
        out.data[ch] = plane.iter().sum::<f32>() / (h * w) as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_pool_2x2_stride2() {
        let img = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6., 7., 8., 9., 10., 11., 12., 13., 14., 15., 16.], &[1, 4, 4]);
        let out = max_pool2d(&img, 2, 2, 0);
        assert_eq!(out.shape, vec![1, 2, 2]);
        assert_eq!(out.data, vec![6., 8., 14., 16.]);
    }

    #[test]
    fn avg_pool_2x2_stride2() {
        let img = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6., 7., 8., 9., 10., 11., 12., 13., 14., 15., 16.], &[1, 4, 4]);
        let out = avg_pool2d(&img, 2, 2, 0);
        assert_eq!(out.data, vec![3.5, 5.5, 11.5, 13.5]);
    }

    #[test]
    fn pool_multi_channel() {
        let mut data = vec![0.0; 2 * 4 * 4];
        data[0] = 5.0;
        data[16 + 5] = 7.0;
        let img = Tensor::from_vec(data, &[2, 4, 4]);
        let out = max_pool2d(&img, 2, 2, 0);
        assert_eq!(out.data[0], 5.0);
        assert_eq!(out.data[4], 7.0);
    }

    #[test]
    fn padded_max_pool_keeps_spatial_dims() {
        // 3×3 window, stride 1, pad 1 — the inception pool-proj branch.
        let img = Tensor::from_vec((0..16).map(|x| x as f32).collect(), &[1, 4, 4]);
        let out = max_pool2d(&img, 3, 1, 1);
        assert_eq!(out.shape, vec![1, 4, 4]);
        assert_eq!(out.data[0], 5.0); // max of the valid 2×2 corner
        assert_eq!(out.data[15], 15.0);
    }

    #[test]
    fn padded_avg_counts_valid_only() {
        let img = Tensor::from_vec(vec![4.0; 4], &[1, 2, 2]);
        let out = avg_pool2d(&img, 3, 1, 1);
        // every window sees only 4.0s, so the mean must be exactly 4.0
        assert_eq!(out.shape, vec![1, 2, 2]);
        assert!(out.data.iter().all(|&v| v == 4.0));
    }

    #[test]
    fn stem_pool_3x3_s2_p1() {
        // ResNet/GoogLeNet stem: 8×8 → 4×4
        let img = Tensor::from_vec((0..64).map(|x| x as f32).collect(), &[1, 8, 8]);
        let out = max_pool2d(&img, 3, 2, 1);
        assert_eq!(out.shape, vec![1, 4, 4]);
        assert_eq!(out.data[0], 9.0); // window over rows 0..2, cols 0..2
    }

    #[test]
    fn pool_stride1_overlapping() {
        let img = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6., 7., 8., 9.], &[1, 3, 3]);
        let out = max_pool2d(&img, 2, 1, 0);
        assert_eq!(out.shape, vec![1, 2, 2]);
        assert_eq!(out.data, vec![5., 6., 8., 9.]);
    }

    #[test]
    fn global_avg() {
        let img = Tensor::from_vec(vec![1., 2., 3., 4., 10., 10., 10., 10.], &[2, 2, 2]);
        let out = global_avg_pool(&img);
        assert_eq!(out.data, vec![2.5, 10.0]);
    }
}
