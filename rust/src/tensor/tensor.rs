//! Dense row-major tensor with lightweight shape bookkeeping.


/// A dense f32 tensor, row-major (last axis fastest). CNN code uses the
/// NCHW convention: `[batch, channels, height, width]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    /// A tensor of zeros with the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        Self { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    /// Wrap existing data; panics if the element count mismatches.
    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data length {} != shape {:?}",
            data.len(),
            shape
        );
        Self { data, shape: shape.to_vec() }
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of axes.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Axis length with bounds check.
    pub fn dim(&self, axis: usize) -> usize {
        self.shape[axis]
    }

    /// Reshape in place (element count must be preserved).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.len(), shape.iter().product::<usize>(), "reshape changes element count");
        self.shape = shape.to_vec();
        self
    }

    /// Mean of squares — the signal energy `E(Y²)` used throughout §4.
    pub fn mean_square(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / self.data.len() as f64
    }

    /// Sum of squares.
    pub fn energy(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
    }

    /// Largest |x|.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// View of batch element `b` of an N≥1-dim tensor (first axis = batch).
    pub fn batch(&self, b: usize) -> &[f32] {
        let per: usize = self.shape[1..].iter().product();
        &self.data[b * per..(b + 1) * per]
    }

    /// Argmax over the last axis for each row of a 2-D `[batch, classes]`
    /// tensor — top-1 predictions.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2, "argmax_rows expects [batch, classes]");
        let classes = self.shape[1];
        self.data
            .chunks_exact(classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.ndim(), 3);
        assert_eq!(t.dim(1), 3);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_length() {
        Tensor::from_vec(vec![1.0; 5], &[2, 3]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec((0..6).map(|x| x as f32).collect(), &[2, 3]);
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.data, t.data);
        assert_eq!(r.shape, vec![3, 2]);
    }

    #[test]
    fn energy_and_mean_square() {
        let t = Tensor::from_vec(vec![1.0, -2.0, 2.0], &[3]);
        assert_eq!(t.energy(), 9.0);
        assert_eq!(t.mean_square(), 3.0);
        assert_eq!(t.max_abs(), 2.0);
    }

    #[test]
    fn batch_views() {
        let t = Tensor::from_vec((0..12).map(|x| x as f32).collect(), &[2, 2, 3]);
        assert_eq!(t.batch(0), &[0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.batch(1), &[6., 7., 8., 9., 10., 11.]);
    }

    #[test]
    fn argmax_rows_basic() {
        let t = Tensor::from_vec(vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0], &[2, 3]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }
}
