//! Minimal dense tensor substrate (NCHW) for the CNN inference stack.
//!
//! * [`tensor`] — the [`Tensor`] container with shape/stride bookkeeping.
//! * [`im2col`] — the Figure 1 transformation: convolution as GEMM.
//! * [`pool`] — max / average pooling windows.

pub mod im2col;
pub mod pool;
#[allow(clippy::module_inception)]
pub mod tensor;

pub use im2col::{im2col, im2col_tile, im2col_whole_exponent, Conv2dGeometry};
pub use pool::{avg_pool2d, global_avg_pool, max_pool2d};
pub use tensor::Tensor;
