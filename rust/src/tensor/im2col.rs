//! The Figure 1 transformation: expand convolution into matrix
//! multiplication.
//!
//! Kernels of one output channel form a row of `W_{M×K}`; the receptive
//! field of each output pixel forms a column of `I_{K×N}` with
//! `M = out_channels`, `K = in_channels·kh·kw`, `N = out_h·out_w`.

use super::tensor::Tensor;

/// Geometry of a 2-D convolution (single image; batching is handled a
/// level up).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dGeometry {
    pub in_channels: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub kernel_h: usize,
    pub kernel_w: usize,
    pub stride: usize,
    pub padding: usize,
}

impl Conv2dGeometry {
    /// Output spatial height.
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.padding - self.kernel_h) / self.stride + 1
    }

    /// Output spatial width.
    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.padding - self.kernel_w) / self.stride + 1
    }

    /// GEMM inner dimension `K = C·kh·kw`.
    pub fn k(&self) -> usize {
        self.in_channels * self.kernel_h * self.kernel_w
    }

    /// GEMM output columns `N = out_h·out_w`.
    pub fn n(&self) -> usize {
        self.out_h() * self.out_w()
    }
}

/// Expand one CHW image into the `K×N` im2col matrix (row-major).
///
/// Rows iterate `(channel, kernel_row, kernel_col)`, columns iterate
/// output pixels `(oy, ox)` — the layout of Figure 1.
pub fn im2col(img: &[f32], geo: &Conv2dGeometry, out: &mut [f32]) {
    let (c, h, w) = (geo.in_channels, geo.in_h, geo.in_w);
    assert_eq!(img.len(), c * h * w, "image size mismatch");
    let (oh, ow) = (geo.out_h(), geo.out_w());
    let n = oh * ow;
    assert_eq!(out.len(), geo.k() * n, "im2col buffer size mismatch");
    let pad = geo.padding as isize;
    let stride = geo.stride as isize;
    let mut row = 0usize;
    for ch in 0..c {
        let plane = &img[ch * h * w..(ch + 1) * h * w];
        for ky in 0..geo.kernel_h {
            for kx in 0..geo.kernel_w {
                let dst = &mut out[row * n..(row + 1) * n];
                let mut idx = 0usize;
                for oy in 0..oh {
                    let iy = oy as isize * stride - pad + ky as isize;
                    if iy < 0 || iy >= h as isize {
                        dst[idx..idx + ow].fill(0.0);
                        idx += ow;
                        continue;
                    }
                    let src_row = &plane[iy as usize * w..(iy as usize + 1) * w];
                    for ox in 0..ow {
                        let ix = ox as isize * stride - pad + kx as isize;
                        dst[idx] = if ix < 0 || ix >= w as isize { 0.0 } else { src_row[ix as usize] };
                        idx += 1;
                    }
                }
                row += 1;
            }
        }
    }
}

/// Emit columns `[col0, col0 + ncols)` of the im2col matrix as a
/// `K×ncols` row-major tile — the fused activation pipeline
/// ([`crate::bfp::kernel::ActPanels::pack_im2col`]) walks the matrix in
/// `NC`-wide tiles instead of materialising the full `K×N` buffer.
/// Tiling the column range produces exactly the columns [`im2col`]
/// produces (tested below), just without the footprint.
pub fn im2col_tile(img: &[f32], geo: &Conv2dGeometry, col0: usize, ncols: usize, out: &mut [f32]) {
    let _span = crate::obs::span(crate::obs::Stage::Im2col);
    let (c, h, w) = (geo.in_channels, geo.in_h, geo.in_w);
    assert_eq!(img.len(), c * h * w, "image size mismatch");
    let (oh, ow) = (geo.out_h(), geo.out_w());
    assert!(col0 + ncols <= oh * ow, "column tile out of range");
    assert_eq!(out.len(), geo.k() * ncols, "im2col tile buffer size mismatch");
    let pad = geo.padding as isize;
    let stride = geo.stride as isize;
    let mut row = 0usize;
    for ch in 0..c {
        let plane = &img[ch * h * w..(ch + 1) * h * w];
        for ky in 0..geo.kernel_h {
            for kx in 0..geo.kernel_w {
                let dst = &mut out[row * ncols..(row + 1) * ncols];
                // walk the tile as runs of contiguous ox within one oy
                let mut idx = 0usize;
                let mut col = col0;
                while idx < ncols {
                    let (oy, ox0) = (col / ow, col % ow);
                    let run = (ow - ox0).min(ncols - idx);
                    let iy = oy as isize * stride - pad + ky as isize;
                    if iy < 0 || iy >= h as isize {
                        dst[idx..idx + run].fill(0.0);
                    } else {
                        let src_row = &plane[iy as usize * w..(iy as usize + 1) * w];
                        for (o, ox) in dst[idx..idx + run].iter_mut().zip(ox0..ox0 + run) {
                            let ix = ox as isize * stride - pad + kx as isize;
                            *o = if ix < 0 || ix >= w as isize { 0.0 } else { src_row[ix as usize] };
                        }
                    }
                    idx += run;
                    col += run;
                }
                row += 1;
            }
        }
    }
}

/// The whole-matrix block exponent of the im2col expansion, computed
/// from the *source image* without materialising the matrix.
///
/// Every im2col entry is either a pixel whose spatial coordinates are
/// covered by at least one receptive field, or a padding zero — and
/// zeros never raise a block maximum. The maximum is insensitive to the
/// duplication im2col introduces, so scanning each covered pixel once
/// yields bit-identically the same exponent as
/// `max_exponent(full im2col matrix)` (tested below, including
/// geometries whose stride skips pixels). This is what lets the fused
/// quantize-while-packing pipeline know the eq. (2)/(4) `Whole`-axis
/// exponent before the first tile is emitted.
pub fn im2col_whole_exponent(img: &[f32], geo: &Conv2dGeometry) -> Option<i32> {
    let (c, h, w) = (geo.in_channels, geo.in_h, geo.in_w);
    assert_eq!(img.len(), c * h * w, "image size mismatch");
    let (oh, ow) = (geo.out_h(), geo.out_w());
    let pad = geo.padding as isize;
    let stride = geo.stride as isize;
    // spatial coverage masks: is row iy / col ix read by any field tap?
    let mut cov_y = vec![false; h];
    for oy in 0..oh {
        for ky in 0..geo.kernel_h {
            let iy = oy as isize * stride - pad + ky as isize;
            if iy >= 0 && iy < h as isize {
                cov_y[iy as usize] = true;
            }
        }
    }
    let mut cov_x = vec![false; w];
    for ox in 0..ow {
        for kx in 0..geo.kernel_w {
            let ix = ox as isize * stride - pad + kx as isize;
            if ix >= 0 && ix < w as isize {
                cov_x[ix as usize] = true;
            }
        }
    }
    // same max-|payload-bits| scan as `bfp::quantize::max_exponent`
    let mut max_abs_bits: u32 = 0;
    for ch in 0..c {
        let plane = &img[ch * h * w..(ch + 1) * h * w];
        for (iy, &cy) in cov_y.iter().enumerate() {
            if !cy {
                continue;
            }
            let row = &plane[iy * w..(iy + 1) * w];
            for (&v, &cx) in row.iter().zip(&cov_x) {
                if cx && v.is_finite() {
                    let b = v.to_bits() & 0x7FFF_FFFF;
                    if b > max_abs_bits {
                        max_abs_bits = b;
                    }
                }
            }
        }
    }
    if max_abs_bits == 0 {
        None
    } else {
        crate::bfp::exponent_of(f32::from_bits(max_abs_bits))
    }
}

/// Direct (naive) convolution reference used to validate `im2col`+GEMM.
pub fn direct_conv2d(
    img: &Tensor, // [C, H, W]
    weights: &Tensor, // [M, C, kh, kw]
    bias: Option<&[f32]>,
    stride: usize,
    padding: usize,
) -> Tensor {
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    let (m, wc, kh, kw) = (weights.shape[0], weights.shape[1], weights.shape[2], weights.shape[3]);
    assert_eq!(c, wc);
    let geo = Conv2dGeometry { in_channels: c, in_h: h, in_w: w, kernel_h: kh, kernel_w: kw, stride, padding };
    let (oh, ow) = (geo.out_h(), geo.out_w());
    let mut out = Tensor::zeros(&[m, oh, ow]);
    for oc in 0..m {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = bias.map(|b| b[oc]).unwrap_or(0.0);
                for ic in 0..c {
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * stride + ky) as isize - padding as isize;
                            let ix = (ox * stride + kx) as isize - padding as isize;
                            if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                                let iv = img.data[(ic * h + iy as usize) * w + ix as usize];
                                let wv = weights.data[((oc * c + ic) * kh + ky) * kw + kx];
                                acc += iv * wv;
                            }
                        }
                    }
                }
                out.data[(oc * oh + oy) * ow + ox] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::gemm::f32_gemm;

    fn seq(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.37).sin()).collect()
    }

    #[test]
    fn geometry_basics() {
        let g = Conv2dGeometry { in_channels: 3, in_h: 224, in_w: 224, kernel_h: 3, kernel_w: 3, stride: 1, padding: 1 };
        assert_eq!(g.out_h(), 224);
        assert_eq!(g.out_w(), 224);
        assert_eq!(g.k(), 27);
        assert_eq!(g.n(), 224 * 224);
    }

    /// Figure 1's example: 3×3 input, 2×2 kernel, no padding, stride 1.
    #[test]
    fn figure1_layout() {
        let img = [1., 2., 3., 4., 5., 6., 7., 8., 9.];
        let geo = Conv2dGeometry { in_channels: 1, in_h: 3, in_w: 3, kernel_h: 2, kernel_w: 2, stride: 1, padding: 0 };
        let mut col = vec![0f32; geo.k() * geo.n()];
        im2col(&img, &geo, &mut col);
        // K=4 rows (k00,k01,k10,k11) × N=4 receptive fields
        assert_eq!(col, vec![
            1., 2., 4., 5., // kernel (0,0) over the 4 fields
            2., 3., 5., 6.,
            4., 5., 7., 8.,
            5., 6., 8., 9.,
        ]);
    }

    #[test]
    fn im2col_gemm_matches_direct_conv() {
        for (c, h, w, m, k, stride, pad) in
            [(1, 5, 5, 2, 3, 1, 0), (3, 8, 8, 4, 3, 1, 1), (2, 9, 7, 3, 3, 2, 1), (4, 6, 6, 5, 1, 1, 0)]
        {
            let img = Tensor::from_vec(seq(c * h * w), &[c, h, w]);
            let wt = Tensor::from_vec(seq(m * c * k * k), &[m, c, k, k]);
            let geo = Conv2dGeometry { in_channels: c, in_h: h, in_w: w, kernel_h: k, kernel_w: k, stride, padding: pad };
            let mut col = vec![0f32; geo.k() * geo.n()];
            im2col(&img.data, &geo, &mut col);
            let mut out = vec![0f32; m * geo.n()];
            f32_gemm(&wt.data, &col, m, geo.k(), geo.n(), &mut out);
            let reference = direct_conv2d(&img, &wt, None, stride, pad);
            for (a, b) in out.iter().zip(&reference.data) {
                assert!((a - b).abs() < 1e-4, "conv mismatch: {a} vs {b} (c={c},h={h},stride={stride},pad={pad})");
            }
        }
    }

    /// Tiled emission must reproduce the corresponding column range of
    /// the full im2col matrix exactly, for every tile width and offset —
    /// including tiles that straddle output-row boundaries.
    #[test]
    fn tile_emission_matches_full_matrix() {
        for (c, h, w, kh, kw, stride, pad) in
            [(1usize, 5, 5, 3, 3, 1, 0), (3, 8, 7, 3, 3, 1, 1), (2, 9, 7, 2, 3, 2, 1), (1, 6, 6, 1, 1, 3, 0)]
        {
            let img = seq(c * h * w);
            let geo = Conv2dGeometry { in_channels: c, in_h: h, in_w: w, kernel_h: kh, kernel_w: kw, stride, padding: pad };
            let (k, n) = (geo.k(), geo.n());
            let mut full = vec![0f32; k * n];
            im2col(&img, &geo, &mut full);
            for tile_w in [1usize, 3, 7, n] {
                let mut c0 = 0usize;
                while c0 < n {
                    let cw = tile_w.min(n - c0);
                    let mut tile = vec![9f32; k * cw];
                    im2col_tile(&img, &geo, c0, cw, &mut tile);
                    for r in 0..k {
                        assert_eq!(
                            &tile[r * cw..(r + 1) * cw],
                            &full[r * n + c0..r * n + c0 + cw],
                            "row {r} cols [{c0}, {})", c0 + cw
                        );
                    }
                    c0 += cw;
                }
            }
        }
    }

    /// The coverage-based whole-matrix exponent must equal the scan of
    /// the materialised matrix bit-for-bit — including geometries whose
    /// stride leaves pixels unread (their values must not leak into the
    /// block exponent) and all-padding/all-zero cases.
    #[test]
    fn whole_exponent_matches_materialized_scan() {
        use crate::bfp::max_exponent;
        for (c, h, w, kh, kw, stride, pad) in [
            (1usize, 5, 5, 3, 3, 1, 0),
            (3, 8, 8, 3, 3, 1, 1),
            (2, 9, 7, 3, 3, 2, 1),
            (1, 10, 10, 2, 2, 3, 0), // stride 3 > kernel 2: pixels skipped
            (2, 7, 7, 1, 1, 2, 0),   // 1×1 kernel, stride 2: checkerboard coverage
        ] {
            let mut img = seq(c * h * w);
            let geo = Conv2dGeometry { in_channels: c, in_h: h, in_w: w, kernel_h: kh, kernel_w: kw, stride, padding: pad };
            let check = |img: &[f32], ctx: &str| {
                let mut col = vec![0f32; geo.k() * geo.n()];
                im2col(img, &geo, &mut col);
                assert_eq!(
                    im2col_whole_exponent(img, &geo),
                    max_exponent(&col),
                    "{ctx} ({c}ch {h}x{w} k{kh}x{kw} s{stride} p{pad})"
                );
            };
            check(&img, "plain");
            // a huge value on an *uncovered* pixel must not change the result
            if stride > kh {
                img[2 * w + 2] = 1e30; // (iy=2, ix=2) uncovered for stride 3, k 2, pad 0
                check(&img, "outlier on uncovered pixel");
            }
            // non-finite values are ignored, exactly like max_exponent
            img[0] = f32::NAN;
            check(&img, "with NaN");
        }
        // all-zero image: no exponent
        let geo = Conv2dGeometry { in_channels: 1, in_h: 4, in_w: 4, kernel_h: 3, kernel_w: 3, stride: 1, padding: 1 };
        assert_eq!(im2col_whole_exponent(&[0.0; 16], &geo), None);
    }

    #[test]
    fn padding_zero_fills() {
        let img = [1.0f32; 4]; // 1×2×2
        let geo = Conv2dGeometry { in_channels: 1, in_h: 2, in_w: 2, kernel_h: 3, kernel_w: 3, stride: 1, padding: 1 };
        let mut col = vec![9f32; geo.k() * geo.n()];
        im2col(&img, &geo, &mut col);
        // top-left output pixel's first kernel tap reads the padded corner
        assert_eq!(col[0], 0.0);
        // centre taps read real data
        assert_eq!(col[4 * geo.n()], 1.0); // kernel (1,1), first field
    }
}
