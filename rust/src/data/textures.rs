//! Procedural texture dataset (cifar-like), DESIGN.md §4.
//!
//! 3×32×32 RGB images from ten parameterised texture families (gradients,
//! stripes at several orientations, checkers, blobs, rings, speckle). The
//! families are visually separable, so a small CNN trained on them reaches
//! high accuracy — giving a realistic trained-weight distribution for the
//! cifar10 rows of Table 3.

use super::rng::Rng;
use crate::tensor::Tensor;

/// A generated cifar-like dataset: images `[n, 3, 32, 32]`, labels `[n]`.
pub struct TextureDataset {
    pub images: Vec<Tensor>,
    pub labels: Vec<usize>,
}

/// Render one 3×32×32 texture of class `class` (0..10).
pub fn render_texture(class: usize, rng: &mut Rng) -> Tensor {
    let mut img = vec![0f32; 3 * 32 * 32];
    let phase = rng.uniform_range(0.0, std::f64::consts::TAU);
    let freq = rng.uniform_range(0.5, 1.5);
    let base = [rng.uniform_range(0.2, 0.8), rng.uniform_range(0.2, 0.8), rng.uniform_range(0.2, 0.8)];
    for y in 0..32 {
        for x in 0..32 {
            let (xf, yf) = (x as f64 / 32.0, y as f64 / 32.0);
            let v = match class % 10 {
                0 => xf,                                                     // horizontal gradient
                1 => yf,                                                     // vertical gradient
                2 => (((xf * 8.0 * freq) as usize + (yf * 8.0 * freq) as usize) % 2) as f64, // checker
                3 => ((xf * 12.0 * freq + phase).sin() + 1.0) / 2.0,         // vertical stripes
                4 => ((yf * 12.0 * freq + phase).sin() + 1.0) / 2.0,         // horizontal stripes
                5 => (((xf + yf) * 9.0 * freq + phase).sin() + 1.0) / 2.0,   // diagonal stripes
                6 => {
                    let r = ((xf - 0.5).powi(2) + (yf - 0.5).powi(2)).sqrt();
                    ((r * 20.0 * freq + phase).sin() + 1.0) / 2.0            // rings
                }
                7 => {
                    let r2 = (xf - 0.5).powi(2) + (yf - 0.5).powi(2);
                    (-r2 * 12.0 * freq).exp()                                // centre blob
                }
                8 => ((xf * 25.0 * freq).sin() * (yf * 25.0 * freq).sin() + 1.0) / 2.0, // grid dots
                _ => rng.uniform(),                                           // speckle noise
            };
            for c in 0..3 {
                let chan_mod = 0.6 + 0.4 * ((c as f64 + 1.0) * v).sin().abs();
                img[(c * 32 + y) * 32 + x] =
                    ((v * chan_mod * 0.8 + base[c] * 0.2) as f32 + (rng.normal() * 0.02) as f32).clamp(0.0, 1.0);
            }
        }
    }
    Tensor::from_vec(img, &[3, 32, 32])
}

impl TextureDataset {
    /// Generate `n` labelled texture images from `seed`.
    pub fn generate(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC1FA_C1FA);
        let mut images = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = i % 10;
            images.push(render_texture(class, &mut rng));
            labels.push(class);
        }
        Self { images, labels }
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = TextureDataset::generate(10, 4);
        let b = TextureDataset::generate(10, 4);
        assert_eq!(a.images[7].data, b.images[7].data);
    }

    #[test]
    fn classes_visually_distinct() {
        let d = TextureDataset::generate(10, 1);
        // mean absolute difference between class exemplars should be large
        for i in 0..9 {
            let diff: f32 = d.images[i]
                .data
                .iter()
                .zip(&d.images[i + 1].data)
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>()
                / d.images[i].len() as f32;
            assert!(diff > 0.02, "classes {i} and {} too similar: {diff}", i + 1);
        }
    }

    #[test]
    fn shape_and_range() {
        let d = TextureDataset::generate(5, 2);
        for img in &d.images {
            assert_eq!(img.shape, vec![3, 32, 32]);
            assert!(img.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }
}
