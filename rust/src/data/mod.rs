//! Synthetic workloads substituting for the paper's proprietary data
//! (DESIGN.md §4): deterministic RNG, a procedural digit dataset
//! (mnist-like), a procedural texture dataset (cifar-like) and
//! ImageNet-statistics activation generators.

pub mod digits;
pub mod labeled;
pub mod imagenet_like;
pub mod rng;
pub mod textures;

pub use digits::DigitDataset;
pub use imagenet_like::imagenet_like_batch;
pub use labeled::labeled_imagenet_like;
pub use rng::Rng;
pub use textures::TextureDataset;
