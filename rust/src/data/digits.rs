//! Procedural digit dataset (mnist-like), DESIGN.md §4.
//!
//! 28×28 grayscale digits rendered from 5×7 stroke-font bitmaps with random
//! sub-pixel shift, scale jitter, stroke-intensity jitter and additive
//! noise. Labels are the digit identities, so a small CNN can genuinely be
//! *trained* on this set (the JAX build-time trainer uses the same
//! generator, re-implemented in `python/compile/datagen.py` with identical
//! glyphs — the Rust and Python sides share golden vectors in tests).

use super::rng::Rng;
use crate::tensor::Tensor;

/// 5×7 glyphs for digits 0–9 (1 bit per cell, row-major, top to bottom).
pub const GLYPHS: [[u8; 7]; 10] = [
    // each row is 5 bits, MSB = leftmost column
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110], // 0
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110], // 1
    [0b01110, 0b10001, 0b00001, 0b00010, 0b00100, 0b01000, 0b11111], // 2
    [0b11111, 0b00010, 0b00100, 0b00010, 0b00001, 0b10001, 0b01110], // 3
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010], // 4
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110], // 5
    [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110], // 6
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000], // 7
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110], // 8
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100], // 9
];

/// A generated mnist-like dataset: images `[n, 1, 28, 28]`, labels `[n]`.
pub struct DigitDataset {
    pub images: Vec<Tensor>,
    pub labels: Vec<usize>,
}

/// Render one 28×28 digit image with the given jitter parameters.
pub fn render_digit(digit: usize, rng: &mut Rng) -> Tensor {
    let glyph = &GLYPHS[digit % 10];
    let mut img = vec![0f32; 28 * 28];
    // random placement: glyph scaled ~3.2±0.6 px/cell, shifted ±3 px
    let scale = rng.uniform_range(2.6, 3.8);
    let ox = rng.uniform_range(2.0, 8.0);
    let oy = rng.uniform_range(1.0, 5.0);
    let intensity = rng.uniform_range(0.75, 1.0) as f32;
    for y in 0..28 {
        for x in 0..28 {
            // map pixel back to glyph cell (bilinear-ish coverage)
            let gx = (x as f64 - ox) / scale;
            let gy = (y as f64 - oy) / scale;
            if (0.0..5.0).contains(&gx) && (0.0..7.0).contains(&gy) {
                let (cx, cy) = (gx as usize, gy as usize);
                let bit = (glyph[cy] >> (4 - cx)) & 1;
                if bit == 1 {
                    // soft edges: fade near the cell boundary
                    let fx = (gx - cx as f64 - 0.5).abs();
                    let fy = (gy - cy as f64 - 0.5).abs();
                    let soft = (1.0 - (fx.max(fy) * 0.6)) as f32;
                    img[y * 28 + x] = intensity * soft.clamp(0.3, 1.0);
                }
            }
        }
    }
    // additive noise + normalization roughly matching mnist preprocessing
    for v in &mut img {
        *v += (rng.normal() * 0.03) as f32;
        *v = v.clamp(0.0, 1.0);
    }
    Tensor::from_vec(img, &[1, 28, 28])
}

impl DigitDataset {
    /// Generate `n` labelled digit images from `seed`.
    pub fn generate(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut images = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let digit = i % 10; // balanced classes
            images.push(render_digit(digit, &mut rng));
            labels.push(digit);
        }
        Self { images, labels }
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_are_deterministic() {
        let a = DigitDataset::generate(10, 1);
        let b = DigitDataset::generate(10, 1);
        assert_eq!(a.images[3].data, b.images[3].data);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn digits_differ_across_seeds_and_classes() {
        let a = DigitDataset::generate(20, 1);
        let b = DigitDataset::generate(20, 2);
        assert_ne!(a.images[0].data, b.images[0].data);
        assert_ne!(a.images[0].data, a.images[1].data, "different digits must differ");
    }

    #[test]
    fn images_are_normalized() {
        let d = DigitDataset::generate(30, 5);
        for img in &d.images {
            assert_eq!(img.shape, vec![1, 28, 28]);
            assert!(img.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
            assert!(img.energy() > 1.0, "digit must have visible strokes");
        }
    }

    #[test]
    fn labels_balanced() {
        let d = DigitDataset::generate(100, 3);
        for digit in 0..10 {
            assert_eq!(d.labels.iter().filter(|&&l| l == digit).count(), 10);
        }
    }

    #[test]
    fn same_class_varies_by_jitter() {
        let d = DigitDataset::generate(30, 9);
        // samples 0, 10, 20 are all digit 0 but jittered differently
        assert_ne!(d.images[0].data, d.images[10].data);
        assert_ne!(d.images[10].data, d.images[20].data);
    }
}
