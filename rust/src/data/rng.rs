//! Deterministic pseudo-random generator (splitmix64 + Box–Muller).
//!
//! All synthetic weights and datasets flow from explicit seeds so every
//! experiment in EXPERIMENTS.md is bit-reproducible.

/// Small, fast, deterministic RNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    cached_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15), cached_normal: None }
    }

    /// Next raw u64 (splitmix64).
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.cached_normal.take() {
            return v;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// `n` normal samples scaled by `std` as f32.
    pub fn normal_vec(&mut self, n: usize, std: f64) -> Vec<f32> {
        (0..n).map(|_| (self.normal() * std) as f32).collect()
    }

    /// Laplacian sample (heavier tails — CNN activations / weights are
    /// closer to Laplacian than Gaussian, which matters for BFP because
    /// the block max sets the shared exponent).
    pub fn laplacian(&mut self, scale: f64) -> f64 {
        let u = self.uniform() - 0.5;
        -scale * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// `n` Laplacian samples as f32.
    pub fn laplacian_vec(&mut self, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| self.laplacian(scale) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = { let mut r = Rng::new(42); (0..8).map(|_| r.next_u64()).collect() };
        let b: Vec<u64> = { let mut r = Rng::new(42); (0..8).map(|_| r.next_u64()).collect() };
        let c: Vec<u64> = { let mut r = Rng::new(43); (0..8).map(|_| r.next_u64()).collect() };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn laplacian_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let scale = 0.5;
        let xs: Vec<f64> = (0..n).map(|_| r.laplacian(scale)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02);
        // Laplacian variance = 2·scale²
        assert!((var - 2.0 * scale * scale).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }
}
