//! ImageNet-statistics input generator, DESIGN.md §4.
//!
//! The large-network experiments (VGG-16, ResNets, GoogLeNet) need inputs
//! whose *value distribution* resembles mean-subtracted natural images:
//! spatially correlated, heavy-tailed, per-channel offsets. We synthesise
//! them as multi-octave value noise (random low-resolution grids,
//! bilinearly upsampled and summed), which reproduces the 1/f-ish spatial
//! spectrum of natural images — the property that matters for BFP because
//! it controls the block max / mean ratio that drives quantization error.

use super::rng::Rng;
use crate::tensor::Tensor;

/// One synthetic "natural" image, `[3, size, size]`, roughly
/// mean-subtracted-RGB distributed (values ~ [-120, 130] like Caffe's
/// BGR-minus-mean inputs).
pub fn imagenet_like_image(size: usize, rng: &mut Rng) -> Tensor {
    let mut img = vec![0f32; 3 * size * size];
    // channel means of ImageNet BGR mean subtraction leave slight offsets
    let chan_offset = [rng.normal() * 8.0, rng.normal() * 8.0, rng.normal() * 8.0];
    for c in 0..3 {
        let plane = &mut img[c * size * size..(c + 1) * size * size];
        // multi-octave value noise: grids of 4, 8, 16 cells
        for (octave, amp) in [(4usize, 60.0f64), (8, 30.0), (16, 15.0)] {
            let g = octave + 1;
            let grid: Vec<f64> = (0..g * g).map(|_| rng.normal()).collect();
            for y in 0..size {
                for x in 0..size {
                    let gy = y as f64 / size as f64 * octave as f64;
                    let gx = x as f64 / size as f64 * octave as f64;
                    let (y0, x0) = (gy as usize, gx as usize);
                    let (fy, fx) = (gy - y0 as f64, gx - x0 as f64);
                    let v00 = grid[y0 * g + x0];
                    let v01 = grid[y0 * g + x0 + 1];
                    let v10 = grid[(y0 + 1) * g + x0];
                    let v11 = grid[(y0 + 1) * g + x0 + 1];
                    let v = v00 * (1.0 - fy) * (1.0 - fx)
                        + v01 * (1.0 - fy) * fx
                        + v10 * fy * (1.0 - fx)
                        + v11 * fy * fx;
                    plane[y * size + x] += (v * amp) as f64 as f32;
                }
            }
        }
        // pixel noise + channel offset, clamp to the mean-subtracted range
        for v in plane.iter_mut() {
            *v += (rng.normal() * 6.0) as f32 + chan_offset[c] as f32;
            *v = v.clamp(-123.0, 132.0);
        }
    }
    Tensor::from_vec(img, &[3, size, size])
}

/// A batch of `n` imagenet-like images.
pub fn imagenet_like_batch(n: usize, size: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed ^ 0x1A6E_7E57);
    (0..n).map(|_| imagenet_like_image(size, &mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = imagenet_like_batch(2, 32, 9);
        let b = imagenet_like_batch(2, 32, 9);
        assert_eq!(a[1].data, b[1].data);
    }

    #[test]
    fn shape_and_range() {
        let imgs = imagenet_like_batch(3, 64, 1);
        for img in &imgs {
            assert_eq!(img.shape, vec![3, 64, 64]);
            assert!(img.data.iter().all(|&v| (-123.0..=132.0).contains(&v)));
        }
    }

    #[test]
    fn spatially_correlated() {
        // neighbouring pixels must correlate far more than distant ones
        let img = &imagenet_like_batch(1, 64, 7)[0];
        let plane = &img.data[0..64 * 64];
        let mut near = 0f64;
        let mut far = 0f64;
        let mean: f64 = plane.iter().map(|&v| v as f64).sum::<f64>() / plane.len() as f64;
        for y in 0..63 {
            for x in 0..32 {
                let a = plane[y * 64 + x] as f64 - mean;
                near += a * (plane[y * 64 + x + 1] as f64 - mean);
                far += a * (plane[y * 64 + x + 31] as f64 - mean);
            }
        }
        assert!(near.abs() > 2.0 * far.abs(), "near={near} far={far}");
    }

    #[test]
    fn wide_dynamic_range() {
        // BFP cares about max/mean ratio; natural-image stats are heavy-ish
        let img = &imagenet_like_batch(1, 64, 3)[0];
        let ms = img.mean_square().sqrt();
        let max = img.max_abs() as f64;
        assert!(max / ms > 1.5, "dynamic range too flat: {}", max / ms);
    }
}
