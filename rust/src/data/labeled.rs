//! Labelled imagenet-like dataset for the random-features evaluation of
//! the large networks (DESIGN.md §4).
//!
//! Each image is multi-octave natural-image noise ([`super::imagenet_like`])
//! plus a class-conditional texture pattern, giving a 10-way task that a
//! linear readout on frozen conv features can genuinely learn — so
//! "accuracy drop" has trained-network semantics (real margins) instead
//! of the flip-rate of an arbitrary random projection.

use super::rng::Rng;
use super::textures::render_texture;
use crate::tensor::Tensor;

/// Amplitude of the class pattern relative to the ±120 image range.
const PATTERN_AMPLITUDE: f32 = 95.0;

/// One labelled image: natural-noise background + class texture.
pub fn labeled_image(class: usize, size: usize, rng: &mut Rng) -> Tensor {
    let mut img = super::imagenet_like::imagenet_like_image(size, rng);
    let pattern = render_texture(class, rng); // [3, 32, 32] in [0,1]
    for c in 0..3 {
        for y in 0..size {
            for x in 0..size {
                // nearest-neighbour stretch of the 32×32 pattern
                let py = y * 32 / size;
                let px = x * 32 / size;
                let p = pattern.data[(c * 32 + py) * 32 + px] - 0.5;
                let v = &mut img.data[(c * size + y) * size + x];
                *v = (*v + p * 2.0 * PATTERN_AMPLITUDE).clamp(-123.0, 132.0);
            }
        }
    }
    img
}

/// A balanced labelled set: `(images, labels)` over 10 classes.
pub fn labeled_imagenet_like(n: usize, size: usize, seed: u64) -> (Vec<Tensor>, Vec<usize>) {
    let mut rng = Rng::new(seed ^ 0x1AB_E1ED);
    let mut images = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % 10;
        images.push(labeled_image(class, size, &mut rng));
        labels.push(class);
    }
    (images, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_balanced() {
        let (a, la) = labeled_imagenet_like(20, 32, 3);
        let (b, _) = labeled_imagenet_like(20, 32, 3);
        assert_eq!(a[7].data, b[7].data);
        for c in 0..10 {
            assert_eq!(la.iter().filter(|&&l| l == c).count(), 2);
        }
    }

    #[test]
    fn classes_are_separable_in_pixel_space() {
        // same-class images correlate more than cross-class (pattern term)
        let (imgs, labels) = labeled_imagenet_like(40, 32, 5);
        let dot = |a: &Tensor, b: &Tensor| -> f64 {
            a.data.iter().zip(&b.data).map(|(x, y)| (*x as f64) * (*y as f64)).sum()
        };
        let mut same = 0f64;
        let mut diff = 0f64;
        let mut ns = 0;
        let mut nd = 0;
        for i in 0..imgs.len() {
            for j in (i + 1)..imgs.len() {
                if labels[i] == labels[j] {
                    same += dot(&imgs[i], &imgs[j]);
                    ns += 1;
                } else {
                    diff += dot(&imgs[i], &imgs[j]);
                    nd += 1;
                }
            }
        }
        assert!(same / ns as f64 > diff / nd as f64, "class structure missing");
    }

    #[test]
    fn values_in_caffe_range() {
        let (imgs, _) = labeled_imagenet_like(5, 32, 1);
        for img in imgs {
            assert!(img.data.iter().all(|&v| (-123.0..=132.0).contains(&v)));
        }
    }
}
