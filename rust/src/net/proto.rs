//! Wire protocol: length-prefixed binary frames.
//!
//! Every frame is a `u32` little-endian payload length followed by the
//! payload. A payload starts with a protocol version byte and a message
//! kind, then kind-specific fields, and ends with a little-endian
//! FNV-1a CRC over everything before it; integers are little-endian and
//! tensors carry their shape plus raw f32 bits, so logits round-trip
//! the wire bit-identically. The decoder is a bounds-checked cursor —
//! truncated, oversized, bit-flipped or garbage frames surface as a
//! typed [`DecodeError`], never a panic or an out-of-bounds read. The
//! version byte is checked *before* the CRC, so a peer speaking an
//! older protocol is told so ([`DecodeError::BadVersion`]) instead of
//! being accused of corruption.

use crate::coordinator::QosClass;
use crate::tensor::Tensor;
use std::fmt;
use std::io::{self, Read, Write};

/// Bumped on any incompatible layout change; the server rejects frames
/// carrying any other version instead of misparsing them. Version 2
/// added the trailing payload CRC.
pub const PROTO_VERSION: u8 = 2;

/// Bytes of the trailing payload CRC.
const CRC_BYTES: usize = 4;

/// Hard cap on a frame payload: large enough for any batch-1 CNN input
/// in this repo, small enough that a hostile length prefix cannot make
/// the server allocate gigabytes.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Tensor sanity bounds (a request carries exactly one image).
const MAX_DIMS: usize = 8;
const MAX_ELEMS: usize = MAX_FRAME_BYTES / 4;
/// Tenant ids / error strings are short identifiers, not payloads.
const MAX_STR_BYTES: usize = 1024;

const KIND_REQUEST: u8 = 1;
const KIND_RESPONSE: u8 = 2;
const KIND_ERROR: u8 = 3;
const KIND_HEALTH_REQ: u8 = 4;
const KIND_HEALTH: u8 = 5;
const KIND_STATS_REQ: u8 = 6;
const KIND_STATS: u8 = 7;

/// Lanes a health frame may claim (a sanity cap, far above the four
/// real lanes, so hostile frames cannot demand huge allocations).
const MAX_HEALTH_LANES: usize = 64;

/// Tenants a stats frame may claim (sanity cap against hostile frames;
/// the server truncates its own report to fit).
pub const MAX_STATS_TENANTS: usize = 256;

/// (lane, stage) latency rows a stats frame may claim — 5 lane labels ×
/// 7 stages is the real ceiling; the cap just bounds allocation.
pub const MAX_STATS_STAGES: usize = 1024;

/// Why a payload failed to decode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// The payload ended before the field being read.
    Truncated,
    /// Version byte differs from [`PROTO_VERSION`].
    BadVersion { got: u8 },
    /// Unknown message kind byte.
    BadKind(u8),
    /// Unknown QoS class or error code byte.
    BadEnum(u8),
    /// String field is not UTF-8 or exceeds [`MAX_STR_BYTES`].
    BadString,
    /// Tensor shape is empty, too deep, overflows, or exceeds caps.
    BadShape,
    /// The payload decoded but left unread trailing bytes.
    TrailingBytes { extra: usize },
    /// The trailing payload CRC does not match: the frame was damaged
    /// in flight (or forged). Nothing in it can be trusted.
    Corrupt,
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DecodeError::Truncated => write!(f, "payload truncated mid-field"),
            DecodeError::BadVersion { got } => {
                write!(f, "protocol version {got} (this side speaks {PROTO_VERSION})")
            }
            DecodeError::BadKind(k) => write!(f, "unknown message kind {k}"),
            DecodeError::BadEnum(v) => write!(f, "unknown enum byte {v}"),
            DecodeError::BadString => write!(f, "string field invalid or too long"),
            DecodeError::BadShape => write!(f, "tensor shape invalid or too large"),
            DecodeError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after the message")
            }
            DecodeError::Corrupt => write!(f, "payload CRC mismatch (corrupt frame)"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// One inference request as it travels the wire.
#[derive(Debug, Clone)]
pub struct NetRequest {
    /// Client-chosen id, echoed on the response (responses return out of
    /// order, so the client correlates by id, not arrival order).
    pub id: u64,
    /// Tenant identifier for quota accounting.
    pub tenant: String,
    pub class: QosClass,
    /// Relative deadline in µs; 0 ⇒ the class default.
    pub deadline_us: u64,
    pub image: Tensor,
}

/// One served response (mirrors [`crate::coordinator::QosResponse`]).
#[derive(Debug, Clone)]
pub struct NetResponse {
    /// The client id from the matching request.
    pub id: u64,
    /// The class the request asked for.
    pub class: QosClass,
    /// The lane that served it.
    pub served_by: String,
    /// The serving lane's active precision step.
    pub lane_plan: String,
    /// Served by a cheaper lane than requested (pressure or quota).
    pub downgraded: bool,
    /// The downgrade was the tenant quota's doing specifically.
    pub quota_downgraded: bool,
    pub deadline_missed: bool,
    pub queue_wait_us: u64,
    pub batch_size: u32,
    pub logits: Tensor,
}

/// Why the server refused a request (or a whole connection).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Tenant exceeded its hard quota debt; request shed.
    OverQuota,
    /// Connection-level admission: the server is at `max_conns`.
    ConnLimit,
    /// Unparseable or non-request frame.
    BadRequest,
    /// The serving fabric is shutting down.
    ServerGone,
    /// The request sat queued past `deadline + grace` and was reaped.
    Timeout,
    /// The serving lane failed the request (executor panic, retired
    /// lane) — a server-side fault, not the client's.
    Internal,
    /// The request tensor failed admission validation (NaN/Inf values
    /// or a shape the model cannot take); it was never enqueued.
    BadInput,
    /// Data corruption: the request frame failed its CRC, or the
    /// serving lane produced non-finite logits and refused to reply
    /// with garbage.
    Corrupt,
}

impl ErrorCode {
    fn code(self) -> u8 {
        match self {
            ErrorCode::OverQuota => 1,
            ErrorCode::ConnLimit => 2,
            ErrorCode::BadRequest => 3,
            ErrorCode::ServerGone => 4,
            ErrorCode::Timeout => 5,
            ErrorCode::Internal => 6,
            ErrorCode::BadInput => 7,
            ErrorCode::Corrupt => 8,
        }
    }

    fn from_code(v: u8) -> Option<Self> {
        match v {
            1 => Some(ErrorCode::OverQuota),
            2 => Some(ErrorCode::ConnLimit),
            3 => Some(ErrorCode::BadRequest),
            4 => Some(ErrorCode::ServerGone),
            5 => Some(ErrorCode::Timeout),
            6 => Some(ErrorCode::Internal),
            7 => Some(ErrorCode::BadInput),
            8 => Some(ErrorCode::Corrupt),
            _ => None,
        }
    }
}

/// An error frame: `id` is the offending request's id when known, 0 for
/// connection-level refusals and frames that never parsed far enough to
/// carry one.
#[derive(Debug, Clone)]
pub struct NetError {
    pub id: u64,
    pub code: ErrorCode,
    pub message: String,
}

/// One lane's liveness as carried by a health frame (mirrors
/// [`crate::coordinator::LaneHealth`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneHealthWire {
    pub label: String,
    pub retired: bool,
    pub restarts: u64,
    pub queued: u64,
}

/// The server's answer to a health probe: per-lane liveness, restart
/// counts and queue depths as of the scheduler's last pass.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetHealth {
    pub lanes: Vec<LaneHealthWire>,
}

/// One lane's live counters as carried by a stats frame (mirrors
/// [`crate::coordinator::LaneStats`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneStatsWire {
    pub label: String,
    pub retired: bool,
    pub restarts: u64,
    pub queued: u64,
    /// Active precision rung, 1-based; 0 when the lane has not
    /// published yet.
    pub rung: u32,
    /// Ladder length, so clients can render `rung/ladder`.
    pub ladder: u32,
    pub swaps: u64,
    pub promotions: u64,
}

/// One tenant's quota balance as carried by a stats frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantStatsWire {
    pub tenant: String,
    /// Remaining token balance in milli-tokens, clamped at zero (debt
    /// is not exposed on the wire).
    pub tokens_milli: u64,
}

/// One (lane, stage) latency cell from the span flight recorder.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageStatsWire {
    pub lane: String,
    pub stage: String,
    pub count: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

/// The server's answer to a stats probe: uptime and request totals,
/// per-lane counters, per-tenant quota balances, and per-stage latency
/// attribution (empty unless tracing is armed on the server).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetStats {
    pub uptime_ms: u64,
    pub total_requests: u64,
    /// Data-integrity counters (weight-cache scrubber, frame CRCs,
    /// numeric guard rails).
    pub integrity: IntegrityWire,
    pub lanes: Vec<LaneStatsWire>,
    pub tenants: Vec<TenantStatsWire>,
    pub stages: Vec<StageStatsWire>,
}

/// The integrity counters carried by a stats frame (mirrors the
/// corresponding [`crate::coordinator::Metrics`] fields).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct IntegrityWire {
    /// Weight-cache scrub passes that actually verified checksums.
    pub scrub_passes: u64,
    /// Cache entries whose checksum mismatched and were requantized
    /// from the fp32 weights.
    pub scrub_repairs: u64,
    /// Inbound frames rejected for a payload CRC mismatch.
    pub frame_crc_errors: u64,
    /// Requests refused at admission for NaN/Inf values or a bad shape.
    pub bad_inputs: u64,
    /// Batches whose lane produced non-finite logits and was failed
    /// with a typed error instead of replying with garbage.
    pub corrupt_outputs: u64,
}

/// Any decoded payload.
#[derive(Debug, Clone)]
pub enum Msg {
    Request(NetRequest),
    Response(NetResponse),
    Error(NetError),
    /// Client → server: report your lane health.
    HealthReq,
    Health(NetHealth),
    /// Client → server: report your live serving stats.
    StatsReq,
    Stats(NetStats),
}

/// What a client gets back for a request.
#[derive(Debug, Clone)]
pub enum Reply {
    Response(NetResponse),
    Error(NetError),
}

fn class_code(c: QosClass) -> u8 {
    match c {
        QosClass::Gold => 0,
        QosClass::Standard => 1,
        QosClass::Economy => 2,
    }
}

fn class_from_code(v: u8) -> Option<QosClass> {
    match v {
        0 => Some(QosClass::Gold),
        1 => Some(QosClass::Standard),
        2 => Some(QosClass::Economy),
        _ => None,
    }
}

// ---- framing ---------------------------------------------------------

/// Write one frame (length prefix + payload) and flush.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    debug_assert!(payload.len() <= MAX_FRAME_BYTES, "oversized outgoing frame");
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame's payload. `Ok(None)` is a clean EOF *between* frames
/// (the peer closed); EOF mid-frame and hostile length prefixes are
/// `io::Error`s — once framing desyncs the stream cannot be trusted.
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<Vec<u8>>> {
    let mut len4 = [0u8; 4];
    match r.read(&mut len4[..1]) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(e) => return Err(e),
    }
    r.read_exact(&mut len4[1..])?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap"),
        ));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(Some(buf))
}

// ---- payload CRC -----------------------------------------------------

/// 32-bit FNV-1a over the payload body. Not cryptographic — it guards
/// against accidental corruption (flipped bits, truncated copies), not
/// an adversary, and costs one multiply-add per byte with zero tables.
fn payload_crc(body: &[u8]) -> u32 {
    let mut hash: u32 = 0x811c_9dc5;
    for &b in body {
        hash ^= u32::from(b);
        hash = hash.wrapping_mul(0x0100_0193);
    }
    hash
}

/// Append the trailing CRC to a fully encoded payload body. Every
/// `encode_*` returns through here.
fn seal(mut p: Vec<u8>) -> Vec<u8> {
    let crc = payload_crc(&p);
    p.extend_from_slice(&crc.to_le_bytes());
    p
}

// ---- encoding --------------------------------------------------------

fn put_str(out: &mut Vec<u8>, s: &str) {
    debug_assert!(s.len() <= MAX_STR_BYTES, "string field too long");
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    debug_assert!(!t.shape.is_empty() && t.shape.len() <= MAX_DIMS);
    out.push(t.shape.len() as u8);
    for &d in &t.shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for &v in &t.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a request payload (frame it with [`write_frame`]).
pub fn encode_request(req: &NetRequest) -> Vec<u8> {
    let mut p = Vec::with_capacity(32 + req.tenant.len() + 4 * req.image.len());
    p.push(PROTO_VERSION);
    p.push(KIND_REQUEST);
    p.extend_from_slice(&req.id.to_le_bytes());
    put_str(&mut p, &req.tenant);
    p.push(class_code(req.class));
    p.extend_from_slice(&req.deadline_us.to_le_bytes());
    put_tensor(&mut p, &req.image);
    seal(p)
}

/// Encode a response payload.
pub fn encode_response(resp: &NetResponse) -> Vec<u8> {
    let mut p = Vec::with_capacity(64 + resp.served_by.len() + 4 * resp.logits.len());
    p.push(PROTO_VERSION);
    p.push(KIND_RESPONSE);
    p.extend_from_slice(&resp.id.to_le_bytes());
    p.push(class_code(resp.class));
    put_str(&mut p, &resp.served_by);
    put_str(&mut p, &resp.lane_plan);
    let flags = (resp.downgraded as u8)
        | ((resp.quota_downgraded as u8) << 1)
        | ((resp.deadline_missed as u8) << 2);
    p.push(flags);
    p.extend_from_slice(&resp.queue_wait_us.to_le_bytes());
    p.extend_from_slice(&resp.batch_size.to_le_bytes());
    put_tensor(&mut p, &resp.logits);
    seal(p)
}

/// Encode an error payload.
pub fn encode_error(err: &NetError) -> Vec<u8> {
    let mut p = Vec::with_capacity(16 + err.message.len());
    p.push(PROTO_VERSION);
    p.push(KIND_ERROR);
    p.extend_from_slice(&err.id.to_le_bytes());
    p.push(err.code.code());
    put_str(&mut p, &err.message);
    seal(p)
}

/// Encode a health probe (no fields beyond the kind).
pub fn encode_health_req() -> Vec<u8> {
    seal(vec![PROTO_VERSION, KIND_HEALTH_REQ])
}

/// Encode a health report payload.
pub fn encode_health(health: &NetHealth) -> Vec<u8> {
    debug_assert!(health.lanes.len() <= MAX_HEALTH_LANES);
    let mut p = Vec::with_capacity(8 + 32 * health.lanes.len());
    p.push(PROTO_VERSION);
    p.push(KIND_HEALTH);
    p.extend_from_slice(&(health.lanes.len() as u16).to_le_bytes());
    for lane in &health.lanes {
        put_str(&mut p, &lane.label);
        p.push(lane.retired as u8);
        p.extend_from_slice(&lane.restarts.to_le_bytes());
        p.extend_from_slice(&lane.queued.to_le_bytes());
    }
    seal(p)
}

/// Encode a stats probe (no fields beyond the kind).
pub fn encode_stats_req() -> Vec<u8> {
    seal(vec![PROTO_VERSION, KIND_STATS_REQ])
}

/// Encode a stats report payload.
pub fn encode_stats(stats: &NetStats) -> Vec<u8> {
    debug_assert!(stats.lanes.len() <= MAX_HEALTH_LANES);
    debug_assert!(stats.tenants.len() <= MAX_STATS_TENANTS);
    debug_assert!(stats.stages.len() <= MAX_STATS_STAGES);
    let mut p = Vec::with_capacity(
        32 + 64 * stats.lanes.len() + 24 * stats.tenants.len() + 48 * stats.stages.len(),
    );
    p.push(PROTO_VERSION);
    p.push(KIND_STATS);
    p.extend_from_slice(&stats.uptime_ms.to_le_bytes());
    p.extend_from_slice(&stats.total_requests.to_le_bytes());
    p.extend_from_slice(&stats.integrity.scrub_passes.to_le_bytes());
    p.extend_from_slice(&stats.integrity.scrub_repairs.to_le_bytes());
    p.extend_from_slice(&stats.integrity.frame_crc_errors.to_le_bytes());
    p.extend_from_slice(&stats.integrity.bad_inputs.to_le_bytes());
    p.extend_from_slice(&stats.integrity.corrupt_outputs.to_le_bytes());
    p.extend_from_slice(&(stats.lanes.len() as u16).to_le_bytes());
    for lane in &stats.lanes {
        put_str(&mut p, &lane.label);
        p.push(lane.retired as u8);
        p.extend_from_slice(&lane.restarts.to_le_bytes());
        p.extend_from_slice(&lane.queued.to_le_bytes());
        p.extend_from_slice(&lane.rung.to_le_bytes());
        p.extend_from_slice(&lane.ladder.to_le_bytes());
        p.extend_from_slice(&lane.swaps.to_le_bytes());
        p.extend_from_slice(&lane.promotions.to_le_bytes());
    }
    p.extend_from_slice(&(stats.tenants.len() as u16).to_le_bytes());
    for t in &stats.tenants {
        put_str(&mut p, &t.tenant);
        p.extend_from_slice(&t.tokens_milli.to_le_bytes());
    }
    p.extend_from_slice(&(stats.stages.len() as u16).to_le_bytes());
    for s in &stats.stages {
        put_str(&mut p, &s.lane);
        put_str(&mut p, &s.stage);
        p.extend_from_slice(&s.count.to_le_bytes());
        p.extend_from_slice(&s.p50_us.to_le_bytes());
        p.extend_from_slice(&s.p99_us.to_le_bytes());
        p.extend_from_slice(&s.max_us.to_le_bytes());
    }
    seal(p)
}

// ---- decoding --------------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        let end = self.pos.checked_add(n).ok_or(DecodeError::Truncated)?;
        if end > self.buf.len() {
            return Err(DecodeError::Truncated);
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.bytes(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, DecodeError> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, DecodeError> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, DecodeError> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn string(&mut self) -> Result<String, DecodeError> {
        let len = self.u16()? as usize;
        if len > MAX_STR_BYTES {
            return Err(DecodeError::BadString);
        }
        let raw = self.bytes(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| DecodeError::BadString)
    }

    fn tensor(&mut self) -> Result<Tensor, DecodeError> {
        let ndim = self.u8()? as usize;
        if ndim == 0 || ndim > MAX_DIMS {
            return Err(DecodeError::BadShape);
        }
        let mut shape = Vec::with_capacity(ndim);
        let mut elems = 1usize;
        for _ in 0..ndim {
            let d = self.u32()? as usize;
            elems = elems.checked_mul(d).ok_or(DecodeError::BadShape)?;
            shape.push(d);
        }
        if elems > MAX_ELEMS {
            return Err(DecodeError::BadShape);
        }
        let raw = self.bytes(4 * elems)?;
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Tensor::from_vec(data, &shape))
    }

    fn class(&mut self) -> Result<QosClass, DecodeError> {
        let v = self.u8()?;
        class_from_code(v).ok_or(DecodeError::BadEnum(v))
    }

    fn finish(self) -> Result<(), DecodeError> {
        if self.pos != self.buf.len() {
            return Err(DecodeError::TrailingBytes { extra: self.buf.len() - self.pos });
        }
        Ok(())
    }
}

/// Decode one frame payload into a typed message.
///
/// Check order matters: the version byte is judged before the CRC so
/// an old peer gets [`DecodeError::BadVersion`] (its frames carry no
/// CRC at all); only then is the trailing CRC verified, so a single
/// flipped bit anywhere in a current-version payload — fields or CRC
/// alike — surfaces as [`DecodeError::Corrupt`] before any field is
/// believed.
pub fn decode(payload: &[u8]) -> Result<Msg, DecodeError> {
    let Some(&version) = payload.first() else {
        return Err(DecodeError::Truncated);
    };
    if version != PROTO_VERSION {
        return Err(DecodeError::BadVersion { got: version });
    }
    if payload.len() < 2 + CRC_BYTES {
        return Err(DecodeError::Truncated);
    }
    let (body, tail) = payload.split_at(payload.len() - CRC_BYTES);
    let got = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
    if got != payload_crc(body) {
        return Err(DecodeError::Corrupt);
    }
    let mut c = Cursor::new(body);
    let _version = c.u8()?; // already checked above
    let kind = c.u8()?;
    let msg = match kind {
        KIND_REQUEST => Msg::Request(NetRequest {
            id: c.u64()?,
            tenant: c.string()?,
            class: c.class()?,
            deadline_us: c.u64()?,
            image: c.tensor()?,
        }),
        KIND_RESPONSE => {
            let id = c.u64()?;
            let class = c.class()?;
            let served_by = c.string()?;
            let lane_plan = c.string()?;
            let flags = c.u8()?;
            Msg::Response(NetResponse {
                id,
                class,
                served_by,
                lane_plan,
                downgraded: flags & 1 != 0,
                quota_downgraded: flags & 2 != 0,
                deadline_missed: flags & 4 != 0,
                queue_wait_us: c.u64()?,
                batch_size: c.u32()?,
                logits: c.tensor()?,
            })
        }
        KIND_ERROR => {
            let id = c.u64()?;
            let code_byte = c.u8()?;
            let code = ErrorCode::from_code(code_byte).ok_or(DecodeError::BadEnum(code_byte))?;
            Msg::Error(NetError { id, code, message: c.string()? })
        }
        KIND_HEALTH_REQ => Msg::HealthReq,
        KIND_HEALTH => {
            let n = c.u16()? as usize;
            if n > MAX_HEALTH_LANES {
                return Err(DecodeError::BadShape);
            }
            let mut lanes = Vec::with_capacity(n);
            for _ in 0..n {
                lanes.push(LaneHealthWire {
                    label: c.string()?,
                    retired: c.u8()? != 0,
                    restarts: c.u64()?,
                    queued: c.u64()?,
                });
            }
            Msg::Health(NetHealth { lanes })
        }
        KIND_STATS_REQ => Msg::StatsReq,
        KIND_STATS => {
            let uptime_ms = c.u64()?;
            let total_requests = c.u64()?;
            let integrity = IntegrityWire {
                scrub_passes: c.u64()?,
                scrub_repairs: c.u64()?,
                frame_crc_errors: c.u64()?,
                bad_inputs: c.u64()?,
                corrupt_outputs: c.u64()?,
            };
            let n_lanes = c.u16()? as usize;
            if n_lanes > MAX_HEALTH_LANES {
                return Err(DecodeError::BadShape);
            }
            let mut lanes = Vec::with_capacity(n_lanes);
            for _ in 0..n_lanes {
                lanes.push(LaneStatsWire {
                    label: c.string()?,
                    retired: c.u8()? != 0,
                    restarts: c.u64()?,
                    queued: c.u64()?,
                    rung: c.u32()?,
                    ladder: c.u32()?,
                    swaps: c.u64()?,
                    promotions: c.u64()?,
                });
            }
            let n_tenants = c.u16()? as usize;
            if n_tenants > MAX_STATS_TENANTS {
                return Err(DecodeError::BadShape);
            }
            let mut tenants = Vec::with_capacity(n_tenants);
            for _ in 0..n_tenants {
                tenants.push(TenantStatsWire { tenant: c.string()?, tokens_milli: c.u64()? });
            }
            let n_stages = c.u16()? as usize;
            if n_stages > MAX_STATS_STAGES {
                return Err(DecodeError::BadShape);
            }
            let mut stages = Vec::with_capacity(n_stages);
            for _ in 0..n_stages {
                stages.push(StageStatsWire {
                    lane: c.string()?,
                    stage: c.string()?,
                    count: c.u64()?,
                    p50_us: c.u64()?,
                    p99_us: c.u64()?,
                    max_us: c.u64()?,
                });
            }
            Msg::Stats(NetStats { uptime_ms, total_requests, integrity, lanes, tenants, stages })
        }
        k => return Err(DecodeError::BadKind(k)),
    };
    c.finish()?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    fn tensor_bits_equal(a: &Tensor, b: &Tensor) -> bool {
        a.shape == b.shape
            && a.data.len() == b.data.len()
            && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    fn random_tensor(rng: &mut Rng) -> Tensor {
        let ndim = 1 + rng.below(3);
        let shape: Vec<usize> = (0..ndim).map(|_| 1 + rng.below(5)).collect();
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.uniform_range(-8.0, 8.0) as f32).collect();
        Tensor::from_vec(data, &shape)
    }

    /// Property: every randomly generated message round-trips encode →
    /// decode with bit-identical tensors and identical fields.
    #[test]
    fn round_trip_property() {
        let mut rng = Rng::new(99);
        for i in 0..60u64 {
            let req = NetRequest {
                id: rng.next_u64(),
                tenant: format!("tenant-{}", rng.below(9)),
                class: QosClass::ALL[rng.below(3)],
                deadline_us: rng.next_u64() >> 40,
                image: random_tensor(&mut rng),
            };
            match decode(&encode_request(&req)).unwrap() {
                Msg::Request(d) => {
                    assert_eq!(d.id, req.id);
                    assert_eq!(d.tenant, req.tenant);
                    assert_eq!(d.class, req.class);
                    assert_eq!(d.deadline_us, req.deadline_us);
                    assert!(tensor_bits_equal(&d.image, &req.image), "case {i}");
                }
                other => panic!("decoded wrong kind: {other:?}"),
            }

            let resp = NetResponse {
                id: rng.next_u64(),
                class: QosClass::ALL[rng.below(3)],
                served_by: "economy".into(),
                lane_plan: format!("plan[{}dB]", rng.below(40)),
                downgraded: rng.below(2) == 1,
                quota_downgraded: rng.below(2) == 1,
                deadline_missed: rng.below(2) == 1,
                queue_wait_us: rng.next_u64() >> 30,
                batch_size: rng.below(16) as u32,
                logits: random_tensor(&mut rng),
            };
            match decode(&encode_response(&resp)).unwrap() {
                Msg::Response(d) => {
                    assert_eq!(d.id, resp.id);
                    assert_eq!(d.class, resp.class);
                    assert_eq!(d.served_by, resp.served_by);
                    assert_eq!(d.lane_plan, resp.lane_plan);
                    assert_eq!(d.downgraded, resp.downgraded);
                    assert_eq!(d.quota_downgraded, resp.quota_downgraded);
                    assert_eq!(d.deadline_missed, resp.deadline_missed);
                    assert_eq!(d.queue_wait_us, resp.queue_wait_us);
                    assert_eq!(d.batch_size, resp.batch_size);
                    assert!(tensor_bits_equal(&d.logits, &resp.logits), "case {i}");
                }
                other => panic!("decoded wrong kind: {other:?}"),
            }
        }
        let err = NetError { id: 7, code: ErrorCode::OverQuota, message: "shed".into() };
        match decode(&encode_error(&err)).unwrap() {
            Msg::Error(d) => {
                assert_eq!(d.id, 7);
                assert_eq!(d.code, ErrorCode::OverQuota);
                assert_eq!(d.message, "shed");
            }
            other => panic!("decoded wrong kind: {other:?}"),
        }
    }

    /// Logits with special float values (−0.0, subnormals, NaN payloads)
    /// must cross the wire with their exact bit patterns.
    #[test]
    fn special_float_bits_survive() {
        let data = vec![-0.0f32, f32::MIN_POSITIVE / 2.0, f32::NAN, f32::INFINITY, -1.5e-42];
        let t = Tensor::from_vec(data, &[5]);
        let req = NetRequest {
            id: 1,
            tenant: "t".into(),
            class: QosClass::Gold,
            deadline_us: 0,
            image: t.clone(),
        };
        match decode(&encode_request(&req)).unwrap() {
            Msg::Request(d) => assert!(tensor_bits_equal(&d.image, &t)),
            other => panic!("decoded wrong kind: {other:?}"),
        }
    }

    /// Every strict prefix of a valid payload must fail with a typed
    /// error — no panics, no partial messages.
    #[test]
    fn truncated_payloads_are_rejected() {
        let req = NetRequest {
            id: 42,
            tenant: "acme".into(),
            class: QosClass::Standard,
            deadline_us: 1000,
            image: Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]),
        };
        let full = encode_request(&req);
        for cut in 0..full.len() {
            let err = decode(&full[..cut]).unwrap_err();
            assert!(
                matches!(
                    err,
                    DecodeError::Truncated | DecodeError::BadShape | DecodeError::Corrupt
                ),
                "prefix {cut}: unexpected error {err:?}"
            );
        }
        assert!(decode(&full).is_ok());
    }

    /// Health frames round-trip, including the empty probe and the new
    /// resilience error codes.
    #[test]
    fn health_and_resilience_codes_round_trip() {
        match decode(&encode_health_req()).unwrap() {
            Msg::HealthReq => {}
            other => panic!("decoded wrong kind: {other:?}"),
        }
        let health = NetHealth {
            lanes: vec![
                LaneHealthWire { label: "gold".into(), retired: false, restarts: 0, queued: 3 },
                LaneHealthWire { label: "economy".into(), retired: true, restarts: 4, queued: 0 },
            ],
        };
        match decode(&encode_health(&health)).unwrap() {
            Msg::Health(d) => assert_eq!(d, health),
            other => panic!("decoded wrong kind: {other:?}"),
        }
        match decode(&encode_health(&NetHealth { lanes: Vec::new() })).unwrap() {
            Msg::Health(d) => assert!(d.lanes.is_empty()),
            other => panic!("decoded wrong kind: {other:?}"),
        }
        for code in
            [ErrorCode::Timeout, ErrorCode::Internal, ErrorCode::BadInput, ErrorCode::Corrupt]
        {
            let err = NetError { id: 9, code, message: "late".into() };
            match decode(&encode_error(&err)).unwrap() {
                Msg::Error(d) => assert_eq!(d.code, code),
                other => panic!("decoded wrong kind: {other:?}"),
            }
        }
    }

    fn sample_stats(rng: &mut Rng) -> NetStats {
        let lanes = (0..3usize)
            .map(|i| LaneStatsWire {
                label: ["gold", "standard", "economy"][i].into(),
                retired: rng.below(2) == 1,
                restarts: rng.next_u64() >> 56,
                queued: rng.next_u64() >> 56,
                rung: 1 + rng.below(4) as u32,
                ladder: 4,
                swaps: rng.next_u64() >> 56,
                promotions: rng.next_u64() >> 56,
            })
            .collect();
        let tenants = (0..rng.below(4))
            .map(|i| TenantStatsWire { tenant: format!("t{i}"), tokens_milli: rng.next_u64() >> 32 })
            .collect();
        let stages = (0..rng.below(6))
            .map(|i| StageStatsWire {
                lane: "gold".into(),
                stage: format!("stage{i}"),
                count: rng.next_u64() >> 48,
                p50_us: rng.next_u64() >> 40,
                p99_us: rng.next_u64() >> 40,
                max_us: rng.next_u64() >> 40,
            })
            .collect();
        NetStats {
            uptime_ms: rng.next_u64() >> 24,
            total_requests: rng.next_u64() >> 24,
            integrity: IntegrityWire {
                scrub_passes: rng.next_u64() >> 48,
                scrub_repairs: rng.next_u64() >> 56,
                frame_crc_errors: rng.next_u64() >> 56,
                bad_inputs: rng.next_u64() >> 56,
                corrupt_outputs: rng.next_u64() >> 56,
            },
            lanes,
            tenants,
            stages,
        }
    }

    /// Stats frames round-trip exactly, including the empty probe and an
    /// all-empty report.
    #[test]
    fn stats_frames_round_trip() {
        match decode(&encode_stats_req()).unwrap() {
            Msg::StatsReq => {}
            other => panic!("decoded wrong kind: {other:?}"),
        }
        let mut rng = Rng::new(41);
        for _ in 0..40 {
            let stats = sample_stats(&mut rng);
            match decode(&encode_stats(&stats)).unwrap() {
                Msg::Stats(d) => assert_eq!(d, stats),
                other => panic!("decoded wrong kind: {other:?}"),
            }
        }
        let empty = NetStats {
            uptime_ms: 0,
            total_requests: 0,
            integrity: IntegrityWire::default(),
            lanes: Vec::new(),
            tenants: Vec::new(),
            stages: Vec::new(),
        };
        match decode(&encode_stats(&empty)).unwrap() {
            Msg::Stats(d) => assert_eq!(d, empty),
            other => panic!("decoded wrong kind: {other:?}"),
        }
    }

    /// Every strict prefix of a stats payload fails with a typed error,
    /// and trailing garbage after one is rejected.
    #[test]
    fn truncated_or_padded_stats_are_rejected() {
        let mut rng = Rng::new(43);
        let full = encode_stats(&sample_stats(&mut rng));
        for cut in 0..full.len() {
            let err = decode(&full[..cut]).unwrap_err();
            assert!(
                matches!(
                    err,
                    DecodeError::Truncated | DecodeError::BadShape | DecodeError::Corrupt
                ),
                "prefix {cut}: unexpected error {err:?}"
            );
        }
        // a raw extra byte breaks the CRC before trailing-byte detection
        let mut padded = full.clone();
        padded.push(0);
        assert_eq!(decode(&padded).unwrap_err(), DecodeError::Corrupt);
        // extra bytes *inside* a correctly sealed payload are trailing
        let mut body = full[..full.len() - 4].to_vec();
        body.push(0);
        assert_eq!(decode(&seal(body)).unwrap_err(), DecodeError::TrailingBytes { extra: 1 });
        assert!(decode(&full).is_ok());
    }

    /// Hostile stats counts beyond the sanity caps are refused before
    /// any allocation is sized from them.
    /// Header shared by the hand-built hostile stats payloads: version,
    /// kind, zeroed uptime/total and integrity counters.
    fn stats_header() -> Vec<u8> {
        let mut p = vec![PROTO_VERSION, KIND_STATS];
        for _ in 0..7 {
            p.extend_from_slice(&0u64.to_le_bytes());
        }
        p
    }

    #[test]
    fn hostile_stats_counts_are_refused() {
        let mut p = stats_header();
        p.extend_from_slice(&u16::MAX.to_le_bytes()); // absurd lane count
        assert_eq!(decode(&seal(p)).unwrap_err(), DecodeError::BadShape);

        let mut p = stats_header();
        p.extend_from_slice(&0u16.to_le_bytes()); // no lanes
        p.extend_from_slice(&u16::MAX.to_le_bytes()); // absurd tenant count
        assert_eq!(decode(&seal(p)).unwrap_err(), DecodeError::BadShape);

        let mut p = stats_header();
        p.extend_from_slice(&0u16.to_le_bytes());
        p.extend_from_slice(&0u16.to_le_bytes());
        p.extend_from_slice(&u16::MAX.to_le_bytes()); // absurd stage count
        assert_eq!(decode(&seal(p)).unwrap_err(), DecodeError::BadShape);
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let err = NetError { id: 1, code: ErrorCode::BadRequest, message: "x".into() };
        let sealed = encode_error(&err);
        // garbage appended after sealing breaks the CRC
        let mut p = sealed.clone();
        p.push(0xAB);
        assert_eq!(decode(&p).unwrap_err(), DecodeError::Corrupt);
        // garbage inside a correctly re-sealed payload is trailing bytes
        let mut body = sealed[..sealed.len() - 4].to_vec();
        body.push(0xAB);
        assert_eq!(decode(&seal(body)).unwrap_err(), DecodeError::TrailingBytes { extra: 1 });
    }

    /// The mutation sweep: flipping any single byte of any valid frame
    /// kind must yield a typed error (CRC or structural), never a panic
    /// and never a silently different message.
    #[test]
    fn single_byte_mutations_never_decode() {
        let mut rng = Rng::new(17);
        let frames: Vec<(&str, Vec<u8>)> = vec![
            (
                "request",
                encode_request(&NetRequest {
                    id: 3,
                    tenant: "acme".into(),
                    class: QosClass::Gold,
                    deadline_us: 500,
                    image: Tensor::from_vec(vec![1.0, -2.0, 0.5, 4.0], &[2, 2]),
                }),
            ),
            (
                "response",
                encode_response(&NetResponse {
                    id: 3,
                    class: QosClass::Gold,
                    served_by: "gold".into(),
                    lane_plan: "plan[30dB]".into(),
                    downgraded: false,
                    quota_downgraded: false,
                    deadline_missed: false,
                    queue_wait_us: 12,
                    batch_size: 1,
                    logits: Tensor::from_vec(vec![0.25, -0.5], &[2]),
                }),
            ),
            (
                "error",
                encode_error(&NetError {
                    id: 9,
                    code: ErrorCode::Corrupt,
                    message: "bad".into(),
                }),
            ),
            ("health_req", encode_health_req()),
            (
                "health",
                encode_health(&NetHealth {
                    lanes: vec![LaneHealthWire {
                        label: "gold".into(),
                        retired: false,
                        restarts: 1,
                        queued: 2,
                    }],
                }),
            ),
            ("stats_req", encode_stats_req()),
            ("stats", encode_stats(&sample_stats(&mut rng))),
        ];
        for (name, full) in frames {
            assert!(decode(&full).is_ok(), "{name}: pristine frame must decode");
            for pos in 0..full.len() {
                for flip in [0x01u8, 0x80, 0xFF] {
                    let mut p = full.clone();
                    p[pos] ^= flip;
                    let err = decode(&p).unwrap_err();
                    // position 0 is the version byte — rejected before
                    // the CRC so old peers are told about the version
                    if pos == 0 {
                        assert!(
                            matches!(err, DecodeError::BadVersion { .. }),
                            "{name} @0^{flip:#x}: {err:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let req = NetRequest {
            id: 1,
            tenant: "t".into(),
            class: QosClass::Gold,
            deadline_us: 0,
            image: Tensor::from_vec(vec![0.0], &[1]),
        };
        let mut p = encode_request(&req);
        p[0] = PROTO_VERSION + 1;
        assert_eq!(decode(&p).unwrap_err(), DecodeError::BadVersion { got: PROTO_VERSION + 1 });
    }

    #[test]
    fn unknown_kind_class_and_code_are_rejected() {
        assert_eq!(decode(&seal(vec![PROTO_VERSION, 9])).unwrap_err(), DecodeError::BadKind(9));
        // request with class byte 7 (sealed, so the CRC passes and the
        // enum check is what fires)
        let mut p = vec![PROTO_VERSION, KIND_REQUEST];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.extend_from_slice(&0u16.to_le_bytes()); // empty tenant
        p.push(7);
        assert_eq!(decode(&seal(p)).unwrap_err(), DecodeError::BadEnum(7));
    }

    /// Random byte soup must never decode successfully (version byte 1
    /// is excluded from position 0 to keep the property meaningful) and
    /// must never panic.
    #[test]
    fn garbage_never_decodes() {
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let n = rng.below(64);
            let mut p: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            if !p.is_empty() && p[0] == PROTO_VERSION {
                p[0] = PROTO_VERSION + 1;
            }
            assert!(decode(&p).is_err());
        }
    }

    /// A hostile tensor header (huge dims, overflowing element product)
    /// must be refused before any allocation is sized from it.
    #[test]
    fn hostile_shapes_are_refused() {
        // 2 dims of u32::MAX each: product overflows usize::checked_mul
        let mut p = vec![PROTO_VERSION, KIND_REQUEST];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.extend_from_slice(&0u16.to_le_bytes());
        p.push(0); // gold
        p.extend_from_slice(&0u64.to_le_bytes());
        p.push(2);
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = decode(&seal(p)).unwrap_err();
        assert!(matches!(err, DecodeError::BadShape), "{err:?}");
    }

    /// Framing: oversized length prefixes are an I/O error, a clean EOF
    /// between frames is `None`, and EOF mid-frame is an error.
    #[test]
    fn frame_reader_guards_length_and_eof() {
        let mut out = Vec::new();
        write_frame(&mut out, b"hello").unwrap();
        let mut r = &out[..];
        assert_eq!(read_frame(&mut r).unwrap().as_deref(), Some(&b"hello"[..]));
        assert_eq!(read_frame(&mut r).unwrap(), None);

        let huge = (MAX_FRAME_BYTES as u32 + 1).to_le_bytes();
        assert!(read_frame(&mut &huge[..]).is_err());

        let mut cut = Vec::new();
        write_frame(&mut cut, b"hello").unwrap();
        cut.truncate(cut.len() - 2);
        assert!(read_frame(&mut &cut[..]).is_err());
    }
}
