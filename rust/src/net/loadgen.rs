//! Open-loop load generation over the TCP front.
//!
//! The in-process loadgen (and most naive benchmarks) are *closed
//! loop*: each worker waits for a response before sending the next
//! request, so when the server slows down the offered load politely
//! slows down with it and the measured latency hides the stall —
//! coordinated omission. The open-loop engine here fixes every
//! *intended* send time up front from an arrival schedule (Poisson /
//! burst / diurnal), never re-anchors when it falls behind, and
//! measures each request's latency from its intended send instant —
//! so time the generator spends blocked on a saturated socket is
//! charged to the requests that should have been in flight, exactly as
//! a real client population would experience it.

use super::client::NetClient;
use super::proto::{ErrorCode, Reply};
use crate::coordinator::qos::QosClass;
use crate::coordinator::LogHistogram;
use crate::data::Rng;
use crate::obs::Clock;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::net::SocketAddr;
use std::time::{Duration, Instant};

/// An arrival process, parameterised by its *mean* request rate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalKind {
    /// Memoryless arrivals at a constant rate.
    Poisson { rps: f64 },
    /// Poisson base load with a `mult`× spike in the first quarter of
    /// every second — the traffic-spike scenario.
    Burst { rps: f64, mult: f64 },
    /// Rate follows a sinusoid with an 8 s period (±75 %), a compressed
    /// day/night cycle.
    Diurnal { rps: f64 },
}

impl ArrivalKind {
    /// Instantaneous rate at time `t` seconds into the run.
    fn rate_at(self, t: f64) -> f64 {
        match self {
            ArrivalKind::Poisson { rps } => rps,
            ArrivalKind::Burst { rps, mult } => {
                if t.fract() < 0.25 {
                    rps * mult
                } else {
                    rps
                }
            }
            ArrivalKind::Diurnal { rps } => {
                rps * (1.0 + 0.75 * (t * std::f64::consts::TAU / 8.0).sin())
            }
        }
    }
}

/// Parse `poisson:<rps>`, `burst:<rps>:<mult>` or `diurnal:<rps>`.
pub fn parse_arrivals(spec: &str) -> Result<ArrivalKind> {
    let mut parts = spec.split(':');
    let kind = parts.next().unwrap_or_default();
    let rps: f64 = parts
        .next()
        .with_context(|| format!("arrival spec `{spec}` is missing a rate"))?
        .parse()
        .with_context(|| format!("bad rate in arrival spec `{spec}`"))?;
    if !rps.is_finite() || rps <= 0.0 {
        bail!("arrival rate must be positive, got {rps}");
    }
    let kind = match kind {
        "poisson" => ArrivalKind::Poisson { rps },
        "burst" => {
            let mult: f64 = match parts.next() {
                Some(m) => m.parse().with_context(|| format!("bad mult in `{spec}`"))?,
                None => 4.0,
            };
            if !mult.is_finite() || mult < 1.0 {
                bail!("burst mult must be >= 1, got {mult}");
            }
            ArrivalKind::Burst { rps, mult }
        }
        "diurnal" => ArrivalKind::Diurnal { rps },
        other => bail!("unknown arrival kind `{other}` (poisson|burst|diurnal)"),
    };
    if parts.next().is_some() {
        bail!("trailing fields in arrival spec `{spec}`");
    }
    Ok(kind)
}

/// Draw `n` arrival offsets (relative to run start) by inverting the
/// exponential inter-arrival CDF at the instantaneous rate. Deterministic
/// in `seed`.
pub fn schedule(kind: ArrivalKind, n: usize, seed: u64) -> Vec<Duration> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let rate = kind.rate_at(t).max(1e-6);
        let u = rng.uniform().clamp(1e-12, 1.0 - 1e-12);
        t += -(1.0 - u).ln() / rate;
        out.push(Duration::from_secs_f64(t));
    }
    out
}

/// Per-run knobs shared by the open- and closed-loop engines.
#[derive(Debug, Clone)]
pub struct RunOpts {
    pub tenant: String,
    pub class: QosClass,
    /// Per-request relative deadline; `None` uses the class default.
    pub deadline: Option<Duration>,
    /// Artificial pause after *reading* each reply — models a slow
    /// client that drains its socket lazily (backpressure scenario).
    pub read_stall: Duration,
    /// Safety net so a wedged server fails the run instead of hanging.
    pub read_timeout: Duration,
}

impl Default for RunOpts {
    fn default() -> Self {
        Self {
            tenant: "default".to_string(),
            class: QosClass::Standard,
            deadline: None,
            read_stall: Duration::ZERO,
            read_timeout: Duration::from_secs(30),
        }
    }
}

/// What one generator run observed, from the client's side of the wire.
#[derive(Debug, Clone)]
pub struct RunStats {
    /// Scenario / run label.
    pub name: String,
    pub tenant: String,
    /// `"open-loop"` or `"closed-loop"`.
    pub mode: &'static str,
    pub sent: u64,
    /// Served responses (including deadline-missed ones).
    pub ok: u64,
    /// Error frames (quota rejections, bad requests, server gone) plus
    /// requests whose reply was lost to a dead connection — failed
    /// requests never silently shrink the sample.
    pub errors: u64,
    /// Typed `Timeout` refusals: requests reaped past their deadline.
    pub timeouts: u64,
    /// Reconnect-and-resend cycles (only a retrying driver records these).
    pub retries: u64,
    pub downgraded: u64,
    pub quota_downgraded: u64,
    pub deadline_missed: u64,
    /// Open loop: intended-send → reply. Closed loop: actual send → reply.
    pub latency_us: LogHistogram,
    pub wall: Duration,
}

impl RunStats {
    fn new(name: &str, tenant: &str, mode: &'static str) -> Self {
        Self {
            name: name.to_string(),
            tenant: tenant.to_string(),
            mode,
            sent: 0,
            ok: 0,
            errors: 0,
            timeouts: 0,
            retries: 0,
            downgraded: 0,
            quota_downgraded: 0,
            deadline_missed: 0,
            latency_us: LogHistogram::default(),
            wall: Duration::ZERO,
        }
    }

    /// Latency percentile in milliseconds.
    pub fn latency_p(&self, p: f64) -> f64 {
        self.latency_us.percentile(p) / 1000.0
    }

    /// Served responses per wall-clock second.
    pub fn throughput(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s <= 0.0 {
            return 0.0;
        }
        self.ok as f64 / s
    }

    fn absorb_reply(&mut self, reply: &Reply, latency: Option<Duration>) {
        match reply {
            Reply::Response(resp) => {
                self.ok += 1;
                if resp.downgraded {
                    self.downgraded += 1;
                }
                if resp.quota_downgraded {
                    self.quota_downgraded += 1;
                }
                if resp.deadline_missed {
                    self.deadline_missed += 1;
                }
                if let Some(l) = latency {
                    self.latency_us.record(l.as_micros() as u64);
                }
            }
            Reply::Error(e) if e.code == ErrorCode::Timeout => self.timeouts += 1,
            Reply::Error(_) => self.errors += 1,
        }
    }
}

/// Drive one connection open loop: send on the intended schedule (never
/// re-anchoring when behind), drain replies on a second thread, and
/// charge each reply's latency to its *intended* send instant.
pub fn run_open_loop(
    addr: SocketAddr,
    pool: &[Tensor],
    offsets: &[Duration],
    opts: &RunOpts,
    name: &str,
) -> Result<RunStats> {
    if pool.is_empty() || offsets.is_empty() {
        bail!("open-loop run needs a non-empty image pool and schedule");
    }
    let client = NetClient::connect(addr).context("connecting to the serving front")?;
    client.set_read_timeout(Some(opts.read_timeout))?;
    let (mut sender, mut receiver) = client.split();

    let start = Clock::now();
    let intended: Vec<Instant> = offsets.iter().map(|&off| start + off).collect();
    let n = intended.len();
    let read_stall = opts.read_stall;
    let intended_rx = intended.clone();
    let (name_owned, tenant_owned) = (name.to_string(), opts.tenant.clone());

    // replies return out of order; correlate by id (client ids are
    // 1, 2, 3, … so id i maps to intended[i - 1])
    let drain = std::thread::spawn(move || -> Result<RunStats> {
        let mut stats = RunStats::new(&name_owned, &tenant_owned, "open-loop");
        let mut seen = 0usize;
        while seen < n {
            let reply = match receiver.read_reply() {
                Ok(r) => r,
                Err(_) => {
                    // the connection died mid-drain: account every
                    // outstanding request as an error instead of
                    // failing the run and losing the sample
                    stats.errors += (n - seen) as u64;
                    break;
                }
            };
            let now = Clock::now();
            let latency = match &reply {
                Reply::Response(r) if r.id >= 1 && (r.id as usize) <= n => {
                    Some(now.saturating_duration_since(intended_rx[(r.id - 1) as usize]))
                }
                _ => None,
            };
            stats.absorb_reply(&reply, latency);
            seen += 1;
            if !read_stall.is_zero() {
                // LINT-ALLOW: bare-sleep — the slow-client scenario
                // models a real peer stalling its socket reads; it must
                // hold TCP backpressure for genuine wall time.
                std::thread::sleep(read_stall);
            }
        }
        Ok(stats)
    });

    let mut sent = 0u64;
    for (i, when) in intended.iter().enumerate() {
        let now = Clock::now();
        if *when > now {
            // LINT-ALLOW: bare-sleep — open-loop arrival pacing against
            // a real server socket; mocked time would collapse the
            // schedule and destroy the arrival process under test.
            std::thread::sleep(*when - now);
        }
        // behind schedule: send immediately, do NOT shift later arrivals
        sender
            .send(&opts.tenant, opts.class, opts.deadline, pool[i % pool.len()].clone())
            .context("sending a scheduled request")?;
        sent += 1;
    }
    sender.finish();

    let mut stats = drain.join().map_err(|_| anyhow::anyhow!("reply-drain thread panicked"))??;
    stats.sent = sent;
    stats.wall = start.elapsed();
    Ok(stats)
}

/// The coordinated-omission-prone reference: wait for each reply before
/// sending the next request; latency measured from the *actual* send.
pub fn run_closed_loop(
    addr: SocketAddr,
    pool: &[Tensor],
    n: usize,
    opts: &RunOpts,
    name: &str,
) -> Result<RunStats> {
    if pool.is_empty() || n == 0 {
        bail!("closed-loop run needs a non-empty image pool and request count");
    }
    let mut client = NetClient::connect(addr).context("connecting to the serving front")?;
    client.set_read_timeout(Some(opts.read_timeout))?;
    let mut stats = RunStats::new(name, &opts.tenant, "closed-loop");
    let start = Clock::now();
    for i in 0..n {
        let sent_at = Clock::now();
        client.send(&opts.tenant, opts.class, opts.deadline, pool[i % pool.len()].clone())?;
        let reply = client.read_reply().context("waiting for a reply")?;
        stats.absorb_reply(&reply, Some(sent_at.elapsed()));
        stats.sent += 1;
        if !opts.read_stall.is_zero() {
            // LINT-ALLOW: bare-sleep — same slow-client modelling as the
            // open-loop drain: real socket backpressure needs real time.
            std::thread::sleep(opts.read_stall);
        }
    }
    stats.wall = start.elapsed();
    Ok(stats)
}

/// Canonical scenario suite. `which` is `spike`, `tenant-mix`,
/// `slow-client` or `all`; `rps` scales every schedule and `n` is the
/// per-run request count.
pub fn run_scenarios(
    addr: SocketAddr,
    which: &str,
    pool: &[Tensor],
    n: usize,
    rps: f64,
    seed: u64,
) -> Result<Vec<RunStats>> {
    let mut out = Vec::new();
    let all = which == "all";
    let mut matched = all;
    if all || which == "spike" {
        matched = true;
        out.extend(scenario_spike(addr, pool, n, rps, seed)?);
    }
    if all || which == "tenant-mix" {
        matched = true;
        out.extend(scenario_tenant_mix(addr, pool, n, rps, seed)?);
    }
    if all || which == "slow-client" {
        matched = true;
        out.extend(scenario_slow_client(addr, pool, n, rps, seed)?);
    }
    if !matched {
        bail!("unknown scenario `{which}` (spike|tenant-mix|slow-client|all)");
    }
    Ok(out)
}

/// Traffic spike: open-loop burst arrivals (4× the base rate a quarter
/// of the time) against the standard class.
fn scenario_spike(
    addr: SocketAddr,
    pool: &[Tensor],
    n: usize,
    rps: f64,
    seed: u64,
) -> Result<Vec<RunStats>> {
    let offsets = schedule(ArrivalKind::Burst { rps, mult: 4.0 }, n, seed);
    let opts = RunOpts { tenant: "spike".to_string(), ..RunOpts::default() };
    Ok(vec![run_open_loop(addr, pool, &offsets, &opts, "spike")?])
}

/// Tenant mix: a flooding standard-class tenant (open loop, 4× rate)
/// alongside a polite gold-class VIP (closed loop). The VIP's p99 is the
/// number to watch.
fn scenario_tenant_mix(
    addr: SocketAddr,
    pool: &[Tensor],
    n: usize,
    rps: f64,
    seed: u64,
) -> Result<Vec<RunStats>> {
    let offsets = schedule(ArrivalKind::Poisson { rps: rps * 4.0 }, n, seed);
    let flood_pool: Vec<Tensor> = pool.to_vec();
    let flood = std::thread::spawn(move || -> Result<RunStats> {
        let opts = RunOpts { tenant: "flood".to_string(), ..RunOpts::default() };
        run_open_loop(addr, &flood_pool, &offsets, &opts, "tenant-mix")
    });
    let vip_opts =
        RunOpts { tenant: "vip".to_string(), class: QosClass::Gold, ..RunOpts::default() };
    let vip = run_closed_loop(addr, pool, n.div_ceil(4), &vip_opts, "tenant-mix");
    let flood = flood.join().map_err(|_| anyhow::anyhow!("flood thread panicked"))?;
    Ok(vec![flood?, vip?])
}

/// Slow client: a tenant that stalls between reads (its socket fills;
/// responses queue in its per-connection channel) while a concurrent
/// probe tenant verifies everyone else keeps their latency.
fn scenario_slow_client(
    addr: SocketAddr,
    pool: &[Tensor],
    n: usize,
    rps: f64,
    seed: u64,
) -> Result<Vec<RunStats>> {
    let sloth_n = n.min(32); // each reply stalls; keep the run bounded
    let offsets = schedule(ArrivalKind::Poisson { rps: rps * 2.0 }, sloth_n, seed);
    let sloth_pool: Vec<Tensor> = pool.to_vec();
    let sloth = std::thread::spawn(move || -> Result<RunStats> {
        let opts = RunOpts {
            tenant: "sloth".to_string(),
            read_stall: Duration::from_millis(25),
            ..RunOpts::default()
        };
        run_open_loop(addr, &sloth_pool, &offsets, &opts, "slow-client")
    });
    let probe_opts =
        RunOpts { tenant: "probe".to_string(), class: QosClass::Gold, ..RunOpts::default() };
    let probe = run_closed_loop(addr, pool, n.div_ceil(4), &probe_opts, "slow-client");
    let sloth = sloth.join().map_err(|_| anyhow::anyhow!("sloth thread panicked"))?;
    Ok(vec![sloth?, probe?])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_documented_specs() {
        assert_eq!(parse_arrivals("poisson:200").unwrap(), ArrivalKind::Poisson { rps: 200.0 });
        assert_eq!(
            parse_arrivals("burst:150:4").unwrap(),
            ArrivalKind::Burst { rps: 150.0, mult: 4.0 }
        );
        assert_eq!(
            parse_arrivals("burst:150").unwrap(),
            ArrivalKind::Burst { rps: 150.0, mult: 4.0 }
        );
        assert_eq!(parse_arrivals("diurnal:120").unwrap(), ArrivalKind::Diurnal { rps: 120.0 });
        for bad in ["poisson", "poisson:0", "burst:10:0.5", "nope:5", "poisson:5:9"] {
            assert!(parse_arrivals(bad).is_err(), "`{bad}` should be rejected");
        }
    }

    #[test]
    fn schedule_is_deterministic_monotone_and_rate_faithful() {
        let a = schedule(ArrivalKind::Poisson { rps: 1000.0 }, 4000, 7);
        let b = schedule(ArrivalKind::Poisson { rps: 1000.0 }, 4000, 7);
        assert_eq!(a, b, "same seed must give the same schedule");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "offsets must be sorted");
        // 4000 arrivals at 1000 rps should span ~4 s; the mean of the
        // exponential is 1/rate so the tolerance is generous
        let span = a.last().unwrap().as_secs_f64();
        assert!((2.5..6.0).contains(&span), "span {span} s is not near 4 s");
        let c = schedule(ArrivalKind::Poisson { rps: 1000.0 }, 4000, 8);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn burst_runs_hotter_than_its_base_rate() {
        let base = schedule(ArrivalKind::Poisson { rps: 200.0 }, 2000, 11);
        let burst = schedule(ArrivalKind::Burst { rps: 200.0, mult: 8.0 }, 2000, 11);
        // same arrival count at a (mean) higher rate ⇒ shorter span
        assert!(
            burst.last().unwrap() < base.last().unwrap(),
            "burst schedule should finish sooner than its base poisson"
        );
    }

    #[test]
    fn diurnal_rate_oscillates_but_stays_positive() {
        let kind = ArrivalKind::Diurnal { rps: 100.0 };
        let peak = kind.rate_at(2.0); // sin(2π·2/8) = 1
        let trough = kind.rate_at(6.0); // sin(2π·6/8) = −1
        assert!(peak > 160.0 && peak < 180.0, "peak {peak}");
        assert!(trough > 20.0 && trough < 30.0, "trough {trough}");
        let sched = schedule(kind, 500, 3);
        assert!(sched.windows(2).all(|w| w[0] <= w[1]));
    }
}
