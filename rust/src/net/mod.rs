//! Networked serving fabric: a zero-dependency TCP front for the QoS
//! precision router, plus the open-loop load generator that drives it.
//!
//! The paper's premise is that BFP-quantized inference is cheap enough
//! to serve at accelerator scale; this layer is the deployment surface
//! for that claim. Everything is built on blocking `std::net` sockets
//! and threads — the image is offline, so there is no async runtime and
//! no serialization crate:
//!
//! * [`proto`] — length-prefixed binary framing with a version byte,
//!   request ids, tenant ids and class/deadline fields. Logits travel
//!   as raw little-endian f32 bits, so a served tensor round-trips the
//!   wire bit-identically (the loopback integration test pins this
//!   against in-process [`crate::coordinator::QosServer::infer`]).
//! * [`server`] — an acceptor plus one reader and one writer thread per
//!   connection, feeding the existing `QosServer`. Responses return out
//!   of order as batches complete; a slow client only backs up its own
//!   connection (an unbounded per-connection channel decouples lane
//!   executors from client sockets), never the acceptor or other
//!   tenants.
//! * [`quota`] — per-tenant token buckets in front of admission:
//!   over-quota traffic degrades to the economy lane before it can
//!   starve gold, and sustained abuse is shed with an error frame.
//! * [`client`] — a reusable blocking client (loadgen, tests, demos),
//!   plus [`client::RetryingClient`]: reconnect + jittered exponential
//!   backoff across reset sockets and draining servers.
//! * [`loadgen`] — an open-loop arrival engine: Poisson/burst/diurnal
//!   schedules are fixed *before* the run and latency is measured from
//!   each request's intended send instant, so a backed-up server cannot
//!   hide queueing delay behind a stalled sender (no coordinated
//!   omission).

pub mod client;
pub mod loadgen;
pub mod proto;
pub mod quota;
pub mod server;

pub use client::{NetClient, RetryPolicy, RetryingClient};
pub use loadgen::{ArrivalKind, RunStats};
pub use proto::{
    LaneStatsWire, NetError, NetHealth, NetRequest, NetResponse, NetStats, Reply, StageStatsWire,
    TenantStatsWire,
};
pub use quota::{Admission, QuotaConfig};
pub use server::{NetServer, NetServerConfig};
