//! Blocking client for the TCP serving front.
//!
//! [`NetClient`] covers the simple request/response shape
//! ([`NetClient::infer`]) and the pipelined shape (`send` N ids, then
//! `read_reply` as responses stream back out of order). The load
//! generator splits the client into independently-owned sender and
//! receiver halves so intended-send pacing and reply draining can run
//! on separate threads over one connection. [`RetryingClient`] wraps the
//! one-shot shape with reconnect + jittered exponential backoff for
//! reset sockets and `ServerGone` refusals — safe because a request is
//! only ever retried on a *fresh* connection, so a reply can never be
//! double-matched.

use super::proto::{self, ErrorCode, Msg, NetHealth, NetRequest, NetResponse, NetStats, Reply};
use crate::coordinator::qos::QosClass;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};
use std::io::{self, BufReader};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// A blocking connection to a [`super::NetServer`].
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl NetClient {
    /// Connect to a serving front.
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<Self> {
        let writer = TcpStream::connect(addr)?;
        let _ = writer.set_nodelay(true);
        let reader = BufReader::new(writer.try_clone()?);
        Ok(Self { reader, writer, next_id: 0 })
    }

    /// Bound every read; `None` blocks forever.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> io::Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)
    }

    /// Fire one request without waiting; returns the id to correlate the
    /// eventual reply (ids are 1, 2, 3, … per connection).
    pub fn send(
        &mut self,
        tenant: &str,
        class: QosClass,
        deadline: Option<Duration>,
        image: Tensor,
    ) -> io::Result<u64> {
        self.next_id += 1;
        let id = self.next_id;
        let req = NetRequest {
            id,
            tenant: tenant.to_string(),
            class,
            deadline_us: deadline.map_or(0, |d| d.as_micros() as u64),
            image,
        };
        proto::write_frame(&mut self.writer, &proto::encode_request(&req))?;
        Ok(id)
    }

    /// Block for the next reply frame (any id — responses return out of
    /// order as server batches complete).
    pub fn read_reply(&mut self) -> Result<Reply> {
        read_reply_frame(&mut self.reader)
    }

    /// One synchronous request → response round trip; error frames
    /// become `Err`.
    pub fn infer(&mut self, tenant: &str, class: QosClass, image: Tensor) -> Result<NetResponse> {
        let id = self.send(tenant, class, None, image)?;
        match self.read_reply()? {
            Reply::Response(resp) => {
                ensure!(
                    resp.id == id,
                    "reply id {} does not match the lone in-flight request {id}",
                    resp.id
                );
                Ok(resp)
            }
            Reply::Error(e) => bail!("server refused request {}: {:?}: {}", e.id, e.code, e.message),
        }
    }

    /// Probe the server's lane health. Only valid with no in-flight
    /// requests on this connection — a pending response frame would be
    /// misread as the health answer.
    pub fn health(&mut self) -> Result<NetHealth> {
        proto::write_frame(&mut self.writer, &proto::encode_health_req())?;
        let Some(payload) = proto::read_frame(&mut self.reader)? else {
            bail!("server closed the connection before answering the health probe");
        };
        match proto::decode(&payload)? {
            Msg::Health(h) => Ok(h),
            other => bail!("expected a health frame, got {other:?}"),
        }
    }

    /// Probe the server's live serving stats (lane rungs, quota
    /// balances, stage latency attribution). Same contract as
    /// [`NetClient::health`]: only valid with no in-flight requests.
    pub fn stats(&mut self) -> Result<NetStats> {
        proto::write_frame(&mut self.writer, &proto::encode_stats_req())?;
        let Some(payload) = proto::read_frame(&mut self.reader)? else {
            bail!("server closed the connection before answering the stats probe");
        };
        match proto::decode(&payload)? {
            Msg::Stats(s) => Ok(s),
            other => bail!("expected a stats frame, got {other:?}"),
        }
    }

    /// Split into independently-owned halves so a paced sender thread
    /// and a draining receiver thread can share the connection.
    pub fn split(self) -> (NetSender, NetReceiver) {
        (
            NetSender { stream: self.writer, next_id: self.next_id },
            NetReceiver { reader: self.reader },
        )
    }
}

/// The write half of a split [`NetClient`].
pub struct NetSender {
    stream: TcpStream,
    next_id: u64,
}

impl NetSender {
    /// Same contract as [`NetClient::send`].
    pub fn send(
        &mut self,
        tenant: &str,
        class: QosClass,
        deadline: Option<Duration>,
        image: Tensor,
    ) -> io::Result<u64> {
        self.next_id += 1;
        let id = self.next_id;
        let req = NetRequest {
            id,
            tenant: tenant.to_string(),
            class,
            deadline_us: deadline.map_or(0, |d| d.as_micros() as u64),
            image,
        };
        proto::write_frame(&mut self.stream, &proto::encode_request(&req))?;
        Ok(id)
    }

    /// Half-close the write side so the server sees a clean EOF while
    /// the receiver half keeps draining replies.
    pub fn finish(self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Write);
    }
}

/// The read half of a split [`NetClient`].
pub struct NetReceiver {
    reader: BufReader<TcpStream>,
}

impl NetReceiver {
    /// Bound every read; `None` blocks forever.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> io::Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)
    }

    /// Same contract as [`NetClient::read_reply`].
    pub fn read_reply(&mut self) -> Result<Reply> {
        read_reply_frame(&mut self.reader)
    }
}

fn read_reply_frame(reader: &mut BufReader<TcpStream>) -> Result<Reply> {
    let Some(payload) = proto::read_frame(reader)? else {
        bail!("server closed the connection");
    };
    match proto::decode(&payload)? {
        Msg::Response(resp) => Ok(Reply::Response(resp)),
        Msg::Error(err) => Ok(Reply::Error(err)),
        other => bail!("unexpected frame from the server: {other:?}"),
    }
}

// ---- retrying client -------------------------------------------------

/// Reconnect/backoff policy for [`RetryingClient`].
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts per request (first try included).
    pub attempts: u32,
    /// Backoff before the first retry; doubles per retry (jittered).
    pub base: Duration,
    /// Backoff ceiling.
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { attempts: 4, base: Duration::from_millis(20), cap: Duration::from_millis(500) }
    }
}

/// How one [`RetryingClient`] attempt ended: served, refused for good,
/// or lost to a transport fault / draining server (worth a retry).
enum Attempt {
    Served(NetResponse),
    Final(anyhow::Error),
    Lost(anyhow::Error),
}

/// A one-shot client that survives reset sockets, server restarts, and
/// corrupted frames: on a transport error, a `ServerGone` refusal, a
/// `Corrupt` refusal (the server's CRC check rejected our request), or
/// a reply that fails our own CRC check, it drops the connection,
/// sleeps a jittered exponential backoff, reconnects, and resends.
/// Requests are only retried on a fresh connection (one request in
/// flight at a time), so stale replies cannot be matched to a retried
/// request. Typed refusals other than `ServerGone`/`Corrupt` are the
/// server's final word and are not retried.
pub struct RetryingClient {
    addr: String,
    policy: RetryPolicy,
    read_timeout: Option<Duration>,
    inner: Option<NetClient>,
    /// xorshift64 state for backoff jitter (seeded, deterministic).
    rng: u64,
    /// Reconnect-and-resend cycles performed over this client's life.
    pub retries: u64,
}

impl RetryingClient {
    /// Lazily-connecting client; `seed` fixes the jitter sequence.
    pub fn new(addr: impl Into<String>, policy: RetryPolicy, seed: u64) -> Self {
        Self {
            addr: addr.into(),
            policy,
            read_timeout: None,
            inner: None,
            rng: seed | 1,
            retries: 0,
        }
    }

    /// Bound every read on current and future connections.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) {
        self.read_timeout = timeout;
        if let Some(c) = &self.inner {
            let _ = c.set_read_timeout(timeout);
        }
    }

    fn next_jitter(&mut self, bound: u64) -> u64 {
        let mut x = self.rng;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng = x;
        x % bound
    }

    fn connect(&mut self) -> Result<&mut NetClient> {
        if self.inner.is_none() {
            let client = NetClient::connect(&self.addr)?;
            client.set_read_timeout(self.read_timeout)?;
            self.inner = Some(client);
        }
        match self.inner.as_mut() {
            Some(c) => Ok(c),
            None => Err(anyhow::anyhow!("connection closed while connecting")),
        }
    }

    /// One attempt over the current (or a fresh) connection.
    fn try_once(&mut self, tenant: &str, class: QosClass, image: &Tensor) -> Attempt {
        let client = match self.connect() {
            Ok(c) => c,
            Err(e) => return Attempt::Lost(e),
        };
        let id = match client.send(tenant, class, None, image.clone()) {
            Ok(id) => id,
            Err(e) => return Attempt::Lost(e.into()),
        };
        match client.read_reply() {
            Ok(Reply::Response(resp)) if resp.id == id => Attempt::Served(resp),
            Ok(Reply::Response(resp)) => Attempt::Lost(anyhow::anyhow!(
                "reply id {} does not match the lone in-flight request {id}",
                resp.id
            )),
            // the fabric behind this socket is going away — reconnect
            Ok(Reply::Error(e)) if e.code == ErrorCode::ServerGone => {
                Attempt::Lost(anyhow::anyhow!("server gone: {}", e.message))
            }
            // bits flipped somewhere between us and the server (either
            // our request failed its CRC there, or the reply frame is
            // refusing to decode here — the transport is suspect either
            // way): retry on a fresh connection
            Ok(Reply::Error(e)) if e.code == ErrorCode::Corrupt => {
                Attempt::Lost(anyhow::anyhow!("corrupt frame: {}", e.message))
            }
            // any other typed refusal is the server's final word
            Ok(Reply::Error(e)) => Attempt::Final(anyhow::anyhow!(
                "server refused request {}: {:?}: {}",
                e.id,
                e.code,
                e.message
            )),
            Err(e) => Attempt::Lost(e),
        }
    }

    /// One request → response, retried across reconnects. `Err` means
    /// the attempts are exhausted or the server refused the request
    /// with a final (non-`ServerGone`) error.
    pub fn infer(&mut self, tenant: &str, class: QosClass, image: Tensor) -> Result<NetResponse> {
        let mut backoff = self.policy.base.max(Duration::from_millis(1));
        let mut last_err = None;
        for attempt in 0..self.policy.attempts.max(1) {
            if attempt > 0 {
                self.retries += 1;
                let half = (backoff.as_millis() as u64) / 2;
                let jitter = self.next_jitter(half + 1);
                // LINT-ALLOW: bare-sleep — reconnect pacing against a
                // *remote* server must burn real wall time; a mocked
                // fast-forward would hammer a struggling peer.
                std::thread::sleep(Duration::from_millis(half + jitter));
                backoff = (backoff * 2).min(self.policy.cap);
            }
            match self.try_once(tenant, class, &image) {
                Attempt::Served(resp) => return Ok(resp),
                // the connection stays healthy after a typed refusal
                Attempt::Final(e) => return Err(e),
                Attempt::Lost(e) => {
                    self.inner = None;
                    last_err = Some(e);
                }
            }
        }
        let attempts = self.policy.attempts.max(1);
        let e = last_err
            .unwrap_or_else(|| anyhow::anyhow!("no attempt ran (attempt budget is zero?)"));
        Err(e.context(format!("request still failing after {attempts} attempts")))
    }

    /// Probe lane health, reconnecting if needed (no retries — health is
    /// advisory and the caller polls anyway).
    pub fn health(&mut self) -> Result<NetHealth> {
        let out = self.connect()?.health();
        if out.is_err() {
            self.inner = None;
        }
        out
    }

    /// Probe serving stats, reconnecting if needed (no retries — the
    /// caller polls anyway, e.g. the `top` dashboard).
    pub fn stats(&mut self) -> Result<NetStats> {
        let out = self.connect()?.stats();
        if out.is_err() {
            self.inner = None;
        }
        out
    }
}
