//! Blocking client for the TCP serving front.
//!
//! [`NetClient`] covers the simple request/response shape
//! ([`NetClient::infer`]) and the pipelined shape (`send` N ids, then
//! `read_reply` as responses stream back out of order). The load
//! generator splits the client into independently-owned sender and
//! receiver halves so intended-send pacing and reply draining can run
//! on separate threads over one connection.

use super::proto::{self, Msg, NetRequest, NetResponse, Reply};
use crate::coordinator::qos::QosClass;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};
use std::io::{self, BufReader};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// A blocking connection to a [`super::NetServer`].
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl NetClient {
    /// Connect to a serving front.
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<Self> {
        let writer = TcpStream::connect(addr)?;
        let _ = writer.set_nodelay(true);
        let reader = BufReader::new(writer.try_clone()?);
        Ok(Self { reader, writer, next_id: 0 })
    }

    /// Bound every read; `None` blocks forever.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> io::Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)
    }

    /// Fire one request without waiting; returns the id to correlate the
    /// eventual reply (ids are 1, 2, 3, … per connection).
    pub fn send(
        &mut self,
        tenant: &str,
        class: QosClass,
        deadline: Option<Duration>,
        image: Tensor,
    ) -> io::Result<u64> {
        self.next_id += 1;
        let id = self.next_id;
        let req = NetRequest {
            id,
            tenant: tenant.to_string(),
            class,
            deadline_us: deadline.map_or(0, |d| d.as_micros() as u64),
            image,
        };
        proto::write_frame(&mut self.writer, &proto::encode_request(&req))?;
        Ok(id)
    }

    /// Block for the next reply frame (any id — responses return out of
    /// order as server batches complete).
    pub fn read_reply(&mut self) -> Result<Reply> {
        read_reply_frame(&mut self.reader)
    }

    /// One synchronous request → response round trip; error frames
    /// become `Err`.
    pub fn infer(&mut self, tenant: &str, class: QosClass, image: Tensor) -> Result<NetResponse> {
        let id = self.send(tenant, class, None, image)?;
        match self.read_reply()? {
            Reply::Response(resp) => {
                ensure!(
                    resp.id == id,
                    "reply id {} does not match the lone in-flight request {id}",
                    resp.id
                );
                Ok(resp)
            }
            Reply::Error(e) => bail!("server refused request {}: {:?}: {}", e.id, e.code, e.message),
        }
    }

    /// Split into independently-owned halves so a paced sender thread
    /// and a draining receiver thread can share the connection.
    pub fn split(self) -> (NetSender, NetReceiver) {
        (
            NetSender { stream: self.writer, next_id: self.next_id },
            NetReceiver { reader: self.reader },
        )
    }
}

/// The write half of a split [`NetClient`].
pub struct NetSender {
    stream: TcpStream,
    next_id: u64,
}

impl NetSender {
    /// Same contract as [`NetClient::send`].
    pub fn send(
        &mut self,
        tenant: &str,
        class: QosClass,
        deadline: Option<Duration>,
        image: Tensor,
    ) -> io::Result<u64> {
        self.next_id += 1;
        let id = self.next_id;
        let req = NetRequest {
            id,
            tenant: tenant.to_string(),
            class,
            deadline_us: deadline.map_or(0, |d| d.as_micros() as u64),
            image,
        };
        proto::write_frame(&mut self.stream, &proto::encode_request(&req))?;
        Ok(id)
    }

    /// Half-close the write side so the server sees a clean EOF while
    /// the receiver half keeps draining replies.
    pub fn finish(self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Write);
    }
}

/// The read half of a split [`NetClient`].
pub struct NetReceiver {
    reader: BufReader<TcpStream>,
}

impl NetReceiver {
    /// Bound every read; `None` blocks forever.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> io::Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)
    }

    /// Same contract as [`NetClient::read_reply`].
    pub fn read_reply(&mut self) -> Result<Reply> {
        read_reply_frame(&mut self.reader)
    }
}

fn read_reply_frame(reader: &mut BufReader<TcpStream>) -> Result<Reply> {
    let Some(payload) = proto::read_frame(reader)? else {
        bail!("server closed the connection");
    };
    match proto::decode(&payload)? {
        Msg::Response(resp) => Ok(Reply::Response(resp)),
        Msg::Error(err) => Ok(Reply::Error(err)),
        Msg::Request(_) => bail!("server sent a request frame to a client"),
    }
}
