//! TCP serving front over the QoS precision router.
//!
//! Thread shape: one nonblocking acceptor polls for connections and
//! enforces the `max_conns` admission cap; each admitted connection
//! gets a *reader* thread (frames → quota gate → `QosServer`) and a
//! *writer* thread (QoS responses → frames, out of order as batches
//! complete). Responses flow through an unbounded per-connection
//! channel, so a client that stops reading only fills its own channel
//! and socket buffer — lane executors, the acceptor and every other
//! connection keep moving. The reader and writer share the socket for
//! writing behind one mutex (error frames come from the reader path,
//! responses from the writer path), keeping frames interleave-safe.

use super::proto::{
    self, ErrorCode, IntegrityWire, LaneHealthWire, LaneStatsWire, Msg, NetError, NetHealth,
    NetRequest, NetResponse, NetStats, StageStatsWire, TenantStatsWire,
};
use super::quota::{Admission, QuotaConfig, TenantQuotas};
use crate::coordinator::qos::{LaneStats, QosClass, QosErrorKind, QosReport, QosResult, QosServer};
use crate::coordinator::{stage_rows, Metrics};
use crate::runtime::faults::{ConnFault, FaultInjector};
use std::collections::HashMap;
use std::io::{self, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Knobs for the TCP front.
#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Connection-level admission: beyond this many live connections a
    /// new one is refused with a `ConnLimit` error frame and closed.
    pub max_conns: usize,
    /// Per-tenant token-bucket quota (default: unlimited).
    pub quota: QuotaConfig,
    /// Network-front fault injection (`reset:conn:*` / `truncate:conn:*`
    /// / `corrupt:frame:*` connection sabotage, `nan:input:*` payload
    /// poisoning); `None` costs nothing.
    pub faults: Option<Arc<FaultInjector>>,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        Self { max_conns: 256, quota: QuotaConfig::default(), faults: FaultInjector::from_env() }
    }
}

/// State shared by the acceptor and every connection thread.
struct Shared {
    /// The QoS server, taken out at shutdown. Submissions hold the lock
    /// only to push onto the router's unbounded queue — never across a
    /// forward.
    qos: Mutex<Option<QosServer>>,
    metrics: Arc<Mutex<Metrics>>,
    quotas: TenantQuotas,
}

/// Handle to a running TCP front. Dropping it without
/// [`NetServer::shutdown`] leaks the serving threads (matching the
/// `QosServer` convention: shutdown is explicit because it returns the
/// report).
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    /// Drain-style stop: half-close connections (read side) so queued
    /// responses still flush, instead of hard-closing the sockets.
    drain: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl NetServer {
    /// Put a TCP front over `qos`. The listener may be bound to port 0;
    /// the resolved address is [`NetServer::addr`].
    pub fn start(
        listener: TcpListener,
        qos: QosServer,
        config: NetServerConfig,
    ) -> io::Result<Self> {
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let drain = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(Shared {
            metrics: qos.metrics_handle(),
            qos: Mutex::new(Some(qos)),
            quotas: TenantQuotas::new(config.quota),
        });
        let acceptor = {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            let drain = Arc::clone(&drain);
            std::thread::Builder::new()
                .name("net-acceptor".into())
                .spawn(move || accept_loop(listener, shared, stop, drain, config))?
        };
        Ok(Self { addr, stop, drain, acceptor: Some(acceptor), shared })
    }

    /// The bound address (resolves `--listen 127.0.0.1:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, close every connection, drain the router and
    /// return its final report (tenant accounting included).
    pub fn shutdown(mut self) -> QosReport {
        // SeqCst: stop/drain form a two-flag protocol with the acceptor;
        // Release/Acquire would suffice (join() below is the real sync
        // point), but the shutdown path is cold so keep SeqCst for the
        // simpler single-total-order reading.
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let qos = self
            .shared
            .qos
            .lock()
            .unwrap()
            .take()
            // LINT-ALLOW: serving-unwrap — the net server owns the qos
            // router from construction to this take(); absence is a bug
            .expect("the net server owns the qos server until shutdown");
        qos.shutdown()
    }

    /// Graceful stop: refuse new submits immediately, give requests
    /// already queued up to `bound` to be served (anything still queued
    /// after that fails with a typed `Draining` error), half-close the
    /// connections so every pending reply still flushes, and return the
    /// final report. No request this server accepted goes unanswered.
    // LOCK-ORDER: shared.qos is taken and released before the acceptor
    // join; the second take happens after the acceptor (and with it
    // every connection thread) is gone, so the two lock scopes never
    // overlap another holder.
    pub fn shutdown_with_drain(mut self, bound: Duration) -> QosReport {
        if let Some(qos) = self.shared.qos.lock().unwrap().as_ref() {
            qos.begin_drain(bound);
        }
        // SeqCst ×2: drain must be observable before stop so the
        // acceptor picks Shutdown::Read; a Release/Acquire pair would
        // do, but this cold path keeps SeqCst so the two flags read as
        // one totally-ordered protocol.
        self.drain.store(true, Ordering::SeqCst);
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let qos = self
            .shared
            .qos
            .lock()
            .unwrap()
            .take()
            // LINT-ALLOW: serving-unwrap — the net server owns the qos
            // router from construction to this take(); absence is a bug
            .expect("the net server owns the qos server until shutdown");
        qos.shutdown()
    }
}

/// Accept connections until the stop flag. Nonblocking accept + sleep
/// keeps the loop responsive to shutdown without platform-specific
/// selectors; finished connection threads are reaped on each accept so
/// the admission count tracks *live* connections.
fn accept_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    config: NetServerConfig,
) {
    let mut conns: Vec<(TcpStream, JoinHandle<()>)> = Vec::new();
    // SeqCst: pairs with the SeqCst stores in shutdown(); the poll loop
    // re-reads every 2ms so even a relaxed load would converge, but the
    // flag stays SeqCst to match its writers.
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                conns.retain(|(_, h)| !h.is_finished());
                if conns.len() >= config.max_conns {
                    refuse(stream, config.max_conns);
                    continue;
                }
                let _ = stream.set_nodelay(true);
                let fault = config.faults.as_ref().map_or(ConnFault::None, |f| f.on_conn());
                let handle = match stream.try_clone() {
                    Ok(keep) => {
                        let shared = Arc::clone(&shared);
                        let faults = config.faults.clone();
                        let spawned =
                            std::thread::Builder::new().name("net-conn".into()).spawn(move || {
                                match fault {
                                    ConnFault::None => serve_conn(stream, shared, faults),
                                    f => sabotage_conn(stream, f),
                                }
                            });
                        match spawned {
                            Ok(h) => Some((keep, h)),
                            Err(_) => None,
                        }
                    }
                    Err(_) => None,
                };
                if let Some(entry) = handle {
                    conns.push(entry);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                // LINT-ALLOW: bare-sleep — nonblocking-accept poll
                // against a real OS socket; mocked time cannot make the
                // kernel deliver a connection sooner.
                std::thread::sleep(Duration::from_millis(2));
            }
            // LINT-ALLOW: bare-sleep — same accept-poll backoff as above.
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
    // shutdown: close the sockets so blocked readers wake, then join
    // every connection thread (each joins its own writer). A drain stop
    // half-closes (read side only): readers see EOF and stop taking new
    // work, while the write side stays open for every queued reply.
    // SeqCst: pairs with shutdown_with_drain's SeqCst store; drain was
    // written before stop, and this load runs after the stop load broke
    // the loop, so SeqCst's total order guarantees we see it. A
    // downgrade from SeqCst to Acquire would also be correct but this
    // runs once per server lifetime.
    let how = if drain.load(Ordering::SeqCst) { Shutdown::Read } else { Shutdown::Both };
    for (s, _) in &conns {
        let _ = s.shutdown(how);
    }
    for (_, h) in conns {
        let _ = h.join();
    }
}

/// Deliberately break one connection (fault injection): wait for the
/// client's first request so it is mid-round-trip, then reset the
/// socket outright, answer with a truncated frame — a length prefix
/// promising more bytes than ever arrive — or answer with a whole,
/// well-framed reply whose payload had bits flipped after sealing
/// (the client's CRC check must refuse it), and close.
fn sabotage_conn(stream: TcpStream, fault: ConnFault) {
    let reader_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut frames = BufReader::new(reader_half);
    let _ = proto::read_frame(&mut frames);
    let mut w = stream;
    match fault {
        ConnFault::Truncate => {
            let _ = w.write_all(&64u32.to_le_bytes());
            let _ = w.write_all(&[proto::PROTO_VERSION, 2, 0]);
            let _ = w.flush();
        }
        ConnFault::Corrupt => {
            // framing stays in sync — the length prefix is honest — but
            // the payload no longer matches its trailing CRC
            let mut payload = proto::encode_error(&NetError {
                id: 0,
                code: ErrorCode::Internal,
                message: "this frame was corrupted in flight".to_string(),
            });
            let mid = payload.len() / 2;
            payload[mid] ^= 0x10;
            let _ = proto::write_frame(&mut w, &payload);
            let _ = w.flush();
        }
        _ => {}
    }
    let _ = w.shutdown(Shutdown::Both);
}

/// Refuse an over-limit connection with an error frame, then close it.
fn refuse(mut stream: TcpStream, max_conns: usize) {
    let err = NetError {
        id: 0,
        code: ErrorCode::ConnLimit,
        message: format!("server is at its {max_conns}-connection limit"),
    };
    let _ = proto::write_frame(&mut stream, &proto::encode_error(&err));
    let _ = stream.shutdown(Shutdown::Both);
}

/// Client-side context for one in-flight request, keyed by the router's
/// internal id (client ids are only unique per connection).
struct ReqCtx {
    client_id: u64,
    class: QosClass,
    quota_downgraded: bool,
}

/// One connection: read frames until EOF/error, submit to the router,
/// let the writer thread stream responses back out of order.
// LOCK-ORDER: pending → write_half (writer thread), and shared.qos /
// shared.metrics are each taken alone; no scope ever holds two of
// {qos, metrics, pending, write_half} except pending-then-write_half,
// which every path takes in that same order.
fn serve_conn(stream: TcpStream, shared: Arc<Shared>, faults: Option<Arc<FaultInjector>>) {
    let reader_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let write_half = Arc::new(Mutex::new(stream));
    let pending: Arc<Mutex<HashMap<u64, ReqCtx>>> = Arc::new(Mutex::new(HashMap::new()));
    let (resp_tx, resp_rx) = channel::<QosResult>();

    let writer = {
        let write_half = Arc::clone(&write_half);
        let pending = Arc::clone(&pending);
        std::thread::Builder::new().name("net-writer".into()).spawn(move || {
            // exits when every Sender clone is gone: the reader's handle
            // plus one per in-flight request — i.e. after the router has
            // answered everything this connection submitted
            while let Ok(result) = resp_rx.recv() {
                let frame = match result {
                    Ok(resp) => {
                        let ctx = pending.lock().unwrap().remove(&resp.id);
                        let Some(ctx) = ctx else { continue };
                        proto::encode_response(&NetResponse {
                            id: ctx.client_id,
                            class: ctx.class,
                            served_by: resp.served_by,
                            lane_plan: resp.lane_plan,
                            downgraded: resp.downgraded || ctx.quota_downgraded,
                            quota_downgraded: ctx.quota_downgraded,
                            deadline_missed: resp.deadline_missed,
                            queue_wait_us: resp.queue_wait.as_micros() as u64,
                            batch_size: resp.batch_size as u32,
                            logits: resp.logits,
                        })
                    }
                    // typed per-request failures (reaped, executor
                    // panic, retired lane, drain) become error frames
                    Err(e) => {
                        let ctx = pending.lock().unwrap().remove(&e.id);
                        let Some(ctx) = ctx else { continue };
                        let code = match e.kind {
                            QosErrorKind::Timeout => ErrorCode::Timeout,
                            QosErrorKind::Draining => ErrorCode::ServerGone,
                            QosErrorKind::CorruptOutput => ErrorCode::Corrupt,
                            QosErrorKind::ExecutorPanic | QosErrorKind::LaneRetired => {
                                ErrorCode::Internal
                            }
                        };
                        let err = NetError { id: ctx.client_id, code, message: e.to_string() };
                        proto::encode_error(&err)
                    }
                };
                let span = crate::obs::span(crate::obs::Stage::Reply);
                let sent = write_frame_locked(&write_half, &frame);
                drop(span);
                if sent.is_err() {
                    break; // client gone; in-flight responses are dropped
                }
            }
        })
    };
    let writer = match writer {
        Ok(w) => w,
        Err(_) => return,
    };

    let mut frames = BufReader::new(reader_half);
    loop {
        let payload = match proto::read_frame(&mut frames) {
            Ok(Some(p)) => p,
            Ok(None) => break, // clean EOF between frames
            Err(_) => {
                // framing desynced (hostile length prefix, mid-frame
                // EOF): the stream cannot be trusted any further
                send_error(&write_half, 0, ErrorCode::BadRequest, "unreadable frame");
                break;
            }
        };
        match proto::decode(&payload) {
            Ok(Msg::Request(req)) => {
                handle_request(req, &shared, &write_half, &pending, &resp_tx, faults.as_deref());
            }
            Ok(Msg::HealthReq) => {
                let lanes = shared.qos.lock().unwrap().as_ref().map(|q| q.health());
                match lanes {
                    Some(lanes) => {
                        let wire: Vec<LaneHealthWire> = lanes
                            .into_iter()
                            .map(|l| LaneHealthWire {
                                label: l.label,
                                retired: l.retired,
                                restarts: l.restarts,
                                queued: l.queued,
                            })
                            .collect();
                        let frame = proto::encode_health(&NetHealth { lanes: wire });
                        if write_frame_locked(&write_half, &frame).is_err() {
                            break;
                        }
                    }
                    None => {
                        send_error(&write_half, 0, ErrorCode::ServerGone, "server is shutting down")
                    }
                }
            }
            Ok(Msg::StatsReq) => {
                let snap = shared.qos.lock().unwrap().as_ref().map(|q| (q.stats(), q.metrics()));
                match snap {
                    Some((lanes, metrics)) => {
                        let stats = build_stats(lanes, &metrics, &shared.quotas);
                        let frame = proto::encode_stats(&stats);
                        if write_frame_locked(&write_half, &frame).is_err() {
                            break;
                        }
                    }
                    None => {
                        send_error(&write_half, 0, ErrorCode::ServerGone, "server is shutting down")
                    }
                }
            }
            Ok(_) => {
                // frame parsed but isn't a request; the stream is still
                // in sync, so answer and keep serving
                send_error(&write_half, 0, ErrorCode::BadRequest, "expected a request frame");
            }
            Err(proto::DecodeError::Corrupt) => {
                // the frame arrived whole but its payload CRC does not
                // match: bits flipped between the peer's seal and us.
                // The length prefix was honest, so framing is still in
                // sync — count it, answer typed, keep serving
                shared.metrics.lock().unwrap().record_frame_crc_error();
                send_error(&write_half, 0, ErrorCode::Corrupt, "payload CRC mismatch");
            }
            Err(e) => {
                send_error(&write_half, 0, ErrorCode::BadRequest, &format!("bad frame: {e}"));
            }
        }
    }
    drop(resp_tx);
    let _ = writer.join();
    let _ = write_half.lock().unwrap().shutdown(Shutdown::Both);
}

/// Admission guard: a request tensor that is empty, inconsistent with
/// its declared shape, or contains non-finite values is refused with a
/// typed `BadInput` before it can reach a lane. Decode already refuses
/// hostile shapes, so this catches payload memory that went bad *after*
/// the frame CRC passed (and the `nan:input` fault plane, which models
/// exactly that).
fn validate_image(image: &crate::tensor::Tensor) -> Option<String> {
    let elems: usize = image.shape.iter().product();
    if image.shape.is_empty() || elems == 0 {
        return Some("empty input tensor".to_string());
    }
    if image.data.len() != elems {
        return Some(format!(
            "input data length {} does not match shape {:?}",
            image.data.len(),
            image.shape
        ));
    }
    if let Some(pos) = image.data.iter().position(|v| !v.is_finite()) {
        return Some(format!("non-finite input value at index {pos}"));
    }
    None
}

/// Validate, quota-gate, and hand one request to the router.
// LOCK-ORDER: metrics alone, then qos → pending; write_half is only
// taken by send_error with no other lock held except qos (qos →
// write_half), so the global order is qos → {pending, write_half},
// metrics disjoint — consistent with serve_conn's pending → write_half
// because no path here holds pending while writing.
fn handle_request(
    mut req: NetRequest,
    shared: &Shared,
    write_half: &Arc<Mutex<TcpStream>>,
    pending: &Arc<Mutex<HashMap<u64, ReqCtx>>>,
    resp_tx: &Sender<QosResult>,
    faults: Option<&FaultInjector>,
) {
    // deterministic fault injection (`nan:input:<nth>`): poison this
    // request's payload after the CRC check — the guard below must
    // catch it, fail it typed, and never enqueue it
    if let Some(f) = faults {
        if f.poison_input() {
            if let Some(v) = req.image.data.first_mut() {
                *v = f32::NAN;
            }
        }
    }
    if let Some(reason) = validate_image(&req.image) {
        shared.metrics.lock().unwrap().record_bad_input();
        send_error(write_half, req.id, ErrorCode::BadInput, &reason);
        return;
    }
    let admission = shared.quotas.admit(&req.tenant);
    shared.metrics.lock().unwrap().record_tenant(
        &req.tenant,
        admission == Admission::Degrade,
        admission == Admission::Reject,
    );
    if admission == Admission::Reject {
        let msg = format!("tenant `{}` is over its hard quota; request shed", req.tenant);
        send_error(write_half, req.id, ErrorCode::OverQuota, &msg);
        return;
    }
    // over-quota traffic is degraded straight to the cheapest class: it
    // keeps being served, but can no longer contend with in-quota gold
    let effective = match admission {
        Admission::Degrade => QosClass::Economy,
        _ => req.class,
    };
    let quota_downgraded = effective != req.class;
    let deadline = if req.deadline_us == 0 {
        effective.default_deadline()
    } else {
        Duration::from_micros(req.deadline_us)
    };

    let mut qos = shared.qos.lock().unwrap();
    let Some(qos) = qos.as_mut() else {
        send_error(write_half, req.id, ErrorCode::ServerGone, "server is shutting down");
        return;
    };
    // reserve → record → submit: the ctx must be in `pending` before the
    // response can possibly reach the writer thread
    let internal = qos.reserve_id();
    pending.lock().unwrap().insert(
        internal,
        ReqCtx { client_id: req.id, class: req.class, quota_downgraded },
    );
    if let Err(e) = qos.submit_reserved(internal, effective, req.image, deadline, resp_tx.clone()) {
        pending.lock().unwrap().remove(&internal);
        send_error(write_half, req.id, ErrorCode::ServerGone, &format!("{e}"));
    }
}

/// Assemble one `Stats` frame: router lane counters, tenant quota
/// balances (milli-tokens, clamped at zero), and per-stage latency
/// attribution from the span flight recorder (empty unless tracing is
/// armed in this process).
fn build_stats(lanes: Vec<LaneStats>, metrics: &Metrics, quotas: &TenantQuotas) -> NetStats {
    let lanes = lanes
        .into_iter()
        .map(|l| LaneStatsWire {
            label: l.label,
            retired: l.retired,
            restarts: l.restarts,
            queued: l.queued,
            rung: l.rung,
            ladder: l.ladder,
            swaps: l.swaps,
            promotions: l.promotions,
        })
        .collect();
    let mut tenants: Vec<TenantStatsWire> = quotas
        .snapshot()
        .into_iter()
        .map(|(tenant, tokens)| TenantStatsWire {
            tenant,
            tokens_milli: (tokens.max(0.0) * 1000.0) as u64,
        })
        .collect();
    tenants.truncate(proto::MAX_STATS_TENANTS);
    let mut stages: Vec<StageStatsWire> = stage_rows(&crate::obs::snapshot())
        .into_iter()
        .map(|r| StageStatsWire {
            lane: r.lane,
            stage: r.stage.to_string(),
            count: r.hist.count(),
            p50_us: r.hist.percentile(50.0) as u64,
            p99_us: r.hist.percentile(99.0) as u64,
            max_us: r.hist.max(),
        })
        .collect();
    stages.truncate(proto::MAX_STATS_STAGES);
    NetStats {
        uptime_ms: metrics.wall_time.as_millis() as u64,
        total_requests: metrics.total_requests as u64,
        integrity: IntegrityWire {
            scrub_passes: metrics.scrub_passes,
            scrub_repairs: metrics.scrub_repairs,
            frame_crc_errors: metrics.frame_crc_errors,
            bad_inputs: metrics.bad_inputs,
            corrupt_outputs: metrics.corrupt_outputs,
        },
        lanes,
        tenants,
        stages,
    }
}

fn send_error(write_half: &Arc<Mutex<TcpStream>>, id: u64, code: ErrorCode, message: &str) {
    let err = NetError { id, code, message: message.to_string() };
    let _ = write_frame_locked(write_half, &proto::encode_error(&err));
}

/// Serialize whole frames onto the shared socket — the reader (error
/// frames) and writer (responses) must never interleave bytes.
fn write_frame_locked(write_half: &Arc<Mutex<TcpStream>>, payload: &[u8]) -> io::Result<()> {
    let mut stream = write_half.lock().unwrap();
    proto::write_frame(&mut *stream, payload)
}
