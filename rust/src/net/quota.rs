//! Per-tenant token-bucket quotas in front of QoS admission.
//!
//! Each tenant refills at `rate_per_s` tokens/s up to `burst`; every
//! admitted request spends one token. Crossing zero does not reject —
//! it *degrades*: the request is rerouted to the economy lane, feeding
//! the same shed accounting as pressure downgrades, so an over-quota
//! tenant loses quality before it can starve in-quota gold traffic.
//! Only sustained abuse (debt beyond `reject_debt`) is shed outright
//! with an error frame. Rejected requests spend no token, so the debt —
//! and with it the recovery time — stays bounded.

use crate::obs::Clock;
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Token-bucket parameters, shared by every tenant of one server.
#[derive(Debug, Clone, Copy)]
pub struct QuotaConfig {
    /// Sustained admitted requests per second per tenant; `0` disables
    /// quotas entirely (every request admits).
    pub rate_per_s: f64,
    /// Bucket capacity: how far a tenant may burst above the sustained
    /// rate before degradation starts.
    pub burst: f64,
    /// Token debt beyond which over-quota requests are rejected with an
    /// `OverQuota` error frame instead of degraded.
    pub reject_debt: f64,
}

impl Default for QuotaConfig {
    fn default() -> Self {
        Self { rate_per_s: 0.0, burst: 32.0, reject_debt: 64.0 }
    }
}

/// The quota's verdict on one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// In quota: serve at the requested class.
    Admit,
    /// Over quota: serve, but on the economy lane.
    Degrade,
    /// Far over quota: shed with an error frame.
    Reject,
}

/// One tenant's bucket. Time is passed in explicitly so tests are
/// deterministic.
#[derive(Debug, Clone)]
struct TokenBucket {
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    fn new(now: Instant, cfg: &QuotaConfig) -> Self {
        Self { tokens: cfg.burst, last: now }
    }

    fn admit_at(&mut self, now: Instant, cfg: &QuotaConfig) -> Admission {
        if cfg.rate_per_s <= 0.0 {
            return Admission::Admit;
        }
        // `saturating_duration_since`: a same-instant (or clock-skewed)
        // call refills nothing rather than panicking.
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * cfg.rate_per_s).min(cfg.burst);
        self.tokens -= 1.0;
        if self.tokens >= 0.0 {
            Admission::Admit
        } else if self.tokens >= -cfg.reject_debt {
            Admission::Degrade
        } else {
            // rejected work spends no token: debt is bounded, so the
            // tenant recovers in O(reject_debt / rate) once it backs off
            self.tokens += 1.0;
            Admission::Reject
        }
    }
}

/// All tenants' buckets for one server, keyed by the wire tenant id.
#[derive(Debug)]
pub struct TenantQuotas {
    cfg: QuotaConfig,
    buckets: Mutex<HashMap<String, TokenBucket>>,
}

impl TenantQuotas {
    pub fn new(cfg: QuotaConfig) -> Self {
        Self { cfg, buckets: Mutex::new(HashMap::new()) }
    }

    pub fn config(&self) -> &QuotaConfig {
        &self.cfg
    }

    /// Judge one request from `tenant` right now. Called from every
    /// connection reader thread; the map lock is held only for the
    /// constant-time bucket update.
    pub fn admit(&self, tenant: &str) -> Admission {
        let now = Clock::now();
        let mut buckets = self.buckets.lock().unwrap();
        let bucket =
            buckets.entry(tenant.to_string()).or_insert_with(|| TokenBucket::new(now, &self.cfg));
        bucket.admit_at(now, &self.cfg)
    }

    /// Snapshot every tenant's current token balance (in milli-tokens,
    /// clamped at zero on the way to the wire by the caller), sorted by
    /// tenant id — the `Stats` frame's per-tenant quota state.
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let buckets = self.buckets.lock().unwrap();
        let mut out: Vec<(String, f64)> =
            buckets.iter().map(|(t, b)| (t.clone(), b.tokens)).collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    const CFG: QuotaConfig = QuotaConfig { rate_per_s: 1.0, burst: 2.0, reject_debt: 2.0 };

    /// The three-zone ladder at a frozen clock: burst admits, then
    /// degradation down to the debt floor, then rejection — and
    /// rejection does not dig the debt deeper.
    #[test]
    fn admit_then_degrade_then_reject() {
        let now = Instant::now();
        let mut b = TokenBucket::new(now, &CFG);
        let verdicts: Vec<Admission> = (0..6).map(|_| b.admit_at(now, &CFG)).collect();
        assert_eq!(
            verdicts,
            vec![
                Admission::Admit,
                Admission::Admit,
                Admission::Degrade,
                Admission::Degrade,
                Admission::Reject,
                Admission::Reject,
            ]
        );
        // debt stayed clamped at the floor despite repeated rejects
        assert!((b.tokens - (-2.0)).abs() < 1e-9, "tokens {}", b.tokens);
    }

    /// Refill restores service: first back to degraded, then (after the
    /// debt is paid off) to full admission, capped at `burst`.
    #[test]
    fn refill_recovers_through_the_ladder() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(t0, &CFG);
        for _ in 0..6 {
            b.admit_at(t0, &CFG);
        }
        // +1 token after 1s: −2 + 1 − 1 = −2 → still degraded
        assert_eq!(b.admit_at(t0 + Duration::from_secs(1), &CFG), Admission::Degrade);
        // +4 tokens (capped at burst 2): 2 − 1 = 1 → admitted again
        assert_eq!(b.admit_at(t0 + Duration::from_secs(5), &CFG), Admission::Admit);
    }

    /// `rate_per_s: 0` disables quotas: everything admits forever.
    #[test]
    fn zero_rate_means_unlimited() {
        let quotas = TenantQuotas::new(QuotaConfig::default());
        for _ in 0..100 {
            assert_eq!(quotas.admit("anyone"), Admission::Admit);
        }
    }

    /// Buckets are per tenant: one tenant burning its quota must not
    /// touch a sibling's.
    #[test]
    fn tenants_are_isolated() {
        let quotas = TenantQuotas::new(QuotaConfig {
            rate_per_s: 0.0001, // effectively no refill within the test
            burst: 2.0,
            reject_debt: 2.0,
        });
        for _ in 0..10 {
            quotas.admit("abuser");
        }
        assert_eq!(quotas.admit("abuser"), Admission::Reject);
        assert_eq!(quotas.admit("polite"), Admission::Admit);
    }

    /// The stats snapshot lists every tenant seen so far, sorted by id,
    /// with the heavier spender showing the lower balance.
    #[test]
    fn snapshot_reports_sorted_tenant_balances() {
        let quotas = TenantQuotas::new(QuotaConfig {
            rate_per_s: 0.0001,
            burst: 8.0,
            reject_debt: 2.0,
        });
        quotas.admit("zeta");
        quotas.admit("alpha");
        quotas.admit("alpha");
        let snap = quotas.snapshot();
        let names: Vec<&str> = snap.iter().map(|(t, _)| t.as_str()).collect();
        assert_eq!(names, vec!["alpha", "zeta"]);
        assert!(snap[0].1 < snap[1].1, "alpha spent more tokens than zeta");
    }
}
