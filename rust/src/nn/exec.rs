//! Production executors: the FP32 reference path and the BFP path.

use super::graph::Executor;
use super::layers::{BatchNorm, Conv2d, Dense};
use super::ops;
use crate::quant::{BfpConfig, LayerSchedule};
use crate::tensor::{avg_pool2d, global_avg_pool, max_pool2d, Tensor};

/// Plain FP32 inference — the "floating point" baseline of every table.
pub struct Fp32Exec;

impl Executor for Fp32Exec {
    type T = Tensor;
    fn conv(&mut self, layer: &Conv2d, x: Tensor) -> Tensor {
        layer.forward_fp32(&x)
    }
    fn dense(&mut self, layer: &Dense, x: Tensor) -> Tensor {
        layer.forward_fp32(&x)
    }
    fn batch_norm(&mut self, layer: &BatchNorm, x: Tensor) -> Tensor {
        layer.forward(&x)
    }
    fn relu(&mut self, x: Tensor) -> Tensor {
        ops::relu(&x)
    }
    fn max_pool(&mut self, _name: &str, k: usize, s: usize, p: usize, x: Tensor) -> Tensor {
        max_pool2d(&x, k, s, p)
    }
    fn avg_pool(&mut self, _name: &str, k: usize, s: usize, p: usize, x: Tensor) -> Tensor {
        avg_pool2d(&x, k, s, p)
    }
    fn global_avg_pool(&mut self, x: Tensor) -> Tensor {
        global_avg_pool(&x)
    }
    fn flatten(&mut self, x: Tensor) -> Tensor {
        ops::flatten(&x)
    }
    fn add(&mut self, a: Tensor, b: Tensor) -> Tensor {
        ops::add(&a, &b)
    }
    fn concat(&mut self, parts: Vec<Tensor>) -> Tensor {
        ops::concat_channels(&parts)
    }
    fn softmax(&mut self, x: Tensor) -> Tensor {
        ops::softmax(&x)
    }
    fn fork(&mut self, x: &Tensor) -> Tensor {
        x.clone()
    }
}

/// BFP inference: conv layers run the Figure 2 fixed-point data flow;
/// everything else (ReLU, pooling, BN, FC, softmax) stays in floating
/// point exactly as in the paper's Caffe port (§5.1).
///
/// Precision is a per-layer [`LayerSchedule`], so one executor serves
/// both the paper's uniform sweeps ([`BfpExec::new`]) and the
/// mixed-precision plans emitted by [`crate::autotune`]
/// ([`BfpExec::with_schedule`]).
pub struct BfpExec {
    pub schedule: LayerSchedule,
    /// Also quantize fully-connected layers (extension; paper: false).
    pub quantize_dense: bool,
}

impl BfpExec {
    /// Uniform precision: every layer runs at `cfg`.
    pub fn new(cfg: BfpConfig) -> Self {
        Self::with_schedule(LayerSchedule::uniform(cfg))
    }

    /// Mixed precision: each conv layer looks up its own config.
    pub fn with_schedule(schedule: LayerSchedule) -> Self {
        Self { schedule, quantize_dense: false }
    }
}

impl Executor for BfpExec {
    type T = Tensor;
    fn conv(&mut self, layer: &Conv2d, x: Tensor) -> Tensor {
        let cfg = self.schedule.for_layer(&layer.name);
        layer.forward_bfp(&x, &cfg)
    }
    fn dense(&mut self, layer: &Dense, x: Tensor) -> Tensor {
        if self.quantize_dense {
            let cfg = self.schedule.for_layer(&layer.name);
            layer.forward_bfp(&x, &cfg)
        } else {
            layer.forward_fp32(&x)
        }
    }
    fn batch_norm(&mut self, layer: &BatchNorm, x: Tensor) -> Tensor {
        layer.forward(&x)
    }
    fn relu(&mut self, x: Tensor) -> Tensor {
        ops::relu(&x)
    }
    fn max_pool(&mut self, _name: &str, k: usize, s: usize, p: usize, x: Tensor) -> Tensor {
        max_pool2d(&x, k, s, p)
    }
    fn avg_pool(&mut self, _name: &str, k: usize, s: usize, p: usize, x: Tensor) -> Tensor {
        avg_pool2d(&x, k, s, p)
    }
    fn global_avg_pool(&mut self, x: Tensor) -> Tensor {
        global_avg_pool(&x)
    }
    fn flatten(&mut self, x: Tensor) -> Tensor {
        ops::flatten(&x)
    }
    fn add(&mut self, a: Tensor, b: Tensor) -> Tensor {
        ops::add(&a, &b)
    }
    fn concat(&mut self, parts: Vec<Tensor>) -> Tensor {
        ops::concat_channels(&parts)
    }
    fn softmax(&mut self, x: Tensor) -> Tensor {
        ops::softmax(&x)
    }
    fn fork(&mut self, x: &Tensor) -> Tensor {
        x.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::graph::Block;

    fn model() -> Block {
        let w: Vec<f32> = (0..4 * 2 * 9).map(|i| ((i as f32) * 0.17).sin() * 0.4).collect();
        Block::seq(vec![
            Block::Conv(Conv2d::new("c1", Tensor::from_vec(w, &[4, 2, 3, 3]), vec![], 1, 1)),
            Block::ReLU,
            Block::MaxPool { name: "p1".into(), k: 2, s: 2, p: 0 },
            Block::Flatten,
        ])
    }

    fn input() -> Tensor {
        Tensor::from_vec((0..2 * 8 * 8).map(|i| ((i as f32) * 0.31).cos() * 2.0).collect(), &[2, 8, 8])
    }

    #[test]
    fn bfp_exec_tracks_fp32_at_wide_width() {
        let m = model();
        let fp = m.execute(input(), &mut Fp32Exec);
        let bfp = m.execute(input(), &mut BfpExec::new(BfpConfig::new(14, 14)));
        assert_eq!(fp.shape, bfp.shape);
        let nsr = fp.data.iter().zip(&bfp.data).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
            / fp.energy().max(1e-12);
        assert!(nsr < 1e-5, "NSR {nsr}");
    }

    #[test]
    fn narrow_width_is_noisier() {
        let m = model();
        let fp = m.execute(input(), &mut Fp32Exec);
        let nsr = |bits| {
            let b = m.execute(input(), &mut BfpExec::new(BfpConfig::new(bits, bits)));
            fp.data.iter().zip(&b.data).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
                / fp.energy().max(1e-12)
        };
        assert!(nsr(5) > nsr(9));
    }

    #[test]
    fn per_layer_schedule_overrides_default() {
        let m = model();
        let fp = m.execute(input(), &mut Fp32Exec);
        let nsr_of = |exec: &mut BfpExec| {
            let b = m.execute(input(), exec);
            fp.data.iter().zip(&b.data).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
                / fp.energy().max(1e-12)
        };
        // overriding the only conv to 14 bits must match uniform 14-bit
        // execution exactly, regardless of the (narrow) default
        let sched = crate::quant::LayerSchedule::uniform(BfpConfig::new(4, 4))
            .with_layer("c1", BfpConfig::new(14, 14));
        let mixed = m.execute(input(), &mut BfpExec::with_schedule(sched));
        let uniform = m.execute(input(), &mut BfpExec::new(BfpConfig::new(14, 14)));
        assert_eq!(mixed.data, uniform.data);
        // and a narrow override must be noisier than a wide one
        let narrow = nsr_of(&mut BfpExec::with_schedule(
            crate::quant::LayerSchedule::uniform(BfpConfig::new(8, 8))
                .with_layer("c1", BfpConfig::new(4, 4)),
        ));
        let wide = nsr_of(&mut BfpExec::with_schedule(
            crate::quant::LayerSchedule::uniform(BfpConfig::new(8, 8))
                .with_layer("c1", BfpConfig::new(12, 12)),
        ));
        assert!(narrow > wide, "narrow {narrow} vs wide {wide}");
    }
}
