//! Stateless tensor operations shared by all executors.

use crate::tensor::Tensor;

/// ReLU: `max(x, 0)` elementwise.
pub fn relu(x: &Tensor) -> Tensor {
    Tensor::from_vec(x.data.iter().map(|&v| v.max(0.0)).collect(), &x.shape)
}

/// Numerically stable softmax over the last axis of a 1-D tensor.
pub fn softmax(x: &Tensor) -> Tensor {
    assert_eq!(x.ndim(), 1, "softmax expects a flat logits vector");
    let max = x.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = x.data.iter().map(|&v| (v - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    Tensor::from_vec(exps.iter().map(|&e| e / sum).collect(), &x.shape)
}

/// Elementwise add of two same-shape tensors (residual connections).
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape, "residual add shape mismatch: {:?} vs {:?}", a.shape, b.shape);
    Tensor::from_vec(a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(), &a.shape)
}

/// Concatenate CHW tensors along the channel axis (inception merge).
pub fn concat_channels(parts: &[Tensor]) -> Tensor {
    assert!(!parts.is_empty());
    let (h, w) = (parts[0].shape[1], parts[0].shape[2]);
    let mut channels = 0;
    for p in parts {
        assert_eq!(p.ndim(), 3, "concat expects [C,H,W] parts");
        assert_eq!((p.shape[1], p.shape[2]), (h, w), "spatial mismatch in concat");
        channels += p.shape[0];
    }
    let mut data = Vec::with_capacity(channels * h * w);
    for p in parts {
        data.extend_from_slice(&p.data);
    }
    Tensor::from_vec(data, &[channels, h, w])
}

/// Flatten to 1-D.
pub fn flatten(x: &Tensor) -> Tensor {
    let n = x.len();
    x.clone().reshape(&[n])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clamps_negatives() {
        let x = Tensor::from_vec(vec![-1.0, 0.0, 2.5], &[3]);
        assert_eq!(relu(&x).data, vec![0.0, 0.0, 2.5]);
    }

    #[test]
    fn softmax_sums_to_one() {
        let x = Tensor::from_vec(vec![1.0, 2.0, 3.0], &[3]);
        let s = softmax(&x);
        assert!((s.data.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(s.data[2] > s.data[1] && s.data[1] > s.data[0]);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let x = Tensor::from_vec(vec![1000.0, 1001.0], &[2]);
        let s = softmax(&x);
        assert!(s.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn add_residual() {
        let a = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let b = Tensor::from_vec(vec![3.0, 4.0], &[2]);
        assert_eq!(add(&a, &b).data, vec![4.0, 6.0]);
    }

    #[test]
    fn concat_stacks_channels() {
        let a = Tensor::from_vec(vec![1.0; 4], &[1, 2, 2]);
        let b = Tensor::from_vec(vec![2.0; 8], &[2, 2, 2]);
        let c = concat_channels(&[a, b]);
        assert_eq!(c.shape, vec![3, 2, 2]);
        assert_eq!(c.data[0], 1.0);
        assert_eq!(c.data[4], 2.0);
    }
}
