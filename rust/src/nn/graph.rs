//! Composable model graph: a [`Block`] tree walked by an [`Executor`].
//!
//! The executor pattern lets the FP32 reference path, the BFP path and the
//! instrumented dual path (Table 4) share one traversal, so layer order and
//! branch semantics can never diverge between them.

use super::layers::{BatchNorm, Conv2d, Dense};

/// A model is a tree of blocks. Leaves are layers; interior nodes compose.
#[derive(Debug, Clone)]
pub enum Block {
    /// Run children in order.
    Seq(Vec<Block>),
    Conv(Conv2d),
    Dense(Dense),
    BatchNorm(BatchNorm),
    ReLU,
    /// Max pooling, square window `k`, stride `s`, padding `p`.
    MaxPool { name: String, k: usize, s: usize, p: usize },
    /// Average pooling, square window `k`, stride `s`, padding `p`.
    AvgPool { name: String, k: usize, s: usize, p: usize },
    /// Global average pooling `[C,H,W] -> [C]`.
    GlobalAvgPool,
    /// Flatten to 1-D.
    Flatten,
    /// Inference-time identity (kept so graph shapes mirror the papers).
    Dropout,
    /// `main(x) + shortcut(x)` (ResNet). Shapes must match.
    Residual { main: Box<Block>, shortcut: Box<Block> },
    /// Channel-wise concat of parallel branches (GoogLeNet inception).
    Concat(Vec<Block>),
    Softmax,
}

impl Block {
    /// Sequential convenience constructor.
    pub fn seq(blocks: Vec<Block>) -> Block {
        Block::Seq(blocks)
    }

    /// Walk the tree with an executor, threading the tensor state through.
    pub fn execute<E: Executor>(&self, x: E::T, e: &mut E) -> E::T {
        match self {
            Block::Seq(items) => items.iter().fold(x, |acc, b| b.execute(acc, e)),
            Block::Conv(c) => e.conv(c, x),
            Block::Dense(d) => e.dense(d, x),
            Block::BatchNorm(bn) => e.batch_norm(bn, x),
            Block::ReLU => e.relu(x),
            Block::MaxPool { name, k, s, p } => e.max_pool(name, *k, *s, *p, x),
            Block::AvgPool { name, k, s, p } => e.avg_pool(name, *k, *s, *p, x),
            Block::GlobalAvgPool => e.global_avg_pool(x),
            Block::Flatten => e.flatten(x),
            Block::Dropout => x,
            Block::Residual { main, shortcut } => {
                let lhs = main.execute(e.fork(&x), e);
                let rhs = shortcut.execute(x, e);
                e.add(lhs, rhs)
            }
            Block::Concat(branches) => {
                let outs: Vec<E::T> = branches.iter().map(|b| b.execute(e.fork(&x), e)).collect();
                e.concat(outs)
            }
            Block::Softmax => e.softmax(x),
        }
    }

    /// Count conv layers (used by the harness to size Table 4).
    pub fn conv_count(&self) -> usize {
        match self {
            Block::Seq(items) => items.iter().map(|b| b.conv_count()).sum(),
            Block::Conv(_) => 1,
            Block::Residual { main, shortcut } => main.conv_count() + shortcut.conv_count(),
            Block::Concat(branches) => branches.iter().map(|b| b.conv_count()).sum(),
            _ => 0,
        }
    }

    /// Total learnable parameters.
    pub fn param_count(&self) -> usize {
        match self {
            Block::Seq(items) => items.iter().map(|b| b.param_count()).sum(),
            Block::Conv(c) => c.weights.len() + c.bias.len(),
            Block::Dense(d) => d.weights.len() + d.bias.len(),
            Block::BatchNorm(bn) => bn.scale.len() * 2,
            Block::Residual { main, shortcut } => main.param_count() + shortcut.param_count(),
            Block::Concat(branches) => branches.iter().map(|b| b.param_count()).sum(),
            _ => 0,
        }
    }

    /// Visit every conv layer in execution order.
    pub fn visit_convs<'a>(&'a self, f: &mut impl FnMut(&'a Conv2d)) {
        match self {
            Block::Seq(items) => items.iter().for_each(|b| b.visit_convs(f)),
            Block::Conv(c) => f(c),
            Block::Residual { main, shortcut } => {
                main.visit_convs(f);
                shortcut.visit_convs(f);
            }
            Block::Concat(branches) => branches.iter().for_each(|b| b.visit_convs(f)),
            _ => {}
        }
    }
}

/// Tensor-state visitor for [`Block::execute`].
///
/// `T` is whatever flows along the graph edges — a plain [`crate::tensor::Tensor`]
/// for the production paths, a (fp32, bfp) pair for the instrumented path.
pub trait Executor {
    type T;
    fn conv(&mut self, layer: &Conv2d, x: Self::T) -> Self::T;
    fn dense(&mut self, layer: &Dense, x: Self::T) -> Self::T;
    fn batch_norm(&mut self, layer: &BatchNorm, x: Self::T) -> Self::T;
    fn relu(&mut self, x: Self::T) -> Self::T;
    fn max_pool(&mut self, name: &str, k: usize, s: usize, p: usize, x: Self::T) -> Self::T;
    fn avg_pool(&mut self, name: &str, k: usize, s: usize, p: usize, x: Self::T) -> Self::T;
    fn global_avg_pool(&mut self, x: Self::T) -> Self::T;
    fn flatten(&mut self, x: Self::T) -> Self::T;
    fn add(&mut self, a: Self::T, b: Self::T) -> Self::T;
    fn concat(&mut self, parts: Vec<Self::T>) -> Self::T;
    fn softmax(&mut self, x: Self::T) -> Self::T;
    /// Duplicate the state at a branch point.
    fn fork(&mut self, x: &Self::T) -> Self::T;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::exec::Fp32Exec;
    use crate::tensor::Tensor;

    fn tiny_conv(name: &str, c_in: usize, c_out: usize) -> Conv2d {
        let w: Vec<f32> = (0..c_out * c_in * 9).map(|i| ((i as f32) * 0.1).sin() * 0.3).collect();
        Conv2d::new(name, Tensor::from_vec(w, &[c_out, c_in, 3, 3]), vec![], 1, 1)
    }

    #[test]
    fn seq_threads_shapes() {
        let model = Block::seq(vec![
            Block::Conv(tiny_conv("c1", 1, 4)),
            Block::ReLU,
            Block::MaxPool { name: "p1".into(), k: 2, s: 2, p: 0 },
            Block::Flatten,
        ]);
        let x = Tensor::from_vec((0..64).map(|i| i as f32 * 0.01).collect(), &[1, 8, 8]);
        let y = model.execute(x, &mut Fp32Exec);
        assert_eq!(y.shape, vec![4 * 4 * 4]);
    }

    #[test]
    fn residual_identity_shortcut_doubles() {
        let model = Block::Residual {
            main: Box::new(Block::Seq(vec![])),
            shortcut: Box::new(Block::Seq(vec![])),
        };
        let x = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let y = model.execute(x, &mut Fp32Exec);
        assert_eq!(y.data, vec![2.0, 4.0]);
    }

    #[test]
    fn concat_branches() {
        let model = Block::Concat(vec![
            Block::Conv(tiny_conv("b1", 2, 3)),
            Block::Conv(tiny_conv("b2", 2, 5)),
        ]);
        let x = Tensor::from_vec((0..2 * 6 * 6).map(|i| i as f32 * 0.05).collect(), &[2, 6, 6]);
        let y = model.execute(x, &mut Fp32Exec);
        assert_eq!(y.shape, vec![8, 6, 6]);
    }

    #[test]
    fn conv_count_and_params() {
        let model = Block::seq(vec![
            Block::Conv(tiny_conv("c1", 1, 2)),
            Block::Residual {
                main: Box::new(Block::Conv(tiny_conv("c2", 2, 2))),
                shortcut: Box::new(Block::Seq(vec![])),
            },
        ]);
        assert_eq!(model.conv_count(), 2);
        assert_eq!(model.param_count(), 2 * 9 + 2 * 2 * 9);
    }
}
