//! Parameterised layers: convolution, dense, batch-norm.

use crate::bfp::gemm::f32_gemm;
use crate::bfp::kernel::{self, ActPanels, WeightPanels};
use crate::bfp::{bfp_gemm, BfpMatrix};
use crate::quant::BfpConfig;
use crate::tensor::{im2col, Conv2dGeometry, Tensor};

/// 2-D convolution layer (NCHW, square stride/padding).
#[derive(Debug, Clone)]
pub struct Conv2d {
    pub name: String,
    /// `[out_channels, in_channels, kh, kw]`
    pub weights: Tensor,
    /// Per-output-channel bias (empty = no bias).
    pub bias: Vec<f32>,
    pub stride: usize,
    pub padding: usize,
}

impl Conv2d {
    pub fn new(name: impl Into<String>, weights: Tensor, bias: Vec<f32>, stride: usize, padding: usize) -> Self {
        assert_eq!(weights.ndim(), 4, "conv weights must be [M,C,kh,kw]");
        if !bias.is_empty() {
            assert_eq!(bias.len(), weights.shape[0]);
        }
        Self { name: name.into(), weights, bias, stride, padding }
    }

    /// Geometry for an input of shape `[C,H,W]`.
    pub fn geometry(&self, input_shape: &[usize]) -> Conv2dGeometry {
        assert_eq!(input_shape.len(), 3, "conv input must be [C,H,W]");
        assert_eq!(input_shape[0], self.weights.shape[1], "channel mismatch in {}", self.name);
        Conv2dGeometry {
            in_channels: input_shape[0],
            in_h: input_shape[1],
            in_w: input_shape[2],
            kernel_h: self.weights.shape[2],
            kernel_w: self.weights.shape[3],
            stride: self.stride,
            padding: self.padding,
        }
    }

    /// Number of output channels `M`.
    pub fn out_channels(&self) -> usize {
        self.weights.shape[0]
    }

    /// Expand the input into its im2col matrix (`K×N`, row-major).
    pub fn im2col(&self, input: &Tensor) -> (Vec<f32>, Conv2dGeometry) {
        let geo = self.geometry(&input.shape);
        let mut col = vec![0f32; geo.k() * geo.n()];
        im2col(&input.data, &geo, &mut col);
        (col, geo)
    }

    /// FP32 reference forward: im2col + f32 GEMM + bias.
    pub fn forward_fp32(&self, input: &Tensor) -> Tensor {
        let (col, geo) = self.im2col(input);
        let (m, k, n) = (self.out_channels(), geo.k(), geo.n());
        let mut out = vec![0f32; m * n];
        f32_gemm(&self.weights.data, &col, m, k, n, &mut out);
        self.add_bias(&mut out, n);
        Tensor::from_vec(out, &[m, geo.out_h(), geo.out_w()])
    }

    /// Quantize this layer's weights as the `M×K` GEMM operand under
    /// `cfg` — the single routine shared by [`Conv2d::forward_bfp`], the
    /// instrumented dual path and the prepared-model weight cache, so all
    /// paths quantize identically by construction.
    pub fn quantize_weights(&self, cfg: &BfpConfig) -> BfpMatrix {
        let m = self.out_channels();
        let k = self.weights.len() / m;
        BfpMatrix::quantize(&self.weights.data, m, k, cfg.w_format(), cfg.scheme.w_axis())
    }

    /// BFP forward (the Figure 2 data flow): block-format `W` and the
    /// im2col'd input per `cfg.scheme`, multiply-accumulate in fixed
    /// point, rescale to f32, add bias in f32 (the bias path stays float
    /// in the paper's Caffe port as well).
    ///
    /// Runs the tiled microkernel with the fused im2col→quantize→pack
    /// activation pipeline ([`crate::bfp::kernel`]) — bit-identical to
    /// the naive `im2col` + [`bfp_gemm`] pipeline it replaced (the §3.4
    /// exactness argument; enforced by `tests/tiled_kernel.rs`).
    ///
    /// Quantizes and packs the (static) weight matrix on every call;
    /// steady-state serving goes through
    /// [`crate::nn::prepared::PreparedModel`], which caches both per
    /// `(layer, weight format)`.
    pub fn forward_bfp(&self, input: &Tensor, cfg: &BfpConfig) -> Tensor {
        let geo = self.geometry(&input.shape);
        let (m, k, n) = (self.out_channels(), geo.k(), geo.n());
        let wq = self.quantize_weights(cfg);
        debug_assert_eq!(wq.cols, k);
        let lane = kernel::select_lane(wq.frac_bits, cfg.i_format().frac_bits(), k);
        let mut acts = ActPanels::new();
        let mut tile = Vec::new();
        acts.pack_im2col(&input.data, &geo, cfg.i_format(), cfg.scheme.i_axis(), lane, &mut tile);
        let mut out = vec![0f32; m * n];
        if lane.is_f32() {
            kernel::gemm_tiled(&wq, WeightPanels::F32(&kernel::pack_weights_f32(&wq)), &acts, &mut out);
        } else {
            kernel::gemm_tiled(&wq, WeightPanels::Int(&kernel::pack_weights_i32(&wq)), &acts, &mut out);
        }
        self.add_bias(&mut out, n);
        Tensor::from_vec(out, &[m, geo.out_h(), geo.out_w()])
    }

    /// Add the per-output-channel bias to a row-major `M×n` GEMM output.
    pub fn add_bias(&self, out: &mut [f32], n: usize) {
        if self.bias.is_empty() {
            return;
        }
        for (oc, &b) in self.bias.iter().enumerate() {
            for v in &mut out[oc * n..(oc + 1) * n] {
                *v += b;
            }
        }
    }
}

/// Fully-connected layer.
#[derive(Debug, Clone)]
pub struct Dense {
    pub name: String,
    /// `[out_features, in_features]`
    pub weights: Tensor,
    pub bias: Vec<f32>,
}

impl Dense {
    pub fn new(name: impl Into<String>, weights: Tensor, bias: Vec<f32>) -> Self {
        assert_eq!(weights.ndim(), 2);
        if !bias.is_empty() {
            assert_eq!(bias.len(), weights.shape[0]);
        }
        Self { name: name.into(), weights, bias }
    }

    /// FP32 forward: `y = Wx + b`. (The paper's Caffe port keeps
    /// fully-connected layers in floating point; see §5.1 "Experiment
    /// Setup". [`Dense::forward_bfp`] exists for the extension ablation.)
    pub fn forward_fp32(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.ndim(), 1, "dense input must be flat");
        let (o, i) = (self.weights.shape[0], self.weights.shape[1]);
        assert_eq!(x.len(), i, "dense {}: input {} != {}", self.name, x.len(), i);
        let mut out = vec![0f32; o];
        for r in 0..o {
            let row = &self.weights.data[r * i..(r + 1) * i];
            let mut acc = 0f32;
            for (w, v) in row.iter().zip(&x.data) {
                acc += w * v;
            }
            out[r] = acc + self.bias.get(r).copied().unwrap_or(0.0);
        }
        Tensor::from_vec(out, &[o])
    }

    /// BFP forward: treat `x` as a `K×1` input matrix (extension; not the
    /// paper's default data flow).
    pub fn forward_bfp(&self, x: &Tensor, cfg: &BfpConfig) -> Tensor {
        let (o, i) = (self.weights.shape[0], self.weights.shape[1]);
        assert_eq!(x.len(), i);
        let wq = BfpMatrix::quantize(&self.weights.data, o, i, cfg.w_format(), cfg.scheme.w_axis());
        let iq = BfpMatrix::quantize(&x.data, i, 1, cfg.i_format(), crate::bfp::partition::BlockAxis::Whole);
        let mut out = bfp_gemm(&wq, &iq).data;
        for (r, v) in out.iter_mut().enumerate() {
            *v += self.bias.get(r).copied().unwrap_or(0.0);
        }
        Tensor::from_vec(out, &[o])
    }
}

/// Inference-time batch normalisation: `y = scale·x + shift` per channel
/// (running statistics already folded into scale/shift).
#[derive(Debug, Clone)]
pub struct BatchNorm {
    pub name: String,
    pub scale: Vec<f32>,
    pub shift: Vec<f32>,
}

impl BatchNorm {
    pub fn new(name: impl Into<String>, scale: Vec<f32>, shift: Vec<f32>) -> Self {
        assert_eq!(scale.len(), shift.len());
        Self { name: name.into(), scale, shift }
    }

    /// Identity batch-norm over `c` channels.
    pub fn identity(name: impl Into<String>, c: usize) -> Self {
        Self::new(name, vec![1.0; c], vec![0.0; c])
    }

    /// Apply per-channel affine to a `[C,H,W]` tensor.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.ndim(), 3);
        let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
        assert_eq!(c, self.scale.len(), "bn {} channel mismatch", self.name);
        let mut out = x.clone();
        for ch in 0..c {
            let (s, b) = (self.scale[ch], self.shift[ch]);
            for v in &mut out.data[ch * h * w..(ch + 1) * h * w] {
                *v = s * *v + b;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::im2col::direct_conv2d;

    fn seq(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| ((i as f32 * 0.61).sin() + 0.1) * scale).collect()
    }

    #[test]
    fn conv_fp32_matches_direct() {
        let img = Tensor::from_vec(seq(3 * 7 * 7, 1.0), &[3, 7, 7]);
        let w = Tensor::from_vec(seq(4 * 3 * 3 * 3, 0.5), &[4, 3, 3, 3]);
        let bias = vec![0.1, -0.2, 0.3, 0.0];
        let conv = Conv2d::new("c", w.clone(), bias.clone(), 1, 1);
        let out = conv.forward_fp32(&img);
        let reference = direct_conv2d(&img, &w, Some(&bias), 1, 1);
        assert_eq!(out.shape, reference.shape);
        for (a, b) in out.data.iter().zip(&reference.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn conv_bfp_close_to_fp32_at_wide_mantissa() {
        let img = Tensor::from_vec(seq(3 * 8 * 8, 2.0), &[3, 8, 8]);
        let w = Tensor::from_vec(seq(8 * 3 * 3 * 3, 0.3), &[8, 3, 3, 3]);
        let conv = Conv2d::new("c", w, vec![], 1, 1);
        let fp = conv.forward_fp32(&img);
        let bfp = conv.forward_bfp(&img, &BfpConfig::new(14, 14));
        let nsr = fp.data.iter().zip(&bfp.data).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>() / fp.energy();
        assert!(nsr < 1e-5, "NSR {nsr}");
    }

    /// `forward_bfp` (tiled + fused pipeline) must equal the naive
    /// im2col → quantize → `bfp_gemm` pipeline it replaced, bit for bit,
    /// across lanes and schemes.
    #[test]
    fn conv_bfp_tiled_matches_naive_pipeline() {
        use crate::bfp::PartitionScheme;
        let img = Tensor::from_vec(seq(3 * 9 * 7, 2.0), &[3, 9, 7]);
        let w = Tensor::from_vec(seq(5 * 3 * 3 * 3, 0.4), &[5, 3, 3, 3]);
        let conv = Conv2d::new("c", w, vec![0.05, -0.1, 0.0, 0.2, -0.3], 1, 1);
        for cfg in [
            BfpConfig::new(8, 8),                                      // f32 lane
            BfpConfig::new(12, 12),                                    // i32 lane
            BfpConfig::new(16, 16),                                    // i64 lane
            BfpConfig::new(8, 8).with_scheme(PartitionScheme::Eq2),
            BfpConfig::new(8, 8).with_scheme(PartitionScheme::Eq3),    // PerCol input
            BfpConfig::new(8, 8).with_scheme(PartitionScheme::Eq5),
        ] {
            let got = conv.forward_bfp(&img, &cfg);
            let (col, geo) = conv.im2col(&img);
            let (k, n) = (geo.k(), geo.n());
            let wq = conv.quantize_weights(&cfg);
            let iq = BfpMatrix::quantize(&col, k, n, cfg.i_format(), cfg.scheme.i_axis());
            let mut want = bfp_gemm(&wq, &iq).data;
            conv.add_bias(&mut want, n);
            for (a, b) in want.iter().zip(&got.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "cfg {cfg:?}");
            }
        }
    }

    #[test]
    fn conv_bfp_error_grows_as_width_shrinks() {
        let img = Tensor::from_vec(seq(2 * 10 * 10, 3.0), &[2, 10, 10]);
        let w = Tensor::from_vec(seq(4 * 2 * 3 * 3, 0.4), &[4, 2, 3, 3]);
        let conv = Conv2d::new("c", w, vec![], 1, 1);
        let fp = conv.forward_fp32(&img);
        let nsr = |bits: u32| {
            let bfp = conv.forward_bfp(&img, &BfpConfig::new(bits, bits));
            fp.data.iter().zip(&bfp.data).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>() / fp.energy()
        };
        assert!(nsr(6) > nsr(8), "6-bit must be noisier than 8-bit");
        assert!(nsr(8) > nsr(12), "8-bit must be noisier than 12-bit");
    }

    #[test]
    fn dense_forward() {
        let d = Dense::new("fc", Tensor::from_vec(vec![1., 2., 3., 4.], &[2, 2]), vec![0.5, -0.5]);
        let y = d.forward_fp32(&Tensor::from_vec(vec![1.0, 1.0], &[2]));
        assert_eq!(y.data, vec![3.5, 6.5]);
    }

    #[test]
    fn dense_bfp_approximates() {
        let d = Dense::new("fc", Tensor::from_vec(seq(16 * 32, 0.2), &[16, 32]), vec![0.0; 16]);
        let x = Tensor::from_vec(seq(32, 1.5), &[32]);
        let fp = d.forward_fp32(&x);
        let bfp = d.forward_bfp(&x, &BfpConfig::new(12, 12));
        for (a, b) in fp.data.iter().zip(&bfp.data) {
            assert!((a - b).abs() < 0.01, "{a} vs {b}");
        }
    }

    #[test]
    fn batchnorm_affine() {
        let bn = BatchNorm::new("bn", vec![2.0, 0.5], vec![1.0, 0.0]);
        let x = Tensor::from_vec(vec![1., 1., 1., 1., 4., 4., 4., 4.], &[2, 2, 2]);
        let y = bn.forward(&x);
        assert_eq!(&y.data[0..4], &[3.0, 3.0, 3.0, 3.0]);
        assert_eq!(&y.data[4..8], &[2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn batchnorm_identity_is_noop() {
        let bn = BatchNorm::identity("bn", 2);
        let x = Tensor::from_vec(seq(2 * 3 * 3, 1.0), &[2, 3, 3]);
        assert_eq!(bn.forward(&x).data, x.data);
    }
}
