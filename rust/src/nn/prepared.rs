//! Prepared-model serving: the steady-state inference hot path.
//!
//! [`crate::nn::layers::Conv2d::forward_bfp`] re-quantizes its (static)
//! weight matrix and allocates im2col / mantissa / output buffers on
//! every call. That is fine for one-shot analysis runs, but a server
//! answering millions of requests pays that cost per image. This module
//! amortizes it:
//!
//! * [`WeightCache`] quantizes each conv's weights **once** per
//!   `(layer, weight format)` — keyed by what the weight operand of the
//!   configs a [`LayerSchedule`] resolves to actually depends on, so
//!   uniform, `Bfp` and `Mixed` modes share entries and a schedule swap
//!   only quantizes layers whose weight format actually changed — and
//!   lazily holds the mantissas pre-packed in `MR`-row microkernel
//!   panel order ([`crate::bfp::kernel`]) for whichever accumulator
//!   lane the serving config selects.
//! * [`Workspace`] is a scratch arena (the fused pipeline's `K×NC`
//!   im2col tile plus the packed activation panels) that grows to the
//!   model's high-water mark and is reused across layers, images and
//!   server requests.
//! * [`PreparedModel`] ties both to a [`Model`] + [`LayerSchedule`] and
//!   runs `forward`/`forward_batch` **bit-identically** to the unprepared
//!   [`crate::nn::BfpExec`] path (tested in `tests/prepared_parallel.rs`),
//!   parallelizing batches over images via the [`crate::runtime::pool`].

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::graph::Executor;
use super::layers::{BatchNorm, Conv2d, Dense};
use super::ops;
use crate::bfp::kernel::{self, ActPanels, Lane, WeightPanels};
use crate::bfp::partition::BfpMatrix;
use crate::models::Model;
use crate::quant::{BfpConfig, LayerSchedule};
use crate::runtime::pool;
use crate::tensor::{avg_pool2d, global_avg_pool, max_pool2d, Tensor};

/// A conv layer's weights, quantized once and shared read-only.
#[derive(Clone)]
pub struct CachedWeights {
    /// Quantized `M×K` weight matrix.
    pub wq: Arc<BfpMatrix>,
    /// Mantissas packed into `MR`-row panels as exact f32 (the
    /// [`Lane::F32`] fast lane; built lazily on the serving path).
    pub packed_f32: Option<Arc<Vec<f32>>>,
    /// Mantissas packed into `MR`-row panels as i32 (the integer
    /// lanes). A cache entry is keyed by the *weight* format, so an
    /// entry shared by an f32-lane and an integer-lane config carries
    /// both packings, each built on first request.
    pub packed_i32: Option<Arc<Vec<i32>>>,
    /// [`weight_checksum`] of `wq` taken at quantize time — the
    /// scrubber's ground truth. The lazy panel packings are pure
    /// functions of `wq`, so they are not separately checksummed: a
    /// repair requantizes and repacks everything from the fp32 source.
    pub checksum: u32,
}

/// 32-bit FNV-1a over a quantized matrix's mantissas and block
/// exponents (little-endian element bytes) — the same zero-dependency
/// hash the wire CRC uses. It guards against accidental bit flips in
/// the resident cache, not an adversary.
pub fn weight_checksum(wq: &BfpMatrix) -> u32 {
    let mut hash: u32 = 0x811c_9dc5;
    let mut eat = |v: i32| {
        for b in v.to_le_bytes() {
            hash ^= u32::from(b);
            hash = hash.wrapping_mul(0x0100_0193);
        }
    };
    for &m in &wq.mantissas {
        eat(m);
    }
    for &e in &wq.exponents {
        eat(e);
    }
    hash
}

impl CachedWeights {
    /// The panel view the selected lane consumes (packing on the fly if
    /// the cache was warmed for a different lane — correctness never
    /// depends on the prepack).
    fn panels_for(&self, lane: Lane) -> WeightPanelsOwned {
        if lane.is_f32() {
            match &self.packed_f32 {
                Some(p) => WeightPanelsOwned::SharedF32(Arc::clone(p)),
                None => WeightPanelsOwned::F32(kernel::pack_weights_f32(&self.wq)),
            }
        } else {
            match &self.packed_i32 {
                Some(p) => WeightPanelsOwned::SharedI32(Arc::clone(p)),
                None => WeightPanelsOwned::I32(kernel::pack_weights_i32(&self.wq)),
            }
        }
    }
}

/// Owned-or-shared weight panels (borrowed into [`WeightPanels`] at the
/// GEMM call).
enum WeightPanelsOwned {
    SharedF32(Arc<Vec<f32>>),
    SharedI32(Arc<Vec<i32>>),
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl WeightPanelsOwned {
    fn as_panels(&self) -> WeightPanels<'_> {
        match self {
            WeightPanelsOwned::SharedF32(p) => WeightPanels::F32(p.as_slice()),
            WeightPanelsOwned::SharedI32(p) => WeightPanels::Int(p.as_slice()),
            WeightPanelsOwned::F32(p) => WeightPanels::F32(p.as_slice()),
            WeightPanelsOwned::I32(p) => WeightPanels::Int(p.as_slice()),
        }
    }
}

/// Cross-schedule cache of quantized conv weights, keyed by layer name
/// plus the parts of a [`BfpConfig`] the weight operand actually depends
/// on — its [`crate::bfp::BfpFormat`] (width + rounding) and block axis.
/// Configs that differ only in the *input* width resolve to the same
/// entry, so an autotune candidate that strips an activation bit never
/// re-quantizes (or duplicates) the weights.
#[derive(Default)]
pub struct WeightCache {
    /// Per layer: the weight formats seen so far (a handful at most —
    /// linear scan beats hashing). Each entry keeps the [`BfpConfig`]
    /// that produced it so the scrubber can requantize a corrupted
    /// entry from the fp32 source without guessing.
    entries: HashMap<String, Vec<(WeightKey, BfpConfig, CachedWeights)>>,
    hits: usize,
    misses: usize,
    /// Bumped whenever the cache's contents change (a fill, a repair,
    /// or an injected corruption). The background scrubber parks while
    /// this is unchanged, so a steady-state cache costs nothing.
    generation: u64,
}

/// What one [`WeightCache::scrub`] pass found.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ScrubReport {
    /// Entries whose checksum verified clean.
    pub verified: usize,
    /// Layer names of entries whose checksum mismatched and were
    /// requantized from the fp32 weights (one per repaired entry).
    pub repaired: Vec<String>,
    /// Entries that mismatched but had no fp32 source in the scrubbed
    /// model — evicted outright (requantize-on-next-miss).
    pub evicted: usize,
}

/// What weight quantization depends on: `W`'s format, block axis, and a
/// cheap O(1) fingerprint of the weight tensor itself. The fingerprint
/// guards against reusing one cache across models whose same-named
/// layers carry different weights (every zoo model has a "conv1") —
/// a mismatch is a clean cache miss, never a silently wrong matrix.
#[derive(PartialEq, Eq, Clone, Copy)]
struct WeightKey {
    format: crate::bfp::BfpFormat,
    axis: crate::bfp::partition::BlockAxis,
    fingerprint: u64,
}

impl WeightKey {
    fn of(layer: &Conv2d, cfg: &BfpConfig) -> Self {
        Self {
            format: cfg.w_format(),
            axis: cfg.scheme.w_axis(),
            fingerprint: weights_fingerprint(&layer.weights),
        }
    }
}

/// O(1) tensor fingerprint: length plus sampled element bits. Collisions
/// require same-shaped tensors agreeing at the sampled positions — and a
/// collision only ever returns a quantization of those other weights, so
/// the worst case of this *heuristic* misuse guard matches today's
/// intended single-model behaviour.
fn weights_fingerprint(t: &Tensor) -> u64 {
    let d = &t.data;
    let sample = |i: usize| d.get(i).map(|v| v.to_bits() as u64).unwrap_or(0);
    (d.len() as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .rotate_left(17)
        ^ (sample(0) << 32)
        ^ (sample(d.len() / 2) << 16)
        ^ sample(d.len().saturating_sub(1))
}

/// A [`WeightCache`] shared between several [`PreparedModel`]s — the
/// multi-lane serving configuration, where every lane runs the same model
/// under a different [`LayerSchedule`] and a weight format used by two
/// lanes is quantized exactly once.
pub type SharedWeightCache = Arc<Mutex<WeightCache>>;

impl WeightCache {
    /// A fresh cache behind the shared handle several [`PreparedModel`]s
    /// can be built over ([`PreparedModel::with_cache`]).
    pub fn shared() -> SharedWeightCache {
        Arc::new(Mutex::new(WeightCache::default()))
    }

    /// Look up (or quantize and insert) `layer`'s weights under `cfg`.
    /// Does **not** build the packed microkernel panels — the
    /// analysis/autotune instrumentation only needs the quantized
    /// mantissas, and eagerly packing every candidate would grow its
    /// footprint for nothing.
    pub fn get_or_quantize(&mut self, layer: &Conv2d, cfg: BfpConfig) -> CachedWeights {
        self.lookup(layer, cfg, false)
    }

    /// [`WeightCache::get_or_quantize`], additionally materialising (and
    /// caching, lazily on first request) the `MR`-panel weight packing
    /// for the accumulator lane `cfg` selects — the serving path. An
    /// entry shared by configs that land on different lanes (the key
    /// ignores the *input* width, the lane does not) accumulates both
    /// packings.
    pub fn get_or_quantize_packed(&mut self, layer: &Conv2d, cfg: BfpConfig) -> CachedWeights {
        self.lookup(layer, cfg, true)
    }

    fn lookup(&mut self, layer: &Conv2d, cfg: BfpConfig, want_packed: bool) -> CachedWeights {
        let key = WeightKey::of(layer, &cfg);
        let k = layer.weights.len() / layer.out_channels();
        let lane = kernel::select_lane(cfg.w_format().frac_bits(), cfg.i_format().frac_bits(), k);
        let pack = |cached: &mut CachedWeights| {
            if lane.is_f32() {
                if cached.packed_f32.is_none() {
                    cached.packed_f32 = Some(Arc::new(kernel::pack_weights_f32(&cached.wq)));
                }
            } else if cached.packed_i32.is_none() {
                cached.packed_i32 = Some(Arc::new(kernel::pack_weights_i32(&cached.wq)));
            }
        };
        if let Some(list) = self.entries.get_mut(layer.name.as_str()) {
            if let Some((_, _, cached)) = list.iter_mut().find(|(k, _, _)| *k == key) {
                self.hits += 1;
                if want_packed {
                    pack(cached);
                }
                return cached.clone();
            }
        }
        self.misses += 1;
        self.generation += 1;
        let wq = Arc::new(layer.quantize_weights(&cfg));
        let checksum = weight_checksum(&wq);
        let mut cached = CachedWeights { wq, packed_f32: None, packed_i32: None, checksum };
        if want_packed {
            pack(&mut cached);
        }
        self.entries.entry(layer.name.clone()).or_default().push((key, cfg, cached.clone()));
        cached
    }

    /// Cache lookups that were served without quantizing.
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Cache fills (one weight quantization each).
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Total `(layer, config)` entries held.
    pub fn len(&self) -> usize {
        self.entries.values().map(|v| v.len()).sum()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Content generation: bumped on every fill, repair, or injected
    /// corruption. The scrubber verifies only when this moved since its
    /// last pass, so the clean steady state pays one lock + one load
    /// per scrub period.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Verify every entry's checksum against its resident mantissas and
    /// exponents. A mismatch is repaired by requantizing from `model`'s
    /// still-resident fp32 weights under the entry's recorded config
    /// (lazy panel packings are rebuilt from the fresh matrix), so the
    /// repaired entry is bit-identical to a fresh quantize. Corrupt
    /// entries whose fp32 source is not in `model` (or whose weights
    /// changed underneath, per the fingerprint) are evicted so the next
    /// lookup requantizes. Hit/miss counters are untouched — a scrub is
    /// maintenance, not traffic.
    pub fn scrub(&mut self, model: &Model) -> ScrubReport {
        let verify = |c: &CachedWeights| weight_checksum(&c.wq) == c.checksum;
        let mut report = ScrubReport::default();
        let mut any_corrupt = false;
        for list in self.entries.values() {
            for (_, _, cached) in list {
                if verify(cached) {
                    report.verified += 1;
                } else {
                    any_corrupt = true;
                }
            }
        }
        if !any_corrupt {
            return report;
        }
        let entries = &mut self.entries;
        model.graph.visit_convs(&mut |c: &Conv2d| {
            let Some(list) = entries.get_mut(c.name.as_str()) else { return };
            for (key, cfg, cached) in list.iter_mut() {
                if verify(cached) || key.fingerprint != weights_fingerprint(&c.weights) {
                    continue;
                }
                let wq = Arc::new(c.quantize_weights(cfg));
                let checksum = weight_checksum(&wq);
                *cached = CachedWeights {
                    packed_f32: cached
                        .packed_f32
                        .as_ref()
                        .map(|_| Arc::new(kernel::pack_weights_f32(&wq))),
                    packed_i32: cached
                        .packed_i32
                        .as_ref()
                        .map(|_| Arc::new(kernel::pack_weights_i32(&wq))),
                    wq,
                    checksum,
                };
                report.repaired.push(c.name.clone());
            }
        });
        for list in self.entries.values_mut() {
            let before = list.len();
            list.retain(|(_, _, cached)| verify(cached));
            report.evicted += before - list.len();
        }
        self.entries.retain(|_, list| !list.is_empty());
        self.generation += 1;
        report
    }

    /// Deterministically flip one mantissa bit of the `nth` cached
    /// entry for `layer` — the storage half of the fault plane
    /// (`flip:weights:…`). The flip lands on this cache's copy of the
    /// matrix ([`Arc::make_mut`]): lanes holding a clone keep their
    /// clean view, which is the storage-corruption model — the shared
    /// store is poisoned, in-flight readers are not. Returns `false`
    /// when no such entry exists.
    pub fn corrupt_entry_bit(&mut self, layer: &str, nth: usize) -> bool {
        let Some((_, _, cached)) = self.entries.get_mut(layer).and_then(|l| l.get_mut(nth))
        else {
            return false;
        };
        if cached.wq.mantissas.is_empty() {
            return false;
        }
        let wq = Arc::make_mut(&mut cached.wq);
        let mid = wq.mantissas.len() / 2;
        wq.mantissas[mid] ^= 1 << 6;
        self.generation += 1;
        true
    }
}

/// Reusable scratch arena for the prepared forward pass: the fused
/// pipeline's `K×NC` im2col staging tile and the packed activation
/// panels. Buffers only grow (to the model's high-water mark); every
/// element of the active region is fully overwritten before use, so
/// reuse across differently-shaped layers can never leak state (tested
/// in `tests/prepared_parallel.rs`). Compared to the pre-tiled arena
/// (full `K×N` f32 im2col buffer + `K×N` i32 mantissa matrix + `K×N`
/// f32 repack scratch ≈ 3·K·N), this holds one packed operand plus a
/// `K×NC` tile.
pub struct Workspace {
    tile: Vec<f32>,
    acts: ActPanels,
}

impl Default for Workspace {
    fn default() -> Self {
        Self::new()
    }
}

impl Workspace {
    /// An empty arena; it grows on first use.
    pub fn new() -> Self {
        Self { tile: Vec::new(), acts: ActPanels::new() }
    }

    /// Activation high-water mark in elements (reporting/tests): the
    /// packed-panel capacity, at least `K×N` of the largest conv seen.
    pub fn col_capacity(&self) -> usize {
        self.acts.capacity().max(self.tile.len())
    }
}

/// The executor behind [`PreparedModel::forward`]: identical graph
/// semantics to [`crate::nn::BfpExec`], with conv layers reading the
/// weight cache and staging through the workspace arena.
struct PreparedExec<'a> {
    convs: &'a HashMap<String, CachedWeights>,
    /// Conv layer name → graph-order index, for span tagging.
    index: &'a HashMap<String, u16>,
    schedule: &'a LayerSchedule,
    ws: &'a mut Workspace,
}

impl Executor for PreparedExec<'_> {
    type T = Tensor;

    fn conv(&mut self, layer: &Conv2d, x: Tensor) -> Tensor {
        let cached = self
            .convs
            .get(layer.name.as_str())
            .unwrap_or_else(|| panic!("conv layer `{}` missing from the prepared cache", layer.name));
        let cfg = self.schedule.for_layer(&layer.name);
        let geo = layer.geometry(&x.shape);
        let (m, k, n) = (layer.out_channels(), geo.k(), geo.n());
        // tag this thread's spans (pack/im2col/gemm below) with the conv
        // layer index and the schedule's BFP widths while tracing
        let _layer_ctx = crate::obs::armed().then(|| {
            crate::obs::layer_scope(
                self.index.get(layer.name.as_str()).copied().unwrap_or(u16::MAX),
                cached.wq.frac_bits as u8,
                cfg.i_format().frac_bits() as u8,
            )
        });
        let Workspace { tile, acts } = &mut *self.ws;
        let lane = kernel::select_lane(cached.wq.frac_bits, cfg.i_format().frac_bits(), k);
        // fused pipeline: im2col tiles quantized straight into packed
        // panels — no K×N staging matrix exists on this path
        acts.pack_im2col(&x.data, &geo, cfg.i_format(), cfg.scheme.i_axis(), lane, tile);
        // the output buffer becomes the layer's tensor, so it is the one
        // allocation this path keeps
        let mut out = vec![0f32; m * n];
        let panels = cached.panels_for(lane);
        kernel::gemm_tiled(&cached.wq, panels.as_panels(), acts, &mut out);
        layer.add_bias(&mut out, n);
        Tensor::from_vec(out, &[m, geo.out_h(), geo.out_w()])
    }

    fn dense(&mut self, layer: &Dense, x: Tensor) -> Tensor {
        // FC layers stay FP32, matching `BfpExec { quantize_dense: false }`
        layer.forward_fp32(&x)
    }

    fn batch_norm(&mut self, layer: &BatchNorm, x: Tensor) -> Tensor {
        layer.forward(&x)
    }

    fn relu(&mut self, x: Tensor) -> Tensor {
        ops::relu(&x)
    }

    fn max_pool(&mut self, _name: &str, k: usize, s: usize, p: usize, x: Tensor) -> Tensor {
        max_pool2d(&x, k, s, p)
    }

    fn avg_pool(&mut self, _name: &str, k: usize, s: usize, p: usize, x: Tensor) -> Tensor {
        avg_pool2d(&x, k, s, p)
    }

    fn global_avg_pool(&mut self, x: Tensor) -> Tensor {
        global_avg_pool(&x)
    }

    fn flatten(&mut self, x: Tensor) -> Tensor {
        ops::flatten(&x)
    }

    fn add(&mut self, a: Tensor, b: Tensor) -> Tensor {
        ops::add(&a, &b)
    }

    fn concat(&mut self, parts: Vec<Tensor>) -> Tensor {
        ops::concat_channels(&parts)
    }

    fn softmax(&mut self, x: Tensor) -> Tensor {
        ops::softmax(&x)
    }

    fn fork(&mut self, x: &Tensor) -> Tensor {
        x.clone()
    }
}

/// A model prepared for steady-state serving: weights quantized up front
/// per the active schedule, scratch arenas pooled for reuse.
pub struct PreparedModel {
    model: Model,
    schedule: LayerSchedule,
    /// Shared across lanes serving the same model under different
    /// schedules — a weight format is quantized once per cache, not once
    /// per lane.
    cache: SharedWeightCache,
    /// Active view for the current schedule: layer name → cached weights.
    active: HashMap<String, CachedWeights>,
    /// Conv layer name → graph-traversal index (stable across schedule
    /// swaps; tags trace spans with the layer they belong to).
    conv_index: HashMap<String, u16>,
    /// Idle scratch arenas, checked out per forward and returned after —
    /// the pool grows to the peak concurrency and then stops allocating.
    workspaces: Mutex<Vec<Workspace>>,
    /// [`Model::approx_macs_per_image`], computed once — the batched
    /// forward's work estimate for the pool's small-batch guard.
    work_per_image: usize,
}

impl PreparedModel {
    /// Quantize every conv layer of `model` under `schedule`.
    pub fn new(model: Model, schedule: LayerSchedule) -> Self {
        Self::with_cache(model, schedule, WeightCache::shared())
    }

    /// [`PreparedModel::new`] over a caller-provided [`SharedWeightCache`]
    /// — the multi-lane constructor: every lane built over the same handle
    /// shares quantized weights per distinct `(layer, weight format)`.
    pub fn with_cache(model: Model, schedule: LayerSchedule, cache: SharedWeightCache) -> Self {
        let work_per_image = model.approx_macs_per_image();
        let mut prepared = Self {
            model,
            schedule: LayerSchedule::uniform(BfpConfig::paper_default()),
            cache,
            active: HashMap::new(),
            conv_index: HashMap::new(),
            workspaces: Mutex::new(Vec::new()),
            work_per_image,
        };
        prepared.set_schedule(schedule);
        prepared
    }

    /// Swap the precision schedule (plan hot-swap, autotune refinement).
    /// Only layers whose resolved config changed are re-quantized; every
    /// other layer is a cache hit.
    pub fn set_schedule(&mut self, schedule: LayerSchedule) {
        let mut active = HashMap::new();
        let mut index = HashMap::new();
        let mut cache = self.cache.lock().unwrap();
        let graph = &self.model.graph;
        graph.visit_convs(&mut |c: &Conv2d| {
            let cfg = schedule.for_layer(&c.name);
            index.insert(c.name.clone(), index.len().min(u16::MAX as usize) as u16);
            active.insert(c.name.clone(), cache.get_or_quantize_packed(c, cfg));
        });
        drop(cache);
        self.active = active;
        self.conv_index = index;
        self.schedule = schedule;
    }

    /// The wrapped model.
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// The active precision schedule.
    pub fn schedule(&self) -> &LayerSchedule {
        &self.schedule
    }

    /// The shared weight-cache handle (build further lanes over it).
    pub fn shared_cache(&self) -> SharedWeightCache {
        Arc::clone(&self.cache)
    }

    /// `(entries, hits, misses)` of the weight cache.
    pub fn cache_stats(&self) -> (usize, usize, usize) {
        let cache = self.cache.lock().unwrap();
        (cache.len(), cache.hits(), cache.misses())
    }

    fn take_workspace(&self) -> Workspace {
        self.workspaces.lock().unwrap().pop().unwrap_or_default()
    }

    fn put_workspace(&self, ws: Workspace) {
        self.workspaces.lock().unwrap().push(ws);
    }

    /// Grow the scratch arena to its high-water mark with one zero image,
    /// so the first real request pays no allocation.
    pub fn warm(&self) {
        let _ = self.forward(&Tensor::zeros(&self.model.input_shape));
    }

    /// Forward one image (bit-identical to the unprepared BFP path).
    pub fn forward(&self, input: &Tensor) -> Tensor {
        let mut ws = self.take_workspace();
        let out = self.forward_with(input, &mut ws);
        self.put_workspace(ws);
        out
    }

    /// [`PreparedModel::forward`] with a caller-owned workspace
    /// (benchmarks and the stale-data tests).
    pub fn forward_with(&self, input: &Tensor, ws: &mut Workspace) -> Tensor {
        assert_eq!(input.shape, self.model.input_shape, "input shape mismatch for {}", self.model.name);
        let mut exec = PreparedExec {
            convs: &self.active,
            index: &self.conv_index,
            schedule: &self.schedule,
            ws,
        };
        self.model.graph.execute(input.clone(), &mut exec)
    }

    /// Forward a batch, parallelized over images on the thread pool (each
    /// worker checks out its own workspace; a single-image batch instead
    /// parallelizes its GEMM row panels). Output order matches input
    /// order and every image's result is bit-identical to [`Self::forward`].
    pub fn forward_batch(&self, images: Vec<Tensor>) -> Vec<Tensor> {
        for img in &images {
            assert_eq!(img.shape, self.model.input_shape, "input shape mismatch for {}", self.model.name);
        }
        struct ArenaGuard<'a> {
            ws: Option<Workspace>,
            owner: &'a PreparedModel,
        }
        impl Drop for ArenaGuard<'_> {
            fn drop(&mut self) {
                if let Some(ws) = self.ws.take() {
                    self.owner.put_workspace(ws);
                }
            }
        }
        pool::parallel_map_with(
            images,
            self.work_per_image,
            || ArenaGuard { ws: Some(self.take_workspace()), owner: self },
            |guard, img| {
                // LINT-ALLOW: serving-unwrap — ws is Some for the
                // guard's whole life; only Drop takes it out.
                let ws = guard.ws.as_mut().expect("workspace checked out");
                let mut exec = PreparedExec {
                    convs: &self.active,
                    index: &self.conv_index,
                    schedule: &self.schedule,
                    ws,
                };
                self.model.graph.execute(img, &mut exec)
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{BfpExec, Block};

    fn tiny_model(seed: u64) -> Model {
        let mut rng = crate::data::Rng::new(seed);
        Model {
            name: "tiny".into(),
            graph: Block::seq(vec![
                Block::Conv(crate::models::init::conv2d("c1", 6, 2, 3, 3, 1, 1, &mut rng)),
                Block::ReLU,
                Block::MaxPool { name: "p1".into(), k: 2, s: 2, p: 0 },
                Block::Conv(crate::models::init::conv2d("c2", 4, 6, 3, 3, 1, 1, &mut rng)),
                Block::Flatten,
            ]),
            input_shape: vec![2, 10, 10],
            num_classes: 0,
        }
    }

    fn image(seed: u64) -> Tensor {
        let mut rng = crate::data::Rng::new(seed);
        Tensor::from_vec(rng.normal_vec(2 * 10 * 10, 1.5), &[2, 10, 10])
    }

    #[test]
    fn prepared_matches_unprepared_bit_for_bit() {
        let model = tiny_model(3);
        let cfg = BfpConfig::paper_default();
        let img = image(7);
        let want = model.graph.execute(img.clone(), &mut BfpExec::new(cfg));
        let prepared = PreparedModel::new(model, LayerSchedule::uniform(cfg));
        let got = prepared.forward(&img);
        assert_eq!(want.shape, got.shape);
        for (a, b) in want.data.iter().zip(&got.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn schedule_swap_requantizes_only_changes() {
        let model = tiny_model(5);
        let uniform = LayerSchedule::uniform(BfpConfig::paper_default());
        let mut prepared = PreparedModel::new(model, uniform.clone());
        assert_eq!(prepared.cache_stats(), (2, 0, 2), "two convs quantized once each");
        // override one layer: one new entry, one hit
        let mixed = uniform.clone().with_layer("c2", BfpConfig::new(6, 6));
        prepared.set_schedule(mixed);
        assert_eq!(prepared.cache_stats(), (3, 1, 3));
        // swap back: all hits
        prepared.set_schedule(uniform);
        assert_eq!(prepared.cache_stats(), (3, 3, 3));
    }

    #[test]
    fn batch_matches_sequential_forwards() {
        let model = tiny_model(11);
        let prepared = PreparedModel::new(model, LayerSchedule::uniform(BfpConfig::new(7, 9)));
        prepared.warm();
        let images: Vec<Tensor> = (0..5).map(|s| image(100 + s)).collect();
        let one_by_one: Vec<Tensor> = images.iter().map(|i| prepared.forward(i)).collect();
        let batched = prepared.forward_batch(images);
        for (a, b) in one_by_one.iter().zip(&batched) {
            assert_eq!(a.shape, b.shape);
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    /// Multi-lane construction over one shared cache: lanes whose
    /// schedules resolve to the same weight format share quantized
    /// weights — a model's weights are quantized once per distinct
    /// format, not once per lane.
    #[test]
    fn lanes_share_one_weight_cache() {
        let model = tiny_model(9);
        let cache = WeightCache::shared();
        let gold = PreparedModel::with_cache(
            model.clone(),
            LayerSchedule::uniform(BfpConfig::new(8, 8)),
            Arc::clone(&cache),
        );
        assert_eq!(gold.cache_stats(), (2, 0, 2));
        // same weight widths, narrower activations: weight format is
        // unchanged, so the second lane is all cache hits
        let standard = PreparedModel::with_cache(
            model.clone(),
            LayerSchedule::uniform(BfpConfig::new(8, 6)),
            Arc::clone(&cache),
        );
        assert_eq!(standard.cache_stats(), (2, 2, 2), "second lane re-quantized shared weights");
        // a genuinely narrower weight format quantizes once more
        let economy = PreparedModel::with_cache(
            model.clone(),
            LayerSchedule::uniform(BfpConfig::new(5, 5)),
            Arc::clone(&cache),
        );
        assert_eq!(economy.cache_stats(), (4, 2, 4));
        // all lanes report through the same handle
        assert_eq!(gold.cache_stats(), economy.cache_stats());
    }

    /// Two models with a same-named layer but different weights must get
    /// separate cache entries (the fingerprint in the key), never share.
    #[test]
    fn cache_never_serves_another_models_weights() {
        let mut cache = WeightCache::default();
        let mut rng_a = crate::data::Rng::new(1);
        let mut rng_b = crate::data::Rng::new(2);
        let a = crate::models::init::conv2d("conv1", 4, 2, 3, 3, 1, 1, &mut rng_a);
        let b = crate::models::init::conv2d("conv1", 4, 2, 3, 3, 1, 1, &mut rng_b);
        let cfg = BfpConfig::paper_default();
        let wa = cache.get_or_quantize(&a, cfg);
        let wb = cache.get_or_quantize(&b, cfg);
        assert_eq!(cache.misses(), 2, "distinct weights behind one name must both quantize");
        assert_eq!(cache.hits(), 0);
        assert_ne!(wa.wq.mantissas, wb.wq.mantissas);
        // repeat lookups hit their own entries
        assert_eq!(cache.get_or_quantize(&a, cfg).wq.mantissas, wa.wq.mantissas);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_input_shape() {
        let prepared = PreparedModel::new(tiny_model(1), LayerSchedule::uniform(BfpConfig::paper_default()));
        prepared.forward(&Tensor::zeros(&[2, 8, 8]));
    }

    /// The integrity loop end to end at cache level: an injected
    /// mantissa flip bumps the generation (waking a parked scrubber),
    /// is detected by `scrub`, and the repaired entry is bit-identical
    /// to a fresh quantize — while hit/miss counters and lanes'
    /// resident clones stay untouched.
    #[test]
    fn scrub_repairs_a_flipped_entry_bit_identically() {
        let model = tiny_model(21);
        let cfg = BfpConfig::paper_default();
        let cache = WeightCache::shared();
        let prepared = PreparedModel::with_cache(
            model.clone(),
            LayerSchedule::uniform(cfg),
            Arc::clone(&cache),
        );
        let img = image(3);
        let clean = prepared.forward(&img);

        let mut c1 = None;
        model.graph.visit_convs(&mut |c: &Conv2d| {
            if c.name == "c1" {
                c1 = Some(c);
            }
        });
        let c1 = c1.expect("tiny model has a c1 conv");
        let truth = c1.quantize_weights(&cfg);

        {
            let mut cache = cache.lock().unwrap();
            let gen0 = cache.generation();
            assert!(!cache.corrupt_entry_bit("ghost", 0), "unknown layer must be a no-op");
            assert_eq!(cache.generation(), gen0);
            assert!(cache.corrupt_entry_bit("c1", 0));
            assert!(cache.generation() > gen0, "corruption must wake the parked scrubber");
            let (len, hits, misses) = (cache.len(), cache.hits(), cache.misses());
            let report = cache.scrub(&model);
            assert_eq!(report.repaired, vec!["c1".to_string()]);
            assert_eq!((report.verified, report.evicted), (len - 1, 0));
            assert_eq!(
                (cache.len(), cache.hits(), cache.misses()),
                (len, hits, misses),
                "scrub is maintenance, not traffic"
            );
            let again = cache.scrub(&model);
            assert!(again.repaired.is_empty() && again.evicted == 0);
            assert_eq!(again.verified, len);
        }
        // the repaired entry is bit-identical to a fresh quantize
        let repaired = cache.lock().unwrap().get_or_quantize(c1, cfg);
        assert_eq!(repaired.wq.mantissas, truth.mantissas);
        assert_eq!(repaired.wq.exponents, truth.exponents);
        assert_eq!(repaired.checksum, weight_checksum(&truth));
        // the lane's active clone never saw the flip: the forward is
        // bit-identical to the pre-corruption run
        let after = prepared.forward(&img);
        for (a, b) in clean.data.iter().zip(&after.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// A corrupt entry whose fp32 source is absent from the scrubbed
    /// model (fingerprint mismatch) cannot be repaired — it is evicted
    /// so the next lookup requantizes instead of serving garbage.
    #[test]
    fn scrub_evicts_corrupt_entries_without_a_source() {
        let cfg = BfpConfig::paper_default();
        let model_a = tiny_model(31);
        let model_b = tiny_model(32); // same layer names, different weights
        let cache = WeightCache::shared();
        let _lane = PreparedModel::with_cache(
            model_a.clone(),
            LayerSchedule::uniform(cfg),
            Arc::clone(&cache),
        );
        let mut cache = cache.lock().unwrap();
        assert!(cache.corrupt_entry_bit("c2", 0));
        let len = cache.len();
        let report = cache.scrub(&model_b);
        assert!(report.repaired.is_empty(), "wrong-model weights must never repair an entry");
        assert_eq!(report.evicted, 1);
        assert_eq!(cache.len(), len - 1);
        // the evicted entry refills on the next lookup, clean
        let misses = cache.misses();
        let mut c2 = None;
        model_a.graph.visit_convs(&mut |c: &Conv2d| {
            if c.name == "c2" {
                c2 = Some(c);
            }
        });
        let refilled = cache.get_or_quantize(c2.unwrap(), cfg);
        assert_eq!(cache.misses(), misses + 1);
        assert_eq!(refilled.checksum, weight_checksum(&refilled.wq));
    }
}
