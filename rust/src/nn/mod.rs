//! CNN layer zoo and composable model graph.
//!
//! * [`layers`] — parameterised layers: [`Conv2d`], [`Dense`],
//!   [`BatchNorm`] (+ stateless activations in [`ops`]).
//! * [`graph`] — the [`Block`] composition tree (sequential, residual,
//!   inception concat) walked by an [`Executor`]; the same tree serves the
//!   FP32 reference path, the BFP path and the instrumented dual path.
//! * [`exec`] — the two production executors: [`exec::Fp32Exec`] and
//!   [`exec::BfpExec`] (the Figure 2 data flow per conv layer).
//! * [`prepared`] — the steady-state serving path: weight quantization
//!   cached per `(layer, config)`, scratch-arena workspaces, and batch
//!   forwards parallelized on the [`crate::runtime::pool`].

pub mod exec;
pub mod graph;
pub mod layers;
pub mod ops;
pub mod prepared;

pub use exec::{BfpExec, Fp32Exec};
pub use graph::{Block, Executor};
pub use layers::{BatchNorm, Conv2d, Dense};
pub use prepared::{PreparedModel, SharedWeightCache, WeightCache, Workspace};
