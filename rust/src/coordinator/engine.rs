//! Inference engine: run a model over a batch of images in a chosen
//! numeric mode.

use crate::models::Model;
use crate::nn::{BfpExec, Fp32Exec};
use crate::quant::{BfpConfig, LayerSchedule};
use crate::runtime::pool;
use crate::tensor::Tensor;

/// Numeric execution mode.
///
/// No longer `Copy`: [`ExecMode::Mixed`] carries a per-layer
/// [`LayerSchedule`] (a name → config map), so clone where a copy was
/// previously taken.
#[derive(Debug, Clone, PartialEq)]
pub enum ExecMode {
    /// FP32 reference (the paper's "floating point" rows).
    Fp32,
    /// Block-floating-point conv layers (the Figure 2 data flow), one
    /// uniform width pair for the whole network.
    Bfp(BfpConfig),
    /// Per-layer mixed precision — the execution mode of an autotuned
    /// [`crate::autotune::PrecisionPlan`].
    Mixed(LayerSchedule),
}

impl ExecMode {
    /// Short human-readable tag for logs/metrics.
    pub fn describe(&self) -> String {
        match self {
            ExecMode::Fp32 => "fp32".to_string(),
            ExecMode::Bfp(cfg) => format!("bfp{}/{}", cfg.l_w, cfg.l_i),
            ExecMode::Mixed(s) => {
                let d = s.default_config();
                format!("mixed({} overrides, default {}/{})", s.overrides().len(), d.l_w, d.l_i)
            }
        }
    }
}

/// Forward a batch of `[C,H,W]` images, returning per-image logits.
///
/// Takes the batch by value: images flow into `Block::execute` without a
/// per-image copy (the serving path moves tensors straight out of the
/// request queue). Work is spread over the [`pool`] by image — one
/// executor per worker thread, so a Mixed schedule clones its
/// name → config map per thread, not per image — and each image's result
/// is bit-identical to a serial run (the GEMM row panels parallelize
/// instead when the batch is a single image).
pub fn forward_batch(model: &Model, images: Vec<Tensor>, mode: ExecMode) -> Vec<Tensor> {
    for img in &images {
        assert_eq!(img.shape, model.input_shape, "input shape mismatch for {}", model.name);
    }
    let _span = crate::obs::span(crate::obs::Stage::Forward);
    let work = model.approx_macs_per_image();
    match mode {
        ExecMode::Fp32 => {
            pool::parallel_map_with(images, work, || Fp32Exec, |e, img| model.graph.execute(img, e))
        }
        ExecMode::Bfp(cfg) => {
            pool::parallel_map_with(images, work, move || BfpExec::new(cfg), |e, img| {
                model.graph.execute(img, e)
            })
        }
        ExecMode::Mixed(sched) => {
            let sched = &sched;
            pool::parallel_map_with(
                images,
                work,
                move || BfpExec::with_schedule(sched.clone()),
                |e, img| model.graph.execute(img, e),
            )
        }
    }
}

/// [`forward_batch`] over borrowed images: clones the batch once up
/// front. Analysis and harness code that reuses its image set calls
/// this; the serving path uses the by-value form to avoid the copies.
pub fn forward_batch_ref(model: &Model, images: &[Tensor], mode: ExecMode) -> Vec<Tensor> {
    forward_batch(model, images.to_vec(), mode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelId;
    use std::path::Path;

    #[test]
    fn batch_forward_lenet_both_modes() {
        let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
        let images = crate::data::DigitDataset::generate(3, 1).images;
        let fp = forward_batch_ref(&model, &images, ExecMode::Fp32);
        let bfp = forward_batch(&model, images, ExecMode::Bfp(BfpConfig::paper_default()));
        assert_eq!(fp.len(), 3);
        assert_eq!(bfp.len(), 3);
        for (a, b) in fp.iter().zip(&bfp) {
            assert_eq!(a.shape, vec![10]);
            assert_eq!(b.shape, vec![10]);
            // 8-bit BFP predictions should track fp32 closely on lenet
            let nsr = a.data.iter().zip(&b.data).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
                / a.energy().max(1e-12);
            assert!(nsr < 0.05, "NSR {nsr}");
        }
    }

    #[test]
    fn mixed_mode_executes_per_layer_plan() {
        let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
        let images = crate::data::DigitDataset::generate(2, 7).images;
        let fp = forward_batch_ref(&model, &images, ExecMode::Fp32);
        let sched = LayerSchedule::uniform(BfpConfig::new(6, 6))
            .with_layer("conv1", BfpConfig::new(9, 9));
        let mixed = forward_batch(&model, images, ExecMode::Mixed(sched));
        for (a, b) in fp.iter().zip(&mixed) {
            assert_eq!(b.shape, vec![10]);
            let nsr = a.data.iter().zip(&b.data).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
                / a.energy().max(1e-12);
            assert!(nsr < 0.2, "NSR {nsr}");
        }
    }

    /// Image-level parallelism must not change a single bit of output.
    #[test]
    fn parallel_batch_is_bit_identical_to_serial() {
        let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
        let images = crate::data::DigitDataset::generate(5, 3).images;
        let mode = ExecMode::Bfp(BfpConfig::paper_default());
        let serial = crate::runtime::pool::with_threads(1, || forward_batch_ref(&model, &images, mode.clone()));
        for t in [2usize, 4] {
            let par = crate::runtime::pool::with_threads(t, || forward_batch_ref(&model, &images, mode.clone()));
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.shape, b.shape);
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "threads={t}");
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_shape() {
        let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
        let bad = vec![Tensor::zeros(&[3, 32, 32])];
        forward_batch(&model, bad, ExecMode::Fp32);
    }
}
