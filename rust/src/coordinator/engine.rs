//! Inference engine: run a model over a batch of images in a chosen
//! numeric mode.

use crate::models::Model;
use crate::nn::{BfpExec, Fp32Exec};
use crate::quant::BfpConfig;
use crate::tensor::Tensor;

/// Numeric execution mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ExecMode {
    /// FP32 reference (the paper's "floating point" rows).
    Fp32,
    /// Block-floating-point conv layers (the Figure 2 data flow).
    Bfp(BfpConfig),
}

/// Forward a batch of `[C,H,W]` images, returning per-image logits.
pub fn forward_batch(model: &Model, images: &[Tensor], mode: ExecMode) -> Vec<Tensor> {
    images
        .iter()
        .map(|img| {
            assert_eq!(img.shape, model.input_shape, "input shape mismatch for {}", model.name);
            match mode {
                ExecMode::Fp32 => model.graph.execute(img.clone(), &mut Fp32Exec),
                ExecMode::Bfp(cfg) => model.graph.execute(img.clone(), &mut BfpExec::new(cfg)),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelId;
    use std::path::Path;

    #[test]
    fn batch_forward_lenet_both_modes() {
        let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
        let images = crate::data::DigitDataset::generate(3, 1).images;
        let fp = forward_batch(&model, &images, ExecMode::Fp32);
        let bfp = forward_batch(&model, &images, ExecMode::Bfp(BfpConfig::paper_default()));
        assert_eq!(fp.len(), 3);
        assert_eq!(bfp.len(), 3);
        for (a, b) in fp.iter().zip(&bfp) {
            assert_eq!(a.shape, vec![10]);
            assert_eq!(b.shape, vec![10]);
            // 8-bit BFP predictions should track fp32 closely on lenet
            let nsr = a.data.iter().zip(&b.data).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
                / a.energy().max(1e-12);
            assert!(nsr < 0.05, "NSR {nsr}");
        }
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_shape() {
        let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
        let bad = vec![Tensor::zeros(&[3, 32, 32])];
        forward_batch(&model, &bad, ExecMode::Fp32);
    }
}
