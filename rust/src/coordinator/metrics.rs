//! Serving metrics: latency distribution and throughput.
//!
//! Latency and queue-wait distributions live in fixed-size log-linear
//! histograms ([`LogHistogram`]): a long-running server records millions
//! of requests into a few KB of counters, and percentile queries walk the
//! buckets instead of cloning + sorting a sample vector. Per-class
//! breakdowns ([`ClassMetrics`]) feed the QoS report — each serving class
//! gets its own distributions plus downgrade / deadline-miss counters.

use std::time::Duration;

/// Exact buckets below this value (µs); log-linear above.
const LINEAR_CUTOVER: u64 = 32;
/// Sub-buckets per octave above the cutover: 32 ⇒ the bucket midpoint is
/// within 1/64 (≈1.6%) of any recorded value.
const SUB_BUCKETS: usize = 32;
/// Octaves 5..=63 cover the full `u64` range above the cutover.
const BUCKETS: usize = LINEAR_CUTOVER as usize + (64 - 5) * SUB_BUCKETS;

/// Fixed-size log-linear histogram over `u64` samples (HdrHistogram-style):
/// exact below [`LINEAR_CUTOVER`], then [`SUB_BUCKETS`] linear sub-buckets
/// per power of two. Memory is constant regardless of how many samples are
/// recorded, and percentiles are read by a single cumulative walk with a
/// bounded ≈1.6% relative error.
#[derive(Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self { counts: vec![0; BUCKETS], total: 0, sum: 0, max: 0 }
    }
}

impl std::fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LogHistogram")
            .field("total", &self.total)
            .field("sum", &self.sum)
            .field("max", &self.max)
            .finish()
    }
}

fn bucket_index(v: u64) -> usize {
    if v < LINEAR_CUTOVER {
        return v as usize;
    }
    let octave = 63 - v.leading_zeros() as usize; // >= 5
    let sub = ((v >> (octave - 5)) & (SUB_BUCKETS as u64 - 1)) as usize;
    LINEAR_CUTOVER as usize + (octave - 5) * SUB_BUCKETS + sub
}

/// Midpoint of the bucket's value range — what percentile queries return.
///
/// A bucket in octave `o` covers the `step = 2^(o-5)` integers
/// `[edge, edge + step)`, so the midpoint of the *recordable* values is
/// `edge + (step − 1)/2` — computed in f64 so the first octave
/// (`step = 1`, one value per bucket) returns the value itself instead
/// of truncating `step/2` to zero and collapsing onto the lower edge,
/// and even-width buckets land between their two central values rather
/// than biased high. Worst-case error is `(step − 1)/2` against an edge
/// of at least `32·step`: within 1/64 (≈1.6%) of any recorded value.
fn bucket_value(idx: usize) -> f64 {
    if idx < LINEAR_CUTOVER as usize {
        return idx as f64;
    }
    let rel = idx - LINEAR_CUTOVER as usize;
    let octave = 5 + rel / SUB_BUCKETS;
    let sub = (rel % SUB_BUCKETS) as u64;
    let step = 1u64 << (octave - 5);
    let edge = (LINEAR_CUTOVER + sub) as f64 * step as f64;
    edge + (step - 1) as f64 / 2.0
}

impl LogHistogram {
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.total += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Percentile (`p` in [0, 100]) by cumulative bucket walk; returns the
    /// midpoint of the bucket holding the ranked sample.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = (p.clamp(0.0, 100.0) / 100.0 * (self.total - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return bucket_value(idx);
            }
        }
        self.max as f64
    }

    /// Merge another histogram into this one (bucket-wise sum).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }

    /// Zero every counter, keeping the bucket allocation — the per-lane
    /// executors reuse one scratch histogram across batches.
    pub fn clear(&mut self) {
        self.counts.fill(0);
        self.total = 0;
        self.sum = 0;
        self.max = 0;
    }
}

/// Per-QoS-class serving metrics: the same distributions as the global
/// [`Metrics`] plus the counters the QoS report needs.
#[derive(Debug, Clone)]
pub struct ClassMetrics {
    pub label: String,
    latencies_us: LogHistogram,
    queue_waits_us: LogHistogram,
    pub requests: u64,
    /// Requests served by a cheaper lane than their class asked for.
    pub downgrades: u64,
    /// Requests answered after their deadline had passed.
    pub deadline_misses: u64,
    /// Requests failed by the deadline reaper (typed `Timeout`), never
    /// served. Not counted in `requests`.
    pub timeouts: u64,
    /// Requests failed with a typed error (executor panic, retired lane,
    /// drain). Not counted in `requests`.
    pub failures: u64,
}

impl ClassMetrics {
    fn new(label: &str) -> Self {
        Self {
            label: label.to_string(),
            latencies_us: LogHistogram::default(),
            queue_waits_us: LogHistogram::default(),
            requests: 0,
            downgrades: 0,
            deadline_misses: 0,
            timeouts: 0,
            failures: 0,
        }
    }

    /// Latency percentile in milliseconds.
    pub fn latency_p(&self, p: f64) -> f64 {
        self.latencies_us.percentile(p) / 1000.0
    }

    /// Queue-wait percentile in milliseconds.
    pub fn queue_wait_p(&self, p: f64) -> f64 {
        self.queue_waits_us.percentile(p) / 1000.0
    }

    /// Fraction of this class's requests served on a cheaper lane.
    pub fn downgrade_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.downgrades as f64 / self.requests as f64
    }

    fn merge_from(&mut self, other: &ClassMetrics) {
        self.latencies_us.merge(&other.latencies_us);
        self.queue_waits_us.merge(&other.queue_waits_us);
        self.requests += other.requests;
        self.downgrades += other.downgrades;
        self.deadline_misses += other.deadline_misses;
        self.timeouts += other.timeouts;
        self.failures += other.failures;
    }

    fn clear(&mut self) {
        self.latencies_us.clear();
        self.queue_waits_us.clear();
        self.requests = 0;
        self.downgrades = 0;
        self.deadline_misses = 0;
        self.timeouts = 0;
        self.failures = 0;
    }
}

/// Per-tenant admission accounting, recorded by the TCP front's quota
/// gate (the in-process paths carry no tenant identity, so the list
/// stays empty there).
#[derive(Debug, Clone)]
pub struct TenantMetrics {
    pub label: String,
    /// Requests seen from this tenant, shed ones included.
    pub requests: u64,
    /// Requests degraded to the economy lane by the tenant quota.
    pub quota_downgrades: u64,
    /// Requests shed outright with an `OverQuota` error frame.
    pub rejected: u64,
}

impl TenantMetrics {
    fn new(label: &str) -> Self {
        Self { label: label.to_string(), requests: 0, quota_downgrades: 0, rejected: 0 }
    }

    /// Fraction of this tenant's traffic the quota acted on.
    pub fn over_quota_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        (self.quota_downgrades + self.rejected) as f64 / self.requests as f64
    }

    fn merge_from(&mut self, other: &TenantMetrics) {
        self.requests += other.requests;
        self.quota_downgrades += other.quota_downgrades;
        self.rejected += other.rejected;
    }

    fn clear(&mut self) {
        self.requests = 0;
        self.quota_downgrades = 0;
        self.rejected = 0;
    }
}

/// Accumulated serving metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    latencies_us: LogHistogram,
    queue_waits_us: LogHistogram,
    batch_size_sum: u64,
    batch_obs: u64,
    pub total_requests: usize,
    pub wall_time: Duration,
    /// Executor respawns performed by the lane supervisor.
    pub lane_restarts: u64,
    /// Lanes retired after exhausting their restart budget.
    pub lanes_retired: u64,
    /// Weight-cache scrub passes that actually verified checksums (a
    /// pass skipped because the cache generation was unchanged does not
    /// count).
    pub scrub_passes: u64,
    /// Cache entries whose checksum mismatched and were requantized from
    /// the fp32 weights by the scrubber.
    pub scrub_repairs: u64,
    /// Inbound frames rejected for a payload CRC mismatch.
    pub frame_crc_errors: u64,
    /// Requests refused at admission for NaN/Inf values or a shape the
    /// model cannot take.
    pub bad_inputs: u64,
    /// Batches whose lane produced non-finite logits and was failed with
    /// a typed `CorruptOutput` error instead of replying with garbage.
    pub corrupt_outputs: u64,
    /// Per-class breakdowns in first-seen order (empty for classless
    /// serving through the plain [`super::InferenceServer`]).
    classes: Vec<ClassMetrics>,
    /// Per-tenant quota accounting in first-seen order (TCP front only).
    tenants: Vec<TenantMetrics>,
}

impl Metrics {
    pub fn record(&mut self, latency: Duration, queue_wait: Duration, batch_size: usize) {
        self.latencies_us.record(latency.as_micros() as u64);
        self.queue_waits_us.record(queue_wait.as_micros() as u64);
        self.batch_size_sum += batch_size as u64;
        self.batch_obs += 1;
        self.total_requests += 1;
    }

    /// [`Metrics::record`] with a per-class breakdown: also counts the
    /// request under `class`, plus its downgrade / deadline-miss flags.
    pub fn record_class(
        &mut self,
        class: &str,
        latency: Duration,
        queue_wait: Duration,
        batch_size: usize,
        downgraded: bool,
        deadline_missed: bool,
    ) {
        self.record(latency, queue_wait, batch_size);
        let cm = self.class_entry(class);
        cm.latencies_us.record(latency.as_micros() as u64);
        cm.queue_waits_us.record(queue_wait.as_micros() as u64);
        cm.requests += 1;
        if downgraded {
            cm.downgrades += 1;
        }
        if deadline_missed {
            cm.deadline_misses += 1;
        }
    }

    /// Count one request failed by the deadline reaper under `class`.
    /// Reaped requests never reach a lane, so they touch no latency
    /// histogram — only the class's `timeouts` counter.
    pub fn record_timeout(&mut self, class: &str) {
        self.class_entry(class).timeouts += 1;
    }

    /// Count one request failed with a typed error (panicked executor,
    /// retired lane, drain) under `class`.
    pub fn record_failure(&mut self, class: &str) {
        self.class_entry(class).failures += 1;
    }

    /// Count one supervisor respawn of a lane executor.
    pub fn record_restart(&mut self) {
        self.lane_restarts += 1;
    }

    /// Count one lane retirement (restart budget exhausted).
    pub fn record_retired(&mut self) {
        self.lanes_retired += 1;
    }

    /// Count one weight-cache scrub pass that verified checksums, with
    /// however many corrupted entries it repaired.
    pub fn record_scrub(&mut self, repairs: u64) {
        self.scrub_passes += 1;
        self.scrub_repairs += repairs;
    }

    /// Count one inbound frame rejected for a payload CRC mismatch.
    pub fn record_frame_crc_error(&mut self) {
        self.frame_crc_errors += 1;
    }

    /// Count one request refused at admission for non-finite values or a
    /// bad shape.
    pub fn record_bad_input(&mut self) {
        self.bad_inputs += 1;
    }

    /// Count one batch failed for non-finite lane output.
    pub fn record_corrupt_output(&mut self) {
        self.corrupt_outputs += 1;
    }

    fn class_entry(&mut self, class: &str) -> &mut ClassMetrics {
        let idx = match self.classes.iter().position(|c| c.label == class) {
            Some(i) => i,
            None => {
                self.classes.push(ClassMetrics::new(class));
                self.classes.len() - 1
            }
        };
        &mut self.classes[idx]
    }

    /// Count one request under `tenant`'s quota accounting. Unlike
    /// [`Metrics::record_class`] this happens at *admission* (the
    /// connection reader thread), not at response delivery — shed
    /// requests never reach a lane but still count here.
    pub fn record_tenant(&mut self, tenant: &str, quota_downgraded: bool, rejected: bool) {
        let idx = match self.tenants.iter().position(|t| t.label == tenant) {
            Some(i) => i,
            None => {
                self.tenants.push(TenantMetrics::new(tenant));
                self.tenants.len() - 1
            }
        };
        let tm = &mut self.tenants[idx];
        tm.requests += 1;
        if quota_downgraded {
            tm.quota_downgrades += 1;
        }
        if rejected {
            tm.rejected += 1;
        }
    }

    /// Latency percentile in milliseconds (`p` in [0, 100]).
    pub fn latency_p(&self, p: f64) -> f64 {
        self.latencies_us.percentile(p) / 1000.0
    }

    /// Mean queue wait in ms.
    pub fn mean_queue_wait_ms(&self) -> f64 {
        self.queue_waits_us.mean() / 1000.0
    }

    /// Mean batch size actually served.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_obs == 0 {
            return 0.0;
        }
        self.batch_size_sum as f64 / self.batch_obs as f64
    }

    /// Requests per second over the recorded wall time.
    pub fn throughput(&self) -> f64 {
        let s = self.wall_time.as_secs_f64();
        if s <= 0.0 {
            return 0.0;
        }
        self.total_requests as f64 / s
    }

    /// Fold another `Metrics` into this one: histograms merge bucket-wise
    /// ([`LogHistogram::merge`]), counters add, and per-class breakdowns
    /// are matched by label (created on first sight). `wall_time` is the
    /// owner's clock and is left untouched. This is the aggregation path
    /// for the per-lane QoS executors: each lane records into a local
    /// sink and folds it into the shared `Metrics` once per batch, so no
    /// response ever takes the global mutex individually.
    pub fn merge_from(&mut self, other: &Metrics) {
        self.latencies_us.merge(&other.latencies_us);
        self.queue_waits_us.merge(&other.queue_waits_us);
        self.batch_size_sum += other.batch_size_sum;
        self.batch_obs += other.batch_obs;
        self.total_requests += other.total_requests;
        self.lane_restarts += other.lane_restarts;
        self.lanes_retired += other.lanes_retired;
        self.scrub_passes += other.scrub_passes;
        self.scrub_repairs += other.scrub_repairs;
        self.frame_crc_errors += other.frame_crc_errors;
        self.bad_inputs += other.bad_inputs;
        self.corrupt_outputs += other.corrupt_outputs;
        for oc in
            other.classes.iter().filter(|c| c.requests > 0 || c.timeouts > 0 || c.failures > 0)
        {
            match self.classes.iter_mut().find(|c| c.label == oc.label) {
                Some(c) => c.merge_from(oc),
                None => self.classes.push(oc.clone()),
            }
        }
        for ot in other.tenants.iter().filter(|t| t.requests > 0) {
            match self.tenants.iter_mut().find(|t| t.label == ot.label) {
                Some(t) => t.merge_from(ot),
                None => self.tenants.push(ot.clone()),
            }
        }
    }

    /// Zero every counter while keeping allocations (histogram buckets,
    /// class entries) — the executors' scratch sink is cleared after each
    /// fold instead of reallocated.
    pub fn clear(&mut self) {
        self.latencies_us.clear();
        self.queue_waits_us.clear();
        self.batch_size_sum = 0;
        self.batch_obs = 0;
        self.total_requests = 0;
        self.wall_time = Duration::ZERO;
        self.lane_restarts = 0;
        self.lanes_retired = 0;
        self.scrub_passes = 0;
        self.scrub_repairs = 0;
        self.frame_crc_errors = 0;
        self.bad_inputs = 0;
        self.corrupt_outputs = 0;
        for c in &mut self.classes {
            c.clear();
        }
        for t in &mut self.tenants {
            t.clear();
        }
    }

    /// Per-class breakdowns (first-seen order).
    pub fn classes(&self) -> &[ClassMetrics] {
        &self.classes
    }

    /// The breakdown for one class label, if any requests carried it.
    pub fn class(&self, label: &str) -> Option<&ClassMetrics> {
        self.classes.iter().find(|c| c.label == label)
    }

    /// Per-tenant quota accounting (first-seen order; empty off the TCP
    /// path).
    pub fn tenants(&self) -> &[TenantMetrics] {
        &self.tenants
    }

    /// The accounting for one tenant id, if it ever sent a request.
    pub fn tenant(&self, label: &str) -> Option<&TenantMetrics> {
        self.tenants.iter().find(|t| t.label == label)
    }

    /// One-line summary for logs and EXPERIMENTS.md.
    pub fn summary(&self) -> String {
        format!(
            "{} reqs, {:.1} req/s, p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms, mean batch {:.2}, mean queue wait {:.2} ms",
            self.total_requests,
            self.throughput(),
            self.latency_p(50.0),
            self.latency_p(95.0),
            self.latency_p(99.0),
            self.mean_batch_size(),
            self.mean_queue_wait_ms(),
        )
    }
}

// ---- span-derived stage breakdowns -----------------------------------

/// One (lane, stage) cell of the stage-latency breakdown: every
/// completed span of a flight-recorder snapshot carrying that lane tag
/// and stage name, folded into one duration histogram.
#[derive(Debug, Clone)]
pub struct StageRow {
    /// Lane label (`-` for spans recorded outside any lane scope).
    pub lane: String,
    pub stage: &'static str,
    /// Span durations in µs.
    pub hist: LogHistogram,
}

/// Group a flight-recorder snapshot's spans into per-(lane, stage)
/// duration histograms — the data behind the qos_report stage table and
/// the network `Stats` frame. Instant events carry no duration and are
/// skipped. Rows come back lane-major (gold, standard, economy, shed,
/// then untagged) with stages in pipeline order.
pub fn stage_rows(records: &[crate::obs::SpanRecord]) -> Vec<StageRow> {
    let mut rows: Vec<StageRow> = Vec::new();
    for r in records.iter().filter(|r| !r.instant) {
        match rows.iter_mut().find(|row| row.lane == r.lane && row.stage == r.name) {
            Some(row) => row.hist.record(r.dur_us),
            None => {
                let mut hist = LogHistogram::default();
                hist.record(r.dur_us);
                rows.push(StageRow { lane: r.lane.to_string(), stage: r.name, hist });
            }
        }
    }
    let lane_rank = |lane: &str| match lane {
        "gold" => 0,
        "standard" => 1,
        "economy" => 2,
        "shed" => 3,
        _ => 4,
    };
    let stage_rank = |stage: &str| {
        crate::obs::Stage::ALL.iter().position(|s| s.name() == stage).unwrap_or(usize::MAX)
    };
    rows.sort_by_key(|r| (lane_rank(&r.lane), stage_rank(r.stage)));
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::default();
        for i in 1..=100u64 {
            m.record(Duration::from_micros(i * 1000), Duration::ZERO, 4);
        }
        // log-linear buckets: midpoint within 1/64 of the true value
        assert!((m.latency_p(50.0) - 50.0).abs() <= 1.5, "p50 {}", m.latency_p(50.0));
        assert!((m.latency_p(99.0) - 99.0).abs() <= 2.0, "p99 {}", m.latency_p(99.0));
        assert_eq!(m.mean_batch_size(), 4.0);
    }

    #[test]
    fn throughput_from_wall_time() {
        let mut m = Metrics::default();
        for _ in 0..10 {
            m.record(Duration::from_millis(1), Duration::ZERO, 1);
        }
        m.wall_time = Duration::from_secs(2);
        assert_eq!(m.throughput(), 5.0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert_eq!(m.latency_p(50.0), 0.0);
        assert_eq!(m.throughput(), 0.0);
        assert!(!m.summary().is_empty());
        assert!(m.classes().is_empty());
        assert!(m.class("gold").is_none());
    }

    #[test]
    fn histogram_is_fixed_size_and_accurate() {
        let mut h = LogHistogram::default();
        for v in [0u64, 1, 31, 32, 33, 1000, 50_000, 1_000_000, u64::MAX / 2] {
            h.record(v);
        }
        assert_eq!(h.count(), 9);
        assert_eq!(h.max(), u64::MAX / 2);
        // exact below the cutover
        let mut exact = LogHistogram::default();
        exact.record(17);
        assert_eq!(exact.percentile(50.0), 17.0);
        // bounded relative error above it
        let mut big = LogHistogram::default();
        big.record(123_456);
        let got = big.percentile(50.0);
        assert!((got - 123_456.0).abs() / 123_456.0 < 1.0 / 32.0, "got {got}");
    }

    #[test]
    fn histogram_percentile_walk_matches_sorted_rank() {
        let mut h = LogHistogram::default();
        for i in 1..=1000u64 {
            h.record(i);
        }
        for p in [0.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            let want = (p / 100.0 * 999.0).round() + 1.0;
            let got = h.percentile(p);
            assert!((got - want).abs() / want.max(1.0) < 0.05, "p{p}: got {got}, want ~{want}");
        }
    }

    /// Property: a single-valued histogram round-trips through
    /// `percentile` within the advertised ≈1.6% relative error at every
    /// octave — including the first log octave, where `step = 1` buckets
    /// hold exactly one integer and the midpoint must be that value (the
    /// old integer `step / 2` midpoint truncated to the lower edge).
    #[test]
    fn single_value_round_trips_across_octaves() {
        let mut cases: Vec<u64> = (0..64).collect(); // exact range + first octave edge
        for octave in 5..62 {
            let lo = 1u64 << octave;
            // sweep the octave: both edges, sub-bucket boundaries, and
            // a deterministic scatter of interior values
            for k in 0..SUB_BUCKETS as u64 {
                cases.push(lo + k * (lo / SUB_BUCKETS as u64).max(1));
            }
            cases.push(lo);
            cases.push(2 * lo - 1);
            cases.push(lo + (octave as u64 * 2654435761) % lo);
        }
        for v in cases {
            let mut h = LogHistogram::default();
            h.record(v);
            for p in [0.0, 50.0, 100.0] {
                let got = h.percentile(p);
                let err = (got - v as f64).abs();
                assert!(
                    err <= (v as f64 / 64.0).max(0.0),
                    "value {v}: percentile({p}) = {got}, relative error {}",
                    err / (v as f64).max(1.0)
                );
            }
        }
    }

    /// The first log octave is exact: one integer per bucket, and the
    /// midpoint is that integer, not the (identical) lower edge by luck
    /// of truncation.
    #[test]
    fn first_octave_midpoints_are_exact() {
        for v in LINEAR_CUTOVER..2 * LINEAR_CUTOVER {
            let mut h = LogHistogram::default();
            h.record(v);
            assert_eq!(h.percentile(50.0), v as f64, "octave-5 bucket for {v} lost precision");
        }
    }

    #[test]
    fn histogram_merge_accumulates() {
        let (mut a, mut b) = (LogHistogram::default(), LogHistogram::default());
        a.record(100);
        b.record(300);
        b.record(500);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 500);
    }

    /// The per-lane executor aggregation path: record locally, fold into
    /// a shared sink with `merge_from`, clear and reuse the scratch.
    #[test]
    fn merge_from_folds_classes_and_clear_reuses_the_sink() {
        let ms = Duration::from_millis;
        let mut global = Metrics::default();
        global.record_class("gold", ms(5), Duration::ZERO, 2, false, false);

        let mut scratch = Metrics::default();
        scratch.record_class("gold", ms(7), ms(1), 2, false, true);
        scratch.record_class("economy", ms(40), ms(9), 4, true, false);
        global.merge_from(&scratch);
        scratch.clear();
        assert_eq!(scratch.total_requests, 0);
        assert_eq!(scratch.latencies_us.count(), 0);

        // a second batch through the cleared scratch
        scratch.record_class("economy", ms(50), ms(10), 4, false, false);
        global.merge_from(&scratch);

        assert_eq!(global.total_requests, 4);
        let gold = global.class("gold").unwrap();
        assert_eq!((gold.requests, gold.deadline_misses), (2, 1));
        let eco = global.class("economy").unwrap();
        assert_eq!((eco.requests, eco.downgrades), (2, 1));
        // cleared class entries (economy had no gold traffic in batch 2)
        // must not seed zero-count classes in the global view
        assert_eq!(global.classes().len(), 2);
        assert!(eco.latency_p(50.0) >= 40.0 * (1.0 - 1.0 / 32.0));
        assert_eq!(global.mean_batch_size(), (2 + 2 + 4 + 4) as f64 / 4.0);
    }

    /// Tenant accounting: recorded at admission, merged across scratch
    /// sinks by label, cleared with everything else.
    #[test]
    fn tenant_accounting_records_merges_and_clears() {
        let mut m = Metrics::default();
        m.record_tenant("abuser", false, false);
        m.record_tenant("abuser", true, false);
        m.record_tenant("abuser", false, true);
        m.record_tenant("vip", false, false);
        let a = m.tenant("abuser").unwrap();
        assert_eq!((a.requests, a.quota_downgrades, a.rejected), (3, 1, 1));
        assert!((a.over_quota_rate() - 2.0 / 3.0).abs() < 1e-12);
        let v = m.tenant("vip").unwrap();
        assert_eq!((v.requests, v.quota_downgrades, v.rejected), (1, 0, 0));
        assert_eq!(v.over_quota_rate(), 0.0);
        assert!(m.tenant("ghost").is_none());

        let mut global = Metrics::default();
        global.record_tenant("vip", false, false);
        global.merge_from(&m);
        assert_eq!(global.tenant("vip").unwrap().requests, 2);
        assert_eq!(global.tenant("abuser").unwrap().requests, 3);
        assert_eq!(global.tenants().len(), 2);

        m.clear();
        assert_eq!(m.tenant("abuser").unwrap().requests, 0);
        // cleared zero-count tenants must not seed entries on merge
        global.merge_from(&m);
        assert_eq!(global.tenant("abuser").unwrap().requests, 3);
    }

    /// Resilience accounting: timeout/failure-only class entries (a class
    /// whose every request was reaped or error-replied) must still merge
    /// into the global sink, and restart/retire counters accumulate.
    #[test]
    fn failure_only_classes_survive_the_merge() {
        let mut scratch = Metrics::default();
        scratch.record_timeout("economy");
        scratch.record_timeout("economy");
        scratch.record_failure("standard");
        scratch.record_restart();
        scratch.record_retired();
        scratch.record_scrub(2);
        scratch.record_scrub(0);
        scratch.record_frame_crc_error();
        scratch.record_bad_input();
        scratch.record_corrupt_output();
        assert_eq!(scratch.total_requests, 0);

        let mut global = Metrics::default();
        global.merge_from(&scratch);
        let eco = global.class("economy").unwrap();
        assert_eq!((eco.requests, eco.timeouts, eco.failures), (0, 2, 0));
        let std_c = global.class("standard").unwrap();
        assert_eq!((std_c.requests, std_c.timeouts, std_c.failures), (0, 0, 1));
        assert_eq!((global.lane_restarts, global.lanes_retired), (1, 1));
        assert_eq!((global.scrub_passes, global.scrub_repairs), (2, 2));
        assert_eq!(
            (global.frame_crc_errors, global.bad_inputs, global.corrupt_outputs),
            (1, 1, 1)
        );

        scratch.clear();
        assert_eq!(scratch.lane_restarts, 0);
        assert_eq!(scratch.scrub_passes, 0);
        assert_eq!(scratch.corrupt_outputs, 0);
        // cleared zero-count entries must not seed duplicates
        global.merge_from(&scratch);
        assert_eq!(global.classes().len(), 2);
        assert_eq!(global.class("economy").unwrap().timeouts, 2);
    }

    /// Stage rows group span records by (lane, stage), skip instant
    /// events, and come back lane-major in pipeline-stage order.
    #[test]
    fn stage_rows_group_and_order_span_records() {
        let span = |lane: &'static str, name: &'static str, dur_us: u64| crate::obs::SpanRecord {
            ring: 0,
            seq: 0,
            start_us: 0,
            dur_us,
            instant: false,
            name,
            lane,
            layer: None,
            wbits: 0,
            ibits: 0,
        };
        let mut records = vec![
            span("economy", "gemm", 300),
            span("gold", "forward", 120),
            span("gold", "queue", 40),
            span("gold", "queue", 60),
            span("-", "gemm", 10),
        ];
        records.push(crate::obs::SpanRecord { instant: true, ..span("gold", "swap", 0) });
        let rows = stage_rows(&records);
        let keys: Vec<(&str, &str)> = rows.iter().map(|r| (r.lane.as_str(), r.stage)).collect();
        assert_eq!(
            keys,
            vec![("gold", "queue"), ("gold", "forward"), ("economy", "gemm"), ("-", "gemm")],
            "lane-major, pipeline-stage-ordered, instants skipped"
        );
        let queue = &rows[0].hist;
        assert_eq!(queue.count(), 2);
        assert_eq!(queue.max(), 60);
        assert!(queue.percentile(99.0) >= 59.0);
    }

    #[test]
    fn per_class_breakdowns() {
        let mut m = Metrics::default();
        let ms = Duration::from_millis;
        m.record_class("gold", ms(5), Duration::ZERO, 2, false, false);
        m.record_class("economy", ms(50), ms(10), 4, true, true);
        m.record_class("economy", ms(60), ms(12), 4, false, false);
        assert_eq!(m.total_requests, 3);
        assert_eq!(m.classes().len(), 2);
        let gold = m.class("gold").unwrap();
        assert_eq!(gold.requests, 1);
        assert_eq!(gold.downgrades, 0);
        let eco = m.class("economy").unwrap();
        assert_eq!(eco.requests, 2);
        assert_eq!(eco.downgrades, 1);
        assert_eq!(eco.deadline_misses, 1);
        assert!((eco.downgrade_rate() - 0.5).abs() < 1e-12);
        assert!(eco.latency_p(99.0) > gold.latency_p(99.0));
        assert!(eco.queue_wait_p(50.0) > 0.0);
    }
}
