//! Serving metrics: latency distribution and throughput.

use std::time::Duration;

/// Accumulated serving metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    queue_waits_us: Vec<u64>,
    batch_sizes: Vec<usize>,
    pub total_requests: usize,
    pub wall_time: Duration,
}

impl Metrics {
    pub fn record(&mut self, latency: Duration, queue_wait: Duration, batch_size: usize) {
        self.latencies_us.push(latency.as_micros() as u64);
        self.queue_waits_us.push(queue_wait.as_micros() as u64);
        self.batch_sizes.push(batch_size);
        self.total_requests += 1;
    }

    /// Latency percentile in milliseconds (`p` in [0, 100]).
    pub fn latency_p(&self, p: f64) -> f64 {
        percentile(&self.latencies_us, p) / 1000.0
    }

    /// Mean queue wait in ms.
    pub fn mean_queue_wait_ms(&self) -> f64 {
        if self.queue_waits_us.is_empty() {
            return 0.0;
        }
        self.queue_waits_us.iter().sum::<u64>() as f64 / self.queue_waits_us.len() as f64 / 1000.0
    }

    /// Mean batch size actually served.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    /// Requests per second over the recorded wall time.
    pub fn throughput(&self) -> f64 {
        let s = self.wall_time.as_secs_f64();
        if s <= 0.0 {
            return 0.0;
        }
        self.total_requests as f64 / s
    }

    /// One-line summary for logs and EXPERIMENTS.md.
    pub fn summary(&self) -> String {
        format!(
            "{} reqs, {:.1} req/s, p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms, mean batch {:.2}, mean queue wait {:.2} ms",
            self.total_requests,
            self.throughput(),
            self.latency_p(50.0),
            self.latency_p(95.0),
            self.latency_p(99.0),
            self.mean_batch_size(),
            self.mean_queue_wait_ms(),
        )
    }
}

fn percentile(xs: &[u64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_unstable();
    let rank = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)] as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::default();
        for i in 1..=100u64 {
            m.record(Duration::from_micros(i * 1000), Duration::ZERO, 4);
        }
        assert!((m.latency_p(50.0) - 50.0).abs() <= 1.0);
        assert!((m.latency_p(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(m.mean_batch_size(), 4.0);
    }

    #[test]
    fn throughput_from_wall_time() {
        let mut m = Metrics::default();
        for _ in 0..10 {
            m.record(Duration::from_millis(1), Duration::ZERO, 1);
        }
        m.wall_time = Duration::from_secs(2);
        assert_eq!(m.throughput(), 5.0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert_eq!(m.latency_p(50.0), 0.0);
        assert_eq!(m.throughput(), 0.0);
        assert!(!m.summary().is_empty());
    }
}
