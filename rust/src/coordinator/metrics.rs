//! Serving metrics: latency distribution and throughput.
//!
//! Latency and queue-wait distributions live in fixed-size log-linear
//! histograms ([`LogHistogram`]): a long-running server records millions
//! of requests into a few KB of counters, and percentile queries walk the
//! buckets instead of cloning + sorting a sample vector. Per-class
//! breakdowns ([`ClassMetrics`]) feed the QoS report — each serving class
//! gets its own distributions plus downgrade / deadline-miss counters.

use std::time::Duration;

/// Exact buckets below this value (µs); log-linear above.
const LINEAR_CUTOVER: u64 = 32;
/// Sub-buckets per octave above the cutover: 32 ⇒ the bucket midpoint is
/// within 1/64 (≈1.6%) of any recorded value.
const SUB_BUCKETS: usize = 32;
/// Octaves 5..=63 cover the full `u64` range above the cutover.
const BUCKETS: usize = LINEAR_CUTOVER as usize + (64 - 5) * SUB_BUCKETS;

/// Fixed-size log-linear histogram over `u64` samples (HdrHistogram-style):
/// exact below [`LINEAR_CUTOVER`], then [`SUB_BUCKETS`] linear sub-buckets
/// per power of two. Memory is constant regardless of how many samples are
/// recorded, and percentiles are read by a single cumulative walk with a
/// bounded ≈1.6% relative error.
#[derive(Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self { counts: vec![0; BUCKETS], total: 0, sum: 0, max: 0 }
    }
}

impl std::fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LogHistogram")
            .field("total", &self.total)
            .field("sum", &self.sum)
            .field("max", &self.max)
            .finish()
    }
}

fn bucket_index(v: u64) -> usize {
    if v < LINEAR_CUTOVER {
        return v as usize;
    }
    let octave = 63 - v.leading_zeros() as usize; // >= 5
    let sub = ((v >> (octave - 5)) & (SUB_BUCKETS as u64 - 1)) as usize;
    LINEAR_CUTOVER as usize + (octave - 5) * SUB_BUCKETS + sub
}

/// Midpoint of the bucket's value range — what percentile queries return.
fn bucket_value(idx: usize) -> u64 {
    if idx < LINEAR_CUTOVER as usize {
        return idx as u64;
    }
    let rel = idx - LINEAR_CUTOVER as usize;
    let octave = 5 + rel / SUB_BUCKETS;
    let sub = (rel % SUB_BUCKETS) as u64;
    let step = 1u64 << (octave - 5);
    (LINEAR_CUTOVER + sub) * step + step / 2
}

impl LogHistogram {
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.total += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Percentile (`p` in [0, 100]) by cumulative bucket walk; returns the
    /// midpoint of the bucket holding the ranked sample.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = (p.clamp(0.0, 100.0) / 100.0 * (self.total - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return bucket_value(idx) as f64;
            }
        }
        self.max as f64
    }

    /// Merge another histogram into this one (bucket-wise sum).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }
}

/// Per-QoS-class serving metrics: the same distributions as the global
/// [`Metrics`] plus the counters the QoS report needs.
#[derive(Debug, Clone)]
pub struct ClassMetrics {
    pub label: String,
    latencies_us: LogHistogram,
    queue_waits_us: LogHistogram,
    pub requests: u64,
    /// Requests served by a cheaper lane than their class asked for.
    pub downgrades: u64,
    /// Requests answered after their deadline had passed.
    pub deadline_misses: u64,
}

impl ClassMetrics {
    fn new(label: &str) -> Self {
        Self {
            label: label.to_string(),
            latencies_us: LogHistogram::default(),
            queue_waits_us: LogHistogram::default(),
            requests: 0,
            downgrades: 0,
            deadline_misses: 0,
        }
    }

    /// Latency percentile in milliseconds.
    pub fn latency_p(&self, p: f64) -> f64 {
        self.latencies_us.percentile(p) / 1000.0
    }

    /// Queue-wait percentile in milliseconds.
    pub fn queue_wait_p(&self, p: f64) -> f64 {
        self.queue_waits_us.percentile(p) / 1000.0
    }

    /// Fraction of this class's requests served on a cheaper lane.
    pub fn downgrade_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.downgrades as f64 / self.requests as f64
    }
}

/// Accumulated serving metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    latencies_us: LogHistogram,
    queue_waits_us: LogHistogram,
    batch_size_sum: u64,
    batch_obs: u64,
    pub total_requests: usize,
    pub wall_time: Duration,
    /// Per-class breakdowns in first-seen order (empty for classless
    /// serving through the plain [`super::InferenceServer`]).
    classes: Vec<ClassMetrics>,
}

impl Metrics {
    pub fn record(&mut self, latency: Duration, queue_wait: Duration, batch_size: usize) {
        self.latencies_us.record(latency.as_micros() as u64);
        self.queue_waits_us.record(queue_wait.as_micros() as u64);
        self.batch_size_sum += batch_size as u64;
        self.batch_obs += 1;
        self.total_requests += 1;
    }

    /// [`Metrics::record`] with a per-class breakdown: also counts the
    /// request under `class`, plus its downgrade / deadline-miss flags.
    pub fn record_class(
        &mut self,
        class: &str,
        latency: Duration,
        queue_wait: Duration,
        batch_size: usize,
        downgraded: bool,
        deadline_missed: bool,
    ) {
        self.record(latency, queue_wait, batch_size);
        let idx = match self.classes.iter().position(|c| c.label == class) {
            Some(i) => i,
            None => {
                self.classes.push(ClassMetrics::new(class));
                self.classes.len() - 1
            }
        };
        let cm = &mut self.classes[idx];
        cm.latencies_us.record(latency.as_micros() as u64);
        cm.queue_waits_us.record(queue_wait.as_micros() as u64);
        cm.requests += 1;
        if downgraded {
            cm.downgrades += 1;
        }
        if deadline_missed {
            cm.deadline_misses += 1;
        }
    }

    /// Latency percentile in milliseconds (`p` in [0, 100]).
    pub fn latency_p(&self, p: f64) -> f64 {
        self.latencies_us.percentile(p) / 1000.0
    }

    /// Mean queue wait in ms.
    pub fn mean_queue_wait_ms(&self) -> f64 {
        self.queue_waits_us.mean() / 1000.0
    }

    /// Mean batch size actually served.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_obs == 0 {
            return 0.0;
        }
        self.batch_size_sum as f64 / self.batch_obs as f64
    }

    /// Requests per second over the recorded wall time.
    pub fn throughput(&self) -> f64 {
        let s = self.wall_time.as_secs_f64();
        if s <= 0.0 {
            return 0.0;
        }
        self.total_requests as f64 / s
    }

    /// Per-class breakdowns (first-seen order).
    pub fn classes(&self) -> &[ClassMetrics] {
        &self.classes
    }

    /// The breakdown for one class label, if any requests carried it.
    pub fn class(&self, label: &str) -> Option<&ClassMetrics> {
        self.classes.iter().find(|c| c.label == label)
    }

    /// One-line summary for logs and EXPERIMENTS.md.
    pub fn summary(&self) -> String {
        format!(
            "{} reqs, {:.1} req/s, p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms, mean batch {:.2}, mean queue wait {:.2} ms",
            self.total_requests,
            self.throughput(),
            self.latency_p(50.0),
            self.latency_p(95.0),
            self.latency_p(99.0),
            self.mean_batch_size(),
            self.mean_queue_wait_ms(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::default();
        for i in 1..=100u64 {
            m.record(Duration::from_micros(i * 1000), Duration::ZERO, 4);
        }
        // log-linear buckets: midpoint within 1/64 of the true value
        assert!((m.latency_p(50.0) - 50.0).abs() <= 1.5, "p50 {}", m.latency_p(50.0));
        assert!((m.latency_p(99.0) - 99.0).abs() <= 2.0, "p99 {}", m.latency_p(99.0));
        assert_eq!(m.mean_batch_size(), 4.0);
    }

    #[test]
    fn throughput_from_wall_time() {
        let mut m = Metrics::default();
        for _ in 0..10 {
            m.record(Duration::from_millis(1), Duration::ZERO, 1);
        }
        m.wall_time = Duration::from_secs(2);
        assert_eq!(m.throughput(), 5.0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert_eq!(m.latency_p(50.0), 0.0);
        assert_eq!(m.throughput(), 0.0);
        assert!(!m.summary().is_empty());
        assert!(m.classes().is_empty());
        assert!(m.class("gold").is_none());
    }

    #[test]
    fn histogram_is_fixed_size_and_accurate() {
        let mut h = LogHistogram::default();
        for v in [0u64, 1, 31, 32, 33, 1000, 50_000, 1_000_000, u64::MAX / 2] {
            h.record(v);
        }
        assert_eq!(h.count(), 9);
        assert_eq!(h.max(), u64::MAX / 2);
        // exact below the cutover
        let mut exact = LogHistogram::default();
        exact.record(17);
        assert_eq!(exact.percentile(50.0), 17.0);
        // bounded relative error above it
        let mut big = LogHistogram::default();
        big.record(123_456);
        let got = big.percentile(50.0);
        assert!((got - 123_456.0).abs() / 123_456.0 < 1.0 / 32.0, "got {got}");
    }

    #[test]
    fn histogram_percentile_walk_matches_sorted_rank() {
        let mut h = LogHistogram::default();
        for i in 1..=1000u64 {
            h.record(i);
        }
        for p in [0.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            let want = (p / 100.0 * 999.0).round() + 1.0;
            let got = h.percentile(p);
            assert!((got - want).abs() / want.max(1.0) < 0.05, "p{p}: got {got}, want ~{want}");
        }
    }

    #[test]
    fn histogram_merge_accumulates() {
        let (mut a, mut b) = (LogHistogram::default(), LogHistogram::default());
        a.record(100);
        b.record(300);
        b.record(500);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 500);
    }

    #[test]
    fn per_class_breakdowns() {
        let mut m = Metrics::default();
        let ms = Duration::from_millis;
        m.record_class("gold", ms(5), Duration::ZERO, 2, false, false);
        m.record_class("economy", ms(50), ms(10), 4, true, true);
        m.record_class("economy", ms(60), ms(12), 4, false, false);
        assert_eq!(m.total_requests, 3);
        assert_eq!(m.classes().len(), 2);
        let gold = m.class("gold").unwrap();
        assert_eq!(gold.requests, 1);
        assert_eq!(gold.downgrades, 0);
        let eco = m.class("economy").unwrap();
        assert_eq!(eco.requests, 2);
        assert_eq!(eco.downgrades, 1);
        assert_eq!(eco.deadline_misses, 1);
        assert!((eco.downgrade_rate() - 0.5).abs() < 1e-12);
        assert!(eco.latency_p(99.0) > gold.latency_p(99.0));
        assert!(eco.queue_wait_p(50.0) > 0.0);
    }
}
