//! The inference server: a worker thread pulls dynamic batches off the
//! queue and executes them on a pluggable backend (pure-Rust engine or a
//! PJRT-compiled artifact).

use super::batcher::{next_batch, split_batch, BatchPolicy, Request, Response};
use super::metrics::Metrics;
use crate::obs::Clock;
use crate::tensor::Tensor;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A pluggable batch-inference backend.
///
/// Backends need not be `Send` (PJRT handles are thread-pinned); use
/// [`InferenceServer::start_with`] to construct the backend *on* the
/// worker thread.
pub trait Backend: 'static {
    /// Run a batch of `[C,H,W]` images, returning per-image logits.
    /// Images arrive by value — they move straight out of the request
    /// queue, so serving never copies an input tensor.
    fn infer_batch(&mut self, images: Vec<Tensor>) -> Vec<Tensor>;
    /// Human-readable backend description (for logs).
    fn describe(&self) -> String;
}

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { policy: BatchPolicy::default() }
    }
}

/// Handle to a running inference server.
pub struct InferenceServer {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
    next_id: u64,
    started: Instant,
}

impl InferenceServer {
    /// Spawn the worker thread over a `Send` backend.
    pub fn start(backend: Box<dyn Backend + Send>, config: ServerConfig) -> Self {
        Self::start_with(move || backend as Box<dyn Backend>, config)
    }

    /// Spawn the worker thread, constructing the backend on it — required
    /// for thread-pinned backends such as PJRT executables.
    pub fn start_with<F>(factory: F, config: ServerConfig) -> Self
    where
        F: FnOnce() -> Box<dyn Backend> + Send + 'static,
    {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let metrics_worker = Arc::clone(&metrics);
        let worker = std::thread::spawn(move || {
            let mut backend = factory();
            while let Some(batch) = next_batch(&rx, config.policy) {
                let t0 = Clock::now();
                // images move out of the requests — no per-request copy
                let (images, responders) = split_batch(batch);
                let logits = backend.infer_batch(images);
                let batch_size = responders.len();
                // one completion instant per batch: later responses must
                // not absorb metrics-lock/send time into their latency
                let completed = Clock::now();
                for (resp, out) in responders.into_iter().zip(logits) {
                    let queue_wait = t0.duration_since(resp.enqueued_at);
                    let latency = completed.duration_since(resp.enqueued_at);
                    metrics_worker.lock().unwrap().record(latency, queue_wait, batch_size);
                    let _ = resp.respond.send(Response {
                        id: resp.id,
                        logits: out,
                        queue_wait,
                        batch_size,
                    });
                }
            }
        });
        Self { tx: Some(tx), worker: Some(worker), metrics, next_id: 0, started: Clock::now() }
    }

    /// Submit one image; returns the receiver for its response.
    pub fn submit(&mut self, image: Tensor) -> Receiver<Response> {
        let (tx, rx) = channel();
        self.next_id += 1;
        self.tx
            .as_ref()
            // LINT-ALLOW: serving-unwrap — `tx` is Some until shutdown
            // consumes `self`; no call can follow it.
            .expect("server stopped")
            .send(Request { id: self.next_id, image, respond: tx, enqueued_at: Clock::now() })
            // LINT-ALLOW: serving-unwrap — the worker outlives `tx` by
            // construction; a dead worker here is a crashed process.
            .expect("worker gone");
        rx
    }

    /// Submit and wait (convenience for tests / simple clients).
    pub fn infer(&mut self, image: Tensor) -> Response {
        // LINT-ALLOW: serving-unwrap — single-process convenience path;
        // the worker answers every request it dequeues.
        self.submit(image).recv().expect("worker dropped response")
    }

    /// Stop the worker and return the final metrics.
    pub fn shutdown(mut self) -> Metrics {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        let mut m = self.metrics.lock().unwrap().clone();
        m.wall_time = self.started.elapsed();
        m
    }
}

/// Pure-Rust backend over a model from the zoo. Quantizes conv weights
/// on every call — [`PreparedBackend`] is the steady-state configuration.
pub struct RustBackend {
    pub model: crate::models::Model,
    pub mode: super::engine::ExecMode,
}

impl Backend for RustBackend {
    fn infer_batch(&mut self, images: Vec<Tensor>) -> Vec<Tensor> {
        super::engine::forward_batch(&self.model, images, self.mode.clone())
    }
    fn describe(&self) -> String {
        format!("rust/{}/{}", self.model.name, self.mode.describe())
    }
}

/// Prepared-model backend: weight quantization cached per
/// `(layer, config)`, scratch arenas reused across requests, batches
/// parallelized over images — bit-identical to [`RustBackend`] in a BFP
/// or mixed mode, minus the per-request preprocessing.
pub struct PreparedBackend {
    pub prepared: crate::nn::prepared::PreparedModel,
    desc: String,
}

impl PreparedBackend {
    /// Prepare `model` for `mode`. Returns `None` for [`ExecMode::Fp32`]
    /// — there are no quantized weights to cache; serve it through
    /// [`RustBackend`] instead.
    pub fn new(model: crate::models::Model, mode: &super::engine::ExecMode) -> Option<Self> {
        use super::engine::ExecMode;
        let schedule = match mode {
            ExecMode::Fp32 => return None,
            ExecMode::Bfp(cfg) => crate::quant::LayerSchedule::uniform(*cfg),
            ExecMode::Mixed(s) => s.clone(),
        };
        let desc = format!("rust-prepared/{}/{}", model.name, mode.describe());
        let prepared = crate::nn::prepared::PreparedModel::new(model, schedule);
        prepared.warm();
        Some(Self { prepared, desc })
    }
}

impl Backend for PreparedBackend {
    fn infer_batch(&mut self, images: Vec<Tensor>) -> Vec<Tensor> {
        self.prepared.forward_batch(images)
    }
    fn describe(&self) -> String {
        self.desc.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::ExecMode;
    use crate::models::ModelId;
    use crate::quant::BfpConfig;
    use std::path::Path;

    #[test]
    fn serves_lenet_requests() {
        let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
        let backend = RustBackend { model, mode: ExecMode::Bfp(BfpConfig::paper_default()) };
        let mut server = InferenceServer::start(Box::new(backend), ServerConfig::default());
        let images = crate::data::DigitDataset::generate(6, 4).images;
        let mut pending = Vec::new();
        for img in images {
            pending.push(server.submit(img));
        }
        for rx in pending {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.logits.shape, vec![10]);
            assert!(resp.batch_size >= 1);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.total_requests, 6);
        assert!(metrics.throughput() > 0.0);
    }

    /// The prepared backend must serve logits bit-identical to the
    /// unprepared engine path for the same requests.
    #[test]
    fn prepared_backend_matches_unprepared() {
        let mode = ExecMode::Bfp(BfpConfig::paper_default());
        let images = crate::data::DigitDataset::generate(4, 21).images;
        let collect = |backend: Box<dyn Backend + Send>| -> Vec<crate::tensor::Tensor> {
            let mut server = InferenceServer::start(backend, ServerConfig::default());
            let pending: Vec<_> = images.iter().map(|i| server.submit(i.clone())).collect();
            let out = pending.into_iter().map(|rx| rx.recv().unwrap().logits).collect();
            server.shutdown();
            out
        };
        let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
        let plain = collect(Box::new(RustBackend { model: model.clone(), mode: mode.clone() }));
        let prepared = collect(Box::new(PreparedBackend::new(model, &mode).unwrap()));
        for (a, b) in plain.iter().zip(&prepared) {
            assert_eq!(a.shape, b.shape);
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn prepared_backend_refuses_fp32() {
        let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
        assert!(PreparedBackend::new(model, &ExecMode::Fp32).is_none());
    }

    #[test]
    fn batches_form_under_load() {
        let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
        let backend = RustBackend { model, mode: ExecMode::Fp32 };
        let cfg = ServerConfig {
            policy: crate::coordinator::batcher::BatchPolicy {
                max_batch: 4,
                linger: std::time::Duration::from_millis(20),
            },
        };
        let mut server = InferenceServer::start(Box::new(backend), cfg);
        let images = crate::data::DigitDataset::generate(8, 5).images;
        let pending: Vec<_> = images.into_iter().map(|i| server.submit(i)).collect();
        let sizes: Vec<usize> = pending.into_iter().map(|rx| rx.recv().unwrap().batch_size).collect();
        let metrics = server.shutdown();
        assert_eq!(metrics.total_requests, 8);
        // at least one response should have been served in a batch > 1
        assert!(sizes.iter().any(|&s| s > 1), "no batching observed: {sizes:?}");
    }
}
