//! The L3 serving layer: a batched inference coordinator.
//!
//! The paper's contribution is the numeric format, so the coordinator is
//! a thin-but-real driver (DESIGN.md §2): a request queue, a dynamic
//! batcher, worker execution over either the pure-Rust engine or the
//! AOT-compiled PJRT artifacts, and latency/throughput metrics. On top
//! of the single-plan server sits the QoS precision router ([`qos`]):
//! multi-lane serving with per-class precision plans, deadline-aware
//! scheduling, admission/shed downgrades and online NSR telemetry.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod qos;
pub mod server;

pub use engine::{forward_batch, forward_batch_ref, ExecMode};
pub use metrics::{stage_rows, ClassMetrics, LogHistogram, Metrics, StageRow, TenantMetrics};
pub use qos::{
    LaneHealth, LaneReport, LaneSet, LaneSpec, LaneStep, LaneStats, QosClass, QosConfig, QosError,
    QosErrorKind, QosReport, QosResponse, QosResult, QosServer, ShedPolicy, WorkerMode,
};
pub use server::{InferenceServer, PreparedBackend, RustBackend, ServerConfig};
