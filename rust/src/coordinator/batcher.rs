//! Dynamic batcher: groups queued requests into batches bounded by a
//! maximum size and a linger deadline — the standard accelerator-serving
//! pattern (a hardware BFP engine amortises block formatting and weight
//! reuse across the batch).

use crate::obs::Clock;
use crate::tensor::Tensor;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// One inference request: an image plus the channel to answer on.
pub struct Request {
    pub id: u64,
    pub image: Tensor,
    pub respond: std::sync::mpsc::Sender<Response>,
    pub enqueued_at: Instant,
}

/// The answer: logits plus timing metadata.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Tensor,
    pub queue_wait: Duration,
    pub batch_size: usize,
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// Maximum time the first request may wait for the batch to fill.
    pub linger: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 8, linger: Duration::from_millis(5) }
    }
}

/// The response half of a [`Request`] after its image has moved on to
/// the backend — serving never copies input tensors (§Perf).
pub struct Responder {
    pub id: u64,
    pub respond: std::sync::mpsc::Sender<Response>,
    pub enqueued_at: Instant,
}

/// Split a batch into backend inputs (by value) and response handles.
pub fn split_batch(batch: Vec<Request>) -> (Vec<Tensor>, Vec<Responder>) {
    let mut images = Vec::with_capacity(batch.len());
    let mut responders = Vec::with_capacity(batch.len());
    for Request { id, image, respond, enqueued_at } in batch {
        images.push(image);
        responders.push(Responder { id, respond, enqueued_at });
    }
    (images, responders)
}

/// Pull the next batch from the queue: blocks for the first request, then
/// lingers (or until `max_batch`) for more. The linger deadline anchors
/// at the **first request's `enqueued_at`**, not at batch start: a
/// request that already sat in the channel while the worker executed the
/// previous batch has spent its linger budget, so the batch closes as
/// soon as the backlog is drained instead of making it wait up to twice
/// the configured linger. Returns `None` when the queue has disconnected
/// and drained.
pub fn next_batch(rx: &Receiver<Request>, policy: BatchPolicy) -> Option<Vec<Request>> {
    let first = rx.recv().ok()?;
    let deadline = first.enqueued_at + policy.linger;
    let mut batch = vec![first];
    while batch.len() < policy.max_batch {
        let now = Clock::now();
        if now >= deadline {
            // linger budget spent: take only what is already queued
            match rx.try_recv() {
                Ok(req) => batch.push(req),
                Err(_) => break,
            }
        } else {
            match rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64) -> (Request, Receiver<Response>) {
        let (tx, rx) = channel();
        (
            Request { id, image: Tensor::zeros(&[1, 2, 2]), respond: tx, enqueued_at: Instant::now() },
            rx,
        )
    }

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = channel();
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, resp) = req(i);
            keep.push(resp);
            tx.send(r).unwrap();
        }
        let policy = BatchPolicy { max_batch: 3, linger: Duration::from_millis(50) };
        let batch = next_batch(&rx, policy).unwrap();
        assert_eq!(batch.len(), 3);
        let batch = next_batch(&rx, policy).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn returns_none_when_disconnected() {
        let (tx, rx) = channel::<Request>();
        drop(tx);
        assert!(next_batch(&rx, BatchPolicy::default()).is_none());
    }

    #[test]
    fn split_batch_pairs_images_with_responders() {
        let (r1, _k1) = req(1);
        let (r2, _k2) = req(2);
        let (images, responders) = split_batch(vec![r1, r2]);
        assert_eq!(images.len(), 2);
        assert_eq!(responders.len(), 2);
        assert_eq!(responders[0].id, 1);
        assert_eq!(responders[1].id, 2);
    }

    /// Regression: the linger deadline anchors at the first request's
    /// `enqueued_at`. A request that already waited in the channel longer
    /// than the linger must not wait again — the old batch-start anchor
    /// made it wait up to ~2× the configured linger.
    #[test]
    fn linger_anchors_at_enqueue_time() {
        let (tx, rx) = channel();
        let linger = Duration::from_millis(200);
        let stale = Request {
            id: 1,
            image: Tensor::zeros(&[1, 2, 2]),
            respond: channel().0,
            enqueued_at: Instant::now() - 2 * linger,
        };
        tx.send(stale).unwrap();
        let policy = BatchPolicy { max_batch: 100, linger };
        let t0 = Instant::now();
        let batch = next_batch(&rx, policy).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_millis(100),
            "stale request lingered again: {:?}",
            t0.elapsed()
        );
    }

    /// Even past the linger deadline, requests already sitting in the
    /// channel still join the batch (draining costs no extra latency).
    #[test]
    fn expired_linger_still_drains_backlog() {
        let (tx, rx) = channel();
        let linger = Duration::from_millis(50);
        let mut keep = Vec::new();
        for id in 0..3 {
            let (mut r, resp) = req(id);
            r.enqueued_at = Instant::now() - 2 * linger;
            keep.push(resp);
            tx.send(r).unwrap();
        }
        let batch = next_batch(&rx, BatchPolicy { max_batch: 8, linger }).unwrap();
        assert_eq!(batch.len(), 3, "queued backlog should batch together");
    }

    #[test]
    fn linger_bounds_wait() {
        let (tx, rx) = channel();
        let (r, _resp) = req(1);
        tx.send(r).unwrap();
        let policy = BatchPolicy { max_batch: 100, linger: Duration::from_millis(10) };
        let t0 = Instant::now();
        let batch = next_batch(&rx, policy).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }
}
